"""Campaign execution-layer throughput: scenarios/sec, compiles, wall.

Tracks the perf trajectory of the batched failure-campaign engine
(``BENCH_campaign.json``, written next to the working directory so CI
artifacts pick it up):

* ``oneshot``   — a fixed 64-scenario (16 sampled traces x 4 seeds)
  Tol-FL grid in one ``jit(vmap)`` call; wall-clock includes the single
  compile, ``steady`` re-runs it on the warm executable cache (the
  compile-amortisation contract: 0 new traces).
* ``chunked``   — the same grid through host-side chunking
  (``chunk_size=16``): bounded device memory, still one compile.
* ``sweep_padded`` — an all-single-model-cells ``sweep_grid``
  ((tolfl, 5) / (tolfl, 2) / (fl, 1) / (sbt, 10), 128 scenarios) with
  padded-k topology arrays but ONE DISPATCH PER CELL (``fuse=False``):
  compiles are bounded per ISO-TRACKING KIND, not per cell — exactly
  TWO for this grid (one executable shared by the three non-fl cells,
  one for the fl cell's isolated-fallback branch).
* ``sweep_fused_cold`` / ``sweep_fused`` — the SAME 128-scenario grid
  through the fused dispatcher (stacked topology operands, one
  ``jit(vmap)`` over the flattened (cell x trace x seed) axis per
  iso-tracking kind: two dispatches total).  ``_cold`` includes the two
  fused compiles; ``sweep_fused`` re-runs on the warm executable cache —
  the engine's compile-amortised operating regime (every further grid
  on these shapes costs 0 traces) and the row the ISSUE 4 win condition
  tracks against per-cell ``steady`` throughput.
* ``spec_sweep`` — the SAME 128-scenario grid declared as an
  :class:`repro.api.ExperimentSpec` and lowered through
  ``plan -> execute``: the declarative layer rides the identical warm
  executables, so CI asserts it stays within 5% of ``sweep_fused``
  scenarios/sec (the spec layer must be overhead-free).
* ``sampled_max_events`` — compile+run wall of a sampled-rate grid with
  the big default slot budget (max_events = 2N): the regression guard
  for the vectorized ``trace_alive_mask`` (the unrolled fold made this
  compile O(max_events) slower).

The traces are sampled at a fixed RNG seed, so the grid is identical
run-to-run and numbers are comparable across commits — provided the
exec-plan flags match: ``run(shard=..., chunk_size=...)`` (the
``benchmarks.run --shard / --chunk-size`` CLI) applies an
:class:`ExecPlan` to every campaign row so sharded / chunked variants
are reproducible one-liners, but only default-flag runs should be
committed as the baseline JSON.
"""
from __future__ import annotations

import json
import time
from typing import List, Optional

import numpy as np

from benchmarks.datasets import data_spec, prepare
from repro.api import (CellSpec, ExperimentSpec, SeedSpec, TraceSpec,
                       run_experiment)
from repro.core import campaign
from repro.core.campaign import ExecPlan, run_campaign, sweep_grid
from repro.core.failure import sample_rate_grid, sample_traces
from repro.core.simulate import SimConfig

GRID_TRACES = 16
GRID_SEEDS = 4
ROUNDS = 8


def _timed_campaign(label, lines, results, fn, reps: int = 1):
    """Time ``fn``; steady-state rows pass ``reps > 1`` and report the
    BEST wall — the `timeit` convention: external noise (this
    container's cpu budget wobbles for seconds at a time) only ever
    slows a run down, so the minimum is the best estimate of the true
    throughput.  Cold rows stay single-shot because a compile only
    happens once per process.  ``compiles`` counts the whole rep loop —
    0 stays 0."""
    c0 = campaign.TRACE_COUNT
    walls = []
    for _ in range(reps):
        t0 = time.time()
        res = fn()
        walls.append(time.time() - t0)
    wall = min(walls)
    compiles = campaign.TRACE_COUNT - c0
    n = sum(r.num_scenarios for r in
            (res.values() if isinstance(res, dict) else [res]))
    results[label] = {"scenarios": n, "compiles": compiles,
                      "wall_s": round(wall, 3),
                      "scenarios_per_s": round(n / max(wall, 1e-9), 2)}
    lines.append(f"{label},{n},{compiles},{wall:.2f},{n / wall:.1f}")
    return res


def run(out_path: str = "BENCH_campaign.json", shard: bool = False,
        chunk_size: Optional[int] = None) -> List[str]:
    plan = (ExecPlan(shard=shard, chunk_size=chunk_size)
            if (shard or chunk_size) else None)
    prep = prepare("commsml", seed=0, scale=0.25)
    cfg = SimConfig(scheme="tolfl", num_devices=10,
                    num_clusters=prep.clusters, rounds=ROUNDS,
                    lr=prep.lr, local_epochs=1, dropout=False)
    topo = cfg.topology()
    traces = sample_traces(np.random.default_rng(0), topo, 0.3,
                           max_events=8, rounds=ROUNDS,
                           num_traces=GRID_TRACES)
    seeds = range(GRID_SEEDS)
    args = (prep.ae_cfg, prep.device_x, prep.counts, prep.test_x,
            prep.test_y)

    lines = ["name,scenarios,compiles,wall_s,scenarios_per_s"]
    results: dict = {}

    _timed_campaign("oneshot", lines, results,
                    lambda: run_campaign(*args, cfg, traces, seeds,
                                         exec_plan=plan))
    _timed_campaign("steady", lines, results,
                    lambda: run_campaign(*args, cfg, traces, seeds,
                                         exec_plan=plan), reps=3)
    _timed_campaign("chunked", lines, results,
                    lambda: run_campaign(*args, cfg, traces, seeds,
                                         exec_plan=ExecPlan(
                                             shard=shard,
                                             chunk_size=chunk_size or 16)))
    base = SimConfig(num_devices=10, rounds=ROUNDS, lr=prep.lr,
                     dropout=False)
    grid = dict(scheme_ks=[("tolfl", 5), ("tolfl", 2),
                           ("fl", 1), ("sbt", 10)],
                traces=traces, seeds=[0, 1], exec_plan=plan)
    _timed_campaign("sweep_padded", lines, results,
                    lambda: sweep_grid(*args, base, fuse=False, **grid))
    _timed_campaign("sweep_fused_cold", lines, results,
                    lambda: sweep_grid(*args, base, **grid))
    _timed_campaign("sweep_fused", lines, results,
                    lambda: sweep_grid(*args, base, **grid), reps=3)
    # the SAME 128-scenario grid declared as an ExperimentSpec and run
    # through plan -> execute: the declarative layer must be
    # overhead-free over the fused dispatcher it lowers to (same warm
    # executables — CI asserts spec_sweep stays within 5% of
    # sweep_fused scenarios/sec)
    sweep_spec = ExperimentSpec(
        data=data_spec(prep), base=base,
        cells=tuple(CellSpec(s, k) for s, k in grid["scheme_ks"]),
        traces=TraceSpec(traces=tuple(traces)),
        seeds=SeedSpec((0, 1)), exec_plan=plan)
    _timed_campaign("spec_sweep", lines, results,
                    lambda: run_experiment(sweep_spec), reps=3)

    # sampled-rate grid at the big slot budget (max_events = 2N): the
    # vectorized trace_alive_mask keeps this compile O(1) in max_events
    s_traces, _ = sample_rate_grid(np.random.default_rng(1), topo,
                                   p_grid=(0.1, 0.3), rounds=ROUNDS,
                                   traces_per_p=8)
    _timed_campaign("sampled_max_events", lines, results,
                    lambda: run_campaign(*args, cfg, s_traces, [0, 1],
                                         exec_plan=plan))

    assert results["steady"]["compiles"] == 0, results["steady"]
    # 4 cells, 2 compiles: non-fl cells share one executable, fl (whose
    # isolated-fallback branch is extra compute) gets its own
    assert results["sweep_padded"]["compiles"] == 2, \
        results["sweep_padded"]
    # the fused grid compiles once per iso-tracking kind and then
    # amortises: the steady re-run costs ZERO traces
    assert results["sweep_fused_cold"]["compiles"] == 2, \
        results["sweep_fused_cold"]
    assert results["sweep_fused"]["compiles"] == 0, \
        results["sweep_fused"]
    # the declarative pipeline rides the same warm executables (0
    # compiles) and must not tax throughput more than 5%
    assert results["spec_sweep"]["compiles"] == 0, results["spec_sweep"]
    assert (results["spec_sweep"]["scenarios_per_s"]
            >= 0.95 * results["sweep_fused"]["scenarios_per_s"]), \
        (results["spec_sweep"], results["sweep_fused"])
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    lines.append(f"# wrote {out_path}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
