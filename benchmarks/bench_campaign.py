"""Campaign execution-layer throughput: scenarios/sec, compiles, wall.

Tracks the perf trajectory of the batched failure-campaign engine
(``BENCH_campaign.json``, written next to the working directory so CI
artifacts pick it up):

* ``oneshot``   — a fixed 64-scenario (16 sampled traces x 4 seeds)
  Tol-FL grid in one ``jit(vmap)`` call; wall-clock includes the single
  compile, ``steady`` re-runs it on the warm executable cache (the
  compile-amortisation contract: 0 new traces).
* ``chunked``   — the same grid through host-side chunking
  (``chunk_size=16``): bounded device memory, still one compile.
* ``sweep_padded`` — an all-single-model-cells ``sweep_grid``
  ((tolfl, 5) / (tolfl, 2) / (fl, 1) / (sbt, 10), 128 scenarios) with
  padded-k topology arrays but ONE DISPATCH PER CELL (``fuse=False``):
  compiles are bounded per ISO-TRACKING KIND, not per cell — exactly
  TWO for this grid (one executable shared by the three non-fl cells,
  one for the fl cell's isolated-fallback branch).
* ``sweep_fused_cold`` / ``sweep_fused`` — the SAME 128-scenario grid
  through the fused dispatcher (stacked topology operands, one
  ``jit(vmap)`` over the flattened (cell x trace x seed) axis per
  iso-tracking kind: two dispatches total).  ``_cold`` includes the two
  fused compiles; ``sweep_fused`` re-runs on the warm executable cache —
  the engine's compile-amortised operating regime (every further grid
  on these shapes costs 0 traces) and the row the ISSUE 4 win condition
  tracks against per-cell ``steady`` throughput.
* ``spec_sweep`` — the SAME 128-scenario grid declared as an
  :class:`repro.api.ExperimentSpec` and lowered through
  ``plan -> execute``: the declarative layer rides the identical warm
  executables, so CI asserts it stays within 5% of ``sweep_fused``
  scenarios/sec (the spec layer must be overhead-free).
* ``sweep_aot_cold`` — the same grid with ``ExecPlan(aot=True)``: every
  iteration starts from EMPTY in-process caches (a fresh-process
  simulation); the bench-local persistent cache directory is cleared
  before iteration 1 only, so the first iteration is the true
  first-ever cold cost and later iterations are the "new process, warm
  disk" regime — compiled executables deserialise whole
  (``exe_hits``), zero traces, zero XLA.  Best-of-N reports that
  warm-disk regime; per-iteration compiles / XLA misses are in the
  JSON.  The ISSUE 6 win condition: >= 1.5x ``sweep_fused_cold``.
* ``sweep_diskcache_cold`` — the jit-path twin: in-process caches
  cleared per iteration, disk cleared before iteration 1.  Iterations
  after the first still pay Python tracing but XLA compiles come from
  the persistent module cache — the regime a warm-disk fresh process
  hits WITHOUT opting into AOT.
* ``sampled_max_events`` — compile+run wall of a sampled-rate grid with
  the big default slot budget (max_events = 2N): the regression guard
  for the vectorized ``trace_alive_mask`` (the unrolled fold made this
  compile O(max_events) slower).

Cold rows (``sweep_padded``, ``sweep_fused_cold``) clear the
in-process executable caches AND the bench-local disk cache before
EVERY iteration, so every rep genuinely compiles (the committed
baseline used to report best-of-reps where only rep 1 compiled).  The
whole bench runs against a throwaway persistent-cache directory and
restores the prior cache wiring on exit, so it never pollutes
``~/.cache/repro-jax``.

The traces are sampled at a fixed RNG seed, so the grid is identical
run-to-run and numbers are comparable across commits — provided the
exec-plan flags match: ``run(shard=..., chunk_size=...)`` (the
``benchmarks.run --shard / --chunk-size`` CLI) applies an
:class:`ExecPlan` to every campaign row so sharded / chunked variants
are reproducible one-liners, but only default-flag runs should be
committed as the baseline JSON.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import List, Optional

import numpy as np

from benchmarks.datasets import data_spec, prepare
from repro.api import (CellSpec, DataSpec, ExperimentSpec, SeedSpec,
                       SeqDetector, TraceSpec, run_experiment)
from repro.core import campaign, compilecache
from repro.core.campaign import ExecPlan, run_campaign, sweep_grid
from repro.core.failure import sample_rate_grid, sample_traces
from repro.core.simulate import SimConfig

GRID_TRACES = 16
GRID_SEEDS = 4
ROUNDS = 8


def _timed_campaign(label, lines, results, fn, reps: int = 1,
                    pre_iter=None):
    """Time ``fn``; multi-rep rows report the BEST wall — the `timeit`
    convention: external noise (this container's cpu budget wobbles for
    seconds at a time) only ever slows a run down, so the minimum is
    the best estimate of the true throughput.  Cold rows pass
    ``pre_iter(i)`` to clear executable caches before EVERY iteration,
    so every rep genuinely compiles; per-iteration compile counts and
    XLA cache misses land in the JSON (``compiles`` reports the count
    at the best-wall iteration — the regime the headline number
    measures)."""
    walls, compiles_iter, xla_miss_iter, exe_hits_iter = [], [], [], []
    for i in range(reps):
        if pre_iter is not None:
            pre_iter(i)
        c0 = campaign.TRACE_COUNT
        x0 = compilecache.xla_compile_stats()
        t0 = time.time()
        res = fn()
        walls.append(time.time() - t0)
        x1 = compilecache.xla_compile_stats()
        compiles_iter.append(campaign.TRACE_COUNT - c0)
        xla_miss_iter.append(x1["misses"] - x0["misses"])
        exe_hits_iter.append(x1["exe_hits"] - x0["exe_hits"])
    best = int(np.argmin(walls))
    wall = walls[best]
    compiles = compiles_iter[best]
    n = sum(r.num_scenarios for r in
            (res.values() if isinstance(res, dict) else [res]))
    results[label] = {"scenarios": n, "compiles": compiles,
                      "wall_s": round(wall, 3),
                      "scenarios_per_s": round(n / max(wall, 1e-9), 2),
                      "walls_s": [round(w, 3) for w in walls],
                      "compiles_per_iter": compiles_iter,
                      "xla_misses_per_iter": xla_miss_iter,
                      "exe_hits_per_iter": exe_hits_iter}
    lines.append(f"{label},{n},{compiles},{wall:.2f},{n / wall:.1f}")
    return res


def run(out_path: str = "BENCH_campaign.json", shard: bool = False,
        chunk_size: Optional[int] = None) -> List[str]:
    # hermetic persistent cache: the bench measures cold/warm-disk
    # regimes against its own throwaway directory and restores the
    # prior wiring on exit (never touches ~/.cache/repro-jax)
    prev_dir = compilecache.persistent_cache_dir()
    cache_dir = tempfile.mkdtemp(prefix="bench-repro-cache-")
    compilecache.enable_persistent_cache(cache_dir)

    def clear_disk():
        shutil.rmtree(cache_dir, ignore_errors=True)
        os.makedirs(cache_dir, exist_ok=True)

    def cold_iter(i):
        """Fresh-process simulation: every executable cache empty."""
        campaign.clear_executable_caches()
        clear_disk()

    def diskwarm_iter(i):
        """New-process-with-warm-disk simulation: in-process caches
        empty, the persistent directory cleared before iteration 1
        only (iteration 1 populates it; later iterations ride it)."""
        campaign.clear_executable_caches()
        if i == 0:
            clear_disk()

    try:
        return _run_rows(out_path, shard, chunk_size, cold_iter,
                         diskwarm_iter)
    finally:
        clear_disk()
        shutil.rmtree(cache_dir, ignore_errors=True)
        if prev_dir is not None:
            compilecache.enable_persistent_cache(prev_dir)
        else:
            compilecache.disable_persistent_cache()


def _run_rows(out_path, shard, chunk_size, cold_iter, diskwarm_iter
              ) -> List[str]:
    plan = (ExecPlan(shard=shard, chunk_size=chunk_size)
            if (shard or chunk_size) else None)
    prep = prepare("commsml", seed=0, scale=0.25)
    cfg = SimConfig(scheme="tolfl", num_devices=10,
                    num_clusters=prep.clusters, rounds=ROUNDS,
                    lr=prep.lr, local_epochs=1, dropout=False)
    topo = cfg.topology()
    traces = sample_traces(np.random.default_rng(0), topo, 0.3,
                           max_events=8, rounds=ROUNDS,
                           num_traces=GRID_TRACES)
    seeds = range(GRID_SEEDS)
    args = (prep.ae_cfg, prep.device_x, prep.counts, prep.test_x,
            prep.test_y)

    lines = ["name,scenarios,compiles,wall_s,scenarios_per_s"]
    results: dict = {}

    _timed_campaign("oneshot", lines, results,
                    lambda: run_campaign(*args, cfg, traces, seeds,
                                         exec_plan=plan))
    _timed_campaign("steady", lines, results,
                    lambda: run_campaign(*args, cfg, traces, seeds,
                                         exec_plan=plan), reps=3)
    _timed_campaign("chunked", lines, results,
                    lambda: run_campaign(*args, cfg, traces, seeds,
                                         exec_plan=ExecPlan(
                                             shard=shard,
                                             chunk_size=chunk_size or 16)))
    base = SimConfig(num_devices=10, rounds=ROUNDS, lr=prep.lr,
                     dropout=False)
    grid = dict(scheme_ks=[("tolfl", 5), ("tolfl", 2),
                           ("fl", 1), ("sbt", 10)],
                traces=traces, seeds=[0, 1], exec_plan=plan)
    _timed_campaign("sweep_padded", lines, results,
                    lambda: sweep_grid(*args, base, fuse=False, **grid),
                    reps=2, pre_iter=cold_iter)
    _timed_campaign("sweep_fused_cold", lines, results,
                    lambda: sweep_grid(*args, base, **grid),
                    reps=2, pre_iter=cold_iter)
    _timed_campaign("sweep_fused", lines, results,
                    lambda: sweep_grid(*args, base, **grid), reps=3)
    # the SAME 128-scenario grid declared as an ExperimentSpec and run
    # through plan -> execute: the declarative layer must be
    # overhead-free over the fused dispatcher it lowers to (same warm
    # executables — CI asserts spec_sweep stays within 5% of
    # sweep_fused scenarios/sec)
    sweep_spec = ExperimentSpec(
        data=data_spec(prep), base=base,
        cells=tuple(CellSpec(s, k) for s, k in grid["scheme_ks"]),
        traces=TraceSpec(traces=tuple(traces)),
        seeds=SeedSpec((0, 1)), exec_plan=plan)
    _timed_campaign("spec_sweep", lines, results,
                    lambda: run_experiment(sweep_spec), reps=3)
    # a SECOND detector body (the RG-LRU windowed sequence detector)
    # through the same declarative pipeline: its executables key on the
    # frozen spec (never aliasing the autoencoder's), cold iteration
    # compiles one per iso-tracking kind, warm reps ride them for free
    seq_spec = ExperimentSpec(
        data=DataSpec(
            model=SeqDetector(input_dim=prep.device_x.shape[-1],
                              window=16, d_model=8),
            device_x=prep.device_x, device_counts=prep.counts,
            test_x=prep.test_x, test_y=prep.test_y,
            name=prep.name + "-seq"),
        base=base,
        cells=(CellSpec("tolfl", 2), CellSpec("fl", 1)),
        traces=TraceSpec(traces=tuple(traces[:4])),
        seeds=SeedSpec((0,)), exec_plan=plan)
    _timed_campaign("sweep_seq_detector", lines, results,
                    lambda: run_experiment(seq_spec), reps=2)

    # the SAME grid under ExecPlan(aot=True): iteration 1 is the true
    # first-ever cold cost (plan-time lowering overlapping the host
    # array builds + persistent-cache population), iterations 2-3
    # simulate a NEW PROCESS with a warm disk — compiled executables
    # deserialise whole: zero traces, zero XLA (the ISSUE 6 win row)
    aot_plan = ExecPlan(shard=shard, chunk_size=chunk_size, aot=True)
    aot_spec = ExperimentSpec(
        data=data_spec(prep), base=base,
        cells=tuple(CellSpec(s, k) for s, k in grid["scheme_ks"]),
        traces=TraceSpec(traces=tuple(traces)),
        seeds=SeedSpec((0, 1)), exec_plan=aot_plan)
    _timed_campaign("sweep_aot_cold", lines, results,
                    lambda: run_experiment(aot_spec), reps=3,
                    pre_iter=diskwarm_iter)
    # the jit-path twin: a warm disk serves XLA's module cache but
    # Python tracing still runs every fresh process
    _timed_campaign("sweep_diskcache_cold", lines, results,
                    lambda: sweep_grid(*args, base, **grid), reps=3,
                    pre_iter=diskwarm_iter)

    # sampled-rate grid at the big slot budget (max_events = 2N): the
    # vectorized trace_alive_mask keeps this compile O(1) in max_events
    s_traces, _ = sample_rate_grid(np.random.default_rng(1), topo,
                                   p_grid=(0.1, 0.3), rounds=ROUNDS,
                                   traces_per_p=8)
    _timed_campaign("sampled_max_events", lines, results,
                    lambda: run_campaign(*args, cfg, s_traces, [0, 1],
                                         exec_plan=plan))

    # dump BEFORE the guards: a tripped assert must still leave the row
    # data on disk for diagnosis
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    lines.append(f"# wrote {out_path}")

    assert sum(results["steady"]["compiles_per_iter"]) == 0, \
        results["steady"]
    # 4 cells, 2 compiles: non-fl cells share one executable, fl (whose
    # isolated-fallback branch is extra compute) gets its own — and the
    # cold rows re-pay it EVERY iteration (caches cleared per iter)
    assert results["sweep_padded"]["compiles_per_iter"] == [2, 2], \
        results["sweep_padded"]
    assert results["sweep_fused_cold"]["compiles_per_iter"] == [2, 2], \
        results["sweep_fused_cold"]
    assert sum(results["sweep_fused"]["compiles_per_iter"]) == 0, \
        results["sweep_fused"]
    # the declarative pipeline rides the same warm executables (0
    # compiles) and must not tax throughput more than 5%
    assert sum(results["spec_sweep"]["compiles_per_iter"]) == 0, \
        results["spec_sweep"]
    # ... comparing MEDIAN walls: best-of-3 picks each row's independent
    # noise minimum, which flakes the ratio on a 1-core container
    _med = lambda r: float(np.median(r["walls_s"]))  # noqa: E731
    assert _med(results["spec_sweep"]) <= 1.05 * _med(results["sweep_fused"]), \
        (results["spec_sweep"], results["sweep_fused"])
    # second-body row: one compile per iso-tracking kind on the cold
    # iteration, none on the warm rep (detector specs are first-class
    # executable-cache keys, not a retrace hazard)
    seq = results["sweep_seq_detector"]
    assert seq["compiles_per_iter"][0] == 2, seq
    assert seq["compiles_per_iter"][1:] == [0], seq
    # AOT row: iteration 1 traces + compiles + populates the disk;
    # warm-disk iterations deserialise whole executables — no traces,
    # no XLA compiles
    aot = results["sweep_aot_cold"]
    assert aot["compiles_per_iter"][0] == 2, aot
    assert aot["compiles_per_iter"][1:] == [0, 0], aot
    assert all(m == 0 for m in aot["xla_misses_per_iter"][1:]), aot
    assert all(h >= 2 for h in aot["exe_hits_per_iter"][1:]), aot
    # jit twin: tracing recurs every iteration; XLA comes from disk
    disk = results["sweep_diskcache_cold"]
    assert disk["compiles_per_iter"] == [2, 2, 2], disk
    assert all(m == 0 for m in disk["xla_misses_per_iter"][1:]), disk
    # the cold-compile tax is dead: a warm-disk fresh process under AOT
    # must beat the genuinely cold fused sweep by >= 1.5x
    assert (aot["scenarios_per_s"]
            >= 1.5 * results["sweep_fused_cold"]["scenarios_per_s"]), \
        (aot, results["sweep_fused_cold"])
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
