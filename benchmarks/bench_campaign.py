"""Campaign execution-layer throughput: scenarios/sec, compiles, wall.

Tracks the perf trajectory of the batched failure-campaign engine
(``BENCH_campaign.json``, written next to the working directory so CI
artifacts pick it up):

* ``oneshot``   — a fixed 64-scenario (16 sampled traces x 4 seeds)
  Tol-FL grid in one ``jit(vmap)`` call; wall-clock includes the single
  compile, ``steady`` re-runs it on the warm executable cache (the
  compile-amortisation contract: 0 new traces).
* ``chunked``   — the same grid through host-side chunking
  (``chunk_size=16``): bounded device memory, still one compile.
* ``sweep_padded`` — an all-single-model-cells ``sweep_grid``
  ((tolfl, 5) / (tolfl, 2) / (fl, 1) / (sbt, 10)) with padded-k
  topology arrays: compiles are bounded per ISO-TRACKING KIND, not per
  cell — exactly TWO for this grid (one executable shared by the three
  non-fl cells, one for the fl cell's isolated-fallback branch).
* ``sampled_max_events`` — compile+run wall of a sampled-rate grid with
  the big default slot budget (max_events = 2N): the regression guard
  for the vectorized ``trace_alive_mask`` (the unrolled fold made this
  compile O(max_events) slower).

The traces are sampled at a fixed RNG seed, so the grid is identical
run-to-run and numbers are comparable across commits.
"""
from __future__ import annotations

import json
import time
from typing import List

import numpy as np

from benchmarks.datasets import prepare
from repro.core import campaign
from repro.core.campaign import ExecPlan, run_campaign, sweep_grid
from repro.core.failure import sample_rate_grid, sample_traces
from repro.core.simulate import SimConfig

GRID_TRACES = 16
GRID_SEEDS = 4
ROUNDS = 8


def _timed_campaign(label, lines, results, fn):
    c0 = campaign.TRACE_COUNT
    t0 = time.time()
    res = fn()
    wall = time.time() - t0
    compiles = campaign.TRACE_COUNT - c0
    n = sum(r.num_scenarios for r in
            (res.values() if isinstance(res, dict) else [res]))
    results[label] = {"scenarios": n, "compiles": compiles,
                      "wall_s": round(wall, 3),
                      "scenarios_per_s": round(n / max(wall, 1e-9), 2)}
    lines.append(f"{label},{n},{compiles},{wall:.2f},{n / wall:.1f}")
    return res


def run(out_path: str = "BENCH_campaign.json") -> List[str]:
    prep = prepare("commsml", seed=0, scale=0.25)
    cfg = SimConfig(scheme="tolfl", num_devices=10,
                    num_clusters=prep.clusters, rounds=ROUNDS,
                    lr=prep.lr, local_epochs=1, dropout=False)
    topo = cfg.topology()
    traces = sample_traces(np.random.default_rng(0), topo, 0.3,
                           max_events=8, rounds=ROUNDS,
                           num_traces=GRID_TRACES)
    seeds = range(GRID_SEEDS)
    args = (prep.ae_cfg, prep.device_x, prep.counts, prep.test_x,
            prep.test_y)

    lines = ["name,scenarios,compiles,wall_s,scenarios_per_s"]
    results: dict = {}

    _timed_campaign("oneshot", lines, results,
                    lambda: run_campaign(*args, cfg, traces, seeds))
    _timed_campaign("steady", lines, results,
                    lambda: run_campaign(*args, cfg, traces, seeds))
    _timed_campaign("chunked", lines, results,
                    lambda: run_campaign(*args, cfg, traces, seeds,
                                         exec_plan=ExecPlan(chunk_size=16)))
    base = SimConfig(num_devices=10, rounds=ROUNDS, lr=prep.lr,
                     dropout=False)
    _timed_campaign("sweep_padded", lines, results,
                    lambda: sweep_grid(*args, base,
                                       scheme_ks=[("tolfl", 5),
                                                  ("tolfl", 2),
                                                  ("fl", 1), ("sbt", 10)],
                                       traces=traces, seeds=[0, 1]))

    # sampled-rate grid at the big slot budget (max_events = 2N): the
    # vectorized trace_alive_mask keeps this compile O(1) in max_events
    s_traces, _ = sample_rate_grid(np.random.default_rng(1), topo,
                                   p_grid=(0.1, 0.3), rounds=ROUNDS,
                                   traces_per_p=8)
    _timed_campaign("sampled_max_events", lines, results,
                    lambda: run_campaign(*args, cfg, s_traces, [0, 1]))

    assert results["steady"]["compiles"] == 0, results["steady"]
    # 4 cells, 2 compiles: non-fl cells share one executable, fl (whose
    # isolated-fallback branch is extra compute) gets its own
    assert results["sweep_padded"]["compiles"] == 2, \
        results["sweep_padded"]
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    lines.append(f"# wrote {out_path}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
