"""Table VI: distributed-training communication costs (MB/epoch).

Counts model transfers per round per scheme (the paper's accounting) times
the autoencoder's parameter payload, plus the measured expected-complexity
column.  Also cross-checks the datacenter mapping: HLO-parsed collective
bytes of the mesh train step for ring vs psum schedules (from the dry-run
records, if present).
"""
from __future__ import annotations

import glob
import json
from typing import List

from benchmarks.datasets import prepare
from repro.core.simulate import comm_mb_per_round, comm_transfers_per_round
from repro.models.detector import as_detector

N, K = 10, 5


def run() -> List[str]:
    prep = prepare("commsml")
    # payload derived from the detector interface: the same number any
    # body reports via DetectorModel.param_bytes() (one eager tiny init
    # through models.params.param_count/param_bytes)
    det = as_detector(prep.ae_cfg)
    lines = ["# Table VI: communication cost per training round (N=10, k=5)",
             "method,expected,transfers,MB_per_epoch"]
    for scheme, expected in (("fl", "O(2N)"), ("sbt", "O(N)"),
                             ("tolfl", "O(N+k)")):
        tr = comm_transfers_per_round(scheme, N, K)
        lines.append(f"{scheme},{expected},{tr},"
                     f"{comm_mb_per_round(scheme, N, K, det):.2f}")
    # datacenter cross-check from dry-run HLO collective bytes
    recs = []
    for p in glob.glob("results/dryrun/*train_4k__pod16x16.json"):
        with open(p) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            hl = r.get("roofline_hlo", {})
            recs.append((r["arch"], r.get("schedule"),
                         hl.get("coll_bytes_per_chip", 0)))
    if recs:
        lines.append("# datacenter mapping: HLO collective bytes/chip "
                     "(train_4k, single pod)")
        lines.append("arch,schedule,coll_bytes_per_chip")
        for a, s, b in sorted(recs):
            lines.append(f"{a},{s},{b:.3e}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
