"""Paper §IV-B expected-performance ablation (beyond the paper's tables).

The paper observes that expected performance is E[J] = sum_s p_s J_s over
failure scenarios s, and that "depending on the probability of any client
or server failing ... either batch, FL, or Tol-FL may be most suited".
This bench makes that concrete: with per-device failure probability p
(at most one failure per run, the paper's §II model), scenario weights
for a scheme whose topology has N devices of which H are heads:

    P(no failure)      = (1-p)^N
    P(member failure)  = (N-H) p (1-p)^(N-1)   (a non-head dies)
    P(head failure)    = H p (1-p)^(N-1)       (a head/server dies)
    (+ renormalisation over the >=2-failure remainder, assigned the
     head-failure outcome pessimistically)

J_s come from the same simulator cells as Tables III/IV/V.  Output: the
E[AUROC] vs p crossover table — the quantified version of the paper's
"which scheme when" conclusion.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.bench_failure_auroc import run_single_campaign

# scheme -> (N devices, heads H) for the scenario weighting
TOPOLOGY = {
    "tolfl": (10, 5),      # k=5 cluster heads (commsml prep uses k=2;
                           # heads taken from the prep inside the
                           # campaign cell)
    "fl": (11, 1),         # 10 clients + 1 dedicated server
    "batch": (1, 1),       # the server IS the system
}


def expected(j_none: float, j_client: float, j_server: float,
             n: int, h: int, p: float) -> float:
    p_none = (1 - p) ** n
    p_member = (n - h) * p * (1 - p) ** (n - 1)
    p_head = h * p * (1 - p) ** (n - 1)
    rest = max(0.0, 1.0 - p_none - p_member - p_head)
    return (p_none * j_none + p_member * j_client
            + (p_head + rest) * j_server)


def run(reps: int = 1, rounds: int = 40, dataset: str = "commsml"
        ) -> List[str]:
    cells: Dict[str, Dict[str, float]] = {}
    for method in ("tolfl", "fl", "batch"):
        # one batched campaign per scheme covers all three conditions
        # (batch's client failure aliases failure-free inside the cell)
        stats = run_single_campaign(dataset, method, reps, rounds)
        cells[method] = {kind: s["mean"] for kind, s in stats.items()}

    lines = [f"# E[AUROC] = sum_s p_s J_s ({dataset}, {rounds} rounds); "
             "paper section IV-B",
             "p_fail," + ",".join(TOPOLOGY) + ",best"]
    for p in (0.0, 0.01, 0.05, 0.1, 0.2, 0.4):
        row = [f"{p:.2f}"]
        best, best_v = None, -1.0
        for method, (n, h) in TOPOLOGY.items():
            v = expected(cells[method]["none"], cells[method]["client"],
                         cells[method]["server"], n, h, p)
            row.append(f"{v:.3f}")
            if v > best_v:
                best, best_v = method, v
        row.append(best)
        lines.append(",".join(row))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
