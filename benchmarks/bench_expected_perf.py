"""Paper §IV-B expected performance over SAMPLED failure grids.

The paper observes that expected performance is E[J] = sum_s p_s J_s over
failure scenarios s, and that "depending on the probability of any client
or server failing ... either batch, FL, or Tol-FL may be most suited".
The seed's version hand-listed three scenarios (none / one client / one
server) and weighted them analytically under an at-most-one-failure
model.  This bench estimates E[J] by Monte Carlo instead, and the whole
study is ONE declarative spec: a (tolfl, fl, batch) cell grid crossed
with a sampled :class:`repro.api.TraceSpec` — for each failure rate p
the planner draws grids of multi-event failure-and-recovery traces
against EACH SCHEME'S OWN topology (cluster heads count as server
failures, churned devices may come back) and deduplicates identical
draws, so multi-failure scenarios the analytic model lumped into a
pessimistic remainder are actually simulated, once each.

``plan(spec)`` records the draw -> trace map per cell; ``execute``
fuses the non-batch cells per iso-tracking kind — scenario count scales
without recompiles.  E[AUROC](p) is the mean reported AUROC over that
p's sampled draws.  Output: the E[AUROC] vs p crossover table — the
quantified version of the paper's "which scheme when" conclusion.
"""
from __future__ import annotations

import time
from typing import List, Sequence

import numpy as np

from benchmarks.datasets import base_config, data_spec, prepare
from repro.api import (FAMILIES, CellSpec, ExperimentSpec, ProcessGrid,
                       SeedSpec, TraceSpec, execute, family_process, plan)

SCHEMES = ("tolfl", "fl", "batch")
P_GRID = (0.0, 0.01, 0.05, 0.1, 0.2, 0.4)

#: the per-family curves' scheme grid (single + multi-model baselines)
FAMILY_SCHEMES = ("tolfl", "fl", "fedgroup", "ifca", "fesem")
INTENSITIES = (0.05, 0.2, 0.4)


def run(reps: int = 1, rounds: int = 40, dataset: str = "commsml",
        p_grid: Sequence[float] = P_GRID, traces_per_p: int = 8,
        scale: float = 1.0, trace_seed: int = 0) -> List[str]:
    prep = prepare(dataset, seed=0, scale=scale)
    base = base_config(prep, rounds)
    k_of = {"tolfl": prep.clusters, "fl": 1, "batch": 1}
    spec = ExperimentSpec(
        data=data_spec(prep),
        base=base,
        cells=tuple(CellSpec(s, k_of[s]) for s in SCHEMES),
        # dedup identical draws (at low p most are the all-none trace):
        # each distinct trace trains once, draws map results back so the
        # per-p means equal the undeduplicated Monte-Carlo estimate
        traces=TraceSpec.sampled(p_grid, traces_per_p,
                                 sample_seed=trace_seed),
        seeds=SeedSpec.range(reps))
    ep = plan(spec)
    t0 = time.time()
    res = execute(ep)
    n_draws = sum(len(c.draws[p]) for c in ep.cells for p in p_grid)
    print(f"# expected-perf campaign {dataset}: {n_draws * reps} sampled "
          f"draws as {res.num_scenarios} distinct scenarios in "
          f"{len(ep.buckets)} dispatch buckets, {time.time()-t0:.0f}s",
          flush=True)

    cells = {}
    for cplan, cres in zip(ep.cells, res.results):
        for p in p_grid:
            vals = np.concatenate([cres.select(i)
                                   for i in cplan.draws[p]])
            cells[(cplan.cfg.scheme, p)] = float(np.mean(vals))

    lines = [f"# E[AUROC](p) via {traces_per_p} sampled traces x {reps} "
             f"seeds per rate ({dataset}, {rounds} rounds); paper "
             "section IV-B",
             "p_fail," + ",".join(SCHEMES) + ",best"]
    for p in p_grid:
        row = [f"{p:.2f}"]
        best, best_v = None, -1.0
        for scheme in SCHEMES:
            v = cells[(scheme, p)]
            row.append(f"{v:.3f}")
            if v > best_v:
                best, best_v = scheme, v
        row.append(best)
        lines.append(",".join(row))
    return lines


def run_smoke(rounds: int = 8, reps: int = 1) -> List[str]:
    """CI path: a tiny sampled failure-rate sweep, seconds-scale."""
    return run(reps=reps, rounds=rounds, p_grid=(0.0, 0.2),
               traces_per_p=2, scale=0.25)


def run_families(reps: int = 1, rounds: int = 40,
                 dataset: str = "commsml",
                 intensities: Sequence[float] = INTENSITIES,
                 samples: int = 4, scale: float = 1.0,
                 trace_seed: int = 0,
                 families: Sequence[str] = FAMILIES) -> List[str]:
    """E[AUROC]-vs-intensity curves per generative failure family.

    One spec per family: the FAMILY_SCHEMES cell grid crossed with a
    :class:`TraceSpec` of one :class:`ProcessGrid` per intensity of the
    family's canonical process (``family_process``).  Multi-model cells
    report their best (starred) instance; the faulty family runs the
    whole grid on the faulty-aware engine variants."""
    prep = prepare(dataset, seed=0, scale=scale)
    base = base_config(prep, rounds)
    k_of = {"tolfl": prep.clusters, "fl": 1,
            "fedgroup": 3, "ifca": 3, "fesem": 3}
    lines: List[str] = []
    for family in families:
        spec = ExperimentSpec(
            data=data_spec(prep), base=base,
            cells=tuple(CellSpec(s, k_of[s]) for s in FAMILY_SCHEMES),
            traces=TraceSpec.generated(
                *(ProcessGrid(family_process(family, x), samples)
                  for x in intensities),
                sample_seed=trace_seed),
            seeds=SeedSpec.range(reps))
        ep = plan(spec)
        t0 = time.time()
        res = execute(ep)
        per = res.per_process()
        print(f"# {family} family {dataset}: {res.num_scenarios} "
              f"scenarios in {len(ep.buckets)} buckets, "
              f"{time.time()-t0:.0f}s", flush=True)
        lines.append(f"# E[AUROC] vs intensity, {family} failure "
                     f"process ({samples} draws x {reps} seeds per "
                     f"point, {dataset}, {rounds} rounds)")
        lines.append("intensity," + ",".join(FAMILY_SCHEMES))
        for gi, x in enumerate(intensities):
            row = [f"{x:.2f}"]
            for cplan in ep.cells:
                row.append(f"{np.mean(per[cplan.key][gi]):.3f}")
            lines.append(",".join(row))
    return lines


def run_families_smoke(rounds: int = 6, reps: int = 1) -> List[str]:
    """CI path: one intensity point per family, seconds-scale."""
    return run_families(reps=reps, rounds=rounds, intensities=(0.3,),
                        samples=2, scale=0.25)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--families", action="store_true",
                    help="per-family E[AUROC]-vs-intensity curves "
                         "instead of the rate sweep")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.families:
        print("\n".join(run_families_smoke() if args.smoke
                        else run_families()))
    else:
        print("\n".join(run_smoke() if args.smoke else run()))
