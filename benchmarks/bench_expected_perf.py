"""Paper §IV-B expected performance over SAMPLED failure grids.

The paper observes that expected performance is E[J] = sum_s p_s J_s over
failure scenarios s, and that "depending on the probability of any client
or server failing ... either batch, FL, or Tol-FL may be most suited".
The seed's version hand-listed three scenarios (none / one client / one
server) and weighted them analytically under an at-most-one-failure
model.  This bench estimates E[J] by Monte Carlo instead: for each
failure rate p it *samples* grids of multi-event failure-and-recovery
traces (:func:`repro.core.failure.sample_traces` — every device of the
scheme's own topology independently fails with probability p at a random
round, cluster heads count as server failures, churned devices may come
back), so multi-failure scenarios the analytic model lumped into a
pessimistic remainder are actually simulated.

All (p x trace x seed) scenarios for one scheme run through ONE batched
campaign call — scenario count scales without recompiles.  E[AUROC](p)
is the mean reported AUROC over that p's sampled scenarios.  Output: the
E[AUROC] vs p crossover table — the quantified version of the paper's
"which scheme when" conclusion.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from benchmarks.datasets import prepare
from repro.core.campaign import run_campaign
from repro.core.failure import sample_rate_grid
from repro.core.simulate import SimConfig

SCHEMES = ("tolfl", "fl", "batch")
P_GRID = (0.0, 0.01, 0.05, 0.1, 0.2, 0.4)


def run(reps: int = 1, rounds: int = 40, dataset: str = "commsml",
        p_grid: Sequence[float] = P_GRID, traces_per_p: int = 8,
        scale: float = 1.0, trace_seed: int = 0) -> List[str]:
    prep = prepare(dataset, seed=0, scale=scale)
    cells: Dict[Tuple[str, float], float] = {}
    for scheme in SCHEMES:
        cfg = SimConfig(scheme=scheme, num_devices=10,
                        num_clusters=prep.clusters, rounds=rounds,
                        lr=prep.lr, local_epochs=prep.local_epochs)
        # dedup identical draws (at low p most are the all-none trace):
        # each distinct trace trains once, draws map results back so the
        # per-p means equal the undeduplicated Monte-Carlo estimate
        rng = np.random.default_rng(trace_seed)
        traces, draws = sample_rate_grid(rng, cfg.topology(), p_grid,
                                         rounds, traces_per_p)
        t0 = time.time()
        res = run_campaign(prep.ae_cfg, prep.device_x, prep.counts,
                           prep.test_x, prep.test_y, cfg, traces,
                           seeds=range(reps))
        n_draws = sum(len(d) for d in draws.values()) * reps
        print(f"# expected-perf campaign {dataset}/{scheme}: "
              f"{n_draws} sampled draws as {res.num_scenarios} distinct "
              f"scenarios in {time.time()-t0:.0f}s", flush=True)
        for p in p_grid:
            vals = np.concatenate([res.select(i) for i in draws[p]])
            cells[(scheme, p)] = float(np.mean(vals))

    lines = [f"# E[AUROC](p) via {traces_per_p} sampled traces x {reps} "
             f"seeds per rate ({dataset}, {rounds} rounds); paper "
             "section IV-B",
             "p_fail," + ",".join(SCHEMES) + ",best"]
    for p in p_grid:
        row = [f"{p:.2f}"]
        best, best_v = None, -1.0
        for scheme in SCHEMES:
            v = cells[(scheme, p)]
            row.append(f"{v:.3f}")
            if v > best_v:
                best, best_v = scheme, v
        row.append(best)
        lines.append(",".join(row))
    return lines


def run_smoke(rounds: int = 8, reps: int = 1) -> List[str]:
    """CI path: a tiny sampled failure-rate sweep, seconds-scale."""
    return run(reps=reps, rounds=rounds, p_grid=(0.0, 0.2),
               traces_per_p=2, scale=0.25)


if __name__ == "__main__":
    print("\n".join(run()))
