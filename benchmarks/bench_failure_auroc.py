"""Tables III / IV / V: anomaly-detection AUROC without failure, with
client failure, and with server failure (at the training midpoint).

Columns mirror the paper: Tol-FL, FedGroup*/dagger, IFCA*/dagger,
FeSEM*/dagger, FL, Batch (Batch omitted for server failure, as in
Table V).  Results are mean +- std over ``reps`` seeds.

Every scheme runs through the batched campaign engine: per
(dataset, scheme) ONE jitted/vmapped call covers the full
(3 failure traces x reps seeds) grid — the seed's version compiled and
ran every (scheme, failure, rep) cell separately, and until PR 2 the
multi-model baselines still looped per cell.  Randomness across reps
comes from the simulation seed (init/dropout); the dataset draw is
fixed at seed 0 so all scenarios in a batch share one data tensor.
The multi-model cells pass legacy single-event ``FailureSpec``s, which
the campaign normalises with the baseline default targets (client
failure kills device N-1; see
:func:`repro.core.baselines.as_multimodel_trace`) — the Table IV
casualty device matches the seed's looped version.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from benchmarks.datasets import ALL, prepare
from repro.core.baselines import MultiModelConfig
from repro.core.campaign import (CampaignResult, MultiCampaignResult,
                                 mean_ci95, run_campaign,
                                 run_multimodel_campaign)
from repro.core.failure import FailureSpec, NO_FAILURE, as_trace
from repro.core.simulate import SimConfig

ROUNDS = 80
FAIL_KINDS = ("none", "client", "server")


def _failure(kind: str, rounds: int = ROUNDS) -> FailureSpec:
    if kind == "none":
        return NO_FAILURE
    return FailureSpec(epoch=rounds // 2, kind=kind)


def _stats(vals: Sequence[float]) -> Dict[str, float]:
    """Mean +- SAMPLE std (ddof=1) over seeds — ddof=0 under-reports
    the spread of small-rep campaigns (0 std for reps=1 is kept)."""
    mean, std, _ = mean_ci95(np.asarray(vals))
    return {"mean": mean, "std": std}


def run_single_campaign(dataset: str, scheme: str, reps: int,
                        rounds: int = ROUNDS,
                        kinds: Sequence[str] = FAIL_KINDS
                        ) -> Dict[str, Dict[str, float]]:
    """The requested failure conditions x reps seeds for one
    single-model scheme in one batched call; returns
    {fail_kind: {mean, std}}.  Identical conditions share scenarios
    (batch's client failure removes nothing — all data already sits on
    the server, the paper reports its failure-free run — so it aliases
    "none" instead of re-training duplicates)."""
    prep = prepare(dataset, seed=0)
    cfg = SimConfig(scheme=scheme, num_devices=10,
                    num_clusters=prep.clusters, rounds=rounds,
                    lr=prep.lr, local_epochs=prep.local_epochs)
    topo = cfg.topology()
    traces: List = []
    idx_of: Dict[tuple, int] = {}
    kind_idx: Dict[str, int] = {}
    for kind in kinds:
        spec = _failure(kind, rounds)
        if scheme == "batch" and kind == "client":
            spec = NO_FAILURE
        key = (spec.epoch, spec.kind, spec.device)
        if key not in idx_of:
            idx_of[key] = len(traces)
            traces.append(as_trace(spec, topo))
        kind_idx[kind] = idx_of[key]
    res: CampaignResult = run_campaign(
        prep.ae_cfg, prep.device_x, prep.counts, prep.test_x, prep.test_y,
        cfg, traces, seeds=range(reps))
    return {kind: _stats(res.select(i)) for kind, i in kind_idx.items()}


def run_multi_campaign(dataset: str, method: str, reps: int,
                       rounds: int = ROUNDS,
                       kinds: Sequence[str] = FAIL_KINDS
                       ) -> Dict[str, Dict[str, float]]:
    """The requested failure conditions x reps seeds for one multi-model
    baseline in ONE jit(vmap) call; returns
    {fail_kind: {mean, std, multi_mean, multi_std}}."""
    prep = prepare(dataset, seed=0)
    # multi-model engines take one local step per round: give them the
    # same TOTAL local-step budget (rounds x E), failure at the same
    # relative midpoint
    mm_rounds = rounds * prep.local_epochs
    cfg = MultiModelConfig(scheme=method, num_devices=10,
                           num_models=min(prep.clusters, 3),
                           rounds=mm_rounds, lr=prep.lr)
    traces = [_failure(kind, mm_rounds) for kind in kinds]
    res: MultiCampaignResult = run_multimodel_campaign(
        prep.ae_cfg, prep.device_x, prep.counts, prep.test_x, prep.test_y,
        cfg, traces, seeds=range(reps))
    out: Dict[str, Dict[str, float]] = {}
    for i, kind in enumerate(kinds):
        cell = _stats(res.select(i, "best"))
        multi = _stats(res.select(i, "multi"))
        cell["multi_mean"], cell["multi_std"] = multi["mean"], multi["std"]
        out[kind] = cell
    return out


def run(reps: int = 2, rounds: int = ROUNDS, datasets=ALL) -> List[str]:
    single = ("tolfl", "fl", "batch")
    multi = ("fedgroup", "ifca", "fesem")
    # one batched campaign per (dataset, scheme) covers all three tables
    single_cells: Dict[tuple, Dict[str, Dict[str, float]]] = {}
    multi_cells: Dict[tuple, Dict[str, float]] = {}
    for ds in datasets:
        for scheme in single:
            t0 = time.time()
            # the tables never show batch under server failure (Table V
            # omits it) — don't train those scenarios
            kinds = (("none", "client") if scheme == "batch"
                     else FAIL_KINDS)
            single_cells[(ds, scheme)] = run_single_campaign(
                ds, scheme, reps, rounds, kinds)
            print(f"# campaign {ds}/{scheme}: "
                  f"{len(kinds) * reps} scenarios in "
                  f"{time.time()-t0:.0f}s", flush=True)
        for m in multi:
            t0 = time.time()
            cells = run_multi_campaign(ds, m, reps, rounds)
            for kind in FAIL_KINDS:
                multi_cells[(ds, m, kind)] = cells[kind]
            print(f"# multi campaign {ds}/{m}: "
                  f"{len(FAIL_KINDS) * reps} scenarios in "
                  f"{time.time()-t0:.0f}s", flush=True)

    lines = []
    for fail_kind, table in (("none", "Table III (no failure)"),
                             ("client", "Table IV (client failure)"),
                             ("server", "Table V (server failure)")):
        lines.append(f"# {table}, AUROC mean+-std over {reps} reps")
        hdr = ["dataset", "tolfl"]
        for m in multi:
            hdr += [f"{m}*", f"{m}+"]
        hdr += ["fl"]
        if fail_kind != "server":
            hdr += ["batch"]
        lines.append(",".join(hdr))
        for ds in datasets:
            row = [ds]
            c = single_cells[(ds, "tolfl")][fail_kind]
            row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            for m in multi:
                c = multi_cells[(ds, m, fail_kind)]
                row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
                row.append(f"{c['multi_mean']:.3f}+-{c['multi_std']:.3f}")
            c = single_cells[(ds, "fl")][fail_kind]
            row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            if fail_kind != "server":
                c = single_cells[(ds, "batch")][fail_kind]
                row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            lines.append(",".join(row))
    return lines


def run_smoke(rounds: int = 8, reps: int = 2) -> List[str]:
    """CI micro-campaigns: one batched (3 traces x reps seeds) Tol-FL
    sweep plus one batched multi-model (IFCA) sweep on a small Comms-ML
    draw; seconds, not minutes."""
    prep = prepare("commsml", seed=0, scale=0.25)
    cfg = SimConfig(scheme="tolfl", num_devices=10,
                    num_clusters=prep.clusters, rounds=rounds,
                    lr=prep.lr, local_epochs=1)
    traces = [_failure(kind, rounds) for kind in FAIL_KINDS]
    t0 = time.time()
    res = run_campaign(prep.ae_cfg, prep.device_x, prep.counts,
                       prep.test_x, prep.test_y, cfg, traces,
                       seeds=range(reps))
    s = res.summary()
    lines = [f"# smoke micro-campaign: {res.num_scenarios} scenarios, "
             f"1 compile, {time.time()-t0:.1f}s",
             "fail_kind,auroc_mean,auroc_std"]
    for i, kind in enumerate(FAIL_KINDS):
        v = res.select(i)
        lines.append(f"{kind},{v.mean():.3f},{v.std():.3f}")
    lines.append(f"overall,{s['auroc_used_mean']:.3f},"
                 f"{s['auroc_used_std']:.3f}")
    assert np.isfinite(res.auroc_used).all(), "smoke campaign produced NaN"

    mcfg = MultiModelConfig(scheme="ifca", num_devices=10, num_models=2,
                            rounds=rounds, lr=prep.lr)
    t0 = time.time()
    mres = run_multimodel_campaign(prep.ae_cfg, prep.device_x, prep.counts,
                                   prep.test_x, prep.test_y, mcfg, traces,
                                   seeds=range(reps))
    lines.append(f"# smoke multi-model micro-campaign (ifca): "
                 f"{mres.num_scenarios} scenarios, 1 compile, "
                 f"{time.time()-t0:.1f}s")
    lines.append("fail_kind,best_auroc_mean,multi_auroc_mean")
    for i, kind in enumerate(FAIL_KINDS):
        lines.append(f"{kind},{mres.select(i, 'best').mean():.3f},"
                     f"{mres.select(i, 'multi').mean():.3f}")
    assert np.isfinite(mres.best_auroc).all(), "multi smoke produced NaN"
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
