"""Tables III / IV / V: anomaly-detection AUROC without failure, with
client failure, and with server failure (at the training midpoint).

Columns mirror the paper: Tol-FL, FedGroup*/dagger, IFCA*/dagger,
FeSEM*/dagger, FL, Batch (Batch omitted for server failure, as in
Table V).  Results are mean +- std over ``reps`` seeds.

Single-model schemes run through the batched campaign engine: per
(dataset, scheme) ONE jitted/vmapped call covers the full
(3 failure traces x reps seeds) grid — the seed's version compiled and
ran every (scheme, failure, rep) cell separately.  Randomness across
reps comes from the simulation seed (init/dropout); the dataset draw is
fixed at seed 0 so all scenarios in a batch share one data tensor.
Multi-model baselines keep a per-cell loop (their M-model state is a
different program) and still pass legacy single-event ``FailureSpec``s
— their default failure targets differ from the trace encoding's (see
:mod:`repro.core.baselines`), so switching them to traces would change
the Table IV casualty device.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from benchmarks.datasets import ALL, prepare
from repro.core.baselines import MultiModelConfig, run_multimodel
from repro.core.campaign import CampaignResult, run_campaign
from repro.core.failure import FailureSpec, NO_FAILURE, as_trace
from repro.core.simulate import SimConfig

ROUNDS = 80
FAIL_KINDS = ("none", "client", "server")


def _failure(kind: str, rounds: int = ROUNDS) -> FailureSpec:
    if kind == "none":
        return NO_FAILURE
    return FailureSpec(epoch=rounds // 2, kind=kind)


def _stats(vals: Sequence[float]) -> Dict[str, float]:
    return {"mean": float(np.mean(vals)), "std": float(np.std(vals))}


def run_single_campaign(dataset: str, scheme: str, reps: int,
                        rounds: int = ROUNDS,
                        kinds: Sequence[str] = FAIL_KINDS
                        ) -> Dict[str, Dict[str, float]]:
    """The requested failure conditions x reps seeds for one
    single-model scheme in one batched call; returns
    {fail_kind: {mean, std}}.  Identical conditions share scenarios
    (batch's client failure removes nothing — all data already sits on
    the server, the paper reports its failure-free run — so it aliases
    "none" instead of re-training duplicates)."""
    prep = prepare(dataset, seed=0)
    cfg = SimConfig(scheme=scheme, num_devices=10,
                    num_clusters=prep.clusters, rounds=rounds,
                    lr=prep.lr, local_epochs=prep.local_epochs)
    topo = cfg.topology()
    traces: List = []
    idx_of: Dict[tuple, int] = {}
    kind_idx: Dict[str, int] = {}
    for kind in kinds:
        spec = _failure(kind, rounds)
        if scheme == "batch" and kind == "client":
            spec = NO_FAILURE
        key = (spec.epoch, spec.kind, spec.device)
        if key not in idx_of:
            idx_of[key] = len(traces)
            traces.append(as_trace(spec, topo))
        kind_idx[kind] = idx_of[key]
    res: CampaignResult = run_campaign(
        prep.ae_cfg, prep.device_x, prep.counts, prep.test_x, prep.test_y,
        cfg, traces, seeds=range(reps))
    return {kind: _stats(res.select(i)) for kind, i in kind_idx.items()}


def run_multi_cell(dataset: str, method: str, fail_kind: str, reps: int,
                   rounds: int = ROUNDS) -> Dict[str, float]:
    prep = prepare(dataset, seed=0)
    # multi-model engines take one local step per round: give them the
    # same TOTAL local-step budget (rounds x E), failure at the same
    # relative midpoint
    mm_rounds = rounds * prep.local_epochs
    vals: List[float] = []
    extra: List[float] = []
    for rep in range(reps):
        cfg = MultiModelConfig(scheme=method, num_devices=10,
                               num_models=min(prep.clusters, 3),
                               rounds=mm_rounds, lr=prep.lr, seed=rep)
        r = run_multimodel(prep.ae_cfg, prep.device_x, prep.counts,
                           prep.test_x, prep.test_y, cfg,
                           _failure(fail_kind, mm_rounds))
        vals.append(r.best_auroc)
        extra.append(r.multi_auroc)
    out = _stats(vals)
    out["multi_mean"] = float(np.mean(extra))
    out["multi_std"] = float(np.std(extra))
    return out


def run(reps: int = 2, rounds: int = ROUNDS, datasets=ALL) -> List[str]:
    single = ("tolfl", "fl", "batch")
    multi = ("fedgroup", "ifca", "fesem")
    # one batched campaign per (dataset, scheme) covers all three tables
    single_cells: Dict[tuple, Dict[str, Dict[str, float]]] = {}
    multi_cells: Dict[tuple, Dict[str, float]] = {}
    for ds in datasets:
        for scheme in single:
            t0 = time.time()
            # the tables never show batch under server failure (Table V
            # omits it) — don't train those scenarios
            kinds = (("none", "client") if scheme == "batch"
                     else FAIL_KINDS)
            single_cells[(ds, scheme)] = run_single_campaign(
                ds, scheme, reps, rounds, kinds)
            print(f"# campaign {ds}/{scheme}: "
                  f"{len(kinds) * reps} scenarios in "
                  f"{time.time()-t0:.0f}s", flush=True)
        for m in multi:
            for kind in FAIL_KINDS:
                multi_cells[(ds, m, kind)] = run_multi_cell(
                    ds, m, kind, reps, rounds)

    lines = []
    for fail_kind, table in (("none", "Table III (no failure)"),
                             ("client", "Table IV (client failure)"),
                             ("server", "Table V (server failure)")):
        lines.append(f"# {table}, AUROC mean+-std over {reps} reps")
        hdr = ["dataset", "tolfl"]
        for m in multi:
            hdr += [f"{m}*", f"{m}+"]
        hdr += ["fl"]
        if fail_kind != "server":
            hdr += ["batch"]
        lines.append(",".join(hdr))
        for ds in datasets:
            row = [ds]
            c = single_cells[(ds, "tolfl")][fail_kind]
            row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            for m in multi:
                c = multi_cells[(ds, m, fail_kind)]
                row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
                row.append(f"{c['multi_mean']:.3f}+-{c['multi_std']:.3f}")
            c = single_cells[(ds, "fl")][fail_kind]
            row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            if fail_kind != "server":
                c = single_cells[(ds, "batch")][fail_kind]
                row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            lines.append(",".join(row))
    return lines


def run_smoke(rounds: int = 8, reps: int = 2) -> List[str]:
    """CI micro-campaign: one batched (3 traces x reps seeds) Tol-FL
    sweep on a small Comms-ML draw; seconds, not minutes."""
    prep = prepare("commsml", seed=0, scale=0.25)
    cfg = SimConfig(scheme="tolfl", num_devices=10,
                    num_clusters=prep.clusters, rounds=rounds,
                    lr=prep.lr, local_epochs=1)
    traces = [_failure(kind, rounds) for kind in FAIL_KINDS]
    t0 = time.time()
    res = run_campaign(prep.ae_cfg, prep.device_x, prep.counts,
                       prep.test_x, prep.test_y, cfg, traces,
                       seeds=range(reps))
    s = res.summary()
    lines = [f"# smoke micro-campaign: {res.num_scenarios} scenarios, "
             f"1 compile, {time.time()-t0:.1f}s",
             "fail_kind,auroc_mean,auroc_std"]
    for i, kind in enumerate(FAIL_KINDS):
        v = res.select(i)
        lines.append(f"{kind},{v.mean():.3f},{v.std():.3f}")
    lines.append(f"overall,{s['auroc_used_mean']:.3f},"
                 f"{s['auroc_used_std']:.3f}")
    assert np.isfinite(res.auroc_used).all(), "smoke campaign produced NaN"
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
