"""Tables III / IV / V: anomaly-detection AUROC without failure, with
client failure, and with server failure (at the training midpoint).

Columns mirror the paper: Tol-FL, FedGroup*/dagger, IFCA*/dagger,
FeSEM*/dagger, FL, Batch (Batch omitted for server failure, as in
Table V).  Results are mean +- std over ``reps`` seeds.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.datasets import ALL, prepare
from repro.core.baselines import MultiModelConfig, run_multimodel
from repro.core.failure import FailureSpec, NO_FAILURE
from repro.core.simulate import SimConfig, run_simulation

ROUNDS = 80
FAIL_AT = ROUNDS // 2


def _failure(kind: str, rounds: int = ROUNDS) -> FailureSpec:
    if kind == "none":
        return NO_FAILURE
    return FailureSpec(epoch=rounds // 2, kind=kind)


def run_cell(dataset: str, method: str, fail_kind: str, reps: int,
             rounds: int = ROUNDS) -> Dict[str, float]:
    vals: List[float] = []
    extra: List[float] = []
    for rep in range(reps):
        prep = prepare(dataset, seed=rep)
        failure = _failure(fail_kind, rounds)
        if method in ("tolfl", "fl", "sbt", "batch"):
            if method == "batch" and fail_kind == "client":
                # centralised: a client failure removes nothing (all data
                # is already on the server) — paper keeps Batch in the
                # table via the same run as failure-free
                failure = NO_FAILURE
            cfg = SimConfig(scheme=method, num_devices=10,
                            num_clusters=prep.clusters, rounds=rounds,
                            lr=prep.lr, local_epochs=prep.local_epochs,
                            seed=rep)
            r = run_simulation(prep.ae_cfg, prep.device_x, prep.counts,
                               prep.test_x, prep.test_y, cfg, failure)
            vals.append(r.auroc_used)
        else:
            # multi-model engines take one local step per round: give them
            # the same TOTAL local-step budget (rounds x E), failure at
            # the same relative midpoint
            mm_rounds = rounds * prep.local_epochs
            failure = _failure(fail_kind, mm_rounds)
            cfg = MultiModelConfig(scheme=method, num_devices=10,
                                   num_models=min(prep.clusters, 3),
                                   rounds=mm_rounds,
                                   lr=prep.lr, seed=rep)
            r = run_multimodel(prep.ae_cfg, prep.device_x, prep.counts,
                               prep.test_x, prep.test_y, cfg, failure)
            vals.append(r.best_auroc)
            extra.append(r.multi_auroc)
    out = {"mean": float(np.mean(vals)), "std": float(np.std(vals))}
    if extra:
        out["multi_mean"] = float(np.mean(extra))
        out["multi_std"] = float(np.std(extra))
    return out


def run(reps: int = 2, rounds: int = ROUNDS, datasets=ALL) -> List[str]:
    lines = []
    single = ["tolfl", "fl", "batch"]
    multi = ["fedgroup", "ifca", "fesem"]
    for fail_kind, table in (("none", "Table III (no failure)"),
                             ("client", "Table IV (client failure)"),
                             ("server", "Table V (server failure)")):
        lines.append(f"# {table}, AUROC mean+-std over {reps} reps")
        hdr = ["dataset", "tolfl"]
        for m in multi:
            hdr += [f"{m}*", f"{m}+"]
        hdr += ["fl"]
        if fail_kind != "server":
            hdr += ["batch"]
        lines.append(",".join(hdr))
        for ds in datasets:
            t0 = time.time()
            row = [ds]
            c = run_cell(ds, "tolfl", fail_kind, reps, rounds)
            row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            for m in multi:
                c = run_cell(ds, m, fail_kind, reps, rounds)
                row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
                row.append(f"{c['multi_mean']:.3f}+-{c['multi_std']:.3f}")
            c = run_cell(ds, "fl", fail_kind, reps, rounds)
            row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            if fail_kind != "server":
                c = run_cell(ds, "batch", fail_kind, reps, rounds)
                row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            lines.append(",".join(row))
            print(lines[-1], f"({time.time()-t0:.0f}s)", flush=True)
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
