"""Tables III / IV / V: anomaly-detection AUROC without failure, with
client failure, and with server failure (at the training midpoint).

Columns mirror the paper: Tol-FL, FedGroup*/dagger, IFCA*/dagger,
FeSEM*/dagger, FL, Batch (Batch omitted for server failure, as in
Table V).  Results are mean +- std over ``reps`` seeds.

ONE declarative :class:`repro.api.ExperimentSpec` per dataset covers
all three tables: every scheme is a cell (single-model tolfl/fl/batch
plus the multi-model baselines), each with its per-cell canonical trace
list — batch drops the scenarios the tables never show (its "client"
column is its failure-free run, Table V omits it entirely) — and one
``execute`` fuses the grid per iso-tracking kind / per scheme.  The
seed's version compiled and ran every (scheme, failure, rep) cell
separately.  Randomness across reps comes from the simulation seed
(init/dropout); the dataset draw is fixed at seed 0 so all scenarios in
a batch share one data tensor.  The multi-model cells pass legacy
single-event ``FailureSpec``s, which the engine normalises with the
baseline default targets (client failure kills device N-1; see
:func:`repro.core.baselines.as_multimodel_trace`) — the Table IV
casualty device matches the seed's looped version.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from benchmarks.datasets import ALL, base_config, data_spec, prepare
from repro.api import (NO_FAILURE, CellSpec, ExperimentResult,
                       ExperimentSpec, FailureSpec, SeedSpec, mean_ci95,
                       run_experiment)

ROUNDS = 80
FAIL_KINDS = ("none", "client", "server")
SINGLE = ("tolfl", "fl", "batch")
MULTI = ("fedgroup", "ifca", "fesem")


def _failure(kind: str, rounds: int = ROUNDS) -> FailureSpec:
    if kind == "none":
        return NO_FAILURE
    return FailureSpec(epoch=rounds // 2, kind=kind)


def _stats(vals: Sequence[float]) -> Dict[str, float]:
    """Mean +- SAMPLE std (ddof=1) over seeds — ddof=0 under-reports
    the spread of small-rep campaigns (0 std for reps=1 is kept)."""
    mean, std, _ = mean_ci95(np.asarray(vals))
    return {"mean": mean, "std": std}


def _single_cell(prep, scheme: str, rounds: int,
                 kinds: Sequence[str] = FAIL_KINDS
                 ) -> Tuple[CellSpec, Dict[str, int]]:
    """One single-model cell + its fail-kind -> trace-index map.
    Identical conditions share scenarios (batch's client failure removes
    nothing — all data already sits on the server, the paper reports its
    failure-free run — so it aliases "none" instead of re-training
    duplicates)."""
    traces: List[FailureSpec] = []
    idx_of: Dict[tuple, int] = {}
    kind_idx: Dict[str, int] = {}
    for kind in kinds:
        spec = _failure(kind, rounds)
        if scheme == "batch" and kind == "client":
            spec = NO_FAILURE
        key = (spec.epoch, spec.kind, spec.device)
        if key not in idx_of:
            idx_of[key] = len(traces)
            traces.append(spec)
        kind_idx[kind] = idx_of[key]
    k = {"tolfl": prep.clusters, "fl": 1, "batch": 1}[scheme]
    return CellSpec(scheme, k, traces=tuple(traces)), kind_idx


def dataset_spec(dataset: str, reps: int, rounds: int = ROUNDS,
                 schemes: Sequence[str] = SINGLE + MULTI
                 ) -> Tuple[ExperimentSpec, Dict[str, Dict[str, int]]]:
    """(spec, {scheme: fail_kind -> trace index}) for one dataset's full
    table grid.  Multi-model cells inherit the single cells' total
    local-step budget (rounds x E) with failure at the same relative
    midpoint."""
    prep = prepare(dataset, seed=0)
    base = base_config(prep, rounds)
    mm_rounds = rounds * prep.local_epochs
    mm_traces = tuple(_failure(kind, mm_rounds) for kind in FAIL_KINDS)
    cells: List[CellSpec] = []
    kind_maps: Dict[str, Dict[str, int]] = {}
    for scheme in schemes:
        if scheme in MULTI:
            cells.append(CellSpec(scheme, min(prep.clusters, 3),
                                  traces=mm_traces))
            kind_maps[scheme] = {k: i for i, k in enumerate(FAIL_KINDS)}
        else:
            # the tables never show batch under server failure (Table V
            # omits it) — don't train those scenarios
            kinds = (("none", "client") if scheme == "batch"
                     else FAIL_KINDS)
            cell, kind_idx = _single_cell(prep, scheme, rounds, kinds)
            cells.append(cell)
            kind_maps[scheme] = kind_idx
    spec = ExperimentSpec(data=data_spec(prep), base=base,
                          cells=tuple(cells),
                          seeds=SeedSpec.range(reps))
    return spec, kind_maps


def _cell_stats(res: ExperimentResult, kind_maps
                ) -> Dict[Tuple[str, str], Dict[str, float]]:
    """{(scheme, fail_kind): stats} — multi cells add the dagger
    column's multi_mean/std."""
    out: Dict[Tuple[str, str], Dict[str, float]] = {}
    for cplan, cres in zip(res.plan.cells, res.results):
        scheme = cplan.cfg.scheme
        for kind, i in kind_maps[scheme].items():
            if scheme in MULTI:
                stats = _stats(cres.select(i, "best"))
                multi = _stats(cres.select(i, "multi"))
                stats["multi_mean"] = multi["mean"]
                stats["multi_std"] = multi["std"]
            else:
                stats = _stats(cres.select(i))
            out[(scheme, kind)] = stats
    return out


def run(reps: int = 2, rounds: int = ROUNDS, datasets=ALL) -> List[str]:
    # one spec -> one fused execute per dataset covers all three tables
    cells: Dict[tuple, Dict[str, float]] = {}
    for ds in datasets:
        spec, kind_maps = dataset_spec(ds, reps, rounds)
        t0 = time.time()
        res = run_experiment(spec)
        print(f"# experiment {ds}: {res.num_scenarios} scenarios in "
              f"{res.plan.num_dispatch_buckets} dispatch buckets, "
              f"{time.time()-t0:.0f}s", flush=True)
        for (scheme, kind), stats in _cell_stats(res, kind_maps).items():
            cells[(ds, scheme, kind)] = stats

    lines = []
    for fail_kind, table in (("none", "Table III (no failure)"),
                             ("client", "Table IV (client failure)"),
                             ("server", "Table V (server failure)")):
        lines.append(f"# {table}, AUROC mean+-std over {reps} reps")
        hdr = ["dataset", "tolfl"]
        for m in MULTI:
            hdr += [f"{m}*", f"{m}+"]
        hdr += ["fl"]
        if fail_kind != "server":
            hdr += ["batch"]
        lines.append(",".join(hdr))
        for ds in datasets:
            row = [ds]
            c = cells[(ds, "tolfl", fail_kind)]
            row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            for m in MULTI:
                c = cells[(ds, m, fail_kind)]
                row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
                row.append(f"{c['multi_mean']:.3f}+-{c['multi_std']:.3f}")
            c = cells[(ds, "fl", fail_kind)]
            row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            if fail_kind != "server":
                c = cells[(ds, "batch", fail_kind)]
                row.append(f"{c['mean']:.3f}+-{c['std']:.3f}")
            lines.append(",".join(row))
    return lines


def run_smoke(rounds: int = 8, reps: int = 2) -> List[str]:
    """CI micro-campaigns: one declarative spec — a batched
    (3 traces x reps seeds) Tol-FL cell plus a multi-model (IFCA) cell
    on a small Comms-ML draw; seconds, not minutes."""
    prep = prepare("commsml", seed=0, scale=0.25)
    traces = tuple(_failure(kind, rounds) for kind in FAIL_KINDS)
    spec = ExperimentSpec(
        data=data_spec(prep),
        base=base_config(prep, rounds, local_epochs=1),
        cells=(CellSpec("tolfl", prep.clusters, traces=traces),
               CellSpec("ifca", 2, traces=traces)),
        seeds=SeedSpec.range(reps))
    t0 = time.time()
    res = run_experiment(spec)
    tolfl, ifca = res.results
    s = tolfl.summary()
    lines = [f"# smoke micro-experiment: {res.num_scenarios} scenarios, "
             f"{res.plan.num_dispatch_buckets} dispatch buckets, "
             f"{time.time()-t0:.1f}s",
             "fail_kind,auroc_mean,auroc_std"]
    for i, kind in enumerate(FAIL_KINDS):
        v = tolfl.select(i)
        lines.append(f"{kind},{v.mean():.3f},{v.std():.3f}")
    lines.append(f"overall,{s['auroc_used_mean']:.3f},"
                 f"{s['auroc_used_std']:.3f}")
    assert np.isfinite(tolfl.auroc_used).all(), "smoke produced NaN"

    lines.append("fail_kind,best_auroc_mean,multi_auroc_mean")
    for i, kind in enumerate(FAIL_KINDS):
        lines.append(f"{kind},{ifca.select(i, 'best').mean():.3f},"
                     f"{ifca.select(i, 'multi').mean():.3f}")
    assert np.isfinite(ifca.best_auroc).all(), "multi smoke produced NaN"
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
