"""Figure 4: worst-case training curves after server failure.

FL (k=1) server death -> remaining N-1 devices train isolated (their mean
test loss is reported); SBT (k=N) loses one device and keeps training
collaboratively.  Emits the two loss curves as CSV.

Driven by the batched campaign engine: each scheme's scenario batch
(here a single server-failure trace) is one compiled call, and the
per-scenario loss / isolated-loss curves come back stacked.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.datasets import prepare
from repro.core.campaign import run_campaign
from repro.core.failure import FailureSpec
from repro.core.simulate import SimConfig

ROUNDS = 80
FAIL_AT = 20


def run(dataset: str = "fmnist", rounds: int = ROUNDS) -> List[str]:
    prep = prepare(dataset)
    failure = FailureSpec(epoch=FAIL_AT, kind="server")
    out = {}
    for scheme in ("fl", "sbt"):
        cfg = SimConfig(scheme=scheme, num_devices=10, rounds=rounds,
                        lr=prep.lr, local_epochs=prep.local_epochs)
        res = run_campaign(prep.ae_cfg, prep.device_x, prep.counts,
                           prep.test_x, prep.test_y, cfg, [failure],
                           seeds=[0])
        # the reported loss curve already carries Fig 4 semantics: for
        # fl the server-dead rounds hold the isolated devices' mean loss
        out[scheme] = (res.loss_curves[0], float(res.auroc_used[0]))
    lines = [f"# Fig 4: server failure at round {FAIL_AT} ({dataset}); "
             f"final AUROC: fl={out['fl'][1]:.3f} sbt={out['sbt'][1]:.3f}",
             "round,fl_isolated_loss,sbt_collaborative_loss"]
    for t in range(rounds):
        lines.append(f"{t},{out['fl'][0][t]:.4f},{out['sbt'][0][t]:.4f}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
