"""Figure 4: worst-case training curves after server failure.

FL (k=1) server death -> remaining N-1 devices train isolated (their mean
test loss is reported); SBT (k=N) loses one device and keeps training
collaboratively.  Emits the two loss curves as CSV.

One declarative :class:`repro.api.ExperimentSpec` covers both schemes:
the fl and sbt cells share the single server-failure condition and run
through the spec -> plan -> execute pipeline (the fl cell's
isolated-fallback branch dispatches in its own bucket), and the
per-scenario loss curves come back stacked per cell.
"""
from __future__ import annotations

from typing import List

from benchmarks.datasets import base_config, data_spec, prepare
from repro.api import (CellSpec, ExperimentSpec, FailureSpec, SeedSpec,
                       TraceSpec, run_experiment)

ROUNDS = 80
FAIL_AT = 20


def run(dataset: str = "fmnist", rounds: int = ROUNDS) -> List[str]:
    prep = prepare(dataset)
    spec = ExperimentSpec(
        data=data_spec(prep),
        base=base_config(prep, rounds),
        cells=(CellSpec("fl", 1), CellSpec("sbt", 10)),
        traces=TraceSpec.explicit(FailureSpec(epoch=FAIL_AT,
                                              kind="server")),
        seeds=SeedSpec((0,)))
    res = run_experiment(spec)
    # the reported loss curve already carries Fig 4 semantics: for fl
    # the server-dead rounds hold the isolated devices' mean loss
    out = {scheme: (res[(scheme, k)].loss_curves[0],
                    float(res[(scheme, k)].auroc_used[0]))
           for scheme, k in (("fl", 1), ("sbt", 10))}
    lines = [f"# Fig 4: server failure at round {FAIL_AT} ({dataset}); "
             f"final AUROC: fl={out['fl'][1]:.3f} sbt={out['sbt'][1]:.3f}",
             "round,fl_isolated_loss,sbt_collaborative_loss"]
    for t in range(rounds):
        lines.append(f"{t},{out['fl'][0][t]:.4f},{out['sbt'][0][t]:.4f}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
