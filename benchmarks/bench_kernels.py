"""Kernel micro-benchmarks: us_per_call of the Pallas kernels (interpret
mode on CPU — correctness-representative, not TPU timings) vs the XLA
reference path, plus allclose deltas."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(f, *args, n: int = 3) -> float:
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run() -> List[str]:
    key = jax.random.PRNGKey(0)
    lines = ["# Pallas kernels: interpret-mode parity + XLA path timing",
             "name,us_per_call_xla,maxerr_pallas_vs_ref"]
    B, S, H, KVH, D = 2, 256, 4, 2, 64
    mk = lambda i, sh: jax.random.normal(jax.random.fold_in(key, i), sh)  # noqa
    q, k, v = mk(1, (B, S, H, D)), mk(2, (B, S, KVH, D)), mk(3, (B, S, KVH, D))
    t = _time(lambda *a: ops.attention(*a, backend="xla"), q, k, v)
    err = float(jnp.max(jnp.abs(
        ops.attention(q, k, v, backend="pallas")
        - ref.attention_reference(q, k, v))))
    lines.append(f"flash_attention,{t:.1f},{err:.2e}")

    N = 16
    r_, k_, v_ = mk(4, (B, S, 2, N)), mk(5, (B, S, 2, N)), mk(6, (B, S, 2, N))
    w_ = jax.nn.sigmoid(mk(7, (B, S, 2, N)))
    u_ = mk(8, (2, N)) * 0.1
    s0 = mk(9, (B, 2, N, N)) * 0.1
    t = _time(lambda *a: ops.rwkv6(*a, backend="xla")[0], r_, k_, v_, w_, u_, s0)
    y1, _ = ops.rwkv6(r_, k_, v_, w_, u_, s0, backend="pallas")
    y2, _ = ref.rwkv6_reference(r_, k_, v_, w_, u_, s0)
    lines.append(f"rwkv6_scan,{t:.1f},{float(jnp.max(jnp.abs(y1-y2))):.2e}")

    W = 64
    a_, b_ = jax.nn.sigmoid(mk(10, (B, S, W))), mk(11, (B, S, W))
    t = _time(lambda *x: ops.rglru(*x, backend="xla"), a_, b_)
    h1 = ops.rglru(a_, b_, backend="pallas")
    h2 = ref.rglru_reference(a_, b_)
    lines.append(f"rglru_scan,{t:.1f},{float(jnp.max(jnp.abs(h1-h2))):.2e}")

    gs, ns = mk(12, (5, 4096)), jnp.abs(mk(13, (5,))) * 10
    t = _time(lambda *x: ops.tolfl_combine(*x, backend="xla"), gs, ns)
    o1 = ops.tolfl_combine(gs, ns, backend="pallas")
    o2 = ref.tolfl_combine_reference(gs, ns)
    lines.append(f"tolfl_combine,{t:.1f},{float(jnp.max(jnp.abs(o1-o2))):.2e}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
