"""Section-Roofline table: aggregate the dry-run JSON records into the
per-(arch x shape x mesh) three-term roofline table (deliverable g)."""
from __future__ import annotations

import glob
import json
from typing import List



def run(pattern: str = "results/dryrun/*.json") -> List[str]:
    lines = ["# Roofline terms per (arch x shape x mesh); analytic model "
             "(HLO raw kept in the JSONs; see costmodel.py for why).",
             "# variant: 'baseline' or the section-Perf optimised records "
             "(filenames tagged __opt).",
             "arch,shape,mesh,variant,mode,t_compute_s,t_memory_s,"
             "t_collective_s,bottleneck,useful_flops_ratio,mem_per_dev_GB,"
             "status"]
    recs = []
    for p in sorted(glob.glob(pattern)):
        with open(p) as f:
            r = json.load(f)
        r["_variant"] = "opt" if "__opt" in p else "baseline"
        recs.append(r)
    for r in recs:
        v = r["_variant"]
        if r.get("status") == "skipped":
            lines.append(f"{r['arch']},{r['shape']},{r['mesh']},{v},"
                         f"{r.get('mode','')},,,,,,,"
                         f"skipped({r.get('reason','')[:40]})")
            continue
        if r.get("status") != "ok":
            lines.append(f"{r['arch']},{r['shape']},{r['mesh']},{v},"
                         f"{r.get('mode','')},,,,,,,error")
            continue
        rl = r["roofline"]
        mem = rl.get("memory_per_device")
        mem_gb = f"{mem/1e9:.2f}" if mem else ""
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{v},{r['mode']},"
            f"{rl['t_compute']:.3e},{rl['t_memory']:.3e},"
            f"{rl['t_collective']:.3e},{rl['bottleneck']},"
            f"{rl['useful_flops_ratio']:.3f},{mem_gb},ok")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
