"""Anomaly-scoring-service throughput under failure injection.

Tracks the serving layer's perf trajectory (``BENCH_serve.json``,
committed next to ``BENCH_campaign.json``):

* ``direct_bs64`` — the lower bound the service must track: one jitted
  ``det.anomaly_scores`` call on the same rows a full 64-window bucket
  carries (``(64 * W, D)``), best-of-reps windows/sec.
* ``service_bs64`` — the warm service streaming exactly 64 windows per
  tick with nothing failing: full path (submit queue -> coalesce ->
  routed gather -> compiled bucket).  The win condition the ISSUE pins:
  within 10% of ``direct_bs64`` (``ratio_vs_direct >= 0.9``) — the
  batching/routing/queue machinery must be overhead-free at the big
  bucket.
* ``service_iid`` / ``service_markov`` / ``service_cascade`` — the same
  streaming load while a sampled :class:`FailureProcess` of each family
  drives service-time liveness: windows/sec + p50/p99 latency +
  failover/failback counts.  A dead head fans its cluster's windows out
  of the shared global bucket into one isolated-model bucket per client
  — real extra model evaluations — so these rows may run slower, but
  must stay within 2x of the clean row (and ZERO windows drop,
  asserted).

The bank trains once (a tiny Tol-FL run); every service row re-stands
the service from the in-process executable cache, so rows measure
serving, not compilation.  Like ``bench_campaign``, the whole bench
runs against a throwaway persistent-cache directory and restores the
prior wiring on exit.
"""
from __future__ import annotations

import json
import shutil
import tempfile
import time
from typing import List

import jax
import numpy as np

from benchmarks.datasets import prepare
from repro.core import compilecache
from repro.core.processes import (ClusterCascadeProcess, IidRateProcess,
                                  MarkovChurnProcess)
from repro.core.simulate import SimConfig
from repro.serving.anomaly import (AnomalyService, ServiceConfig,
                                   train_model_bank)

WINDOW = 32          # rows per traffic window (big enough that compute,
                     # not submit-loop python, dominates a 64-bucket)
ROUNDS = 6           # bank-training rounds (the bench measures serving)
TICKS = 24           # service ticks per measured rep
REPS = 3             # best-of (timeit convention; see bench_campaign)

#: the failure injections the service rows stream under
PROCESSES = {
    "service_iid": IidRateProcess(p=0.4),
    "service_markov": MarkovChurnProcess(p_fail=0.15, p_recover=0.3),
    "service_cascade": ClusterCascadeProcess(p_head=1.0, recover_prob=1.0,
                                             recovery_lag=6),
}


def _window_pool(prep) -> np.ndarray:
    tx = np.asarray(prep.test_x, np.float32)
    n = tx.shape[0] // WINDOW
    return tx[:n * WINDOW].reshape(n, WINDOW, tx.shape[-1])


def _time_direct(bank, wins, results, lines) -> float:
    """Best-of-reps windows/sec of the raw batched score call on the
    rows one 64-window bucket carries."""
    det = bank.detector
    jitted = jax.jit(det.anomaly_scores)
    flat = np.ascontiguousarray(
        wins[np.arange(64) % wins.shape[0]].reshape(64 * WINDOW, -1))
    jitted(bank.global_params, flat).block_until_ready()   # warm
    walls = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        for _ in range(TICKS):
            jitted(bank.global_params, flat).block_until_ready()
        walls.append(time.perf_counter() - t0)
    wall = min(walls)
    wps = 64 * TICKS / wall
    results["direct_bs64"] = {"windows": 64 * TICKS,
                              "wall_s": round(wall, 3),
                              "windows_per_s": round(wps, 1)}
    lines.append(f"direct_bs64,{64 * TICKS},{wall:.3f},{wps:.0f},-,-,0,0")
    return wps


def _time_service(label, bank, wins, failure, results, lines,
                  direct_wps) -> None:
    """Best-of-reps sustained windows/sec of the full service path
    streaming 64 windows per tick (fresh service per rep — executables
    resolve from the in-process memory cache, so reps measure serving)."""
    best = None
    for _ in range(REPS):
        svc = AnomalyService(bank, ServiceConfig(bucket_sizes=(1, 8, 64),
                                                 window=WINDOW),
                             failure=failure, sample_seed=3,
                             horizon=TICKS)
        n_win = wins.shape[0]
        t0 = time.perf_counter()
        for t in range(TICKS):
            for j in range(64):
                c = j % bank.num_clients
                svc.submit(c, wins[(t * 64 + j) % n_win])
            svc.tick()
        wall = time.perf_counter() - t0
        rep = svc.report()
        assert rep.dropped == 0, (label, rep)
        if best is None or wall < best[0]:
            best = (wall, rep)
    wall, rep = best
    wps = rep.windows / wall
    row = {"windows": rep.windows, "wall_s": round(wall, 3),
           "windows_per_s": round(wps, 1),
           "p50_ms": round(rep.p50_ms, 3), "p99_ms": round(rep.p99_ms, 3),
           "failovers": rep.failovers, "failbacks": rep.failbacks}
    if failure is None:
        row["ratio_vs_direct"] = round(wps / direct_wps, 3)
    results[label] = row
    lines.append(f"{label},{rep.windows},{wall:.3f},{wps:.0f},"
                 f"{rep.p50_ms:.2f},{rep.p99_ms:.2f},"
                 f"{rep.failovers},{rep.failbacks}")


def run(out_path: str = "BENCH_serve.json") -> List[str]:
    prev_dir = compilecache.persistent_cache_dir()
    cache_dir = tempfile.mkdtemp(prefix="bench-serve-cache-")
    compilecache.enable_persistent_cache(cache_dir)
    try:
        return _run_rows(out_path)
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
        if prev_dir is not None:
            compilecache.enable_persistent_cache(prev_dir)
        else:
            compilecache.disable_persistent_cache()


def _run_rows(out_path: str) -> List[str]:
    prep = prepare("commsml", seed=0, scale=0.25)
    cfg = SimConfig(scheme="tolfl", num_devices=10,
                    num_clusters=prep.clusters, rounds=ROUNDS,
                    lr=prep.lr, local_epochs=1, dropout=False)
    t0 = time.perf_counter()
    bank = train_model_bank(prep.ae_cfg, prep.device_x, prep.counts, cfg)
    train_wall = time.perf_counter() - t0
    wins = _window_pool(prep)

    lines = ["name,windows,wall_s,windows_per_s,p50_ms,p99_ms,"
             "failovers,failbacks"]
    results: dict = {"train_bank": {"wall_s": round(train_wall, 3)}}

    direct_wps = _time_direct(bank, wins, results, lines)
    _time_service("service_bs64", bank, wins, None, results, lines,
                  direct_wps)
    for label, proc in PROCESSES.items():
        _time_service(label, bank, wins, proc, results, lines, direct_wps)

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    lines.append(f"# wrote {out_path}")

    # the ISSUE win condition: warm service within 10% of the direct
    # batched score call at the 64-bucket
    ratio = results["service_bs64"]["ratio_vs_direct"]
    assert ratio >= 0.9, (ratio, results["service_bs64"],
                          results["direct_bs64"])
    # injection must actually exercise the failover path...
    assert results["service_cascade"]["failovers"] > 0, \
        results["service_cascade"]
    # ...and keep sustained throughput within 2x of the clean row.
    # Failover is REAL extra work, not routing overhead: a dead head
    # fans its cluster's windows out of the shared global bucket into
    # one isolated-model bucket PER CLIENT (distinct weights can't
    # share a dispatch), so some slowdown is the cost of the models,
    # bounded here so a host-path regression still trips.
    for label in PROCESSES:
        assert (results[label]["windows_per_s"]
                >= 0.5 * results["service_bs64"]["windows_per_s"]), \
            (label, results[label], results["service_bs64"])
    return lines


#: ``benchmarks.run --smoke`` entry point (same rows; the bench is
#: already seconds-scale, so smoke IS the committed-baseline config)
run_smoke = run


if __name__ == "__main__":
    print("\n".join(run()))
