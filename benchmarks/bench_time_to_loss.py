"""Figure 5: epochs + modelled wall-clock to reach centralised training's
converged loss (within 5%).

Batch training establishes the target loss; each scheme reports the number
of rounds to get within 5% of it and the modelled wall-clock (paper
Section IV-A task-sequencing model: parallel stages max, sequential sum).
"""
from __future__ import annotations

from typing import List

import jax

from benchmarks.datasets import prepare
from repro.core.simulate import (SimConfig, round_time_model,
                                 run_simulation)
from repro.models import autoencoder as AE
from repro.models.params import param_bytes, param_count

ROUNDS = 120


def run(dataset: str = "commsml", rounds: int = ROUNDS) -> List[str]:
    prep = prepare(dataset)
    base = SimConfig(scheme="batch", num_devices=10, rounds=rounds,
                     lr=prep.lr, local_epochs=prep.local_epochs, seed=0)
    rb = run_simulation(prep.ae_cfg, prep.device_x, prep.counts,
                        prep.test_x, prep.test_y, base)
    target = float(rb.loss_curve[-1]) * 1.05

    params, _ = AE.init_params(jax.random.PRNGKey(0), prep.ae_cfg)
    pbytes = param_bytes(params)
    flops_per_sample = 6 * param_count(params)
    samples = int(prep.counts.sum())

    lines = [f"# Fig 5: rounds & modelled wall-clock to reach batch loss "
             f"x1.05 = {target:.1f} ({dataset})",
             "method,rounds_to_loss,sec_per_round,wallclock_s"]
    for scheme in ("batch", "fl", "tolfl", "sbt"):
        cfg = SimConfig(scheme=scheme, num_devices=10,
                        num_clusters=prep.clusters, rounds=rounds,
                        lr=prep.lr, local_epochs=prep.local_epochs, seed=0)
        r = run_simulation(prep.ae_cfg, prep.device_x, prep.counts,
                           prep.test_x, prep.test_y, cfg,
                           target_loss=target)
        spr = round_time_model(scheme, 10, prep.clusters, samples, pbytes,
                               flops_per_sample)
        n = r.rounds_to_loss if r.rounds_to_loss else rounds
        lines.append(f"{scheme},{n},{spr:.4f},{n * spr:.2f}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
