"""Dataset preparation shared by the paper-table benchmarks.

Four datasets mirroring Table VII (synthetic stand-ins; see
repro/data/reference.py for why magnitudes differ while orderings hold):
Comms-ML (112-d, 4 classes, 2 anomalous), FMNIST-like (784-d),
CIFAR10-like / CIFAR100-like (3072-d).  One-class-per-cluster layout,
N=10 devices.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from repro.configs.autoencoder_paper import (AutoencoderConfig, CIFAR10,
                                             CIFAR100, COMMSML, FMNIST)
from repro.core.experiment import DataSpec
from repro.core.simulate import SimConfig
from repro.data import commsml, federated, reference

N_DEVICES = 10


def _equalize_scale(X: np.ndarray) -> np.ndarray:
    """Scale high-dim datasets so the summed-square loss (and thus the
    SGD gradient magnitude) matches the 112-dim Comms-ML baseline;
    anomaly scores are scaled by a positive constant, so AUROC — the
    paper's metric — is invariant.  Keeps one lr stable across Table VII
    dimensionalities (the paper tunes per dataset; we document this
    instead)."""
    return X * np.sqrt(112.0 / X.shape[1])


@dataclass
class Prepared:
    name: str
    ae_cfg: AutoencoderConfig
    device_x: np.ndarray
    counts: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray
    clusters: int           # natural k for this dataset
    lr: float = 1e-3
    local_epochs: int = 5   # E local steps per round (paper Table I)


@functools.lru_cache(maxsize=None)
def prepare(name: str, seed: int = 0, scale: float = 1.0) -> Prepared:
    if name == "commsml":
        X, y = commsml.generate(seed=seed,
                                samples_per_class=int(800 * scale))
        split = federated.make_split(X, y, N_DEVICES, num_clusters=2,
                                     anomaly_classes=[2, 3], seed=seed)
        dx, cnt = federated.pad_devices(split)
        # lr/E validated for stability (oscillation <2% of loss) and
        # k-invariance visibility: tolfl==fl==0.923 failure-free
        return Prepared(name, COMMSML, dx, cnt, split.test_x, split.test_y,
                        clusters=2, lr=1e-4, local_epochs=3)
    if name == "fmnist":
        X, y = reference.generate("fmnist", seed=seed,
                                  samples_per_class=int(400 * scale))
        split = federated.make_split(X, y, N_DEVICES, num_clusters=5,
                                     anomaly_classes=[8, 9], seed=seed)
        dx, cnt = federated.pad_devices(split)
        return Prepared(name, FMNIST, dx, cnt, split.test_x, split.test_y,
                        clusters=5)
    if name == "cifar10":
        X, y = reference.generate("cifar10", seed=seed,
                                  samples_per_class=int(150 * scale))
        X = _equalize_scale(X)
        split = federated.make_split(X, y, N_DEVICES, num_clusters=5,
                                     anomaly_classes=[8, 9], seed=seed)
        dx, cnt = federated.pad_devices(split)
        return Prepared(name, CIFAR10, dx, cnt, split.test_x, split.test_y,
                        clusters=5)
    if name == "cifar100":
        X, y = reference.generate("cifar100", seed=seed,
                                  samples_per_class=int(20 * scale))
        X = _equalize_scale(X)
        anom = list(range(90, 100))
        split = federated.make_split(X, y, N_DEVICES, num_clusters=5,
                                     anomaly_classes=anom, seed=seed)
        dx, cnt = federated.pad_devices(split)
        return Prepared(name, CIFAR100, dx, cnt, split.test_x, split.test_y,
                        clusters=5)
    raise KeyError(name)


ALL = ("commsml", "fmnist", "cifar10", "cifar100")


def data_spec(prep: Prepared) -> DataSpec:
    """The :class:`repro.core.experiment.DataSpec` of a prepared
    dataset — the one arrays-plus-config bundle every paper-table bench
    used to rebuild by hand as a 5-tuple."""
    return DataSpec(model=prep.ae_cfg, device_x=prep.device_x,
                    device_counts=prep.counts, test_x=prep.test_x,
                    test_y=prep.test_y, name=prep.name)


def base_config(prep: Prepared, rounds: int, scheme: str = "tolfl",
                **overrides) -> SimConfig:
    """The dataset's canonical base :class:`SimConfig` (N=10 devices,
    the dataset's natural k / validated lr / paper local-epoch budget)
    — the second half of the prep boilerplate the benches shared."""
    kw = dict(scheme=scheme, num_devices=N_DEVICES,
              num_clusters=prep.clusters, rounds=rounds, lr=prep.lr,
              local_epochs=prep.local_epochs)
    kw.update(overrides)
    return SimConfig(**kw)
