"""HLO collective profiler — the dry-run 'profile' for the perf loop.

Lowers one (arch x shape x mesh [x schedule x clusters]) combination and
prints the top-N collective ops by payload bytes, with dtype and shape, so
each hillclimb iteration can see exactly which transfer dominates and
whether a change moved it.

Usage:
  PYTHONPATH=src python -m benchmarks.profile_collectives \
      --arch qwen3-8b --shape train_4k [--schedule ring] [--clusters 4] \
      [--top 12] [--microbatches 1] [--grad-dtype float32]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import collections
import re
import sys

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)


def top_collectives(hlo: str, n: int = 12):
    from repro.analysis.roofline import _shape_bytes
    rows = []
    for m in _OP_RE.finditer(hlo):
        shape_str, kind = m.group(1), m.group(2)
        rows.append((_shape_bytes(shape_str), kind, shape_str[:70]))
    rows.sort(reverse=True)
    agg = collections.Counter()
    for b, kind, _ in rows:
        agg[kind] += b
    return rows[:n], agg, len(rows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--schedule", default="auto",
                    choices=["auto", "ring", "psum"])
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--grad-dtype", default=None, dest="grad_dtype")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--param-cast", default=None, dest="param_cast")
    args = ap.parse_args(argv)

    import jax
    from repro.configs import ARCHS, INPUT_SHAPES, OptimizerConfig, \
        TolFLConfig
    from repro.core import distributed as D
    from repro.launch import specs as SP
    from repro.launch.dryrun import (BF16_STATE_ARCHS, FSDP_ARCHS,
                                     pick_schedule, rules_for_arch)
    from repro.launch.mesh import make_production_mesh
    from repro.serving.decode import decode_step, prefill
    from repro.sharding import logical as L

    cfg = ARCHS[args.arch]
    shape = INPUT_SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    rules = rules_for_arch(args.arch)

    with L.activate_mesh(mesh, rules):
        if shape.mode == "train":
            sched = pick_schedule(args.arch, args.schedule)
            tolfl = TolFLConfig(num_clusters=args.clusters, schedule=sched,
                                grad_sync_dtype=args.grad_dtype,
                                microbatches=args.microbatches,
                                param_cast_dtype=args.param_cast)
            ocfg = OptimizerConfig()
            sdt = "bfloat16" if args.arch in BF16_STATE_ARCHS else None
            step = D.make_train_step(cfg, tolfl, ocfg, mesh, state_dtype=sdt)
            state = SP.state_specs(cfg, ocfg, mesh, rules)
            batch = SP.train_batch_specs(cfg, shape, mesh, rules)
            alive = SP.alive_spec(mesh)
            lowered = jax.jit(step).lower(state, batch, alive)
        elif shape.mode == "prefill":
            batch = SP.prefill_specs(cfg, shape, mesh, rules)
            params = SP.params_specs(cfg, mesh, rules)
            lowered = jax.jit(
                lambda p, b: prefill(p, cfg, b)).lower(params, batch)
        else:
            d = SP.decode_specs(cfg, shape, mesh, rules,
                                long_context=args.shape == "long_500k")
            params = SP.params_specs(cfg, mesh, rules)
            lowered = jax.jit(
                lambda p, t, c, pos: decode_step(p, cfg, t, c, pos)).lower(
                    params, d["tokens"], d["cache"], d["position"])
        compiled = lowered.compile()

    hlo = compiled.as_text()
    rows, agg, n_ops = top_collectives(hlo, args.top)
    print(f"# {args.arch} x {args.shape} x {args.mesh} "
          f"schedule={args.schedule} clusters={args.clusters}")
    print(f"# {n_ops} collective ops; totals per kind:")
    for kind, b in agg.most_common():
        print(f"#   {kind:<22} {b / 1e9:8.2f} GB")
    print(f"# top {args.top} ops:")
    for b, kind, shape_str in rows:
        print(f"  {b / 1e9:8.3f} GB  {kind:<20} {shape_str}")
    mem = compiled.memory_analysis()
    print(f"# temp memory: {mem.temp_size_in_bytes / 1e9:.2f} GB; "
          f"args {mem.argument_size_in_bytes / 1e9:.2f} GB")
    cost = compiled.cost_analysis()
    print(f"# HLO flops {cost.get('flops', 0):.3e} "
          f"bytes {cost.get('bytes accessed', 0):.3e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
