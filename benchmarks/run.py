"""Benchmark harness entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]``

Prints CSV blocks (name,us_per_call,derived per the repo convention, plus
the paper tables).  The failure-AUROC tables dominate runtime; --quick
cuts reps/rounds for smoke purposes.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer reps/rounds (CI smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI path: one batched micro-"
                         "campaign (scripts/ci.sh)")
    ap.add_argument("--only", default=None,
                    help="run a single bench: kernels|roofline|comm|"
                         "curves|time|expected|auroc|campaign|serve")
    ap.add_argument("--shard", action="store_true",
                    help="campaign bench: shard scenario batches across "
                         "local JAX devices (ExecPlan(shard=True))")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="campaign bench: host-side scenario chunk size "
                         "(ExecPlan(chunk_size=...))")
    args = ap.parse_args(argv)

    t_all = time.time()
    sections = []

    if args.smoke:
        from benchmarks import (bench_campaign, bench_expected_perf,
                                bench_failure_auroc, bench_serve)
        lines = bench_failure_auroc.run_smoke()
        print("\n===== smoke: batched failure micro-campaigns =====")
        print("\n".join(lines))
        lines = bench_expected_perf.run_smoke()
        print("\n===== smoke: sampled failure-rate micro-sweep =====")
        print("\n".join(lines))
        lines = bench_campaign.run(shard=args.shard,
                                   chunk_size=args.chunk_size)
        print("\n===== smoke: campaign exec layer (BENCH_campaign.json)"
              " =====")
        print("\n".join(lines))
        lines = bench_serve.run_smoke()
        print("\n===== smoke: anomaly scoring service (BENCH_serve.json)"
              " =====")
        print("\n".join(lines))
        print(f"\nsmoke done in {time.time()-t_all:.0f}s")
        return 0

    def want(name):
        return args.only in (None, name)

    if want("campaign"):
        from benchmarks import bench_campaign
        sections.append(("campaign exec layer (BENCH_campaign.json)",
                         bench_campaign.run(shard=args.shard,
                                            chunk_size=args.chunk_size)))

    if want("serve"):
        from benchmarks import bench_serve
        sections.append(("anomaly scoring service (BENCH_serve.json)",
                         bench_serve.run()))
    if want("kernels"):
        from benchmarks import bench_kernels
        sections.append(("kernels (interpret parity + xla timing)",
                         bench_kernels.run()))
    if want("roofline"):
        from benchmarks import bench_roofline
        sections.append(("roofline table (from dry-run records)",
                         bench_roofline.run()))
    if want("comm"):
        from benchmarks import bench_comm_cost
        sections.append(("Table VI comm cost", bench_comm_cost.run()))
    if want("curves"):
        from benchmarks import bench_failure_curves
        sections.append(("Fig 4 server-failure curves",
                         bench_failure_curves.run(
                             rounds=30 if args.quick else 80)))
    if want("time"):
        from benchmarks import bench_time_to_loss
        sections.append(("Fig 5 time-to-loss",
                         bench_time_to_loss.run(
                             rounds=40 if args.quick else 120)))
    if want("expected"):
        from benchmarks import bench_expected_perf
        sections.append(("Section IV-B expected performance vs p(fail)",
                         bench_expected_perf.run(
                             rounds=30 if args.quick else 60)))
    if want("auroc"):
        from benchmarks import bench_failure_auroc
        # single-core CPU container: 1 rep x 60 rounds keeps the full
        # 3-tables x 4-datasets sweep under an hour; bump for more seeds
        reps = 1
        rounds = 30 if args.quick else 60
        datasets = ("commsml",) if args.quick else \
            ("commsml", "fmnist", "cifar10", "cifar100")
        sections.append(("Tables III/IV/V failure AUROC",
                         bench_failure_auroc.run(reps=reps, rounds=rounds,
                                                 datasets=datasets)))

    for title, lines in sections:
        print(f"\n===== {title} =====")
        print("\n".join(lines))
    print(f"\nall benchmarks done in {time.time()-t_all:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
