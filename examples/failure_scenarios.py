"""Failure-rate sweep: the paper's robustness story, Monte-Carlo style.

ONE declarative :class:`repro.api.ExperimentSpec` describes the whole
study: every scheme is a cell — the single-model schemes (Tol-FL / FL /
SBT / Batch) and the multi-model baselines (FedGroup / IFCA / FeSEM) —
crossed with a :class:`TraceSpec` holding the canonical
no/client/server-failure conditions (Tables III/IV/V in miniature) AND
sampled multi-event failure-and-recovery grids at increasing per-device
failure rates (paper Section IV-B's expected performance E[AUROC](p)).

``plan(spec)`` lowers that to dispatch buckets — the trace grids are
sampled per TOPOLOGY (a tolfl head is a plain client under fl; batch
has no clients at all, so its client column is n/a), identical draws
are deduplicated, and the non-batch single-model cells fuse per
iso-tracking kind: tolfl and sbt share literally ONE jitted/vmapped
call over the flattened (scheme x trace x seed) axis.  ``execute``
runs the buckets; the per-draw result mapping comes back on the plan.

Run:  PYTHONPATH=src python examples/failure_scenarios.py [--rounds 60]
      PYTHONPATH=src python examples/failure_scenarios.py --smoke
      PYTHONPATH=src python examples/failure_scenarios.py \
          --process {iid,markov,cascade,straggler,faulty,all}
The --process path swaps the canonical/rate tables for generative
failure-process studies (repro.core.processes): one E[AUROC] table per
family, one intensity column per ProcessGrid.
The --smoke path (CI) shrinks the grid to seconds-scale and prints the
execution plan before running it.
"""
import argparse

import numpy as np

from repro.api import (FAMILIES, NO_FAILURE, AutoencoderConfig, CellSpec,
                       DataSpec, ExecPlan, ExperimentSpec, FailureSpec,
                       ProcessGrid, SeedSpec, SimConfig, TraceSpec, execute,
                       family_process, mean_ci95, plan)
from repro.data import commsml, federated

SINGLE = [("Tol-FL", "tolfl", 5), ("FL", "fl", 1), ("SBT", "sbt", 10),
          ("Batch", "batch", 1)]
MULTI = ["fedgroup", "ifca", "fesem"]
P_GRID = (0.05, 0.2, 0.4)


COL = 21


def fmt(vals):
    mean, std, _ = mean_ci95(np.asarray(vals))
    return f"{f'{mean:.3f} +- {std:.3f}':<{COL}}"


def build_spec(args, p_grid):
    """The whole study as one spec; returns (spec, canonical labels)."""
    singles = [c for c in SINGLE if c[1] in args.single]
    X, y = commsml.generate(seed=0, samples_per_class=args.samples)
    split = federated.make_split(X, y, args.devices, 5,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)

    canonical = [
        ("no failure", NO_FAILURE),
        ("client fail", FailureSpec(epoch=args.rounds // 4,
                                    kind="client")),
        ("server fail", FailureSpec(epoch=args.rounds // 4,
                                    kind="server")),
    ]
    spec = ExperimentSpec(
        data=DataSpec(model=AutoencoderConfig(), device_x=dx,
                      device_counts=counts, test_x=split.test_x,
                      test_y=split.test_y, name="commsml"),
        base=SimConfig(num_devices=args.devices, rounds=args.rounds,
                       lr=1e-3),
        cells=(tuple(CellSpec(s, k) for _, s, k in singles)
               + tuple(CellSpec(m, args.multi_k) for m in args.multi)),
        traces=TraceSpec(traces=tuple(f for _, f in canonical),
                         p_grid=tuple(p_grid),
                         traces_per_p=args.traces_per_p),
        seeds=SeedSpec.range(args.seeds),
        exec_plan=ExecPlan(shard=args.shard, chunk_size=args.chunk_size))
    return spec, canonical


def build_process_spec(args, families, intensities):
    """One generative-process study per listed family, as ONE spec per
    family (the one-spec-per-study pattern): the schemes crossed with a
    ProcessGrid per intensity of the family's canonical process."""
    singles = [c for c in SINGLE if c[1] in args.single]
    X, y = commsml.generate(seed=0, samples_per_class=args.samples)
    split = federated.make_split(X, y, args.devices, 5,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    data = DataSpec(model=AutoencoderConfig(), device_x=dx,
                    device_counts=counts, test_x=split.test_x,
                    test_y=split.test_y, name="commsml")
    specs = {}
    for family in families:
        specs[family] = ExperimentSpec(
            data=data,
            base=SimConfig(num_devices=args.devices, rounds=args.rounds,
                           lr=1e-3),
            cells=(tuple(CellSpec(s, k) for _, s, k in singles)
                   + tuple(CellSpec(m, args.multi_k) for m in args.multi)),
            traces=TraceSpec.generated(
                *(ProcessGrid(family_process(family, x),
                              args.traces_per_p)
                  for x in intensities)),
            seeds=SeedSpec.range(args.seeds),
            exec_plan=ExecPlan(shard=args.shard,
                               chunk_size=args.chunk_size))
    return specs


def run_process_study(args, intensities):
    """--process path: one E[AUROC]-vs-intensity table per family."""
    families = FAMILIES if args.process == "all" else [args.process]
    specs = build_process_spec(args, families, intensities)
    labels = {s: label for label, s, _ in SINGLE}
    for family, spec in specs.items():
        ep = plan(spec)
        if args.smoke:
            print(ep.describe())
            print()
        res = execute(ep)
        per = res.per_process()
        header = (f"{family + ' process':<12}"
                  + "".join(f"{f'E[AUROC] x={x:.2f}':<{COL}}"
                            for x in intensities))
        print(header)
        print("-" * len(header))
        for cplan in ep.cells:
            scheme = cplan.cfg.scheme
            name = (scheme + "*" if cplan.kind == "multi"
                    else labels[scheme])
            row = f"{name:<12}"
            for gi, _ in enumerate(intensities):
                row += fmt(per[cplan.key][gi])
            print(row)
        print()
    print("* = best single instance of a multi-model scheme; intensity "
          "x is each family's\ncanonical probability knob "
          "(repro.core.processes.family_process).")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--samples", type=int, default=400)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--traces-per-p", type=int, default=4)
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="host-side scenario chunking: bound device "
                         "memory for large grids (one compile either way)")
    ap.add_argument("--shard", action="store_true",
                    help="shard the scenario batch across local JAX "
                         "devices (results unchanged)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI path: tiny grid (seconds-scale), plan "
                         "printed before execution")
    ap.add_argument("--process", choices=list(FAMILIES) + ["all"],
                    default=None,
                    help="generative failure-process study instead of "
                         "the canonical + rate-grid tables: one "
                         "E[AUROC]-vs-intensity table for this family "
                         "(or every family with 'all')")
    args = ap.parse_args()
    args.single = [s for _, s, _ in SINGLE]
    args.multi, args.multi_k = MULTI, 3
    p_grid = P_GRID
    intensities = (0.05, 0.2, 0.4)
    if args.smoke:
        # tiny grid, seconds-scale: one fused non-fl bucket (tolfl+sbt),
        # the fl fallback bucket, one multi bucket — the whole spec ->
        # plan -> execute surface without the batch cell's extra compile
        args.rounds, args.samples, args.seeds = 5, 40, 1
        args.traces_per_p, args.multi, args.multi_k = 1, ["ifca"], 2
        args.single = ["tolfl", "fl", "sbt"]
        p_grid = (0.2,)
        intensities = (0.3,)

    if args.process:
        run_process_study(args, intensities)
        return

    spec, canonical = build_spec(args, p_grid)
    ep = plan(spec)          # pure: inspectable before anything runs
    if args.smoke:
        print(ep.describe())
        print()
    res = execute(ep)

    p_labels = [f"E[AUROC] p={p:.2f}" for p in p_grid]
    header = (f"{'scheme':<12}"
              + "".join(f"{s:<{COL}}" for s, _ in canonical)
              + "".join(f"{s:<{COL}}" for s in p_labels))
    print(header)
    print("-" * len(header))

    labels = {s: label for label, s, _ in SINGLE}
    for cplan, cres in zip(ep.cells, res.results):
        scheme = cplan.cfg.scheme
        if cplan.kind == "multi":
            row = f"{scheme + '*':<12}"
            sel = (lambda i: cres.select(i, "best"))
        else:
            row = f"{labels[scheme]:<12}"
            sel = cres.select
        for j, _ in enumerate(canonical):
            idx = cplan.explicit_index[j]
            if idx is None:       # batch centralises: no clients to fail
                row += f"{'n/a (no clients)':<{COL}}"
                continue
            row += fmt(sel(idx))
        for p in p_grid:
            row += fmt(np.concatenate([sel(i) for i in cplan.draws[p]]))
        print(row)

    print("\n* = best single instance of a multi-model scheme (paper's "
          "starred columns)")
    print("E[AUROC] p=x columns: mean over sampled multi-event failure-"
          "and-recovery traces\nwhere every device independently fails "
          "with probability x (section IV-B).")
    print("Expected ordering (paper Table V): under server failure Tol-FL "
          "stays collaborative\nwhile FL collapses to isolated devices.")


if __name__ == "__main__":
    main()
