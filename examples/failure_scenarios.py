"""Failure-rate sweep: the paper's robustness story, Monte-Carlo style.

Instead of hand-listing one scenario per column, this example *samples*
grids of multi-event failure-and-recovery traces at increasing
per-device failure rates (:func:`repro.core.failure.sample_traces`) and
sweeps every scheme over them — paper Section IV-B's expected
performance E[AUROC](p), with the canonical no/client/server-failure
conditions (Tables III/IV/V in miniature) kept as the p-column anchors.

Everything is batched AND fused: the non-batch single-model schemes run
their whole (canonical + sampled traces) x seeds grids through the
fused campaign dispatcher — tolfl and sbt share literally ONE
jitted/vmapped call over the flattened (scheme x trace x seed) axis
(each scheme keeps its own per-topology trace grid; the fl cell's
isolated-fallback branch dispatches separately) — and the multi-model
baselines (FedGroup / IFCA / FeSEM) each run their grid through one
call of the vmapped multi-model campaign core.  The seed's version
looped Python over every (scheme, scenario, seed) cell.

Run:  PYTHONPATH=src python examples/failure_scenarios.py [--rounds 60]
"""
import argparse

import numpy as np

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core.baselines import MultiModelConfig
from repro.core.campaign import (ExecPlan, mean_ci95, run_campaign,
                                 run_fused_campaigns,
                                 run_multimodel_campaign)
from repro.core.baselines import as_multimodel_trace
from repro.core.failure import (NO_FAILURE, FailureSpec, as_trace,
                                sample_rate_grid)
from repro.core.simulate import SimConfig
from repro.data import commsml, federated

SINGLE = [("Tol-FL", "tolfl", 5), ("FL", "fl", 1), ("SBT", "sbt", 10),
          ("Batch", "batch", 1)]
MULTI = ["fedgroup", "ifca", "fesem"]
P_GRID = (0.05, 0.2, 0.4)


COL = 21


def fmt(vals):
    mean, std, _ = mean_ci95(np.asarray(vals))
    return f"{f'{mean:.3f} +- {std:.3f}':<{COL}}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--samples", type=int, default=400)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--traces-per-p", type=int, default=4)
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="host-side scenario chunking: bound device "
                         "memory for large grids (one compile either way)")
    ap.add_argument("--shard", action="store_true",
                    help="shard the scenario batch across local JAX "
                         "devices (results unchanged)")
    args = ap.parse_args()
    plan = ExecPlan(shard=args.shard, chunk_size=args.chunk_size)

    X, y = commsml.generate(seed=0, samples_per_class=args.samples)
    split = federated.make_split(X, y, args.devices, 5, anomaly_classes=[3],
                                 seed=0)
    dx, counts = federated.pad_devices(split)
    ae = AutoencoderConfig()

    canonical = [
        ("no failure", NO_FAILURE),
        ("client fail", FailureSpec(epoch=args.rounds // 4, kind="client")),
        ("server fail", FailureSpec(epoch=args.rounds // 4, kind="server")),
    ]
    p_labels = [f"E[AUROC] p={p:.2f}" for p in P_GRID]
    header = (f"{'scheme':<12}"
              + "".join(f"{s:<{COL}}" for s, _ in canonical)
              + "".join(f"{s:<{COL}}" for s in p_labels))
    print(header)
    print("-" * len(header))

    # per scheme: canonical traces + sampled grids per failure rate
    # (deduplicated — identical draws, including all-none draws aliasing
    # the canonical no-failure trace, train once).  The trace grids are
    # sampled per TOPOLOGY (a tolfl head is a plain client under fl), so
    # the fused cells carry different trace lists — the fused dispatcher
    # stacks them along the flattened scenario axis all the same.  batch
    # centralises everything (and its data arrays differ in shape, so it
    # cannot fuse): a client failure removes nothing -> column n/a.
    cells, cell_draws = [], {}
    for label, scheme, k in SINGLE:
        cfg = SimConfig(scheme=scheme, num_devices=args.devices,
                        num_clusters=k, rounds=args.rounds, lr=1e-3)
        topo = cfg.topology()
        head = [as_trace(f, topo, 2 * topo.num_devices)
                for _, f in canonical
                if not (scheme == "batch" and f.kind == "client")]
        traces, cell_draws[scheme] = sample_rate_grid(
            np.random.default_rng(0), topo, P_GRID, args.rounds,
            args.traces_per_p, base_traces=head)
        cells.append((cfg, traces))
    fused = run_fused_campaigns(
        ae, dx, counts, split.test_x, split.test_y,
        [(cfg, tr) for cfg, tr in cells if cfg.scheme != "batch"],
        seeds=range(args.seeds), exec_plan=plan)
    results = dict(zip((c[0].scheme for c in cells
                        if c[0].scheme != "batch"), fused))
    for cfg, traces in cells:
        if cfg.scheme == "batch":
            results[cfg.scheme] = run_campaign(
                ae, dx, counts, split.test_x, split.test_y, cfg, traces,
                seeds=range(args.seeds), exec_plan=plan)

    for label, scheme, k in SINGLE:
        res, draws = results[scheme], cell_draws[scheme]
        row, j = f"{label:<12}", 0
        for sname, fail in canonical:
            if scheme == "batch" and fail.kind == "client":
                row += f"{'n/a (no clients)':<{COL}}"
                continue
            row += fmt(res.select(j))
            j += 1
        for p in P_GRID:
            vals = np.concatenate([res.select(i) for i in draws[p]])
            row += fmt(vals)
        print(row)

    for scheme in MULTI:
        mcfg = MultiModelConfig(scheme=scheme, num_devices=args.devices,
                                num_models=3, rounds=args.rounds, lr=1e-3)
        # multi-model engines have no cluster heads: sample against the
        # FL topology (device 0 = the aggregator -> server events) and
        # normalise the canonical specs with the baseline default targets
        topo = SimConfig(scheme="fl", num_devices=args.devices).topology()
        head = [as_multimodel_trace(f, args.devices, 2 * args.devices)
                for _, f in canonical]
        traces, draws = sample_rate_grid(
            np.random.default_rng(0), topo, P_GRID, args.rounds,
            args.traces_per_p, base_traces=head)
        res = run_multimodel_campaign(ae, dx, counts, split.test_x,
                                      split.test_y, mcfg, traces,
                                      seeds=range(args.seeds),
                                      exec_plan=plan)
        row = f"{scheme + '*':<12}"
        for j, _ in enumerate(canonical):
            row += fmt(res.select(j, "best"))
        for p in P_GRID:
            vals = np.concatenate([res.select(i, "best")
                                   for i in draws[p]])
            row += fmt(vals)
        print(row)

    print("\n* = best single instance of a multi-model scheme (paper's "
          "starred columns)")
    print("E[AUROC] p=x columns: mean over sampled multi-event failure-"
          "and-recovery traces\nwhere every device independently fails "
          "with probability x (section IV-B).")
    print("Expected ordering (paper Table V): under server failure Tol-FL "
          "stays collaborative\nwhile FL collapses to isolated devices.")


if __name__ == "__main__":
    main()
