"""Failure-scenario sweep: the paper's Tables III / IV / V in miniature.

Compares Tol-FL against FL, SBT, centralised Batch, and the clustered
baselines (FedGroup / IFCA / FeSEM) on Comms-ML under three conditions:
no failure, client failure, and server / cluster-head failure.

The single-model schemes drive the batched campaign engine
(:mod:`repro.core.campaign`): per scheme, ONE jitted/vmapped call runs
the whole (3 scenarios x seeds) grid — the previous version of this
example compiled and ran every (scheme, scenario, seed) cell one at a
time.

Run:  PYTHONPATH=src python examples/failure_scenarios.py [--rounds 60]
"""
import argparse

import numpy as np

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core.baselines import MultiModelConfig, run_multimodel
from repro.core.campaign import run_campaign
from repro.core.failure import NO_FAILURE, FailureSpec
from repro.core.simulate import SimConfig
from repro.data import commsml, federated

SINGLE = [("Tol-FL", "tolfl", 5), ("FL", "fl", 1), ("SBT", "sbt", 10),
          ("Batch", "batch", 1)]
MULTI = ["fedgroup", "ifca", "fesem"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--samples", type=int, default=400)
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()

    X, y = commsml.generate(seed=0, samples_per_class=args.samples)
    split = federated.make_split(X, y, args.devices, 5, anomaly_classes=[3],
                                 seed=0)
    dx, counts = federated.pad_devices(split)
    ae = AutoencoderConfig()

    scenarios = [
        ("no failure", NO_FAILURE),
        ("client fail", FailureSpec(epoch=args.rounds // 4, kind="client")),
        ("server fail", FailureSpec(epoch=args.rounds // 4, kind="server")),
    ]

    header = f"{'scheme':<12}" + "".join(f"{s:<22}" for s, _ in scenarios)
    print(header)
    print("-" * len(header))

    for label, scheme, k in SINGLE:
        cfg = SimConfig(scheme=scheme, num_devices=args.devices,
                        num_clusters=k, rounds=args.rounds, lr=1e-3)
        # batch centralises everything: a client failure removes
        # nothing, and its column prints n/a — don't train that cell
        cols = [(i, s, f) for i, (s, f) in enumerate(scenarios)
                if not (scheme == "batch" and f.kind == "client")]
        res = run_campaign(ae, dx, counts, split.test_x, split.test_y,
                           cfg, [f for _, _, f in cols],
                           seeds=range(args.seeds))
        cells = {i: res.select(j) for j, (i, _, _) in enumerate(cols)}
        row = f"{label:<12}"
        for i, (sname, fail) in enumerate(scenarios):
            if i not in cells:
                row += f"{'n/a (no clients)':<22}"
                continue
            vals = cells[i]
            row += f"{vals.mean():.3f} +- {vals.std():.3f}       "
        print(row)

    for scheme in MULTI:
        row = f"{scheme + '*':<12}"
        for sname, fail in scenarios:
            vals = []
            for seed in range(args.seeds):
                cfg = MultiModelConfig(scheme=scheme,
                                       num_devices=args.devices,
                                       num_models=3, rounds=args.rounds,
                                       lr=1e-3, seed=seed)
                r = run_multimodel(ae, dx, counts, split.test_x,
                                   split.test_y, cfg, fail)
                vals.append(r.best_auroc)
            row += f"{np.mean(vals):.3f} +- {np.std(vals):.3f}       "
        print(row)

    print("\n* = best single instance of a multi-model scheme (paper's "
          "starred columns)")
    print("Expected ordering (paper Table V): under server failure Tol-FL "
          "stays collaborative\nwhile FL collapses to isolated devices.")


if __name__ == "__main__":
    main()
