"""Quickstart: Tol-FL anomaly detection on a wireless network in ~40 lines.

Trains the paper's autoencoder over a 10-device federation (5 clusters) on
the synthetic Comms-ML wireless dataset, then kills a cluster head halfway
through a second run to show the failure tolerance.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core.failure import NO_FAILURE, FailureSpec
from repro.core.simulate import SimConfig, run_simulation
from repro.data import commsml, federated

# 1. A wireless-network dataset: 4 traffic classes, class 3 = intrusion.
X, y = commsml.generate(seed=0, samples_per_class=200)
split = federated.make_split(X, y, num_devices=10, num_clusters=5,
                             anomaly_classes=[3], seed=0)
device_x, device_counts = federated.pad_devices(split)

# 2. The paper's autoencoder anomaly detector.
ae_cfg = AutoencoderConfig()          # 128-64-32-64-128, dropout 0.2

# 3. Train with Tol-FL: k=5 clusters over 10 devices.
sim_cfg = SimConfig(scheme="tolfl", num_devices=10, num_clusters=5,
                    rounds=40, lr=1e-3, seed=0)
res = run_simulation(ae_cfg, device_x, device_counts, split.test_x,
                     split.test_y, sim_cfg, NO_FAILURE)
print(f"Tol-FL (k=5), no failures:     AUROC = {res.final_auroc:.3f}")

# 4. Kill a cluster head at round 5: only that cluster drops out;
#    the other 4 clusters keep training collaboratively.
fail = FailureSpec(epoch=5, kind="server")
res_f = run_simulation(ae_cfg, device_x, device_counts, split.test_x,
                       split.test_y, sim_cfg, fail)
print(f"Tol-FL (k=5), head failure:    AUROC = {res_f.auroc_used:.3f}")

# 5. The same failure under plain FL (k=1): the server IS the head, so the
#    remaining devices fall back to isolated local training (paper V-C).
fl_cfg = SimConfig(scheme="fl", num_devices=10, num_clusters=1,
                   rounds=40, lr=1e-3, seed=0)
res_fl = run_simulation(ae_cfg, device_x, device_counts, split.test_x,
                        split.test_y, fl_cfg, fail)
print(f"FL (k=1),     server failure:  AUROC = {res_fl.auroc_used:.3f} "
      f"(isolated fallback)")
print(f"\nTol-FL advantage under server failure: "
      f"+{(res_f.auroc_used - res_fl.auroc_used) * 100:.1f}% AUROC")
