"""Live anomaly scoring under failure: train -> serve -> fail over.

End-to-end demo of the serving half of the paper's failure-tolerance
story (:mod:`repro.serving.anomaly`):

1. train a tiny Tol-FL scenario and bank its params — the global
   (cluster-head) model plus one genuinely-isolated model per client;
2. stand up the batched scoring service (fixed batch buckets, each ONE
   pre-compiled entry point; a warm persistent cache serves with zero
   compiles);
3. stream traffic windows from every client while a sampled cluster
   cascade kills heads MID-STREAM: windows route to the global model
   until the client's head dies, fail over ResiliNet-style to the
   client's isolated model (bit-identical to scoring it directly —
   asserted below), and fail back on recovery;
4. print the failover timeline and the :class:`ServiceReport`
   (sustained windows/sec, latency percentiles, per-regime AUROC) —
   with ZERO dropped windows, also asserted.

Run:  PYTHONPATH=src python examples/score_stream.py [--epochs 40]
      PYTHONPATH=src python examples/score_stream.py --smoke
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.api import (AnomalyService, AutoencoderConfig,
                       ClusterCascadeProcess, ServiceConfig, SimConfig,
                       train_model_bank)
from repro.data import commsml, federated

WINDOW = 8


def build_bank(args):
    X, y = commsml.generate(seed=0, samples_per_class=args.samples)
    split = federated.make_split(X, y, args.devices, args.clusters,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    model = AutoencoderConfig(input_dim=commsml.N_FEATURES,
                              hidden=(32, 16), code_dim=8, dropout=0.2)
    cfg = SimConfig(scheme="tolfl", num_devices=args.devices,
                    num_clusters=args.clusters, rounds=args.rounds,
                    lr=1e-3, dropout=False)
    print(f"training bank: tolfl k={args.clusters} N={args.devices} "
          f"rounds={args.rounds} ...")
    bank = train_model_bank(model, dx, counts, cfg)
    return bank, split


def stream(bank, split, args):
    # every head fails at a sampled epoch, cascades to members, and the
    # cluster staggers back -- the paper's correlated-outage scenario,
    # now driving SERVICE-time liveness instead of training rounds
    cascade = ClusterCascadeProcess(p_head=1.0, q=0.5, recover_prob=1.0,
                                    recovery_lag=max(2, args.epochs // 4))
    svc = AnomalyService(
        bank, ServiceConfig(bucket_sizes=(1, 8, 64), window=WINDOW),
        failure=cascade, sample_seed=1, horizon=args.epochs)
    print("bucket compile sources:",
          {bs: src for bs, src in sorted(svc.compile_sources.items())})

    tx = np.asarray(split.test_x, np.float32)
    ty = np.asarray(split.test_y)
    n_win = tx.shape[0] // WINDOW
    wins = tx[:n_win * WINDOW].reshape(n_win, WINDOW, tx.shape[-1])
    labs = ty[:n_win * WINDOW].reshape(n_win, WINDOW)

    scored = []
    for t in range(args.epochs):
        for c in range(bank.num_clients):
            i = (t * bank.num_clients + c) % n_win
            svc.submit(c, wins[i], labels=labs[i])
        scored.extend(svc.tick())

    # ---- the serving contract, asserted live ----
    submitted = args.epochs * bank.num_clients
    assert len(scored) == submitted, "dropped windows!"
    fo = [r for r in scored if r.served_by == "isolated"]
    assert fo, "cascade never triggered a failover"
    r = fo[0]
    i = (r.epoch * bank.num_clients + r.client) % n_win
    direct = np.asarray(bank.detector.anomaly_scores(
        bank.client_iso_params(r.client), jnp.asarray(wins[i])))
    assert np.array_equal(r.scores, direct), \
        "failover scores are not bit-identical to the isolated model"

    print("\nfailover timeline (service epoch, client, event):")
    for epoch, client, event in svc.timeline:
        head = bank.topology.heads[bank.topology.cluster_of(client)]
        print(f"  t={epoch:>3}  client {client} "
              f"{'->' if event == 'failover' else '<-'} "
              f"{event:<8} (head device {head})")
    rep = svc.report()
    print(f"\n{rep.describe()}")
    print(f"head-served windows: AUROC {rep.auroc_head:.3f} | "
          f"failover-served: AUROC {rep.auroc_isolated:.3f}")
    print("zero dropped windows; failover scores bit-identical to the "
          "isolated models (asserted)")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--clusters", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--samples", type=int, default=400)
    ap.add_argument("--epochs", type=int, default=40,
                    help="service ticks to stream")
    ap.add_argument("--smoke", action="store_true",
                    help="CI path: seconds-scale train + stream")
    args = ap.parse_args()
    if args.smoke:
        args.rounds, args.samples, args.epochs = 3, 60, 12

    bank, split = build_bank(args)
    stream(bank, split, args)


if __name__ == "__main__":
    main()
