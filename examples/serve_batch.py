"""Serving example: batched prefill + decode against a KV cache.

Demonstrates the serving layer the decode_32k / long_500k dry-run shapes
lower: prefill a batch of prompts, extend the cache, then stream tokens
with one-token ``decode_step`` calls — for a dense, an SSM, and a hybrid
architecture (reduced configs so it runs on CPU in seconds).

Run:  PYTHONPATH=src python examples/serve_batch.py [--tokens 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import transformer as T
from repro.serving.decode import decode_step, pad_cache, prefill
from repro.serving.inputs import synthetic_batch


def serve(arch: str, batch_size: int, prompt_len: int, gen_tokens: int,
          seed: int = 0):
    cfg = ARCHS[arch].reduced()
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg)

    # --- prefill: process the whole prompt batch in one shot ---
    t0 = time.time()
    batch = synthetic_batch(cfg, batch_size, prompt_len, seed=seed)
    logits, cache = prefill(params, cfg, batch)
    cache = pad_cache(cache, cfg, prompt_len=prompt_len,
                      target_len=prompt_len + gen_tokens)
    t_prefill = time.time() - t0

    # --- decode: greedy, one token per step, O(1) cache update ---
    step = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(gen_tokens - 1):
        logits, cache = step(params, tok, cache, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None] \
            .astype(jnp.int32)
        out.append(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    per_tok = t_decode / max(gen_tokens - 1, 1) * 1000
    print(f"{arch:<28} prefill {t_prefill * 1000:7.1f} ms   "
          f"decode {per_tok:6.1f} ms/tok   sample: {gen[0, :8].tolist()}")
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for the synthetic prompt batch "
                         "(equal seeds reproduce latency inputs exactly)")
    args = ap.parse_args()

    print(f"batched serving: batch={args.batch} prompt={args.prompt} "
          f"generate={args.tokens} seed={args.seed}\n")
    for arch in ("qwen3-8b", "rwkv6-7b", "recurrentgemma-9b",
                 "whisper-large-v3"):
        serve(arch, args.batch, args.prompt, args.tokens, seed=args.seed)
    print("\n(reduced configs; the full-size path is exercised by the "
          "multi-pod dry-run)")


if __name__ == "__main__":
    main()
