"""End-to-end driver: train a ~100M-parameter transformer with Tol-FL.

The datacenter-scale path: the same ``make_train_step`` the multi-pod
dry-run lowers, on the host mesh, with the Tol-FL ring schedule (per-cluster
psum + sequential ppermute chain), failure injection, checkpointing and the
synthetic non-IID token pipeline.

Run (full, ~hundreds of steps):
    PYTHONPATH=src python examples/train_100m.py --steps 300
Quick sanity (a couple of minutes):
    PYTHONPATH=src python examples/train_100m.py --steps 20 --layers 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (AttentionConfig, ModelConfig,
                                OptimizerConfig, TolFLConfig)
from repro.core import distributed as D
from repro.core.failure import NO_FAILURE, FailureSpec, alive_mask
from repro.core.topology import Topology
from repro.data.pipeline import TokenPipeline, shard_batch
from repro.launch.mesh import make_host_mesh
from repro.sharding import logical as L
from repro.training.checkpoint import CheckpointManager


def build_config(layers: int, d_model: int) -> ModelConfig:
    return ModelConfig(
        name=f"tolfl-{d_model}x{layers}",
        num_layers=layers,
        d_model=d_model,
        d_ff=4 * d_model,
        vocab_size=32000,
        attention=AttentionConfig(num_heads=d_model // 64,
                                  num_kv_heads=max(1, d_model // 128),
                                  head_dim=64),
        remat="none",
        dtype="float32",
        tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fail-epoch", type=int, default=-1)
    ap.add_argument("--ckpt-dir", default="/tmp/tolfl_100m")
    args = ap.parse_args()

    cfg = build_config(args.layers, args.d_model)
    print(f"model: {cfg.name}  params={cfg.param_count() / 1e6:.1f}M")

    mesh = make_host_mesh(data=1, model=1)
    G = D.num_groups(mesh)
    tolfl = TolFLConfig(num_clusters=min(args.clusters, G),
                        schedule="tolfl_ring")
    ocfg = OptimizerConfig(name="adam", lr=args.lr, warmup_steps=20,
                           total_steps=args.steps, schedule="cosine")
    topo = Topology(G, tolfl.num_clusters)
    failure = (NO_FAILURE if args.fail_epoch < 0
               else FailureSpec(epoch=args.fail_epoch, kind="server"))

    rules = L.rules_for("replicated_data")
    with L.activate_mesh(mesh, rules):
        step_fn = jax.jit(D.make_train_step(cfg, tolfl, ocfg, mesh),
                          donate_argnums=0)
        state = D.init_state(jax.random.PRNGKey(0), cfg, ocfg)
        pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                             global_batch=args.batch, num_groups=G)
        ckpt = CheckpointManager(args.ckpt_dir, keep=2)

        losses = []
        t0 = time.time()
        for step, host_batch in enumerate(pipe.batches(args.steps)):
            alive = jnp.asarray(np.asarray(
                alive_mask(failure, topo, jnp.int32(step))))
            state, metrics = step_fn(state, shard_batch(host_batch, mesh),
                                     alive)
            losses.append(float(metrics["loss"]))
            if step % 10 == 0 or step == args.steps - 1:
                dt = time.time() - t0
                tok_s = (step + 1) * args.batch * args.seq / dt
                print(f"step {step:4d}  loss {losses[-1]:7.4f}  "
                      f"{tok_s:7.0f} tok/s  ({dt:5.1f}s)")
            if (step + 1) % 100 == 0:
                ckpt.save({"params": state["params"],
                           "step": state["step"]}, step + 1)

        print(f"\nfinal loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
              f"{'LEARNED' if losses[-1] < losses[0] else 'NO PROGRESS'}")


if __name__ == "__main__":
    main()
