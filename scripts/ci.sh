#!/usr/bin/env bash
# Tier-1 CI: full test suite + one batched failure micro-campaign.
# Run from the repo root:  bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== sharded campaign parity (forced 8-device host platform) =="
# the test itself forces XLA_FLAGS=--xla_force_host_platform_device_count=8
# in a subprocess; run it explicitly so a collection filter can never
# silently drop the multi-device parity contract from CI
python -m pytest -q tests/test_campaign_exec.py -k sharded

echo "== smoke micro-campaign (also writes BENCH_campaign.json) =="
python -m benchmarks.run --smoke
