#!/usr/bin/env bash
# Tier-1 CI: full test suite + one batched failure micro-campaign.
# Run from the repo root:  bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== smoke micro-campaign =="
python -m benchmarks.run --smoke
