#!/usr/bin/env bash
# Tier-1 CI: full test suite + one batched failure micro-campaign.
# Run from the repo root:  bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint (ruff) =="
# generic lint (pyflakes / curated pycodestyle / isort) — config in
# pyproject.toml; gated so local runs without ruff still work (the
# container bakes no ruff; the GitHub workflow installs it)
if command -v ruff >/dev/null 2>&1; then
  ruff check .
else
  echo "ruff not installed; skipping (CI installs and enforces it)"
fi

echo "== plancheck =="
# repo-specific static analysis: AST lint over src/repro, the
# executable-cache-key completeness contract, and the plan-time jaxpr
# pass over a demo plan covering every executable kind.  Fails on any
# finding NOT covered by the committed plancheck_baseline.toml; the
# full report (new + baselined) lands in plancheck_report.json
python -m repro.analysis.plancheck \
  --baseline plancheck_baseline.toml --report plancheck_report.json

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== sharded campaign parity (forced 8-device host platform) =="
# the test itself forces XLA_FLAGS=--xla_force_host_platform_device_count=8
# in a subprocess; run it explicitly so a collection filter can never
# silently drop the multi-device parity contract from CI
python -m pytest -q tests/test_campaign_exec.py -k sharded

echo "== example smoke: declarative spec -> plan -> execute surface =="
# tiny grid (<~30 s): keeps the experiment-API surface the example
# exercises (spec, sampled TraceSpec, plan.describe, fused buckets)
# from silently rotting
python examples/failure_scenarios.py --smoke

echo "== second-body smoke: SeqDetector campaign through plan -> execute =="
# a tiny RG-LRU sequence-detector campaign: pins the pluggable-model
# seam end-to-end (DataSpec.model -> simulate/baselines cores ->
# detector-keyed executables) with the plan-time static analyzer on
python - <<'PY'
from repro.api import (CellSpec, DataSpec, ExperimentSpec, SeedSpec,
                       SeqDetector, SimConfig, TraceSpec, execute, plan)
from repro.core.failure import NO_FAILURE, FailureSpec
from repro.data import commsml, federated

X, y = commsml.generate(seed=0, samples_per_class=40)
split = federated.make_split(X, y, num_devices=6, num_clusters=2,
                             anomaly_classes=[3], seed=0)
dx, counts = federated.pad_devices(split)
spec = ExperimentSpec(
    data=DataSpec(model=SeqDetector(input_dim=commsml.N_FEATURES,
                                    window=16, d_model=8),
                  device_x=dx, device_counts=counts,
                  test_x=split.test_x, test_y=split.test_y,
                  name="ci-seq-smoke"),
    base=SimConfig(num_devices=6, rounds=2, lr=1e-3, dropout=False),
    cells=(CellSpec("tolfl", 2), CellSpec("ifca", 2)),
    traces=TraceSpec(traces=(NO_FAILURE, FailureSpec(1, "server"))),
    seeds=SeedSpec((0,)))
p = plan(spec, check=True)
assert p.static_report().clean, p.describe()
res = execute(p)
assert res.num_scenarios == 4, res.num_scenarios
print(p.describe())
print("seq smoke OK:", {k: round(v["auroc_used_mean"]
                                 if "auroc_used_mean" in v
                                 else v["best_auroc_mean"], 3)
                        for k, v in res.summary().items()})
PY

echo "== failure-process smoke: Markov churn + cluster cascade micro-campaign =="
# generative fault injection end-to-end: TraceSpec.processes lowers to
# deduplicated trace grids in plan(check=True), executes clean, and a
# warm replay of the same spec retraces nothing (deterministic process
# seeds -> identical traces -> identical executables)
python - <<'PY'
from repro.api import (AutoencoderConfig, CellSpec, ClusterCascadeProcess,
                       DataSpec, ExperimentSpec, MarkovChurnProcess,
                       ProcessGrid, SeedSpec, SimConfig, TraceSpec,
                       execute, plan)
from repro.core import campaign
from repro.data import commsml, federated

X, y = commsml.generate(seed=0, samples_per_class=40)
split = federated.make_split(X, y, num_devices=6, num_clusters=2,
                             anomaly_classes=[3], seed=0)
dx, counts = federated.pad_devices(split)
spec = ExperimentSpec(
    data=DataSpec(model=AutoencoderConfig(), device_x=dx,
                  device_counts=counts, test_x=split.test_x,
                  test_y=split.test_y, name="ci-process-smoke"),
    base=SimConfig(num_devices=6, rounds=3, lr=1e-3, dropout=False),
    cells=(CellSpec("tolfl", 2), CellSpec("fl", 1)),
    traces=TraceSpec.generated(
        ProcessGrid(MarkovChurnProcess(p_fail=0.2, p_recover=0.5), 2),
        ProcessGrid(ClusterCascadeProcess(p_head=0.8), 2)),
    seeds=SeedSpec((0,)))
p = plan(spec, check=True)
assert p.static_report().clean, p.describe()
execute(p)
before = campaign.TRACE_COUNT
res = execute(plan(spec))        # warm replay: bit-identical traces
assert campaign.TRACE_COUNT == before, "retrace on warm process replay"
print(p.describe())
print("process smoke OK:", res.process_summary())
PY

echo "== serving smoke: train -> batched scoring service -> failover =="
# the anomaly-scoring demo: a cascade kills heads mid-stream; the
# service must keep scoring (zero drops) with bit-identical failover —
# both asserted inside the script
python examples/score_stream.py --smoke

echo "== smoke micro-campaign (also writes BENCH_campaign.json + BENCH_serve.json) =="
# stash the committed baselines before --smoke overwrites them, so the
# perf trajectory of this change is visible in the CI log below
baseline="${TMPDIR:-/tmp}/bench_campaign_baseline.json"
serve_baseline="${TMPDIR:-/tmp}/bench_serve_baseline.json"
rm -f "$baseline" "$serve_baseline"
cp BENCH_campaign.json "$baseline" 2>/dev/null || true
cp BENCH_serve.json "$serve_baseline" 2>/dev/null || true
python -m benchmarks.run --smoke

echo "== campaign scenarios/sec + wall vs committed baseline =="
# wall_s carries the compile cost on the cold rows (sweep_*_cold,
# sweep_aot_cold), so its delta column is the compile-time trajectory
python - "$baseline" <<'PY'
import json, os, sys
base_path = sys.argv[1]
fresh = json.load(open("BENCH_campaign.json"))
base = json.load(open(base_path)) if os.path.exists(base_path) else {}
hdr = (f"{'row':<22}{'base/s':>9}{'fresh/s':>9}{'delta':>8}"
       f"{'base_w':>9}{'fresh_w':>9}{'wdelta':>8}")
print(hdr)
for row in sorted(fresh):
    f = fresh[row]["scenarios_per_s"]
    fw = fresh[row]["wall_s"]
    b = base.get(row, {}).get("scenarios_per_s")
    bw = base.get(row, {}).get("wall_s")
    delta = f"{(f - b) / b * 100.0:+.0f}%" if b else "new"
    wdelta = f"{(fw - bw) / bw * 100.0:+.0f}%" if bw else "new"
    print(f"{row:<22}{b if b is not None else '-':>9}{f:>9}{delta:>8}"
          f"{bw if bw is not None else '-':>9}{fw:>9}{wdelta:>8}")
PY

echo "== serving windows/sec + tail latency vs committed baseline =="
# the failure rows (iid/markov/cascade) legitimately run slower than
# service_bs64 — failover adds per-client isolated-model dispatches —
# so compare each row against ITS OWN baseline
python - "$serve_baseline" <<'PY'
import json, os, sys
base_path = sys.argv[1]
fresh = json.load(open("BENCH_serve.json"))
base = json.load(open(base_path)) if os.path.exists(base_path) else {}
hdr = (f"{'row':<18}{'base_w/s':>10}{'fresh_w/s':>10}{'delta':>8}"
       f"{'base_p99':>10}{'fresh_p99':>10}")
print(hdr)
for row in sorted(fresh):
    if "windows_per_s" not in fresh[row]:
        continue
    f = fresh[row]["windows_per_s"]
    fp = fresh[row].get("p99_ms", "-")
    b = base.get(row, {}).get("windows_per_s")
    bp = base.get(row, {}).get("p99_ms", "-")
    delta = f"{(f - b) / b * 100.0:+.0f}%" if b else "new"
    print(f"{row:<18}{b if b is not None else '-':>10}{f:>10}{delta:>8}"
          f"{bp:>10}{fp:>10}")
r = fresh["service_bs64"].get("ratio_vs_direct")
print(f"service_bs64 vs direct_bs64 throughput ratio: {r}"
      f"  (bench asserts >= 0.9)")
PY
