"""Analytic FLOPs / HBM-bytes / collective-bytes model per (arch x shape).

WHY THIS EXISTS (EXPERIMENTS.md section Dry-run records the finding): XLA's
``cost_analysis()`` counts while-loop bodies ONCE — our layer stack, the
blocked-attention online-softmax, the MoE chunk loop and the chunked
cross-entropy are all ``lax.scan``s, so raw HLO flops/bytes undercount by
the trip counts (verified: scanned matmul reports 1/10th of the unrolled
one).  The roofline table therefore uses this analytic model — standard
napkin math over the workload, the same arithmetic used to pick the
optimisations — while the dry-run JSON keeps the raw HLO numbers and the
HLO-parsed collective bytes as cross-checks (the Tol-FL aggregation
collectives are NOT inside scans, so those parse correctly).

All results are PER-CHIP values for the given mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import (ATTN, InputShape, LOCAL_ATTN, ModelConfig,
                                RECURRENT, RWKV)


@dataclass
class CostBreakdown:
    flops: float              # per chip
    hbm_bytes: float          # per chip
    coll_bytes: float         # per chip
    detail: Dict[str, float]


def _itemsize(dtype: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2}[dtype]


def _attn_window(cfg: ModelConfig, kind: str, S: int, long_ctx: bool) -> int:
    a = cfg.attention
    if kind == LOCAL_ATTN and a.sliding_window:
        return min(S, a.sliding_window)
    if long_ctx:
        return min(S, a.long_context_window)
    return S


def forward_flops(cfg: ModelConfig, B: int, S: int, mode: str,
                  long_ctx: bool = False) -> Dict[str, float]:
    """Global forward FLOPs by component.  mode: train|prefill|decode.
    For decode, B tokens total are processed (one per sequence) against a
    cache of length S."""
    T = B * S if mode != "decode" else B
    d, f = cfg.d_model, cfg.d_ff
    a = cfg.attention
    comps: Dict[str, float] = {}
    # matmul core: 2 flops per param per token over all matmul params
    n_matmul = cfg.active_param_count() - cfg.vocab_size * cfg.d_model * \
        (1 if cfg.tie_embeddings else 2)
    comps["matmul"] = 2.0 * T * n_matmul
    # logits
    from repro.models.transformer import padded_vocab
    comps["logits"] = 2.0 * T * d * padded_vocab(cfg)
    # attention score x value contractions
    att = 0.0
    for kind in cfg.layer_pattern:
        if kind in (ATTN, LOCAL_ATTN):
            W = _attn_window(cfg, kind, S, long_ctx)
            if mode == "decode":
                # one query against W cached positions
                att += 4.0 * B * W * a.num_heads * a.head_dim
            else:
                eff = min(W, S)
                # causal: each query sees ~min(pos, W) keys ~ eff/2 on
                # average for full causal, ~W for windowed
                avg = eff / 2 if eff == S else eff
                att += 4.0 * B * S * avg * a.num_heads * a.head_dim
        elif kind == RWKV:
            H, N = cfg.recurrent.num_heads, cfg.recurrent.head_size
            att += 4.0 * (B * S if mode != "decode" else B) * H * N * N
        elif kind == RECURRENT:
            Wd = cfg.recurrent.lru_width or d
            att += 10.0 * (B * S if mode != "decode" else B) * Wd
    comps["attention"] = att
    if cfg.is_encdec:
        F = cfg.encoder_seq
        # encoder matmuls via per-layer params
        enc_params = cfg.num_encoder_layers * (
            4 * d * a.num_heads * a.head_dim + (3 if cfg.glu else 2) * d * f)
        if mode != "decode":
            comps["encoder"] = 2.0 * B * F * enc_params
            comps["encoder_attn"] = (2.0 * B * F * F * a.num_heads
                                     * a.head_dim * cfg.num_encoder_layers)
        # cross attention: queries x F encoder keys, every decoder layer
        q_T = B * (S if mode != "decode" else 1)
        comps["cross_attn"] = (4.0 * q_T * F * a.num_heads * a.head_dim
                               * cfg.num_layers)
    return comps


def step_costs(cfg: ModelConfig, shape: InputShape, chips: int,
               model_shards: int, data_shards: int, schedule: str,
               num_clusters: int = 4, pods: int = 1,
               long_ctx: bool = False, fsdp: bool = False,
               grad_sync_dtype: str = None, microbatches: int = 1,
               param_cast_dtype: str = None) -> CostBreakdown:
    """Per-chip analytic costs for one step of the mode implied by shape.

    Perf knobs (section Perf): ``grad_sync_dtype`` narrows the gradient
    sync payload; ``microbatches`` divides activation memory;
    ``param_cast_dtype`` narrows the FSDP all-gather payload.  On the CPU
    dry-run the narrowed-collective effects are verified at the StableHLO
    level (the CPU float-normalization pass widens bf16 compute collectives
    back to f32 in compiled HLO; TPU — the target — keeps them narrow)."""
    mode = shape.mode
    B, S = shape.global_batch, shape.seq_len
    fwd = forward_flops(cfg, B, S, mode, long_ctx)
    f_fwd = sum(fwd.values())
    if mode == "train":
        mult = 4.0 if cfg.remat == "full" else 3.0   # fwd + 2x bwd (+remat)
    else:
        mult = 1.0
    flops_global = f_fwd * mult
    flops_chip = flops_global / chips

    it = _itemsize(cfg.dtype)
    pit = _itemsize(cfg.param_dtype)
    P = cfg.param_count()
    d = cfg.d_model
    L_ = cfg.num_layers
    T = B * S if mode != "decode" else B
    # HBM traffic: weights streamed per pass + activations + opt update
    # weights are sharded over model (and data if fsdp): per-chip share
    w_share = P * pit / (model_shards * (data_shards * pods if fsdp else 1))
    passes = mult            # one weight stream per fwd/bwd pass
    act_bytes = 12.0 * T * d * L_ * it / chips / max(microbatches, 1)
    hbm = w_share * passes + act_bytes
    if mode == "train":
        hbm += 5.0 * w_share * 3                     # adam: p,mu,nu r+w
    if mode == "decode":
        # read the whole cache once per step
        a = cfg.attention
        cache = 0
        for kind in cfg.layer_pattern:
            if kind in (ATTN, LOCAL_ATTN):
                W = _attn_window(cfg, kind, S, long_ctx)
                cache += 2 * B * W * a.num_kv_heads * a.head_dim * it
            elif kind == RWKV:
                H, N = cfg.recurrent.num_heads, cfg.recurrent.head_size
                cache += B * H * N * N * 4
            elif kind == RECURRENT:
                cache += B * (cfg.recurrent.lru_width or d) * 4
        hbm += cache / chips
        hbm += w_share            # weights streamed once
    bytes_chip = hbm

    # ---- collectives (per chip) ----
    coll = 0.0
    sync_it = _itemsize(grad_sync_dtype) if grad_sync_dtype else 4
    grad_share = P * sync_it / model_shards   # sync payload, model-sharded
    if mode == "train":
        if schedule == "tolfl_ring":
            k = num_clusters
            members = max(data_shards // k, 1)
            # intra-cluster psum (ring all-reduce ~ 2x payload when m>1)
            coll += (2.0 * grad_share if members > 1 else 0.0)
            # SBT chain: k-1 sequential hops, payload = grad share, but
            # only head chips move data; amortised per chip over the data
            # axis it is (k-1)/data_shards x payload... report the HEAD
            # chip (critical path): k-1 hops + pod hops
            coll += (k - 1 + (pods - 1)) * grad_share
            # broadcast (masked all-reduce)
            coll += 2.0 * grad_share
        else:
            coll += 2.0 * grad_share     # reduce-scatter + all-gather
        if fsdp:
            # param all-gather per pass (each chip receives ~the full
            # model-axis share it doesn't hold)
            gather_it = (_itemsize(param_cast_dtype)
                         if param_cast_dtype else pit)
            coll += passes * P * gather_it / model_shards
        # tensor-parallel all-reduces: 2 per layer per pass on (T_loc, d)
        if model_shards > 1:
            t_loc = T / (data_shards * pods)
            coll += 2.0 * L_ * passes * t_loc * d * it
    else:
        t_loc = T / max(data_shards * pods, 1)
        coll += 2.0 * L_ * t_loc * d * it * (1.0 if model_shards > 1 else 0.0)
        if mode == "decode" and B < data_shards:
            # sequence-parallel cache: flash-decoding combine per layer
            coll += L_ * B * cfg.attention.num_heads * cfg.attention.head_dim * 4
    return CostBreakdown(flops_chip, bytes_chip, coll,
                         dict(fwd, mult=mult, grad_share=grad_share))
