"""plancheck: plan-time static analysis for the campaign engine.

Two passes plus a cache-key contract check:

* **Pass 1** (:mod:`.jaxprpass`) lowers every dispatch bucket of an
  :class:`repro.core.experiment.ExecutionPlan` at its plan-predicted
  abstract shapes (zero execution) and walks the jaxpr for retrace
  hazards, by-value data captures, host-sync primitives and size-budget
  breaches.
* **Pass 2** (:mod:`.astpass`) lints the repo source for stray
  jit/vmap, Python-loop metrics, PRNG key reuse and nondeterminism in
  traced-core position.
* :mod:`.cachekey` statically checks that every program-shape-changing
  knob reaches ``campaign._exe_key`` (or is allowlisted with a reason).

Run the whole battery from the repo root::

    PYTHONPATH=src python -m repro.analysis.plancheck

or get a per-bucket report straight off a plan::

    plan = experiment.plan(spec, check=True)
    print(plan.describe())          # includes the static-report section

Findings are suppressed inline (``# plancheck: ignore[RULE]``) or via
the committed ``plancheck_baseline.toml`` (:mod:`.findings`).
"""
from repro.analysis.plancheck.astpass import check_repo, check_source
from repro.analysis.plancheck.budgets import (BUDGETS, Budget,
                                              check_budget,
                                              constant_across,
                                              count_jaxpr, eqn_count)
from repro.analysis.plancheck.cachekey import (classify_field,
                                               check_cache_keys)
from repro.analysis.plancheck.findings import (RULES, Finding, Report,
                                               apply_baseline,
                                               apply_inline,
                                               format_baseline,
                                               load_baseline)
from repro.analysis.plancheck.jaxprpass import (check_jaxpr, check_plan,
                                                trace_closed_jaxpr)

__all__ = [
    "BUDGETS", "Budget", "Finding", "RULES", "Report",
    "apply_baseline", "apply_inline", "check_budget", "check_cache_keys",
    "check_jaxpr", "check_plan", "check_repo", "check_source",
    "classify_field", "constant_across", "count_jaxpr", "eqn_count",
    "format_baseline", "load_baseline", "trace_closed_jaxpr",
]
