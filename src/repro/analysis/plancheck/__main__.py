"""CLI driver: ``PYTHONPATH=src python -m repro.analysis.plancheck``.

Runs the full battery from the repo root:

1. **AST lint** (pass 2) over ``src/repro``;
2. **cache-key completeness** (``PC-KEY``) against the live
   ``campaign._exe_key`` / ``ExecPlan`` / ``BucketPlan`` definitions;
3. **plan analysis** (pass 1) on a tiny built-in demo spec that lowers
   all three executable kinds (fused single, fl/iso single, multi) —
   nothing executes, but the buckets trace exactly like a first
   compile, so what ships is what gets analysed.

Findings are filtered against ``plancheck_baseline.toml`` (committed
suppressions, each with a reason); the full report lands in
``plancheck_report.json``.  Exit status 1 iff NEW findings remain —
the CI contract (``scripts/ci.sh``).

``--write-baseline`` rewrites the baseline from the current findings
(then edit in real reasons); ``--skip-plan`` skips the jaxpr pass for
fast editor loops.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.plancheck import (Report, apply_baseline,
                                      check_cache_keys, check_plan,
                                      check_repo, format_baseline,
                                      load_baseline)

_HERE = os.path.dirname(os.path.abspath(__file__))
#: src/repro, found relative to this file so the CLI works from any cwd
SRC_REPRO = os.path.normpath(os.path.join(_HERE, os.pardir, os.pardir))


def _demo_plan():
    """A minimal ExecutionPlan exercising every executable kind."""
    import dataclasses

    import numpy as np

    from repro.api import (AutoencoderConfig, CellSpec, DataSpec,
                           ExperimentSpec, SeedSpec, SimConfig,
                           TraceSpec, plan)
    from repro.core.failure import sample_traces
    from repro.data import commsml, federated

    ae = AutoencoderConfig(input_dim=commsml.N_FEATURES, hidden=(16,),
                           code_dim=4, dropout=0.2)
    X, y = commsml.generate(seed=0, samples_per_class=40)
    split = federated.make_split(X, y, num_devices=6, num_clusters=3,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    base = SimConfig(num_devices=6, rounds=2, lr=1e-3, dropout=False)
    tcfg = dataclasses.replace(base, scheme="tolfl", num_clusters=3)
    traces = sample_traces(np.random.default_rng(0), tcfg.topology(),
                           0.5, max_events=6, rounds=2, num_traces=1)
    spec = ExperimentSpec(
        data=DataSpec(model=ae, device_x=dx, device_counts=counts,
                      test_x=split.test_x, test_y=split.test_y,
                      name="plancheck-demo"),
        base=base,
        cells=(CellSpec("tolfl", 2), CellSpec("fl", 1),
               CellSpec("ifca", 2)),
        traces=TraceSpec(traces=tuple(traces)), seeds=SeedSpec((0,)))
    return plan(spec)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.plancheck",
        description="static analysis: repo lint + cache-key contract "
                    "+ plan-time jaxpr checks")
    ap.add_argument("--baseline", default="plancheck_baseline.toml",
                    help="suppression baseline (default: %(default)s)")
    ap.add_argument("--report", default="plancheck_report.json",
                    help="JSON report output (default: %(default)s)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite --baseline from current findings")
    ap.add_argument("--skip-plan", action="store_true",
                    help="skip the (slower) jaxpr plan pass")
    ap.add_argument("--src", default=SRC_REPRO,
                    help="source tree for the AST pass")
    args = ap.parse_args(argv)

    findings, inline_suppressed = check_repo(args.src,
                                             rel_prefix="src/repro/")
    findings += check_cache_keys()
    if not args.skip_plan:
        findings += check_plan(_demo_plan())

    if args.write_baseline:
        with open(args.baseline, "w", encoding="utf-8") as f:
            f.write(format_baseline(findings))
        print(f"wrote {len(findings)} suppression(s) to {args.baseline}"
              f" -- now fill in the reasons")
        return 0

    baseline = load_baseline(args.baseline)
    new, baselined = apply_baseline(findings, baseline)
    report = Report(findings=new,
                    suppressed=baselined + inline_suppressed)
    with open(args.report, "w", encoding="utf-8") as f:
        f.write(report.to_json() + "\n")
    print(report.describe())
    print(f"report: {args.report}")
    return 0 if report.clean else 1


if __name__ == "__main__":
    sys.exit(main())
