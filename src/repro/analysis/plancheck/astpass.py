"""Pass 2: repo-specific AST lint over ``src/``.

Four rules, each encoding a bug class this repo has actually hit (or
structurally guards against):

``PC-AST-JIT``
    ``jax.jit`` / ``jax.vmap`` / ``jax.pmap`` referenced outside the
    blessed executable-builder modules (:data:`BLESSED_JIT_MODULES` /
    :data:`BLESSED_JIT_PREFIXES`).  Every campaign executable must flow
    through ``campaign._exe_key``'s canonical cache key; a stray jit
    builds an executable the key contract never sees.
``PC-AST-LOOPMETRIC``
    The scalar ``auroc`` / ``roc_curve`` called inside a Python loop or
    comprehension — the pre-PR-3 bug class where per-scenario metrics
    ran host-side O(B) instead of one ``auroc_batch`` sweep.  Loops
    over a bounded per-scenario axis (devices) can be inline-ignored
    with a justification.
``PC-AST-KEYREUSE``
    The same PRNG key variable consumed by two ``jax.random.*`` draws
    in one function scope without an intervening ``split``/``fold_in``
    or reassignment: the draws are perfectly correlated.
``PC-AST-NONDET``
    ``time.*``, stdlib ``random.*`` or legacy global-state
    ``np.random.*`` calls inside a NESTED function.  In this codebase
    nested functions are the traced cores (closures built by
    ``_build_core*``); a host clock or global RNG inside one either
    bakes a trace-time value into the executable or desyncs under
    ``vmap``.  Module-level functions (timers around compiles, CLI
    mains) are exempt by construction.

Findings carry file:line + rule id + fix hint and honour inline
``# plancheck: ignore[RULE]`` comments (:mod:`.findings`).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.plancheck.findings import (Finding, apply_inline,
                                               finding)

#: modules (src/repro-relative) allowed to build jit/vmap executables
BLESSED_JIT_MODULES: Set[str] = {
    "core/campaign.py", "core/simulate.py", "core/baselines.py",
    # the anomaly service's bucket entry points: compiled through the
    # same canonical-key + persistent-cache discipline (serve_score
    # keys in engine._SCORE_CACHE / compilecache fingerprints)
    "serving/anomaly/engine.py",
}
#: whole subtrees allowed to jit (kernels, launch entry points, the
#: sharding wrappers they compose with)
BLESSED_JIT_PREFIXES: Tuple[str, ...] = ("kernels/", "launch/",
                                         "sharding/")

#: the module that DEFINES the metrics is exempt from PC-AST-LOOPMETRIC
METRIC_DEF_MODULES: Set[str] = {"training/metrics.py"}

#: scalar per-scenario metrics that must not run in Python loops
LOOP_METRIC_NAMES: Set[str] = {"auroc", "roc_curve"}

#: jax.random consumers that legitimately take an unsplit key
PRNG_NONCONSUMERS: Set[str] = {"split", "fold_in", "PRNGKey", "key",
                               "wrap_key_data", "key_data", "clone",
                               "key_impl"}

#: legacy numpy global-RNG entry points (Generator methods are fine)
NP_RANDOM_LEGACY: Set[str] = {"rand", "randn", "random", "seed",
                              "randint", "choice", "shuffle",
                              "permutation", "uniform", "normal",
                              "random_sample"}


class _Imports(ast.NodeVisitor):
    """Module-alias table: local name -> dotted module it refers to."""

    def __init__(self):
        self.modules: Dict[str, str] = {}
        self.names: Dict[str, str] = {}      # from-imports: name -> fqn

    def visit_Import(self, node):
        for a in node.names:
            self.modules[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0])
            if a.asname and "." in a.name:
                self.modules[a.asname] = a.name

    def visit_ImportFrom(self, node):
        if node.module is None or node.level:
            return
        for a in node.names:
            fqn = f"{node.module}.{a.name}"
            self.names[a.asname or a.name] = fqn
            # `from jax import random` makes `random` a module alias
            self.modules.setdefault(a.asname or a.name, fqn)


def _resolve(node: ast.AST, imp: _Imports) -> Optional[str]:
    """Dotted name of an expression, import-aware: Name('jnp') ->
    'jax.numpy', Attribute(jax, 'jit') -> 'jax.jit'."""
    if isinstance(node, ast.Name):
        if node.id in imp.names:
            return imp.names[node.id]
        return imp.modules.get(node.id, node.id)
    if isinstance(node, ast.Attribute):
        base = _resolve(node.value, imp)
        return None if base is None else f"{base}.{node.attr}"
    return None


def _call_name(node: ast.Call, imp: _Imports) -> Optional[str]:
    return _resolve(node.func, imp)


# ---------------------------------------------------------------------------
# per-file checker
# ---------------------------------------------------------------------------
def _jit_blessed(relpath: str) -> bool:
    return (relpath in BLESSED_JIT_MODULES
            or relpath.startswith(BLESSED_JIT_PREFIXES))


def check_source(source: str, relpath: str,
                 apply_suppressions: bool = True
                 ) -> Tuple[List[Finding], List[Finding]]:
    """(findings, inline-suppressed findings) of one module."""
    tree = ast.parse(source, filename=relpath)
    imp = _Imports()
    imp.visit(tree)
    out: List[Finding] = []

    out += _check_jit(tree, imp, relpath)
    if relpath not in METRIC_DEF_MODULES:
        out += _check_loop_metrics(tree, imp, relpath)
    out += _check_nondet(tree, imp, relpath)
    out += _check_key_reuse(tree, imp, relpath)

    out.sort(key=lambda f: (f.line, f.rule))
    if not apply_suppressions:
        return out, []
    return apply_inline(out, source)


def _check_jit(tree, imp, relpath) -> List[Finding]:
    if _jit_blessed(relpath):
        return []
    out = []
    for node in ast.walk(tree):
        name = None
        if isinstance(node, ast.Attribute):
            name = _resolve(node, imp)
        elif isinstance(node, ast.Name):
            name = imp.names.get(node.id)
        if name in ("jax.jit", "jax.vmap", "jax.pmap"):
            out.append(finding(
                "PC-AST-JIT", relpath, node.lineno,
                f"{name} referenced outside the blessed executable-"
                f"builder modules",
                hint="route executables through repro.core.campaign's "
                     "cached builders (or add the module to "
                     "plancheck.astpass.BLESSED_JIT_MODULES with a "
                     "review)",
                tag=f"L{node.lineno}:{name}"))
    return out


def _check_loop_metrics(tree, imp, relpath) -> List[Finding]:
    out = []
    loops = (ast.For, ast.While, ast.ListComp, ast.SetComp,
             ast.DictComp, ast.GeneratorExp)

    def walk(node, in_loop):
        for child in ast.iter_child_nodes(node):
            child_in_loop = in_loop or isinstance(child, loops)
            if isinstance(child, ast.Call) and child_in_loop:
                name = _call_name(child, imp) or ""
                leaf = name.rsplit(".", 1)[-1]
                if leaf in LOOP_METRIC_NAMES:
                    out.append(finding(
                        "PC-AST-LOOPMETRIC", relpath, child.lineno,
                        f"scalar metric '{leaf}' called inside a "
                        f"Python loop",
                        hint="stack the scores and make ONE "
                             "auroc_batch call over the batch axis "
                             "(repro.training.metrics)",
                        tag=f"L{child.lineno}:{leaf}"))
            walk(child, child_in_loop)

    walk(tree, False)
    return out


def _check_nondet(tree, imp, relpath) -> List[Finding]:
    out = []

    def fn_depth_walk(node, depth):
        for child in ast.iter_child_nodes(node):
            d = depth + isinstance(child, (ast.FunctionDef,
                                           ast.AsyncFunctionDef))
            if isinstance(child, ast.Call) and depth >= 2:
                name = _call_name(child, imp) or ""
                bad = None
                if (name.startswith("time.")
                        and imp.modules.get("time") == "time"):
                    bad = name
                elif (name.startswith("random.")
                        and imp.modules.get("random") == "random"):
                    bad = name
                elif (name.startswith("numpy.random.")
                        and name.rsplit(".", 1)[-1] in NP_RANDOM_LEGACY):
                    bad = name
                if bad:
                    out.append(finding(
                        "PC-AST-NONDET", relpath, child.lineno,
                        f"nondeterministic host call {bad}() inside a "
                        f"nested function (the traced-core position)",
                        hint="thread explicit seeds/keys through the "
                             "core's arguments; host clocks and global "
                             "RNGs must stay at module-function level",
                        tag=f"L{child.lineno}:{bad}"))
            fn_depth_walk(child, d)

    fn_depth_walk(tree, 0)
    return out


# ---------------------------------------------------------------------------
# PRNG key reuse (sequential dataflow per function scope)
# ---------------------------------------------------------------------------
def _prng_consumer(call: ast.Call, imp: _Imports) -> Optional[str]:
    """jax.random function name if ``call`` is a key CONSUMER."""
    name = _call_name(call, imp)
    if not name:
        return None
    parts = name.split(".")
    if len(parts) < 2 or parts[-2] != "random":
        return None
    root = imp.modules.get(parts[0], parts[0])
    if not (name.startswith("jax.random.") or root.startswith("jax")):
        return None
    fn = parts[-1]
    return None if fn in PRNG_NONCONSUMERS else fn


def _key_args(call: ast.Call) -> List[ast.Name]:
    names = []
    if call.args and isinstance(call.args[0], ast.Name):
        names.append(call.args[0])
    for kw in call.keywords:
        if kw.arg == "key" and isinstance(kw.value, ast.Name):
            names.append(kw.value)
    return names


def _assigned_names(target) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(target):
        if isinstance(node, ast.Name):
            out.add(node.id)
    return out


def _walk_same_scope(node):
    """Source-ordered depth-first walk that does NOT descend into
    nested function / lambda scopes (each gets its own scan)."""
    yield node
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        yield from _walk_same_scope(child)


def _check_key_reuse(tree, imp, relpath) -> List[Finding]:
    out = []

    def flag_consumers(node, consumed):
        for sub in _walk_same_scope(node):
            if not isinstance(sub, ast.Call):
                continue
            fn_name = _prng_consumer(sub, imp)
            if fn_name is None:
                continue
            for key in _key_args(sub):
                if key.id in consumed:
                    line0, fn0 = consumed[key.id]
                    out.append(finding(
                        "PC-AST-KEYREUSE", relpath, sub.lineno,
                        f"PRNG key '{key.id}' consumed by "
                        f"jax.random.{fn_name} was already consumed "
                        f"by jax.random.{fn0} at line {line0} with "
                        f"no split/fold_in between",
                        hint="split the key (k1, k2 = "
                             "jax.random.split(key)) and give each "
                             "draw its own stream",
                        tag=f"L{sub.lineno}:{key.id}"))
                else:
                    consumed[key.id] = (sub.lineno, fn_name)

    def scan_block(stmts, consumed):
        # consumed: key name -> (line, fn) of its first consuming draw
        for stmt in stmts:
            if isinstance(stmt, ast.If):
                flag_consumers(stmt.test, consumed)
                before = dict(consumed)
                scan_block(stmt.body, consumed)
                other = dict(before)
                scan_block(stmt.orelse, other)
                consumed.update(other)
                continue
            if isinstance(stmt, (ast.For, ast.AsyncFor)):
                flag_consumers(stmt.iter, consumed)
                for name in _assigned_names(stmt.target):
                    consumed.pop(name, None)
                scan_block(stmt.body, consumed)
                scan_block(stmt.orelse, consumed)
                continue
            if isinstance(stmt, ast.While):
                flag_consumers(stmt.test, consumed)
                scan_block(stmt.body, consumed)
                scan_block(stmt.orelse, consumed)
                continue
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    flag_consumers(item.context_expr, consumed)
                scan_block(stmt.body, consumed)
                continue
            if isinstance(stmt, ast.Try):
                scan_block(stmt.body, consumed)
                for handler in stmt.handlers:
                    scan_block(handler.body, consumed)
                scan_block(stmt.orelse, consumed)
                scan_block(stmt.finalbody, consumed)
                continue
            flag_consumers(stmt, consumed)
            if isinstance(stmt, (ast.Assign, ast.AugAssign,
                                 ast.AnnAssign)):
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                for t in targets:
                    for name in _assigned_names(t):
                        consumed.pop(name, None)

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan_block(node.body, {})
    return out


# ---------------------------------------------------------------------------
# repo driver
# ---------------------------------------------------------------------------
def check_repo(src_root: str,
               rel_prefix: str = "") -> Tuple[List[Finding],
                                              List[Finding]]:
    """Run the AST pass over every ``*.py`` under ``src_root``;
    returns (findings, inline-suppressed).  ``rel_prefix`` prepends to
    reported paths (e.g. ``src/repro/``)."""
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    for dirpath, dirnames, filenames in os.walk(src_root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__",))
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, src_root).replace(os.sep, "/")
            with open(path, "r", encoding="utf-8") as f:
                source = f.read()
            kept, silenced = check_source(source, rel)
            prefix = rel_prefix
            if prefix:
                kept = [finding(f.rule, prefix + f.file, f.line,
                                f.message, f.hint, f.tag)
                        for f in kept]
                silenced = [finding(f.rule, prefix + f.file, f.line,
                                    f.message, f.hint, f.tag)
                            for f in silenced]
            findings.extend(kept)
            suppressed.extend(silenced)
    return findings, suppressed
