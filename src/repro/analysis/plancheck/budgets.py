"""Named jaxpr-size budgets for the engine's traced cores.

The repo has carried one such guard informally since PR 3:
``failure.trace_alive_mask`` must lower to a FIXED handful of ops
whatever ``max_events`` is (the unrolled fold it replaced emitted O(M)
``where``s, which blew up compile time on sampled grids where
M = 2 * num_devices).  That invariant used to live as ad-hoc arithmetic
inside ``tests/test_failure_trace.py``; this module is its promotion to
a shared, named contract:

* :data:`BUDGETS` names every guarded core and its equation ceiling;
* :func:`eqn_count` / :func:`count_jaxpr` measure a traced callable /
  lowered jaxpr (recursively — scan/cond/jit bodies count, so a budget
  bounds the WHOLE program, not just the top level);
* :func:`check_budget` turns a breach into a plancheck
  :class:`~repro.analysis.plancheck.findings.Finding`;
* :func:`constant_across` pins the O(1)-in-knob property itself (equal
  counts across a sweep of the knob, e.g. ``max_events``).

The jaxpr analysis pass applies the ``campaign_core_*`` budgets to
every dispatch bucket of an :class:`repro.core.experiment.ExecutionPlan`
(rule ``PC-JAX-BUDGET``), and ``tests/test_plancheck.py`` applies them
directly to the simulator's cached core and the fused campaign core.

Budget values are ceilings with ~2x headroom over the measured counts
at the time they were set — they exist to catch the *class* of
regression where a graph starts scaling with a knob that used to be
shape-only (an unrolled Python fold, a per-slot ``where`` chain), not
to pin exact op counts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Optional, Sequence

import jax

from repro.analysis.plancheck.findings import Finding, finding


@dataclass(frozen=True)
class Budget:
    """One named ceiling on a traced core's recursive equation count."""
    name: str
    max_eqns: int
    note: str = ""


#: the named per-core budgets (recursive equation counts, 2x headroom)
BUDGETS: Dict[str, Budget] = {
    b.name: b for b in (
        Budget("trace_alive_mask", 30,
               "O(1) in max_events: one reversed argmax, never a "
               "per-slot where chain (PR 3 regression class; measured "
               "19 recursive eqns)"),
        Budget("trace_faulty_scale", 30,
               "the faulty-update channel reader: shadow-device twin "
               "of trace_alive_mask, same O(1)-in-max_events shape "
               "(measured ~20 recursive eqns)"),
        Budget("campaign_core_single", 1400,
               "static-topology single-model scenario core, whole scan "
               "body included (measured 669 / 727 with track_iso)"),
        Budget("campaign_core_single_fused", 1400,
               "padded-topology fused single-model core, track_iso "
               "either way (measured 650 / 709 with track_iso)"),
        Budget("campaign_core_multi", 1500,
               "multi-model baseline core (kmeans init + assignment "
               "scan; measured 757)"),
        # seq family: RG-LRU windowed sequence detector bodies
        # (repro.models.detector.SeqDetector).  The recurrence lowers
        # through an associative scan, so the cores are materially
        # bigger than the dense autoencoder's — they get their own
        # named ceilings rather than inflating the ae family's.
        Budget("campaign_core_single:seq", 3600,
               "static-topology single-model core with the RG-LRU seq "
               "body (measured 1458 / 1760 with track_iso)"),
        Budget("campaign_core_single_fused:seq", 3600,
               "padded-topology fused single-model core with the "
               "RG-LRU seq body (measured 1431 / 1734 with track_iso)"),
        Budget("campaign_core_multi:seq", 3600,
               "multi-model baseline core with the RG-LRU seq body "
               "(measured 1778)"),
        # serving: the anomaly service's batched score entry point
        # (repro.serving.anomaly.engine) — ONE bank-row gather (scalar
        # row, per-leaf dynamic slice) + a shared-weight vmapped
        # anomaly_scores.  Must stay O(1) in bucket size, window length
        # and bank height (all shape-only knobs); a count that scales
        # with any of them means a Python fold crept into the serving
        # hot path.
        Budget("serving_score_core", 250,
               "uniform-row bank-gather + score core, autoencoder body "
               "(measured 156, constant across bucket/window/bank)"),
        Budget("serving_score_core:seq", 450,
               "uniform-row bank-gather + score core, RG-LRU seq body "
               "(measured 270, constant across bucket/window/bank)"),
    )
}


def count_jaxpr(jaxpr) -> int:
    """Recursive equation count of a (Closed)Jaxpr: every sub-jaxpr
    reachable through eqn params (scan/while/cond bodies, inner jits)
    contributes, so the count bounds the whole lowered program."""
    if hasattr(jaxpr, "jaxpr"):         # ClosedJaxpr -> Jaxpr
        jaxpr = jaxpr.jaxpr
    total = 0
    for eqn in jaxpr.eqns:
        total += 1
        for sub in subjaxprs(eqn):
            total += count_jaxpr(sub)
    return total


def subjaxprs(eqn) -> Iterable[object]:
    """Every jaxpr nested in one equation's params (duck-typed so no
    private jax.core imports are needed)."""
    for v in eqn.params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if hasattr(x, "eqns") or hasattr(x, "jaxpr"):
                yield x


def eqn_count(fn: Callable, *args, **kwargs) -> int:
    """Recursive equation count of ``fn`` traced at ``args`` (abstract
    values or concrete arrays — nothing executes)."""
    return count_jaxpr(jax.make_jaxpr(fn, **kwargs)(*args))


def check_budget(name: str, count: int, where: str = "",
                 file: str = "", line: int = 0) -> Optional[Finding]:
    """None when ``count`` fits the named budget, else a
    ``PC-JAX-BUDGET`` finding.  An UNKNOWN name is itself a finding:
    a detector family without a declared ceiling is unguarded, which
    is the regression class this module exists to catch."""
    budget = BUDGETS.get(name)
    if budget is None:
        return finding(
            "PC-JAX-BUDGET", file or where or name, line,
            f"{where or name}: no named budget {name!r} — this core "
            f"runs unguarded",
            hint=("declare a Budget in plancheck.budgets.BUDGETS for "
                  "this detector family (ceiling ~2x the measured "
                  "recursive eqn count)"),
            tag=name)
    if count <= budget.max_eqns:
        return None
    loc = where or name
    return finding(
        "PC-JAX-BUDGET", file or loc, line,
        f"{loc}: {count} equations > budget {budget.max_eqns} "
        f"({budget.name})",
        hint=(budget.note or "find the knob the graph started scaling "
              "with and move it into array shapes"),
        tag=name)


def constant_across(make_count: Callable[[int], int],
                    values: Sequence[int]) -> bool:
    """True iff the count is identical across every knob value — the
    O(1)-in-knob property (``make_count(v)`` measures at knob v)."""
    counts = {make_count(v) for v in values}
    return len(counts) == 1


def bucket_budget_name(kind: str, fused: bool, family: str = "ae") -> str:
    """The budget governing one experiment dispatch bucket.

    ``family`` is the detector's ``budget_family``: the default "ae"
    keeps the historical names; any other family suffixes them
    (``campaign_core_single:seq``), so each body is ceilinged against
    its own measured size."""
    if kind == "multi":
        base = "campaign_core_multi"
    else:
        base = ("campaign_core_single_fused" if fused
                else "campaign_core_single")
    return base if family == "ae" else f"{base}:{family}"
