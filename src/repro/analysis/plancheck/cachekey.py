"""Static completeness check of the executable-cache key.

PR 6 fixed, by hand, a class of production bug this module makes a lint
error: a knob that changes the lowered program but not
``campaign._exe_key`` silently aliases two DIFFERENT programs to one
cache entry (or, on the AOT path, serialises the wrong executable).
The check is structural, so it fires the moment someone ADDS such a
knob — before any campaign runs:

* ``SimConfig`` / ``MultiModelConfig`` / ``AutoencoderConfig`` — and
  every registered detector spec class
  (:func:`repro.models.detector.spec_classes`) — enter the key as whole
  frozen dataclasses, so every field they ever grow is covered BY
  CONSTRUCTION — the check verifies that containment property
  (frozen + eq + hash) rather than enumerating fields.
* ``ExecPlan`` / ``BucketPlan`` / ``DataSpec`` — and the serving
  layer's ``ServiceConfig`` — fields do NOT ride along wholesale;
  each field must either map onto a key component
  (:data:`KEY_COMPONENTS`) via :data:`FIELD_COVERAGE`, or appear in the
  allowlist with a reason (shape-only / bookkeeping knobs).  A new
  field in either dataclass that is in neither place is a ``PC-KEY``
  finding.
* ``_exe_key`` and ``_build_executable`` must agree on their parameter
  list (the key must span exactly the builder's degrees of freedom);
  a parameter added to the builder but not the key — or vice versa —
  is a ``PC-KEY`` finding.

``tests/test_plancheck.py`` runs the same classification as a
generated test (one case per field), so the contract is enforced both
statically (this pass, in CI via ``python -m repro.analysis.plancheck``)
and at test time — the runtime twin the issue asks for.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.plancheck.findings import Finding, finding

#: the canonical key components (campaign._exe_key's parameters)
KEY_COMPONENTS: Tuple[str, ...] = ("kind", "model", "cfg", "k_pad",
                                   "ndev", "track_iso", "fused")

#: program-changing fields -> the key component that carries them
FIELD_COVERAGE: Dict[Tuple[str, str], str] = {
    ("ExecPlan", "shard"): "ndev",      # resolved_devices() -> ndev
    ("ExecPlan", "devices"): "ndev",
    ("BucketPlan", "kind"): "kind",
    ("BucketPlan", "fused"): "fused",
    ("BucketPlan", "track_iso"): "track_iso",
    ("BucketPlan", "k_pad"): "k_pad",
    ("BucketPlan", "key_cfg"): "cfg",
    ("BucketPlan", "m_pad"): "cfg",     # folded into cfg.num_models by
    #                                     experiment._bucket_exe_args
    ("BucketPlan", "devices"): "ndev",
    ("DataSpec", "model"): "model",     # the detector body the cores
    #                                     close over
    ("DataSpec", "ae_cfg"): "model",    # deprecated alias; __post_init__
    #                                     folds it into model
}

#: fields that deliberately stay OUT of the key, each with its reason
#: (the "explicitly allowlisted with a comment" contract)
ALLOWLIST: Dict[Tuple[str, str], str] = {
    ("ExecPlan", "chunk_size"):
        "shape-only: chunking changes batch SHAPES, not the program; "
        "the jit path retraces per shape inside one entry and the AOT "
        "key extends with the abstract-argument signature "
        "(_avals_signature)",
    ("ExecPlan", "aot"):
        "path-only: AOT lowers THROUGH the same lru-cached jitted "
        "executable, so the program is identical by construction "
        "(pinned by tests/test_aot.py)",
    ("BucketPlan", "index"): "bookkeeping: dispatch order only",
    ("BucketPlan", "cell_indices"):
        "bookkeeping: which cells ride the bucket; their program-"
        "relevant content is normalised into key_cfg / k_pad / m_pad",
    ("BucketPlan", "num_scenarios"):
        "shape-only: batch length, covered by the aval signature",
    ("BucketPlan", "chunk"):
        "shape-only: per-dispatch batch length, covered by the aval "
        "signature",
    ("BucketPlan", "num_chunks"): "shape-only: host-side loop count",
    ("BucketPlan", "padded_scenarios"):
        "shape-only: padded batch length, covered by the aval "
        "signature",
    ("DataSpec", "device_x"):
        "data-as-arguments: enters the compiled program as a traced "
        "operand, never closed over; its shape/dtype are covered by "
        "the aval signature",
    ("DataSpec", "device_counts"):
        "data-as-arguments: traced operand, shapes covered by the aval "
        "signature",
    ("DataSpec", "test_x"):
        "data-as-arguments: traced operand, shapes covered by the aval "
        "signature",
    ("DataSpec", "test_y"):
        "host-only: consumed by AUROC post-processing after the "
        "dispatch returns, never lowered",
    ("DataSpec", "name"): "cosmetic: tags ExperimentResult.to_rows",
    # serving (repro.serving.anomaly): the service's compiled bucket
    # keys are (serve_score, canonical model key) + aval signature —
    # every ServiceConfig field is shape-only by design, landing in the
    # avals, never in the program
    ("ServiceConfig", "bucket_sizes"):
        "shape-only: each bucket size is the batch dim of its OWN "
        "compiled entry point, covered by that executable's "
        "abstract-argument signature (engine.score_executable)",
    ("ServiceConfig", "window"):
        "shape-only: rows per traffic window, the second dim of the "
        "x operand — covered by the aval signature",
}


def _field_findings(cls, file: str,
                    extra_fields: Sequence[str] = ()) -> List[Finding]:
    """Classify every field of ``cls``: covered, allowlisted, or a
    PC-KEY finding.  ``extra_fields`` lets tests simulate a knob being
    added without editing the dataclass."""
    out: List[Finding] = []
    names = [f.name for f in dataclasses.fields(cls)]
    names += list(extra_fields)
    for name in names:
        slot = (cls.__name__, name)
        if slot in FIELD_COVERAGE:
            assert FIELD_COVERAGE[slot] in KEY_COMPONENTS, slot
            continue
        if slot in ALLOWLIST:
            continue
        out.append(finding(
            "PC-KEY", file, 0,
            f"{cls.__name__}.{name} is not mapped to an executable-"
            f"cache key component and not allowlisted: if it changes "
            f"the lowered program, two configurations will share one "
            f"cache entry (the PR-6 bug class)",
            hint=("either thread it into campaign._exe_key (and "
                  "FIELD_COVERAGE) or add it to "
                  "plancheck.cachekey.ALLOWLIST with a reason"),
            tag=f"{cls.__name__}.{name}"))
    return out


def classify_field(cls_name: str, field_name: str) -> Optional[str]:
    """'covered' | 'allowlisted' | None (= unaccounted) — the single
    source of truth the generated test enumerates."""
    slot = (cls_name, field_name)
    if slot in FIELD_COVERAGE:
        return "covered"
    if slot in ALLOWLIST:
        return "allowlisted"
    return None


def check_cache_keys(extra_execplan_fields: Sequence[str] = (),
                     extra_bucket_fields: Sequence[str] = ()
                     ) -> List[Finding]:
    """The full PC-KEY pass (see the module docstring)."""
    from repro.core import campaign as _c
    from repro.core.experiment import BucketPlan

    out: List[Finding] = []
    key_params = tuple(inspect.signature(_c._exe_key).parameters)
    build_params = tuple(
        inspect.signature(_c._build_executable.__wrapped__).parameters)
    if key_params != KEY_COMPONENTS:
        out.append(finding(
            "PC-KEY", "repro/core/campaign.py", 0,
            f"campaign._exe_key parameters {key_params} drifted from "
            f"the declared KEY_COMPONENTS {KEY_COMPONENTS}",
            hint="update plancheck.cachekey.KEY_COMPONENTS and classify "
                 "the new component's feeding fields",
            tag="_exe_key.signature"))
    if build_params != key_params:
        out.append(finding(
            "PC-KEY", "repro/core/campaign.py", 0,
            f"campaign._build_executable parameters {build_params} != "
            f"_exe_key parameters {key_params}: a builder degree of "
            f"freedom is outside the cache key",
            hint="every _build_executable parameter must be produced "
                 "by _exe_key",
            tag="_build_executable.signature"))

    # containment property: whole-dataclass key components must be
    # frozen + hashable, or lru_cache would reject them and ad-hoc
    # per-field keys (the incomplete kind) would creep back in.  Every
    # REGISTERED detector spec class joins the sweep: any body can land
    # in the key via DataSpec.model.
    from repro.configs.autoencoder_paper import AutoencoderConfig
    from repro.core.baselines import FaultyMultiModelConfig, MultiModelConfig
    from repro.core.simulate import FaultySimConfig, SimConfig
    from repro.models.detector import spec_classes
    # the Faulty* engine variants join the sweep: they reach the key as
    # cfg whenever TraceSpec.processes needs the faulty engine, and
    # their CLASS is the keyed bit (failure PROCESSES themselves never
    # enter the key — they are host-side samplers lowering to traces,
    # which travel as data arguments)
    for cls in ((SimConfig, FaultySimConfig, MultiModelConfig,
                 FaultyMultiModelConfig, AutoencoderConfig)
                + spec_classes()):
        params = getattr(cls, "__dataclass_params__", None)
        if params is None or not params.frozen or not params.eq:
            out.append(finding(
                "PC-KEY", f"{cls.__module__}", 0,
                f"{cls.__name__} must stay a frozen eq dataclass: it "
                f"enters the executable cache key wholesale, which is "
                f"what makes every future field covered by "
                f"construction",
                tag=f"{cls.__name__}.containment"))

    from repro.core.experiment import DataSpec
    from repro.serving.anomaly.service import ServiceConfig
    out += _field_findings(_c.ExecPlan, "repro/core/campaign.py",
                           extra_execplan_fields)
    out += _field_findings(BucketPlan, "repro/core/experiment.py",
                           extra_bucket_fields)
    out += _field_findings(DataSpec, "repro/core/experiment.py")
    # the serving layer's config: its compiled buckets key on
    # (serve_score, model) + avals, so every field must be shape-only
    # (allowlisted as such) or threaded into the engine key
    out += _field_findings(ServiceConfig,
                           "repro/serving/anomaly/service.py")
    return out
