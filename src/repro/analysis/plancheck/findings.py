"""Finding / report / suppression layer shared by every plancheck pass.

A :class:`Finding` is one rule violation with enough location to act on
(``file:line``, rule id, message, fix hint).  Findings can be silenced
two ways, both reviewable in the diff:

* **inline** — the offending line (or the line above it) carries a
  ``# plancheck: ignore[RULE-ID]`` comment (bare ``# plancheck: ignore``
  silences every rule on that line);
* **baseline** — a committed ``plancheck_baseline.toml`` lists
  ``[[suppress]]`` entries with a mandatory ``reason``, so pre-existing
  or deliberate findings are acknowledged without editing the flagged
  code.  CI fails only on findings NOT covered by the baseline.

The baseline format is a TOML subset (an array of ``[[suppress]]``
tables with string/int values) parsed by :func:`load_baseline` — via
``tomllib`` where available (Python >= 3.11), else a small built-in
parser for exactly the subset :func:`format_baseline` writes.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

#: rule ids -> one-line description (the authoritative registry; tests
#: assert every emitted finding names a registered rule)
RULES: Dict[str, str] = {
    # pass 1 — jaxpr / plan analysis
    "PC-JAX-RETRACE": (
        "weak-typed abstract input: a Python scalar leaked into the "
        "traced call and will fork the jit cache key per call site"),
    "PC-JAX-CONST": (
        "large array captured by value in the jaxpr: data closed over "
        "instead of passed as an operand retraces per dataset"),
    "PC-JAX-SYNC": (
        "host callback / transfer primitive inside a batched core: "
        "implicit host-device sync serialises the scenario batch"),
    "PC-JAX-BUDGET": (
        "lowered jaxpr exceeds its named size budget (see "
        "plancheck.budgets): compile time will scale with the knob "
        "that grew it"),
    "PC-KEY": (
        "program-shape-changing knob missing from the executable "
        "cache key (campaign._exe_key) and not explicitly allowlisted"),
    # pass 2 — repo AST lint
    "PC-AST-JIT": (
        "jax.jit/vmap/pmap call outside the blessed executable-builder "
        "modules: stray executables bypass the cached-key contract"),
    "PC-AST-LOOPMETRIC": (
        "per-scenario metric computed in a Python loop: use the "
        "batched metric (training.metrics.auroc_batch) over the "
        "stacked scenario axis"),
    "PC-AST-KEYREUSE": (
        "same PRNG key consumed by two jax.random draws without a "
        "split/fold_in between: correlated randomness"),
    "PC-AST-NONDET": (
        "nondeterministic host call (time.*, stdlib random, legacy "
        "np.random.*) inside a nested (potentially traced) function"),
}

_IGNORE_RE = re.compile(r"#\s*plancheck:\s*ignore(?:\[([A-Z0-9,\- ]+)\])?")


@dataclass(frozen=True)
class Finding:
    """One rule violation.  ``key()`` is the identity the baseline
    matches on — file + rule + a stable detail tag (NOT the line
    number, so unrelated edits above a baselined finding don't
    invalidate the baseline)."""
    rule: str
    file: str
    line: int
    message: str
    hint: str = ""
    tag: str = ""                # stable detail (symbol / bucket name)

    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.file, self.tag)

    def describe(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        out = f"{loc}: {self.rule}: {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


@dataclass
class Report:
    """All findings of one plancheck run, split against a baseline."""
    findings: List[Finding] = field(default_factory=list)
    suppressed: List[Finding] = field(default_factory=list)

    @property
    def clean(self) -> bool:
        return not self.findings

    def describe(self) -> str:
        lines = [f"plancheck: {len(self.findings)} finding(s), "
                 f"{len(self.suppressed)} baselined"]
        lines.extend("  " + f.describe().replace("\n", "\n  ")
                     for f in self.findings)
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps(
            {"findings": [asdict(f) for f in self.findings],
             "suppressed": [asdict(f) for f in self.suppressed]},
            indent=2, sort_keys=True)


def finding(rule: str, file: str, line: int, message: str,
            hint: str = "", tag: str = "") -> Finding:
    assert rule in RULES, f"unregistered rule id {rule!r}"
    return Finding(rule=rule, file=file, line=line, message=message,
                   hint=hint, tag=tag)


# ---------------------------------------------------------------------------
# Inline suppression
# ---------------------------------------------------------------------------
def inline_suppressions(source: str) -> Dict[int, Optional[set]]:
    """{line number: set of rule ids silenced there, or None = all}.

    A comment on line L silences findings on L; a comment on a line of
    its own also silences the NEXT line (decorator-style)."""
    out: Dict[int, Optional[set]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _IGNORE_RE.search(text)
        if not m:
            continue
        rules = (None if m.group(1) is None else
                 {r.strip() for r in m.group(1).split(",") if r.strip()})
        own_line = bool(text[:m.start()].strip() == "")
        for ln in ((i, i + 1) if own_line else (i,)):
            prev = out.get(ln, set())
            if rules is None or prev is None:
                out[ln] = None
            else:
                out[ln] = prev | rules
    return out


def apply_inline(findings: Sequence[Finding], source: str
                 ) -> Tuple[List[Finding], List[Finding]]:
    """Split findings of ONE file by its inline-ignore comments."""
    supp = inline_suppressions(source)
    kept, silenced = [], []
    for f in findings:
        rules = supp.get(f.line, set())
        if rules is None or (rules and f.rule in rules):
            silenced.append(f)
        else:
            kept.append(f)
    return kept, silenced


# ---------------------------------------------------------------------------
# Baseline file
# ---------------------------------------------------------------------------
_KV_RE = re.compile(r'^\s*([A-Za-z_]+)\s*=\s*(".*"|\d+)\s*$')


def _parse_baseline_text(text: str) -> List[Dict[str, object]]:
    """Minimal parser for the ``[[suppress]]`` TOML subset we write."""
    entries: List[Dict[str, object]] = []
    cur: Optional[Dict[str, object]] = None
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].rstrip() if not raw.lstrip(
            ).startswith("#") else ""
        if not line.strip():
            continue
        if line.strip() == "[[suppress]]":
            cur = {}
            entries.append(cur)
            continue
        m = _KV_RE.match(line)
        if m and cur is not None:
            v = m.group(2)
            cur[m.group(1)] = (int(v) if not v.startswith('"')
                               else json.loads(v))
        elif cur is None:
            raise ValueError(f"baseline: unexpected line {raw!r}")
    return entries


def load_baseline(path: str) -> List[Dict[str, object]]:
    """Parse ``plancheck_baseline.toml`` -> list of suppress entries.
    Missing file -> empty baseline.  Every entry must carry a
    ``reason`` — an unexplained suppression is itself an error."""
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except FileNotFoundError:
        return []
    try:
        import tomllib
        entries = tomllib.loads(raw.decode()).get("suppress", [])
    except ModuleNotFoundError:
        entries = _parse_baseline_text(raw.decode())
    for e in entries:
        if not str(e.get("reason", "")).strip():
            raise ValueError(
                f"baseline entry {e!r} has no reason: every suppression "
                f"must justify itself")
    return list(entries)


def _entry_matches(entry: Dict[str, object], f: Finding) -> bool:
    if entry.get("rule") not in (None, f.rule):
        return False
    if entry.get("file") not in (None, f.file):
        return False
    if entry.get("tag") not in (None, "", f.tag):
        return False
    return True


def apply_baseline(findings: Sequence[Finding],
                   baseline: Sequence[Dict[str, object]]
                   ) -> Tuple[List[Finding], List[Finding]]:
    """(new findings, baselined findings)."""
    kept, silenced = [], []
    for f in findings:
        if any(_entry_matches(e, f) for e in baseline):
            silenced.append(f)
        else:
            kept.append(f)
    return kept, silenced


def format_baseline(findings: Sequence[Finding],
                    reason: str = "TODO: justify") -> str:
    """Render findings as a baseline file body (``--write-baseline``)."""
    blocks = ["# plancheck suppression baseline.  Every entry MUST",
              "# carry a reason; delete entries as the findings are",
              "# fixed.  Matching is (rule, file, tag) — line numbers",
              "# deliberately don't participate.", ""]
    for f in findings:
        blocks.append("[[suppress]]")
        blocks.append(f'rule = "{f.rule}"')
        blocks.append(f'file = "{f.file}"')
        if f.tag:
            blocks.append(f'tag = "{f.tag}"')
        blocks.append(f'reason = "{reason}"')
        blocks.append("")
    return "\n".join(blocks)
