"""Pass 1: plan-time jaxpr analysis of experiment dispatch buckets.

For each :class:`repro.core.experiment.BucketPlan` of a lowered
:class:`ExecutionPlan`, the pass traces the bucket's batched executable
at the plan-predicted abstract shapes (reusing the AOT machinery:
``experiment._bucket_avals`` + the lru-cached ``campaign._executable``
— ZERO execution, and the trace it pays is shared with any later
compile of the same bucket) and walks the jaxpr for the invariants the
engine otherwise enforces only by convention:

``PC-JAX-RETRACE``
    A weak-typed abstract input.  Weak types mean a Python scalar
    reached the traced call as an operand; jit keys on weak-typedness,
    so two call sites spelling the same value differently fork the
    cache ("data as arguments" only amortises when the argument avals
    are canonical).
``PC-JAX-CONST``
    A large array constant captured by value.  The PR-3 contract is
    that data/topology arrays are ARGUMENTS of the cached executable;
    a captured array means the executable is pinned to one dataset and
    retraces per dataset.
``PC-JAX-SYNC``
    A host-callback / infeed-outfeed primitive anywhere in the bucket's
    program.  Inside a vmapped scenario core such a primitive
    serialises the whole batch on host round-trips.
``PC-JAX-BUDGET``
    Recursive equation count vs the bucket kind's named budget
    (:mod:`repro.analysis.plancheck.budgets`) — the formalised
    ``trace_alive_mask`` O(1)-in-max_events guard, applied to whole
    cores.

:func:`check_jaxpr` is the reusable single-program version the fixture
battery (and any future pass) drives directly.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.plancheck import budgets as _budgets
from repro.analysis.plancheck.findings import Finding, finding

#: primitive names that imply a host round-trip inside the program
HOST_SYNC_PRIMS = {"infeed", "outfeed", "host_callback"}
_SYNC_SUBSTRING = "callback"          # pure_callback / io_callback /
#                                       debug_callback / custom variants

#: array constants at or above this element count are "data captured by
#: value" (topology-sized captures are fine; dataset-sized are not)
CONST_ELEMENT_THRESHOLD = 64


def _as_closed(jaxpr):
    """Duck-typed (jaxpr, consts) of a Jaxpr or ClosedJaxpr."""
    if hasattr(jaxpr, "jaxpr"):
        return jaxpr.jaxpr, list(getattr(jaxpr, "consts", ()))
    return jaxpr, []


def iter_jaxprs(closed) -> Iterable[Tuple[object, list]]:
    """Every (jaxpr, consts) pair reachable from ``closed`` — the top
    level plus scan/cond/pjit bodies, recursively."""
    jaxpr, consts = _as_closed(closed)
    yield jaxpr, consts
    for eqn in jaxpr.eqns:
        for sub in _budgets.subjaxprs(eqn):
            yield from iter_jaxprs(sub)


def _aval_weak(aval) -> bool:
    return bool(getattr(aval, "weak_type", False))


def trace_closed_jaxpr(fn, abstract_args):
    """ClosedJaxpr of ``fn`` at ``abstract_args`` without executing or
    compiling anything (works for plain callables and jit wrappers
    alike)."""
    return jax.make_jaxpr(fn)(*abstract_args)


def check_jaxpr(closed, where: str, file: str = "",
                budget: Optional[str] = None,
                const_threshold: int = CONST_ELEMENT_THRESHOLD
                ) -> List[Finding]:
    """All pass-1 findings of one traced program (see module
    docstring).  ``where`` tags the findings (bucket name / fixture
    name); ``budget`` optionally names a :data:`budgets.BUDGETS`
    entry."""
    out: List[Finding] = []
    file = file or where
    top_jaxpr, _ = _as_closed(closed)

    for var in top_jaxpr.invars:
        if _aval_weak(var.aval):
            out.append(finding(
                "PC-JAX-RETRACE", file, 0,
                f"{where}: weak-typed input {var.aval.str_short()} — a "
                f"Python scalar reached the traced call as an operand "
                f"and will fork the jit cache key per spelling",
                hint="wrap the operand in jnp.asarray(..., dtype) "
                     "before the batched call (seeds/epochs must enter "
                     "as canonical int32 arrays)",
                tag=f"{where}:retrace"))
            break                      # one finding per program

    seen_const = seen_sync = False
    total = 0
    for jaxpr, consts in iter_jaxprs(closed):
        total += len(jaxpr.eqns)
        if not seen_const:
            for c in consts:
                size = int(np.size(c)) if hasattr(c, "shape") else 0
                if size >= const_threshold:
                    out.append(finding(
                        "PC-JAX-CONST", file, 0,
                        f"{where}: array of {size} elements captured "
                        f"by value in the jaxpr — the executable is "
                        f"pinned to this data and retraces per "
                        f"dataset",
                        hint="pass data/topology arrays as operands of "
                             "the batched call (the PR-3 'data as "
                             "arguments' contract)",
                        tag=f"{where}:const"))
                    seen_const = True
                    break
        if not seen_sync:
            for eqn in jaxpr.eqns:
                name = eqn.primitive.name
                if name in HOST_SYNC_PRIMS or _SYNC_SUBSTRING in name:
                    out.append(finding(
                        "PC-JAX-SYNC", file, 0,
                        f"{where}: host primitive '{name}' inside the "
                        f"program — an implicit host-device sync "
                        f"serialises the vmapped scenario batch",
                        hint="drop jax.debug.print / callbacks from "
                             "batched cores; surface values through "
                             "the outputs pytree instead",
                        tag=f"{where}:sync"))
                    seen_sync = True
                    break

    if budget is not None:
        b = _budgets.check_budget(budget, total, where=where, file=file)
        if b is not None:
            out.append(b)
    return out


def check_plan(plan, data=None, budgets: bool = True) -> List[Finding]:
    """Pass 1 over every bucket of an ``ExecutionPlan``.

    ``data`` defaults to the plan's own spec data.  Tracing a bucket
    bumps ``campaign.TRACE_COUNT`` exactly like a first compile would
    (the lru trace cache is shared, so a later ``execute()`` of the
    same plan re-traces nothing)."""
    from repro.core import campaign as _c
    from repro.core import experiment as _x

    data = data or plan.spec.data
    # the detector's budget_family picks the named eqn ceilings the
    # buckets are checked against ("ae" = the historical names)
    family = getattr(getattr(data, "model", None), "budget_family", "ae")
    out: List[Finding] = []
    for bucket in plan.buckets:
        cells = [plan.cells[i] for i in bucket.cell_indices]
        avals = _x._bucket_avals(data, bucket, cells)
        jitted = _c._executable(*_x._bucket_exe_args(data, bucket))
        where = (f"bucket {bucket.index} ({bucket.kind}"
                 f"{' fused' if bucket.fused else ''})")
        closed = trace_closed_jaxpr(jitted, avals)
        out.extend(check_jaxpr(
            closed, where, file=f"plan://bucket{bucket.index}",
            budget=(_budgets.bucket_budget_name(bucket.kind,
                                                bucket.fused, family)
                    if budgets else None)))
    return out
