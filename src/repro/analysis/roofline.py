"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), seconds:

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

Sources: ``compiled.cost_analysis()`` (per-chip — XLA reports the SPMD
per-device program) for flops/bytes; collective bytes are parsed out of
the (post-SPMD) HLO text by summing the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# result shapes: "bf16[1,2,3]{...}" or tuple "(f32[8]{0}, f32[8]{0})"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes summed over the module.

    '-start'/'-done' async pairs are counted once (the -done op carries no
    new transfer)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = m.group(0)
        if "-done(" in line:
            continue
        out[kind] += _shape_bytes(shape_str)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, int]
    model_flops: float            # 6 N D (active params) global
    memory_per_device: Optional[float] = None   # from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops — catches remat/redundancy."""
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops / hlo_global if hlo_global else float("nan")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def model_flops_for(cfg, shape, mode: str) -> float:
    """6 N D for training; 2 N D for inference, D = tokens processed."""
    n_active = cfg.active_param_count()
    if mode == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if mode == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def build_roofline(arch: str, shape_name: str, mesh_name: str, chips: int,
                   cost: dict, hlo_text: str, model_flops: float,
                   memory_per_device: Optional[float] = None) -> Roofline:
    coll = collective_bytes(hlo_text)
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_chip=float(cost.get("flops", 0.0)),
        bytes_per_chip=float(cost.get("bytes accessed", 0.0)),
        coll_bytes_per_chip=float(sum(coll.values())),
        coll_breakdown=coll, model_flops=model_flops,
        memory_per_device=memory_per_device)


def format_table(rows) -> str:
    hdr = (f"{'arch':28s} {'shape':12s} {'mesh':10s} "
           f"{'t_comp(s)':>10s} {'t_mem(s)':>10s} {'t_coll(s)':>10s} "
           f"{'bottleneck':>10s} {'useful':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:28s} {r.shape:12s} {r.mesh:10s} "
            f"{r.t_compute:10.3e} {r.t_memory:10.3e} {r.t_collective:10.3e} "
            f"{r.bottleneck:>10s} {r.useful_flops_ratio:7.3f}")
    return "\n".join(lines)
