"""One import surface for the reproduction's experiment pipeline.

Everything a study needs — declare a spec, lower it to a plan, execute
it, and the legacy imperative entry points — re-exported from one
place::

    from repro import api

    spec = api.ExperimentSpec(...)
    res = api.run_experiment(spec)          # == execute(plan(spec))

See :mod:`repro.core.experiment` for the spec -> plan -> execute
contract and :mod:`repro.core.campaign` for the execution mechanism.
"""
from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core.baselines import (FaultyMultiModelConfig, MultiModelConfig,
                                  MultiModelResult, run_multimodel)
from repro.core.campaign import (MULTI_SCHEMES, CampaignResult, ExecPlan,
                                 MultiCampaignResult,
                                 clear_executable_caches, mean_ci95,
                                 run_campaign, run_fused_campaigns,
                                 run_fused_multimodel_campaigns,
                                 run_multimodel_campaign, sweep_grid)
from repro.core.compilecache import (disable_persistent_cache,
                                     enable_persistent_cache,
                                     persistent_cache_dir,
                                     xla_compile_stats)
from repro.core.experiment import (SINGLE_SCHEMES, BucketCompileStats,
                                   BucketPlan, CellPlan, CellSpec,
                                   CompileReport, DataSpec, ExecutionPlan,
                                   ExperimentResult, ExperimentSpec,
                                   SeedSpec, TraceSpec, cell, execute,
                                   plan, run_experiment)
from repro.core.failure import (MAX_EVENTS, NO_FAILURE, FailureEvent,
                                FailureSpec, FailureTrace, sample_rate_grid,
                                sample_traces, trace_faulty_scale)
from repro.core.processes import (FAMILIES, ClusterCascadeProcess,
                                  FailureProcess, FaultyUpdateProcess,
                                  IidRateProcess, MarkovChurnProcess,
                                  ProcessGrid, StragglerProcess,
                                  family_process, process_seed)
from repro.core.simulate import (FaultySimConfig, SimConfig, SimResult,
                                 run_simulation)
from repro.core.topology import Topology
from repro.core.simulate import trained_params
from repro.models.detector import (AutoencoderDetector, DetectorModel,
                                   SeqDetector, as_detector, detector_names,
                                   make_detector, register_detector)
from repro.serving.anomaly import (AnomalyService, ModelBank, ScoredWindow,
                                   ServiceConfig, ServiceReport,
                                   train_model_bank)

__all__ = [
    # declarative pipeline
    "ExperimentSpec", "DataSpec", "CellSpec", "TraceSpec", "SeedSpec",
    "cell", "plan", "execute", "run_experiment", "ExecutionPlan",
    "CellPlan", "BucketPlan", "ExperimentResult",
    # execution policy + results
    "ExecPlan", "CampaignResult", "MultiCampaignResult", "mean_ci95",
    # compilation & caching
    "CompileReport", "BucketCompileStats", "clear_executable_caches",
    "enable_persistent_cache", "disable_persistent_cache",
    "persistent_cache_dir", "xla_compile_stats",
    # configs / schemes
    "AutoencoderConfig", "SimConfig", "MultiModelConfig", "Topology",
    "SINGLE_SCHEMES", "MULTI_SCHEMES",
    # detector bodies (pluggable model specs)
    "DetectorModel", "AutoencoderDetector", "SeqDetector", "as_detector",
    "make_detector", "register_detector", "detector_names",
    # failure model
    "FailureSpec", "FailureEvent", "FailureTrace", "NO_FAILURE",
    "MAX_EVENTS", "sample_traces", "sample_rate_grid",
    # generative failure processes (fault injection)
    "FailureProcess", "IidRateProcess", "MarkovChurnProcess",
    "ClusterCascadeProcess", "StragglerProcess", "FaultyUpdateProcess",
    "ProcessGrid", "FAMILIES", "family_process", "process_seed",
    "trace_faulty_scale", "FaultySimConfig", "FaultyMultiModelConfig",
    # serving: the live anomaly-scoring service under failure
    "AnomalyService", "ServiceConfig", "ServiceReport", "ScoredWindow",
    "ModelBank", "train_model_bank", "trained_params",
    # legacy imperative entry points (thin shims over the pipeline)
    "run_simulation", "SimResult", "run_multimodel", "MultiModelResult",
    "run_campaign", "run_multimodel_campaign", "sweep_grid",
    "run_fused_campaigns", "run_fused_multimodel_campaigns",
]
