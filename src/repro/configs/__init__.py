from repro.configs.base import (AttentionConfig, FrontendConfig, InputShape,
                                INPUT_SHAPES, MeshConfig, ModelConfig,
                                MoEConfig, OptimizerConfig, RecurrentConfig,
                                RunConfig, TolFLConfig)
from repro.configs.registry import ARCHS, ASSIGNED, get_arch

__all__ = [
    "AttentionConfig", "FrontendConfig", "InputShape", "INPUT_SHAPES",
    "MeshConfig", "ModelConfig", "MoEConfig", "OptimizerConfig",
    "RecurrentConfig", "RunConfig", "TolFLConfig", "ARCHS", "ASSIGNED",
    "get_arch",
]
