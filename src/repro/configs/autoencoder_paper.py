"""The paper's anomaly-detection autoencoder (Section V-A).

Fully-connected encoder/decoder, three hidden layers with 64-128 neurons,
code vector length 32, ReLU hidden activations, linear output, dropout 0.2.
Trained to minimise reconstruction error ||x - x_hat||^2; the reconstruction
error is the anomaly score.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class AutoencoderConfig:
    name: str = "paper-autoencoder"
    input_dim: int = 112                       # Comms-ML sample shape 112x1
    hidden: Tuple[int, ...] = (128, 64)        # encoder hidden layers
    code_dim: int = 32
    dropout: float = 0.2
    act: str = "relu"


# per-dataset variants used by the paper (Table VII)
COMMSML = AutoencoderConfig(input_dim=112)
FMNIST = AutoencoderConfig(name="paper-autoencoder-fmnist", input_dim=784)
CIFAR10 = AutoencoderConfig(name="paper-autoencoder-cifar10", input_dim=3072)
CIFAR100 = AutoencoderConfig(name="paper-autoencoder-cifar100", input_dim=3072)

CONFIG = COMMSML
