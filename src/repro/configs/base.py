"""Config system for the Tol-FL framework.

Plain dataclasses, no external deps.  Every assigned architecture gets one
module in this package exporting ``CONFIG`` (a :class:`ModelConfig`), and the
registry in :mod:`repro.configs.registry` maps ``--arch <id>`` to it.

Design notes
------------
* ``ModelConfig`` is a superset covering all six architecture families
  (dense / moe / ssm / hybrid / audio / vlm).  Family-specific fields are
  ignored by families that do not use them.
* ``reduced()`` produces the CPU-smoke-test variant of the same family
  (2 layers, d_model<=512, <=4 experts) required by the brief.
* Everything is hashable (tuples, not lists) so configs can be closed over
  by jitted functions without retracing surprises.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Architecture families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"        # attention-free (RWKV6)
HYBRID = "hybrid"  # RG-LRU + local attention (RecurrentGemma)
AUDIO = "audio"    # encoder-decoder with stubbed conv/mel frontend (Whisper)
VLM = "vlm"        # decoder with stubbed vision frontend (InternVL2)

FAMILIES = (DENSE, MOE, SSM, HYBRID, AUDIO, VLM)

# Layer kinds used by the hybrid pattern.
ATTN = "attn"          # global attention
LOCAL_ATTN = "local"   # sliding-window / local attention
RECURRENT = "rec"      # RG-LRU recurrent block
RWKV = "rwkv"          # RWKV6 time-mix block


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int = 8
    num_kv_heads: int = 8          # GQA: kv heads <= heads
    head_dim: int = 128
    qk_norm: bool = False          # Qwen3-style per-head RMSNorm on q,k
    qkv_bias: bool = False         # Qwen1.5-style bias on qkv projections
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # None => full causal attention
    causal: bool = True
    # Beyond-paper extension: dense archs may select a sliding-window variant
    # for the long_500k shape (DESIGN.md section 4).
    long_context_window: int = 4096


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0           # 0 => dense MLP
    num_experts_per_tok: int = 1   # top-k routing (Llama-4: top-1)
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01
    # every `interleave` layers is MoE; others dense (Llama-4 interleaves;
    # we default to all-MoE when num_experts>0 and interleave==1)
    interleave: int = 1
    shared_expert: bool = False


@dataclass(frozen=True)
class RecurrentConfig:
    """RG-LRU (RecurrentGemma) / RWKV6 recurrence parameters."""
    lru_width: Optional[int] = None     # defaults to d_model
    conv1d_width: int = 4               # temporal conv in recurrent block
    num_heads: int = 8                  # rwkv heads = d_model // head_size
    head_size: int = 64
    # hybrid pattern: e.g. ("rec", "rec", "local") repeated => 1:2 attn ratio
    block_pattern: Tuple[str, ...] = ()


@dataclass(frozen=True)
class FrontendConfig:
    """Stubbed modality frontend (audio conv / vision patch embedder).

    Per the brief the frontend itself is NOT implemented; ``input_specs``
    provides precomputed embeddings of shape (batch, frontend_seq, d_model)
    for the encoder (audio) or prefix tokens (vlm).
    """
    kind: str = "none"                  # "audio" | "vision" | "none"
    frontend_seq: int = 0               # frames / patches after the stub
    frontend_dim: int = 0               # embedding dim handed to backbone


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = DENSE
    citation: str = ""
    num_layers: int = 2
    d_model: int = 256
    d_ff: int = 1024
    vocab_size: int = 32000
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    recurrent: RecurrentConfig = field(default_factory=RecurrentConfig)
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # encoder-decoder (whisper): encoder layer count; 0 => decoder-only
    num_encoder_layers: int = 0
    encoder_seq: int = 0                 # encoder positions (whisper: 1500)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"                    # mlp activation
    glu: bool = True                     # gated linear unit mlp
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # remat policy for the layer scan: "none" | "full" | "dots_saveable"
    remat: str = "full"
    max_seq_len: int = 8192

    # ---------------- derived ----------------
    @property
    def head_dim(self) -> int:
        return self.attention.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def layer_pattern(self) -> Tuple[str, ...]:
        """Per-layer kind sequence of length num_layers."""
        pat = self.recurrent.block_pattern
        if not pat:
            base = (RWKV,) if self.family == SSM else (ATTN,)
            return base * self.num_layers
        reps = (self.num_layers + len(pat) - 1) // len(pat)
        return (pat * reps)[: self.num_layers]

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        a = self.attention
        total = v * d                               # embedding
        if not self.tie_embeddings:
            total += v * d
        q = a.num_heads * a.head_dim
        kv = a.num_kv_heads * a.head_dim
        attn_p = d * q + 2 * d * kv + q * d
        if a.qkv_bias:
            attn_p += q + 2 * kv
        mlp_dense = (3 if self.glu else 2) * d * f
        rec = self.recurrent
        lru_w = rec.lru_width or d
        rglru_p = (2 * d * lru_w            # in proj (x branch + gate branch)
                   + lru_w * d              # out proj
                   + rec.conv1d_width * lru_w
                   + 2 * lru_w)             # a-param + input gate
        rwkv_p = (4 * d * d                 # r,k,v,g (o folded into v-ish) …
                  + d * d                   # output
                  + 6 * d                   # mu / decay params
                  + 2 * d * 64)             # lora-style ddlerp adapters
        for li, kind in enumerate(self.layer_pattern):
            total += 2 * d                  # norms
            if kind == ATTN or kind == LOCAL_ATTN:
                total += attn_p
            elif kind == RECURRENT:
                total += rglru_p
            elif kind == RWKV:
                total += rwkv_p
            # mlp / moe (moe only on every `interleave`-th layer)
            m = self.moe
            if m.num_experts > 0 and li % m.interleave == 0:
                total += d * m.num_experts                   # router
                total += m.num_experts * mlp_dense
                if m.shared_expert:
                    total += mlp_dense
            else:
                total += mlp_dense
        if self.is_encdec:
            enc_layer = 2 * d + attn_p + mlp_dense
            total += self.num_encoder_layers * enc_layer
        total += d                                           # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe.num_experts == 0:
            return self.param_count()
        m = self.moe
        dense_like = dataclasses.replace(self, moe=MoEConfig(num_experts=0))
        per_expert = (3 if self.glu else 2) * self.d_model * self.d_ff
        n_moe_layers = sum(1 for li in range(self.num_layers)
                           if li % m.interleave == 0)
        extra_per_moe = (self.d_model * m.num_experts
                         + m.num_experts_per_tok * per_expert
                         + (per_expert if m.shared_expert else 0)
                         - per_expert)  # replaces the dense mlp counted above
        return dense_like.param_count() + n_moe_layers * extra_per_moe

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant of the same family (brief requirement:
        2 layers, d_model<=512, <=4 experts)."""
        d = min(self.d_model, 256)
        heads = min(self.attention.num_heads, 4)
        kvh = max(1, min(self.attention.num_kv_heads, heads))
        att = dataclasses.replace(
            self.attention, num_heads=heads, num_kv_heads=kvh,
            head_dim=d // heads if d // heads >= 8 else 8,
            sliding_window=(64 if self.attention.sliding_window else None),
            long_context_window=64,
        )
        moe = dataclasses.replace(
            self.moe,
            num_experts=min(self.moe.num_experts, 4) if self.moe.num_experts else 0)
        rec = dataclasses.replace(
            self.recurrent,
            lru_width=d if self.recurrent.lru_width else None,
            num_heads=max(1, min(self.recurrent.num_heads, 4)),
            head_size=d // max(1, min(self.recurrent.num_heads, 4)),
            # keep one recurrent + one local-attn layer so the reduced
            # hybrid still exercises both block kinds in 2 layers
            block_pattern=((RECURRENT, LOCAL_ATTN)
                           if self.recurrent.block_pattern else ()))
        return dataclasses.replace(
            self, name=self.name + "-reduced", num_layers=2, d_model=d,
            d_ff=min(self.d_ff, 512), vocab_size=min(self.vocab_size, 1024),
            attention=att, moe=moe, recurrent=rec,
            num_encoder_layers=min(self.num_encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            frontend=dataclasses.replace(
                self.frontend,
                frontend_seq=min(self.frontend.frontend_seq, 16),
                frontend_dim=d if self.frontend.frontend_dim else 0),
            max_seq_len=512, remat="none", dtype="float32")


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Training / run config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TolFLConfig:
    """The paper's technique: hierarchical aggregation over the data axis.

    num_clusters == 1  -> plain FedAvg (FL)
    num_clusters == N  -> SBT (flat ring)
    1 < k < N          -> Tol-FL proper
    """
    num_clusters: int = 4
    # "tolfl_ring": paper-faithful — psum inside clusters + sequential
    #               ppermute chain over cluster heads (Algorithm 1).
    # "tolfl_psum": beyond-paper — algebraically identical weighted psum.
    # "fedavg":     single global psum with a designated server coordinate.
    # "sbt_ring":   full sequential ring (k = N).
    schedule: str = "tolfl_ring"
    local_epochs: int = 1          # E: local steps per round
    server_coord: int = 0          # which member index acts as cluster head
    pod_ring: bool = True          # multi-pod: SBT ring over the pod axis
    # ---- beyond-paper perf levers (EXPERIMENTS.md section Perf) ----
    # dtype the gradients are cast to for the cross-device sync
    # (psum / ppermute payload); f32 master grads are restored after.
    grad_sync_dtype: Optional[str] = None        # e.g. "bfloat16"
    # gradient accumulation: split the per-shard batch into m microbatches
    # scanned sequentially — divides activation memory by m.
    microbatches: int = 1
    # cast the param tree once at step start so FSDP all-gathers move this
    # dtype instead of the f32 master copy.
    param_cast_dtype: Optional[str] = None       # e.g. "bfloat16"


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adam"             # "sgd" | "adam" | "adamw"
    lr: float = 1e-3
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"       # "constant" | "cosine" | "linear"
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def multi_pod(self) -> bool:
        return self.pods > 1


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    tolfl: TolFLConfig = field(default_factory=TolFLConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    shape: InputShape = field(default_factory=lambda: INPUT_SHAPES["train_4k"])
    seed: int = 0
    use_pallas: bool = False       # Pallas kernels (interpret on CPU)
    log_every: int = 10
    ckpt_every: int = 0            # 0 => disabled
    ckpt_dir: str = "/tmp/repro_ckpt"
