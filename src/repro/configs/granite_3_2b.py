"""Granite-3.0-2B [hf:ibm-granite/granite-3.0-2b-base] — dense, GQA kv=8."""
from repro.configs.base import AttentionConfig, ModelConfig, DENSE

CONFIG = ModelConfig(
    name="granite-3-2b",
    family=DENSE,
    citation="hf:ibm-granite/granite-3.0-2b-base",
    num_layers=40,
    d_model=2048,
    d_ff=8192,
    vocab_size=49155,
    attention=AttentionConfig(
        num_heads=32, num_kv_heads=8, head_dim=64, rope_theta=1e6),
    tie_embeddings=True,
)
