"""InternLM2-1.8B [arXiv:2403.17297] — dense, GQA kv=8."""
from repro.configs.base import AttentionConfig, ModelConfig, DENSE

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family=DENSE,
    citation="arXiv:2403.17297",
    num_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=92544,
    attention=AttentionConfig(
        num_heads=16, num_kv_heads=8, head_dim=128, rope_theta=1e6),
    tie_embeddings=False,
)
