"""InternVL2-26B [arXiv:2404.16821] — VLM: InternViT vision encoder (STUB —
input_specs() provides projected patch embeddings) + InternLM2-20B-class
language backbone (48L, d_model=6144, GQA kv=8)."""
from repro.configs.base import (AttentionConfig, FrontendConfig, ModelConfig,
                                VLM)

CONFIG = ModelConfig(
    name="internvl2-26b",
    family=VLM,
    citation="arXiv:2404.16821",
    num_layers=48,
    d_model=6144,
    d_ff=16384,
    vocab_size=92553,
    attention=AttentionConfig(
        num_heads=48, num_kv_heads=8, head_dim=128, rope_theta=1e6),
    frontend=FrontendConfig(kind="vision", frontend_seq=256,   # 256 patch toks
                            frontend_dim=6144),
    tie_embeddings=False,
)
