"""Llama-4-Scout-17B-A16E [hf:meta-llama/Llama-4-Scout-17B-16E] —
MoE 16 experts top-1, GQA kv=8, early fusion."""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig, MOE

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family=MOE,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab_size=202048,
    attention=AttentionConfig(
        num_heads=40, num_kv_heads=8, head_dim=128, rope_theta=5e5),
    moe=MoEConfig(num_experts=16, num_experts_per_tok=1,
                  capacity_factor=1.25, shared_expert=True),
    tie_embeddings=False,
)
