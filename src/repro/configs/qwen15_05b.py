"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — dense, QKV bias, MHA (kv=16)."""
from repro.configs.base import AttentionConfig, ModelConfig, DENSE

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family=DENSE,
    citation="hf:Qwen/Qwen1.5-0.5B",
    num_layers=24,
    d_model=1024,
    d_ff=2816,
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=16, num_kv_heads=16, head_dim=64,
        qkv_bias=True, rope_theta=1e6),
    tie_embeddings=True,
)
