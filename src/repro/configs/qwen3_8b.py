"""Qwen3-8B [hf:Qwen/Qwen3-8B] — dense, GQA kv=8, qk_norm."""
from repro.configs.base import AttentionConfig, ModelConfig, DENSE

CONFIG = ModelConfig(
    name="qwen3-8b",
    family=DENSE,
    citation="hf:Qwen/Qwen3-8B",
    num_layers=36,
    d_model=4096,
    d_ff=12288,
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=32, num_kv_heads=8, head_dim=128,
        qk_norm=True, rope_theta=1e6),
    tie_embeddings=False,
)
