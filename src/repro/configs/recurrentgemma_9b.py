"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427] — hybrid: RG-LRU recurrent
blocks + local (sliding-window 2048) attention in a 2:1 pattern."""
from repro.configs.base import (AttentionConfig, ModelConfig, RecurrentConfig,
                                HYBRID, LOCAL_ATTN, RECURRENT)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family=HYBRID,
    citation="arXiv:2402.19427",
    num_layers=38,
    d_model=4096,
    d_ff=12288,
    vocab_size=256000,
    attention=AttentionConfig(
        num_heads=16, num_kv_heads=1, head_dim=256,
        sliding_window=2048, rope_theta=10000.0),
    recurrent=RecurrentConfig(
        lru_width=4096, conv1d_width=4,
        block_pattern=(RECURRENT, RECURRENT, LOCAL_ATTN)),  # 1:2 attn:rec
    glu=True,
    act="gelu",
    tie_embeddings=True,
)
