"""--arch <id> registry mapping architecture ids to ModelConfigs."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig


def _load() -> Dict[str, ModelConfig]:
    from repro.configs import (granite_3_2b, internlm2_18b, internvl2_26b,
                               llama4_maverick, llama4_scout, qwen15_05b,
                               qwen3_8b, recurrentgemma_9b, rwkv6_7b,
                               whisper_large_v3)
    mods = [recurrentgemma_9b, rwkv6_7b, whisper_large_v3, internlm2_18b,
            llama4_maverick, internvl2_26b, llama4_scout, qwen3_8b,
            granite_3_2b, qwen15_05b]
    return {m.CONFIG.name: m.CONFIG for m in mods}


ARCHS: Dict[str, ModelConfig] = _load()

# ids as assigned in the brief
ASSIGNED = (
    "recurrentgemma-9b", "rwkv6-7b", "whisper-large-v3", "internlm2-1.8b",
    "llama4-maverick-400b-a17b", "internvl2-26b", "llama4-scout-17b-a16e",
    "qwen3-8b", "granite-3-2b", "qwen1.5-0.5b",
)

assert set(ASSIGNED) == set(ARCHS), (set(ASSIGNED), set(ARCHS))


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
