"""RWKV6-7B "Finch" [arXiv:2404.05892] — attention-free SSM with
data-dependent decay.  d_model=4096, 32 layers, head_size=64."""
from repro.configs.base import ModelConfig, RecurrentConfig, SSM

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family=SSM,
    citation="arXiv:2404.05892",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    recurrent=RecurrentConfig(num_heads=64, head_size=64),
    glu=False,            # rwkv channel-mix is a squared-relu 2-matrix mlp
    act="sqrelu",
    tie_embeddings=False,
)
