"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder audio backbone.
Conv/mel frontend is a STUB: input_specs() provides precomputed frame
embeddings (batch, 1500, 1280) for the encoder."""
from repro.configs.base import (AttentionConfig, FrontendConfig, ModelConfig,
                                AUDIO)

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family=AUDIO,
    citation="arXiv:2212.04356",
    num_layers=32,                 # decoder layers
    num_encoder_layers=32,
    encoder_seq=1500,              # 30 s of audio at 50 Hz after conv stub
    d_model=1280,
    d_ff=5120,
    vocab_size=51866,
    attention=AttentionConfig(
        num_heads=20, num_kv_heads=20, head_dim=64,
        qkv_bias=True, rope_theta=0.0),   # whisper uses learned abs pos
    frontend=FrontendConfig(kind="audio", frontend_seq=1500,
                            frontend_dim=1280),
    glu=False,
    act="gelu",
    tie_embeddings=True,
)
