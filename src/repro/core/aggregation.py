"""Tol-FL aggregation algebra (paper Algorithm 1 / 2, Appendix A eq. 1-2).

The streaming weighted mean:

    n <- n + n_i
    r  = n_i / n
    g <- r g_i + (1 - r) g

applied over clusters (Tol-FL) or devices (SBT).  The central mathematical
property — the paper's k-invariance — is that this equals the direct
sample-weighted mean regardless of grouping; ``tests/test_tolfl_invariance``
property-tests it.  These are *pure pytree functions* shared by the
paper-scale simulator and the mesh engine (which realises the same algebra
with psum / ppermute collectives).
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def combine_pair(n_a: jax.Array, g_a: Pytree, n_b: jax.Array, g_b: Pytree
                 ) -> Tuple[jax.Array, Pytree]:
    """One streaming-mean step: absorb (n_b, g_b) into running (n_a, g_a).

    Weights are sample counts; zero-count operands are absorbed as no-ops
    (the failure-masking path)."""
    n = n_a + n_b
    r = jnp.where(n > 0, n_b / jnp.maximum(n, 1e-30), 0.0)
    g = jax.tree.map(
        lambda a, b: (1.0 - r).astype(a.dtype) * a + r.astype(a.dtype) * b,
        g_a, g_b)
    return n, g


def streaming_weighted_mean(gs: Sequence[Pytree], ns: Sequence[jax.Array]
                            ) -> Tuple[jax.Array, Pytree]:
    """Sequential SBT combine over a python sequence (Algorithm 2)."""
    n = jnp.zeros(())
    g = jax.tree.map(jnp.zeros_like, gs[0])
    for gi, ni in zip(gs, ns):
        n, g = combine_pair(n, g, ni, gi)
    return n, g


def stacked_streaming_mean(gs: Pytree, ns: jax.Array, unroll: int = 16
                           ) -> Tuple[jax.Array, Pytree]:
    """Same, but inputs stacked on a leading axis — the jit-friendly
    form used by the simulator.

    Small stacks (cluster counts; ``k <= unroll``) combine through a
    Python-unrolled chain of the SAME ``combine_pair`` steps in the same
    order — bit-identical to the ``lax.scan`` form, but one fusable
    elementwise graph instead of a sequential while-loop, which is a
    measurable win inside the batched campaign round loop where the
    combine competes with the gradient work.  Large stacks keep the
    scan (bounded graph size)."""
    if ns.shape[0] <= unroll:
        n = jnp.zeros(())
        g = jax.tree.map(lambda x: jnp.zeros_like(x[0]), gs)
        for j in range(ns.shape[0]):
            n, g = combine_pair(n, g, ns[j],
                                jax.tree.map(lambda x: x[j], gs))
        return n, g

    def step(carry, xs):
        n, g = carry
        ni, gi = xs
        return combine_pair(n, g, ni, gi), None

    g0 = jax.tree.map(lambda x: jnp.zeros_like(x[0]), gs)
    (n, g), _ = jax.lax.scan(step, (jnp.zeros(()), g0), (ns, gs))
    return n, g


def weighted_mean(gs: Pytree, ns: jax.Array) -> Pytree:
    """Direct sample-weighted mean over a stacked leading axis (the
    algebraically-equal 'optimised' form — one fused reduction)."""
    tot = jnp.maximum(jnp.sum(ns), 1e-30)
    w = ns / tot
    def wm(x):
        wr = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        return jnp.sum(wr * x, axis=0)
    return jax.tree.map(wm, gs)


def cluster_reduce(gs: Pytree, ns: jax.Array, cluster_ids: jax.Array,
                   num_clusters: int) -> Tuple[Pytree, jax.Array]:
    """Per-cluster FedAvg (Algorithm 1 inner loop): stacked device grads
    (N, ...) -> cluster grads (k, ...) + counts (k,)."""
    onehot = jax.nn.one_hot(cluster_ids, num_clusters, dtype=jnp.float32)
    n_c = onehot.T @ ns                                     # (k,)
    def red(x):
        flat = x.reshape(x.shape[0], -1).astype(jnp.float32)
        num = onehot.T @ (flat * ns[:, None])
        return (num / jnp.maximum(n_c[:, None], 1e-30)).reshape(
            (num_clusters,) + x.shape[1:]).astype(x.dtype)
    return jax.tree.map(red, gs), n_c
