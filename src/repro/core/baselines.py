"""Clustered-FL baselines the paper compares against (Section V-A).

* **FedGroup** [arXiv:2010.06870] — static grouping by a data-driven
  measure: devices are clustered once (cosine similarity of their initial
  local updates, k-means in gradient space), then per-group FedAvg.
* **IFCA** [NeurIPS'20] — iterative: every round each device picks the
  model with the lowest loss on its local data, trains it, and models are
  aggregated over their adopters.
* **FeSEM** [arXiv:2005.01026] — multi-center EM: devices are assigned to
  the nearest center in parameter space after a local step; centers move
  to the weighted mean of their members.

All train M model instances.  Reporting matches the paper's columns:
``best`` (*) = highest test AUROC of any single instance; ``multi`` (†) =
per-sample min reconstruction error over instances (the multi-model
oracle score).

Like :mod:`repro.core.simulate`, the whole engine is a pure *core*
function whose only dynamic inputs are ``(dx, counts, valid, tx,
model_valid, trace, seed)`` — everything else (scheme, rounds, ...) is
closed over statically, and ``cfg.num_models`` is only the STATIC model
axis length: the live-model count arrives in the ``model_valid`` mask,
so (scheme, M) sweep cells padded to a common max M share one compiled
executable (:func:`repro.core.campaign.sweep_grid`'s fused dispatch).
The trace split into client events (device mask) and server events
(group mask) happens in-graph, so the core ``vmap``s over stacked
traces: :func:`repro.core.campaign.run_multimodel_campaign` sweeps a
whole (trace x seed) grid through ONE compiled executable, while
:func:`run_multimodel` stays the single-scenario entry point on the
same cached jitted core.  Declaratively, a multi-model cell is just a
``CellSpec("ifca", M)`` in an :class:`repro.core.experiment.
ExperimentSpec` — the planner groups same-scheme cells into one
padded-M bucket.

Failure semantics: a *client* failure removes that device; a *server*
failure kills the aggregator of group 0 — that instance freezes and its
devices stop contributing (they keep their last model for evaluation).

Default targets differ by encoding: a legacy ``FailureSpec`` with
``device=None`` kills device N-1 here (there are no cluster heads to
anchor the Tol-FL default), while a ``FailureTrace`` carries explicit
device ids resolved against the Tol-FL topology at construction time.
Pass an explicit ``device`` when comparing the two encodings.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.failure import (Failure, FailureTrace, KIND_CODES,
                                MAX_EVENTS, NO_FAILURE, PAD_EPOCH,
                                trace_alive_mask, trace_faulty_scale)
from repro.models import detector as D
from repro.models.detector import ModelLike
from repro.training.metrics import auroc_batch


@dataclass(frozen=True)
class MultiModelConfig:
    scheme: str = "ifca"          # fedgroup | ifca | fesem
    num_devices: int = 10
    num_models: int = 3
    rounds: int = 100
    lr: float = 1e-4
    dropout: bool = True
    seed: int = 0


@dataclass(frozen=True)
class FaultyMultiModelConfig(MultiModelConfig):
    """Faulty-update variant of the multi-model engine: per-device
    deltas are scaled by the ORIGINAL trace's faulty channel before the
    per-model aggregation (assignment probes stay clean — a faulty
    device corrupts what it sends, not how it measures).  A distinct
    frozen subclass for the same reason as
    :class:`repro.core.simulate.FaultySimConfig`: class identity keys
    the cached cores/fingerprints while plain-config keys stay
    bit-identical."""
    faulty_updates: bool = True


@dataclass
class MultiModelResult:
    best_auroc: float             # the paper's * column
    multi_auroc: float            # the paper's dagger column
    loss_curve: np.ndarray
    assignments: np.ndarray       # final device -> model map


class MultiOutputs(NamedTuple):
    """Raw in-graph outputs of one multi-model scenario (pre-AUROC)."""
    losses: jax.Array             # (rounds,) per-sample-min test loss
    final_scores: jax.Array       # (M, T) per-instance anomaly scores
    assignments: jax.Array        # (N,) final device -> model map


def _grad_fn(model: ModelLike, dropout: bool):
    det = D.as_detector(model)

    def local_loss(params, x, valid, key):
        return det.loss(params, x, valid, key if dropout else None)
    return local_loss, jax.grad(local_loss)


def _flat(tree):
    return jnp.concatenate([t.ravel() for t in jax.tree.leaves(tree)])


def _kmeans_groups(vectors: jax.Array, m: int, key: jax.Array,
                   iters: int = 20,
                   center_valid: Optional[jax.Array] = None) -> jax.Array:
    """Tiny in-graph k-means for FedGroup's static gradient-similarity
    grouping (cosine metric: rows are L2-normalised).

    Traceable/vmappable: centers seed from a random permutation of the
    data, and a group that empties during Lloyd iterations is RE-SEEDED
    on a random data point instead of keeping a stale center.

    ``center_valid`` (shape ``(m,)``, 1 = real) supports the padded-M
    sweep path: padded center slots never win the assignment argmax, and
    every per-center random draw is keyed on the CENTER INDEX (scalar
    ``fold_in`` draws, not one shape-``(m,)`` draw), so the assignment
    of the valid centers is bit-identical whatever ``m`` they are padded
    to.
    """
    n = vectors.shape[0]
    if m > n:
        raise ValueError(
            f"FedGroup k-means needs num_models <= num_devices to seed "
            f"distinct centers; got num_models={m} > num_devices={n}")
    v = vectors / (jnp.linalg.norm(vectors, axis=1, keepdims=True) + 1e-9)
    init_key, reseed_key = jax.random.split(key)
    centers = v[jax.random.permutation(init_key, n)[:m]]

    def masked_assign(sim):
        if center_valid is not None:
            sim = jnp.where(center_valid[None, :] > 0, sim, -jnp.inf)
        return jnp.argmax(sim, axis=1)

    def step(centers, i):
        assign = masked_assign(v @ centers.T)
        onehot = jax.nn.one_hot(assign, m, dtype=v.dtype)      # (n, m)
        cnt = jnp.sum(onehot, axis=0)                          # (m,)
        means = onehot.T @ v / jnp.maximum(cnt[:, None], 1.0)
        means = means / (jnp.linalg.norm(means, axis=1,
                                         keepdims=True) + 1e-9)
        ki = jax.random.fold_in(reseed_key, i)
        idx = jax.vmap(lambda j: jax.random.randint(
            jax.random.fold_in(ki, j), (), 0, n))(jnp.arange(m))
        reseed = v[idx]
        return jnp.where((cnt > 0)[:, None], means, reseed), None

    centers, _ = jax.lax.scan(step, centers, jnp.arange(iters))
    return masked_assign(v @ centers.T)


def as_multimodel_trace(failure: Failure, num_devices: int,
                        max_events: int = MAX_EVENTS) -> FailureTrace:
    """Normalise a failure to a trace with the BASELINE default targets.

    A legacy single-event ``FailureSpec`` with ``device=None`` resolves
    to device N-1 for client failures (there are no cluster heads here)
    and to an arbitrary device for server failures (server events kill
    group 0 whatever device they name).
    """
    if isinstance(failure, FailureTrace):
        return failure
    if failure.kind == "none":
        return FailureTrace.none(max_events)
    device = failure.device
    if device is None:
        device = num_devices - 1 if failure.kind == "client" else 0
    ep = np.full((max_events,), PAD_EPOCH, np.int32)
    dev = np.full((max_events,), -1, np.int32)
    alv = np.ones((max_events,), np.float32)
    knd = np.zeros((max_events,), np.int32)
    ep[0], dev[0], alv[0] = failure.epoch, device, 0.0
    knd[0] = KIND_CODES[failure.kind]
    return FailureTrace(jnp.asarray(ep), jnp.asarray(dev),
                        jnp.asarray(alv), jnp.asarray(knd))


def _split_trace(trace: FailureTrace) -> Tuple[FailureTrace, FailureTrace]:
    """In-graph split of one trace into (client events -> device mask,
    server events -> group-0 mask).  Pure ``jnp.where`` on the kind
    codes, so it survives ``vmap`` over stacked traces; the slots of the
    other kind keep ``PAD_EPOCH`` and never fire."""
    is_client = trace.kinds == KIND_CODES["client"]
    is_server = trace.kinds == KIND_CODES["server"]
    client_tr = FailureTrace(
        epochs=jnp.where(is_client, trace.epochs, PAD_EPOCH),
        devices=trace.devices,
        alive_after=trace.alive_after,
        kinds=trace.kinds)
    # server events all target group 0, whatever device they named
    server_tr = FailureTrace(
        epochs=jnp.where(is_server, trace.epochs, PAD_EPOCH),
        devices=jnp.zeros_like(trace.devices),
        alive_after=trace.alive_after,
        kinds=trace.kinds)
    return client_tr, server_tr


def prepare_multimodel_arrays(device_x: np.ndarray,
                              device_counts: np.ndarray):
    dx = jnp.asarray(device_x)
    counts = jnp.asarray(device_counts, jnp.float32)
    valid = (jnp.arange(device_x.shape[1])[None, :]
             < counts[:, None]).astype(jnp.float32)
    return dx, counts, valid


def _build_multimodel_core(model: ModelLike, cfg: MultiModelConfig):
    """Pure scenario function: (dx, counts, valid, tx, model_valid,
    trace, seed) -> :class:`MultiOutputs`, mirroring
    ``simulate._build_core``.

    ``model_valid`` (shape ``(M,)``, 1 = real) is the multi-model twin of
    the single-model path's ``head_valid``: ``cfg.num_models`` is only
    the STATIC length of the model axis, and the number of LIVE model
    instances arrives in the mask, so cells of a (scheme, M) sweep padded
    to a common max M share ONE compiled executable per scheme
    (:func:`repro.core.campaign.sweep_grid`).  Padded model slots never
    win an assignment, aggregate zero devices (so they stay at init), and
    are masked out of the per-sample-min loss — live-model results are
    bit-identical to the unpadded build.  All-ones restores the legacy
    semantics exactly.

    PRNG discipline: the root key splits into disjoint streams for model
    inits, FedGroup's grouping probe (probe init / probe grads / k-means
    seeding), and the training scan — grouping and training randomness
    are uncorrelated.
    """
    N, M = cfg.num_devices, cfg.num_models
    det = D.as_detector(model)
    local_loss, grad_fn = _grad_fn(det, cfg.dropout)
    # faulty-update gate (static, class-level — see FaultyMultiModelConfig)
    faulty = bool(getattr(cfg, "faulty_updates", False))

    def core(dx, counts, valid, tx, model_valid, trace: FailureTrace,
             seed):
        key = jax.random.PRNGKey(seed)
        k_init, k_group, k_train = jax.random.split(key, 3)
        # M model instances with different inits
        models = []
        for j in range(M):
            models.append(det.init_params(jax.random.fold_in(k_init, j)))
        models = jax.tree.map(lambda *xs: jnp.stack(xs), *models)

        client_tr, server_tr = _split_trace(trace)
        # number of LIVE models (a traced scalar; == M when unpadded)
        m_live = jnp.maximum(jnp.sum(model_valid), 1.0).astype(jnp.int32)

        # ---- initial assignment ----
        if cfg.scheme == "fedgroup":
            k_probe, k_pgrad, k_km = jax.random.split(k_group, 3)
            p0 = det.init_params(k_probe)
            g0 = jax.vmap(lambda x, v, k_: _flat(grad_fn(p0, x, v, k_)),
                          in_axes=(0, 0, 0))(
                dx, valid, jax.random.split(k_pgrad, N))
            assign0 = _kmeans_groups(g0, M, k_km,
                                     center_valid=model_valid)
        else:
            assign0 = jnp.arange(N) % m_live

        def device_losses(models_, x, v, k_):
            """(M,) local loss of each model instance on one device."""
            return jax.vmap(lambda p: local_loss(p, x, v, k_))(models_)

        def round_fn(carry, epoch):
            models_, assign, rkey = carry
            rkey, dkey = jax.random.split(rkey)
            dkeys = jax.random.split(dkey, N)
            a_dev = trace_alive_mask(client_tr, N, epoch)
            a_grp = trace_alive_mask(server_tr, M, epoch)

            # ---- (re)assignment ----
            # padded model slots never win: their loss/distance is +inf
            if cfg.scheme == "ifca":
                losses = jax.vmap(device_losses, in_axes=(None, 0, 0, 0))(
                    models_, dx, valid, dkeys)          # (N, M)
                losses = jnp.where(model_valid[None, :] > 0, losses,
                                   jnp.inf)
                assign = jnp.argmin(losses, axis=1)
            elif cfg.scheme == "fesem":
                # e-step: distance between one-step-updated local params
                # and each center, in parameter space
                def dev_assign(x, v, k_, a):
                    p_cur = jax.tree.map(lambda t: t[a], models_)
                    g = grad_fn(p_cur, x, v, k_)
                    upd = jax.tree.map(lambda p_, g_: p_ - cfg.lr * g_,
                                       p_cur, g)
                    fu = _flat(upd)
                    d = jax.vmap(lambda j: jnp.sum(jnp.square(
                        fu - _flat(jax.tree.map(lambda t: t[j],
                                                models_)))))(jnp.arange(M))
                    return jnp.argmin(jnp.where(model_valid > 0, d,
                                                jnp.inf))
                assign = jax.vmap(dev_assign)(dx, valid, dkeys, assign)
            # fedgroup: static

            # ---- local grads on the assigned model ----
            def dev_grad(x, v, k_, a):
                p_cur = jax.tree.map(lambda t: t[a], models_)
                return grad_fn(p_cur, x, v, k_)
            gs = jax.vmap(dev_grad)(dx, valid, dkeys, assign)
            if faulty:
                # faulty channel lives on the ORIGINAL trace's shadow
                # device range — _split_trace PADs kind-3 rows out of
                # both client/server splits, so read ``trace`` directly
                fscale = trace_faulty_scale(trace, N, epoch)
                gs = jax.tree.map(
                    lambda g_: g_ * fscale.reshape(
                        (-1,) + (1,) * (g_.ndim - 1)), gs)

            # ---- per-model weighted aggregation ----
            onehot = jax.nn.one_hot(assign, M, dtype=jnp.float32)  # (N, M)
            w = counts * a_dev
            denom = onehot.T @ w                                   # (M,)

            def agg_leaf(gleaf):
                flatg = gleaf.reshape(N, -1)
                num = onehot.T @ (flatg * w[:, None])
                mean = num / jnp.maximum(denom[:, None], 1e-30)
                return mean.reshape((M,) + gleaf.shape[1:])
            g_m = jax.tree.map(agg_leaf, gs)
            upd_gate = ((denom > 0).astype(jnp.float32) * a_grp)
            models_ = jax.tree.map(
                lambda p_, g_: p_ - cfg.lr * upd_gate.reshape(
                    (-1,) + (1,) * (g_.ndim - 1)) * g_,
                models_, g_m)

            scores = jax.vmap(
                lambda p: det.anomaly_scores(p, tx))(models_)
            # per-sample min over LIVE models only (padded slots hold
            # untrained inits whose scores must not leak into the loss)
            tl = jnp.mean(jnp.min(
                jnp.where(model_valid[:, None] > 0, scores, jnp.inf),
                axis=0))
            return (models_, assign, rkey), tl

        (models, assign, _), losses = jax.lax.scan(
            round_fn, (models, assign0, k_train), jnp.arange(cfg.rounds))
        final_scores = jax.vmap(
            lambda p: det.anomaly_scores(p, tx))(models)
        return MultiOutputs(losses, final_scores, assign)

    return core


@functools.lru_cache(maxsize=32)
def _jitted_multimodel_core_cached(model: ModelLike,
                                   cfg: MultiModelConfig):
    return jax.jit(_build_multimodel_core(model, cfg))


def _jitted_multimodel_core(model: ModelLike,
                            cfg: MultiModelConfig):
    """Compiled single-scenario core, cached on static config (the seed
    field of ``cfg`` is ignored — seed is a dynamic argument; the model
    spec is canonicalised so the config and detector spellings of the
    same autoencoder share one cache entry)."""
    return _jitted_multimodel_core_cached(
        D.canonical_model_key(model), dataclasses.replace(cfg, seed=0))


def run_multimodel(model: ModelLike, device_x: np.ndarray,
                   device_counts: np.ndarray, test_x: np.ndarray,
                   test_y: np.ndarray, cfg: MultiModelConfig,
                   failure: Failure = NO_FAILURE) -> MultiModelResult:
    """Single-scenario entry point on the shared cached jitted core.

    A repeated call with a new failure/seed reuses the compiled
    executable; :func:`repro.core.campaign.run_multimodel_campaign`
    batches whole (trace x seed) grids through the same core.
    """
    trace = as_multimodel_trace(failure, cfg.num_devices)
    dx, counts, valid = prepare_multimodel_arrays(device_x, device_counts)
    tx = jnp.asarray(test_x)
    core = _jitted_multimodel_core(model, cfg)
    out = core(dx, counts, valid, tx,
               jnp.ones((cfg.num_models,), jnp.float32), trace,
               jnp.int32(cfg.seed))

    final_scores = np.asarray(out.final_scores)                # (M, T)
    per_model = auroc_batch(final_scores, np.asarray(test_y))
    multi = auroc_batch(final_scores.min(axis=0, keepdims=True),
                        np.asarray(test_y))[0]
    return MultiModelResult(float(np.max(per_model)), float(multi),
                            np.asarray(out.losses),
                            np.asarray(out.assignments))
