"""Clustered-FL baselines the paper compares against (Section V-A).

* **FedGroup** [arXiv:2010.06870] — static grouping by a data-driven
  measure: devices are clustered once (cosine similarity of their initial
  local updates, k-means in gradient space), then per-group FedAvg.
* **IFCA** [NeurIPS'20] — iterative: every round each device picks the
  model with the lowest loss on its local data, trains it, and models are
  aggregated over their adopters.
* **FeSEM** [arXiv:2005.01026] — multi-center EM: devices are assigned to
  the nearest center in parameter space after a local step; centers move
  to the weighted mean of their members.

All train M model instances.  Reporting matches the paper's columns:
``best`` (*) = highest test AUROC of any single instance; ``multi`` (†) =
per-sample min reconstruction error over instances (the multi-model
oracle score).

Failure semantics: a *client* failure removes that device; a *server*
failure kills the aggregator of group 0 — that instance freezes and its
devices stop contributing (they keep their last model for evaluation).

Default targets differ by encoding: a legacy ``FailureSpec`` with
``device=None`` kills device N-1 here (there are no cluster heads to
anchor the Tol-FL default), while a ``FailureTrace`` carries explicit
device ids resolved against the Tol-FL topology at construction time.
Pass an explicit ``device`` when comparing the two encodings.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core.failure import (Failure, FailureTrace, KIND_CODES,
                                NO_FAILURE, PAD_EPOCH, trace_alive_mask)
from repro.core.simulate import SimConfig
from repro.models import autoencoder as AE
from repro.training.metrics import auroc


@dataclass(frozen=True)
class MultiModelConfig:
    scheme: str = "ifca"          # fedgroup | ifca | fesem
    num_devices: int = 10
    num_models: int = 3
    rounds: int = 100
    lr: float = 1e-4
    dropout: bool = True
    seed: int = 0


@dataclass
class MultiModelResult:
    best_auroc: float             # the paper's * column
    multi_auroc: float            # the paper's dagger column
    loss_curve: np.ndarray
    assignments: np.ndarray       # final device -> model map


def _grad_fn(ae_cfg: AutoencoderConfig, dropout: bool):
    def local_loss(params, x, valid, key):
        x_hat = AE.forward(params, ae_cfg, x,
                           dropout_key=key if dropout else None)
        err = jnp.sum(jnp.square(x - x_hat), axis=-1) * valid
        return jnp.sum(err) / jnp.maximum(jnp.sum(valid), 1.0)
    return local_loss, jax.grad(local_loss)


def _flat(tree):
    return jnp.concatenate([t.ravel() for t in jax.tree.leaves(tree)])


def _kmeans_groups(vectors: np.ndarray, m: int, seed: int,
                   iters: int = 20) -> np.ndarray:
    """Tiny k-means for FedGroup's static gradient-similarity grouping."""
    rng = np.random.default_rng(seed)
    v = vectors / (np.linalg.norm(vectors, axis=1, keepdims=True) + 1e-9)
    centers = v[rng.choice(len(v), m, replace=False)]
    for _ in range(iters):
        sim = v @ centers.T
        assign = sim.argmax(1)
        for j in range(m):
            sel = v[assign == j]
            if len(sel):
                c = sel.mean(0)
                centers[j] = c / (np.linalg.norm(c) + 1e-9)
    return assign


def run_multimodel(ae_cfg: AutoencoderConfig, device_x: np.ndarray,
                   device_counts: np.ndarray, test_x: np.ndarray,
                   test_y: np.ndarray, cfg: MultiModelConfig,
                   failure: Failure = NO_FAILURE) -> MultiModelResult:
    N, M = cfg.num_devices, cfg.num_models
    key = jax.random.PRNGKey(cfg.seed)
    local_loss, grad_fn = _grad_fn(ae_cfg, cfg.dropout)
    # M model instances with different inits
    models = []
    for j in range(M):
        p, _ = AE.init_params(jax.random.fold_in(key, j), ae_cfg)
        models.append(p)
    models = jax.tree.map(lambda *xs: jnp.stack(xs), *models)

    dx = jnp.asarray(device_x)
    counts = jnp.asarray(device_counts, jnp.float32)
    valid = (jnp.arange(device_x.shape[1])[None, :]
             < counts[:, None]).astype(jnp.float32)
    tx = jnp.asarray(test_x)

    # ---- initial assignment ----
    if cfg.scheme == "fedgroup":
        p0, _ = AE.init_params(key, ae_cfg)
        g0 = jax.vmap(lambda x, v, k_: _flat(grad_fn(p0, x, v, k_)),
                      in_axes=(0, 0, 0))(dx, valid,
                                         jax.random.split(key, N))
        assign0 = jnp.asarray(_kmeans_groups(np.asarray(g0), M, cfg.seed))
    else:
        assign0 = jnp.arange(N) % M

    # Failure semantics: "client" events remove that device; "server"
    # events kill the aggregator of group 0 (no head devices exist
    # here).  A FailureTrace carries per-event kinds, so client events
    # drive the device mask and server events drive the group-0 mask —
    # multiple events and recoveries compose like in the Tol-FL engine.
    if isinstance(failure, FailureTrace):
        knd = np.asarray(failure.kinds)
        client_tr = FailureTrace(
            epochs=jnp.where(knd == KIND_CODES["client"],
                             failure.epochs, PAD_EPOCH),
            devices=failure.devices,
            alive_after=failure.alive_after,
            kinds=failure.kinds)
        # server events all target group 0, whatever device they named
        server_tr = FailureTrace(
            epochs=jnp.where(knd == KIND_CODES["server"],
                             failure.epochs, PAD_EPOCH),
            devices=jnp.zeros_like(failure.devices),
            alive_after=failure.alive_after,
            kinds=failure.kinds)

        def dev_alive(epoch):
            return trace_alive_mask(client_tr, N, epoch)

        def group_alive(epoch):
            return trace_alive_mask(server_tr, M, epoch)
    else:
        # legacy single-event spec: the default client target is the
        # last device (no topology heads here)
        tgt_device = (failure.device if failure.device is not None
                      else N - 1)

        def dev_alive(epoch):
            if failure.kind != "client":
                return jnp.ones((N,), jnp.float32)
            dead = ((jnp.arange(N) == tgt_device)
                    & (epoch >= failure.epoch))
            return (~dead).astype(jnp.float32)

        def group_alive(epoch):
            if failure.kind != "server":
                return jnp.ones((M,), jnp.float32)
            dead = (jnp.arange(M) == 0) & (epoch >= failure.epoch)
            return (~dead).astype(jnp.float32)

    def device_losses(models_, x, v, k_):
        """(M,) local loss of each model instance on one device's data."""
        return jax.vmap(lambda p: local_loss(p, x, v, k_))(models_)

    def round_fn(carry, epoch):
        models_, assign, rkey = carry
        rkey, dkey = jax.random.split(rkey)
        dkeys = jax.random.split(dkey, N)
        a_dev = dev_alive(epoch)
        a_grp = group_alive(epoch)

        # ---- (re)assignment ----
        if cfg.scheme == "ifca":
            losses = jax.vmap(device_losses, in_axes=(None, 0, 0, 0))(
                models_, dx, valid, dkeys)          # (N, M)
            assign = jnp.argmin(losses, axis=1)
        elif cfg.scheme == "fesem":
            # e-step: distance between one-step-updated local params and
            # each center, in parameter space
            def dev_assign(x, v, k_, a):
                p_cur = jax.tree.map(lambda t: t[a], models_)
                g = grad_fn(p_cur, x, v, k_)
                upd = jax.tree.map(lambda p_, g_: p_ - cfg.lr * g_, p_cur, g)
                fu = _flat(upd)
                d = jax.vmap(lambda j: jnp.sum(jnp.square(
                    fu - _flat(jax.tree.map(lambda t: t[j], models_)))))(
                        jnp.arange(M))
                return jnp.argmin(d)
            assign = jax.vmap(dev_assign)(dx, valid, dkeys, assign)
        # fedgroup: static

        # ---- local grads on the assigned model ----
        def dev_grad(x, v, k_, a):
            p_cur = jax.tree.map(lambda t: t[a], models_)
            return grad_fn(p_cur, x, v, k_)
        gs = jax.vmap(dev_grad)(dx, valid, dkeys, assign)

        # ---- per-model weighted aggregation ----
        onehot = jax.nn.one_hot(assign, M, dtype=jnp.float32)  # (N, M)
        w = counts * a_dev
        denom = onehot.T @ w                                   # (M,)

        def agg_leaf(gleaf):
            flatg = gleaf.reshape(N, -1)
            num = onehot.T @ (flatg * w[:, None])
            mean = num / jnp.maximum(denom[:, None], 1e-30)
            return mean.reshape((M,) + gleaf.shape[1:])
        g_m = jax.tree.map(agg_leaf, gs)
        upd_gate = ((denom > 0).astype(jnp.float32) * a_grp)
        models_ = jax.tree.map(
            lambda p_, g_: p_ - cfg.lr * upd_gate.reshape(
                (-1,) + (1,) * (g_.ndim - 1)) * g_,
            models_, g_m)

        scores = jax.vmap(lambda p: AE.anomaly_scores(p, ae_cfg, tx))(
            models_)                                           # (M, T)
        tl = jnp.mean(jnp.min(scores, axis=0))
        return (models_, assign, rkey), (tl, scores)

    (models, assign, _), (losses, scores_hist) = jax.lax.scan(
        round_fn, (models, assign0, key), jnp.arange(cfg.rounds))

    final_scores = np.asarray(scores_hist[-1])                 # (M, T)
    per_model = [auroc(final_scores[j], test_y) for j in range(M)]
    multi = auroc(final_scores.min(axis=0), test_y)
    return MultiModelResult(float(np.max(per_model)), float(multi),
                            np.asarray(losses), np.asarray(assign))
