"""Batched Monte-Carlo failure-campaign engine.

The paper's robustness claims ("a range of realistic settings that
consider client as well as server failure") need *scenario diversity*:
grids of failure traces x seeds, not one hand-picked event per run.
This module sweeps such grids at hardware speed: for one static
``SimConfig`` (scheme, k, rounds, ...) every (trace, seed) scenario in
the batch runs through ONE ``jit(vmap(core))`` executable — the core is
the exact same round-loop :func:`repro.core.simulate.run_simulation`
uses, so per-scenario results match the single-shot simulator
(``tests/test_campaign.py`` asserts equality and the single compile).

Typical use::

    traces = [FailureTrace.none(),
              FailureTrace.from_spec(FailureSpec(10, "server"), topo)]
    res = run_campaign(ae_cfg, dx, counts, test_x, test_y,
                       SimConfig(scheme="tolfl", num_clusters=5),
                       traces, seeds=range(8))
    res.summary()["auroc_used_mean"]

The multi-model baselines (FedGroup / IFCA / FeSEM) get the same
treatment: :func:`run_multimodel_campaign` vmaps the pure core from
:mod:`repro.core.baselines` over a stacked (trace x seed) grid, so the
paper's Table III-V comparison columns also cost one compile per cell.

Execution scales along three independent axes (:class:`ExecPlan`):

* **compile amortisation** — data arrays are ARGUMENTS of an
  lru-cached jitted batched core, never closed over, so repeated
  campaigns on the same shapes (the benchmarks' inner loops) reuse the
  compiled executable instead of re-tracing per campaign;
* **scenario sharding** — ``ExecPlan(shard=True)`` pads the
  (trace x seed) batch to a device-divisible size and dispatches it
  through a ``shard_map`` over the local-device "scenario" mesh axis
  (:func:`repro.sharding.scenario_shard_map`), so B scenarios run on D
  devices in B/D time;
* **host chunking** — ``ExecPlan(chunk_size=c)`` slices the batch into
  same-shape chunks (the last one padded, padding stripped after), so
  arbitrarily large grids run in bounded device memory with ONE compile.

Different schemes / k imply different topologies, but topology is just
ARRAYS to the core (:func:`repro.core.simulate._build_core_arrays`), so
a (scheme x k) grid needs neither one compile per cell NOR one dispatch
per cell — :func:`sweep_grid` stacks the padded per-cell cluster arrays
(head indices, ``device_cluster_array``, ``head_valid`` — padded to the
per-kind max k) along the scenario axis and runs ALL cells of one
iso-tracking kind through a SINGLE ``jit(vmap)`` over the flattened
(cell x trace x seed) batch: all sbt/tolfl cells in one dispatch, all
fl cells in another (the fl fallback branch costs extra per-round
compute, so non-fl cells never pay for it).  Multi-model cells fuse the
same way per scheme: the model axis pads to the grid's max M with a
``model_valid`` mask, so (ifca, 2) and (ifca, 3) share one executable
and one dispatch.  Padded cluster/model slots are exact no-ops in the
combine algebra, so results match the per-cell paths bit-for-bit
(``fuse=False`` restores one dispatch per cell, ``pad_k=False`` the
one-compile-per-cell static build; both pinned equal by tests).

As of the declarative experiment layer
(:mod:`repro.core.experiment` / :mod:`repro.api`) this module is the
MECHANISM — cached executables, batched dispatch, result types — and
the entry points above are thin shims that lower their arguments to an
``ExperimentSpec`` and run ``plan(spec) -> execute(plan)``.  New code
should declare specs; the shims stay for back-compat and are pinned
bit-identical by ``tests/test_experiment.py``.
"""
from __future__ import annotations

import functools
import threading
import time
import warnings
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import MultiModelConfig, _build_multimodel_core
from repro.core.failure import Failure, FailureTrace
from repro.core.simulate import SimConfig, _build_core, _build_core_arrays
from repro.models.detector import ModelLike, canonical_model_key
from repro.sharding import scenario_shard_map
from repro.training.metrics import auroc_batch

#: incremented each time a batched campaign core is (re)traced — lets
#: tests assert that a whole campaign costs exactly one compile.  AOT
#: lowering (``aot_executable``) traces on worker threads, so the
#: increment is lock-guarded.
TRACE_COUNT = 0
_TRACE_LOCK = threading.Lock()

#: schemes dispatched to the multi-model engine by :func:`sweep_grid`
MULTI_SCHEMES = ("fedgroup", "ifca", "fesem")


@dataclass(frozen=True)
class ExecPlan:
    """How a campaign batch is executed (results never change with it).

    shard
        Split the scenario axis across the local JAX devices via
        ``shard_map`` (the batch is padded up to a device-divisible
        size; padding is stripped from the results).  On a single-device
        host sharding warns and degrades to the plain jitted path.
    chunk_size
        Host-side chunking: at most this many scenarios are resident on
        the devices at once; every chunk has the same padded shape so
        the whole campaign still costs one compile.  ``None`` runs the
        batch in one shot.
    devices
        Cap on the number of local devices used when sharding
        (default: all of ``jax.local_device_count()``).
    aot
        Ahead-of-time compilation: ``execute()`` lowers and compiles
        every dispatch bucket (``jit(...).lower().compile()``) on a
        thread pool at plan-finalise time, overlapping XLA with the
        host-side data/trace array builds, and dispatches through the
        compiled executables.  Results are bit-identical to the jit
        path (pinned by ``tests/test_aot.py``); combined with the
        persistent disk cache (:mod:`repro.core.compilecache`) a warm
        re-run in a fresh process skips XLA entirely.

    Invalid values raise ``ValueError`` at construction (they used to
    surface as shape errors deep inside ``_run_batched``).
    """
    shard: bool = False
    chunk_size: Optional[int] = None
    devices: Optional[int] = None
    aot: bool = False

    def __post_init__(self):
        if self.chunk_size is not None and self.chunk_size <= 0:
            raise ValueError(
                f"ExecPlan.chunk_size must be a positive number of "
                f"scenarios (or None for one-shot), got "
                f"{self.chunk_size}")
        if self.devices is not None and self.devices <= 0:
            raise ValueError(
                f"ExecPlan.devices must be a positive device count "
                f"(or None for all local devices), got {self.devices}")

    def num_devices(self) -> int:
        n = jax.local_device_count()
        return min(self.devices, n) if self.devices else n

    def resolved_devices(self, warn: bool = True) -> Optional[int]:
        """Shard width actually used: ``None`` when not sharding — and
        when ``shard=True`` finds only one local device, in which case
        it warns and degrades to the (identical-result) unsharded path
        instead of paying shard_map overhead for nothing."""
        if not self.shard:
            return None
        n = self.num_devices()
        if n <= 1:
            if warn:
                warnings.warn(
                    "ExecPlan(shard=True) found a single local device; "
                    "degrading to the unsharded path (results are "
                    "identical). Set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N to fake "
                    "a multi-device host.", UserWarning, stacklevel=2)
            return None
        return n


def mean_ci95(vals: np.ndarray) -> Tuple[float, float, float]:
    """(mean, sample std, normal-approx 95% CI half-width) over seeds.

    Uses the SAMPLE standard deviation (ddof=1): campaigns estimate the
    spread of a seed population from few draws, and the ddof=0
    population formula reports over-tight intervals for small B.  A
    single scenario has no spread estimate: std 0, CI half-width nan."""
    b = len(vals)
    mean = float(np.mean(vals))
    if b <= 1:
        return mean, 0.0, float("nan")
    std = float(np.std(vals, ddof=1))
    return mean, std, 1.96 * std / np.sqrt(b)


@dataclass
class CampaignResult:
    """Stacked per-scenario results of one batched campaign.

    Scenario b is (trace ``trace_index[b]``, seed ``seed[b]``); arrays
    are aligned on that leading axis."""
    cfg: SimConfig
    trace_index: np.ndarray        # (B,) int — index into the trace list
    seed: np.ndarray               # (B,) int
    auroc_used: np.ndarray         # (B,) paper-reported AUROC
    final_auroc: np.ndarray        # (B,) global-model AUROC
    iso_auroc: np.ndarray          # (B,) isolated-mean AUROC (nan if n/a)
    iso_active: np.ndarray         # (B,) bool — FL fallback engaged
    loss_curves: np.ndarray        # (B, rounds) REPORTED loss: global,
    #                                but FL server-dead rounds carry the
    #                                isolated mean (Fig 4 semantics)
    iso_loss_curves: np.ndarray    # (B, rounds)
    rounds_to_loss: np.ndarray     # (B,) float, nan when never reached

    @property
    def num_scenarios(self) -> int:
        return len(self.auroc_used)

    def select(self, trace_index: int) -> np.ndarray:
        """auroc_used of every scenario using trace ``trace_index``."""
        return self.auroc_used[self.trace_index == trace_index]

    def summary(self) -> Dict[str, float]:
        """Mean / sample std / normal-approx 95% CI of the reported
        AUROC plus mean rounds-to-loss (over scenarios that reached the
        target)."""
        mean, std, half = mean_ci95(self.auroc_used)
        r2l = self.rounds_to_loss[np.isfinite(self.rounds_to_loss)]
        return {
            "num_scenarios": float(self.num_scenarios),
            "auroc_used_mean": mean,
            "auroc_used_std": std,
            "auroc_used_ci95_lo": mean - half,
            "auroc_used_ci95_hi": mean + half,
            "rounds_to_loss_mean": (float(np.mean(r2l)) if len(r2l)
                                    else float("nan")),
        }


@dataclass
class MultiCampaignResult:
    """Stacked per-scenario results of one batched multi-model campaign.

    Scenario b is (trace ``trace_index[b]``, seed ``seed[b]``)."""
    cfg: MultiModelConfig
    trace_index: np.ndarray        # (B,) int — index into the trace list
    seed: np.ndarray               # (B,) int
    best_auroc: np.ndarray         # (B,) the paper's * column
    multi_auroc: np.ndarray        # (B,) the paper's dagger column
    loss_curves: np.ndarray        # (B, rounds) per-sample-min test loss
    assignments: np.ndarray        # (B, N) final device -> model maps

    @property
    def num_scenarios(self) -> int:
        return len(self.best_auroc)

    def select(self, trace_index: int, column: str = "best") -> np.ndarray:
        """best/multi AUROC of every scenario using ``trace_index``."""
        vals = {"best": self.best_auroc, "multi": self.multi_auroc}[column]
        return vals[self.trace_index == trace_index]

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {"num_scenarios": float(self.num_scenarios)}
        for column in ("best", "multi"):
            vals = {"best": self.best_auroc,
                    "multi": self.multi_auroc}[column]
            mean, std, half = mean_ci95(vals)
            out[f"{column}_auroc_mean"] = mean
            out[f"{column}_auroc_std"] = std
            out[f"{column}_auroc_ci95_lo"] = mean - half
            out[f"{column}_auroc_ci95_hi"] = mean + half
        return out


def _scenario_grid(num_traces: int, seeds: Sequence[int]
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Full cross product: trace-major, seed-minor."""
    seeds = np.asarray(list(seeds), np.int32)
    trace_idx = np.repeat(np.arange(num_traces, dtype=np.int32),
                          len(seeds))
    seed_arr = np.tile(seeds, num_traces)
    return trace_idx, seed_arr


# ---------------------------------------------------------------------------
# Cached batched executables.  Data/topology arrays are ARGUMENTS (broadcast
# over the vmap), never closed over: the jit lives in an lru_cache keyed on
# the static config, so repeated campaigns with the same shapes reuse the
# compiled executable instead of re-tracing per campaign.
# ---------------------------------------------------------------------------
def _exe_key(kind: str, model: ModelLike, cfg, k_pad, ndev,
             track_iso: bool, fused: bool) -> tuple:
    """Canonical executable-cache key.

    Everything that changes the lowered program is in here — the
    detector-model spec, the static config (scheme/k-normalised by the
    caller on padded paths), the cluster-axis pad, the shard width, the
    iso-tracking kind and the fused/broadcast operand split — and
    NOTHING else: redundant degrees of freedom are normalised away so
    two spellings of the same configuration can never compile twice
    (``functools.lru_cache`` would otherwise key ``f(a, b)`` and
    ``f(a, b=...)`` differently, and a ``track_iso`` flag disagreeing
    with ``cfg.scheme`` on the static path would duplicate an identical
    program).  ``model`` canonicalises through
    :func:`repro.models.detector.canonical_model_key`: autoencoder
    specs key on the raw :class:`AutoencoderConfig` — whichever
    spelling the caller used — so pre-detector cache keys and
    persistent-cache fingerprints are bit-identical; other bodies key
    on their frozen spec.  Shapes/dtypes are deliberately NOT part of
    this key: the jit path retraces per shape inside one entry, and the
    AOT path extends the key with the abstract-argument signature
    (``aot_executable``).  The faulty-update engine variants
    (``FaultySimConfig`` / ``FaultyMultiModelConfig``) key through
    ``cfg`` by CLASS IDENTITY — dataclass ``__eq__``/``repr`` include
    the class — so faulty cores get distinct entries and fingerprints
    while plain-config keys stay bit-identical.  Pinned by
    ``tests/test_cache_semantics.py`` and ``tests/test_detector.py``."""
    model = canonical_model_key(model)
    if kind == "multi":
        assert k_pad is None, "multi-model cells pad M via cfg.num_models"
        track_iso = False          # the multi core has no iso branch
    elif k_pad is None:
        # static build: _build_core derives the iso branch from
        # cfg.scheme and there is no fused-static path — a divergent
        # flag would alias a second identical executable
        track_iso = cfg.scheme == "fl"
        fused = False
    return (kind, model, cfg, k_pad, ndev, bool(track_iso), bool(fused))


def _executable(kind: str, model: ModelLike, cfg, k_pad, ndev,
                track_iso: bool = False, fused: bool = False):
    """Batched scenario executable (see :func:`_build_executable`); the
    lru key is the canonical :func:`_exe_key`, never the raw call
    spelling."""
    return _build_executable(*_exe_key(kind, model, cfg, k_pad, ndev,
                                       track_iso, fused))


@functools.lru_cache(maxsize=64)
def _build_executable(kind: str, model: ModelLike, cfg, k_pad,
                      ndev, track_iso: bool, fused: bool):
    """Batched scenario executable.

    kind
        "single" (SimConfig core) or "multi" (MultiModelConfig core).
    k_pad
        None -> topology closed over statically (the single-campaign
        path); int -> topology enters as dynamic arrays padded to
        ``k_pad`` — the compile-amortised sweep path.  The ``cfg`` key
        is then scheme/k-normalised by the caller so every sweep cell of
        the same ``track_iso`` kind hits the SAME cache entry
        (``track_iso`` stays in the key: the fl fallback branch costs
        extra per-round compute, so non-fl cells must not pay for it —
        one executable per kind, not per cell).
    fused
        The cell-varying operands (padded cluster arrays for "single",
        the ``model_valid`` mask for "multi") move from the broadcast
        group into the MAPPED group, so one dispatch sweeps a flattened
        (cell x trace x seed) scenario axis where every row carries its
        own topology — the whole-grid fused path.
    ndev
        None -> plain ``jit``; int -> ``jit(shard_map(...))`` over an
        (ndev,)-device "scenario" mesh, batch axis sharded, broadcasts
        replicated (:func:`repro.sharding.scenario_shard_map`).
    """
    if kind == "multi":
        core = _build_multimodel_core(model, cfg)
        n_bcast, n_mapped = (4, 3) if fused else (5, 2)
    elif k_pad is None:
        core = _build_core(model, cfg, score_history=False)
        n_bcast, n_mapped = 4, 2
    else:
        core = _build_core_arrays(model, cfg, cfg.num_devices, k_pad,
                                  track_iso=track_iso,
                                  score_history=False)
        n_bcast, n_mapped = (4, 5) if fused else (7, 2)

    def scenario(*args):
        global TRACE_COUNT
        with _TRACE_LOCK:         # AOT lowers on worker threads
            TRACE_COUNT += 1      # runs at trace time only: 1 per compile
        return core(*args)

    vm = jax.vmap(scenario,
                  in_axes=(None,) * n_bcast + (0,) * n_mapped)
    if ndev is None:
        return jax.jit(vm)
    return jax.jit(scenario_shard_map(vm, ndev, n_bcast, n_mapped))


# ---------------------------------------------------------------------------
# AOT entry path.  Same canonical key-space as the jit path, extended by
# the abstract-argument signature (a compiled executable is pinned to
# exact shapes/dtypes, unlike a jit that retraces per shape).  Because
# ``aot_executable`` lowers THROUGH the lru-cached jit wrapper, the
# trace cache is shared: an AOT compile followed by a jit call on the
# same shapes re-traces nothing, and vice versa — the warm in-process
# path is untouched and bit-identical.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AotTimes:
    """Wall-clock of one :func:`aot_executable` resolution.  ``source``
    is where the executable came from: ``"memory"`` (in-process AOT
    cache, both times 0), ``"disk"`` (deserialised from the persistent
    executable cache — ``compile_s`` is the load time and NOTHING was
    traced or XLA-compiled) or ``"compiled"`` (lowered + compiled this
    call)."""
    lower_s: float = 0.0
    compile_s: float = 0.0
    source: str = "compiled"

    @property
    def cached(self) -> bool:
        return self.source == "memory"


_AOT_CACHE: Dict[tuple, Any] = {}
_AOT_LOCK = threading.Lock()

#: AOT bucket compiles pass this explicit (default-valued, so codegen
#: is unchanged) option override: it lands in the compile-options hash,
#: giving AOT compiles a module-cache key DISJOINT from the jit path's.
#: An executable that XLA's persistent module cache served cannot be
#: re-serialised ("Symbols not found" on reload, jaxlib 0.4.36 CPU), so
#: an AOT compile must never be satisfied by a module entry a previous
#: jit-path run wrote — the key split guarantees a genuine, whole-
#: serialisable compile without touching global config (the host's
#: utility jits keep caching normally while the worker pool compiles).
_AOT_COMPILER_OPTIONS = {"xla_embed_ir_in_executable": False}


def _avals_signature(abstract_args) -> tuple:
    """Hashable (treedef, leaf shape/dtype) signature of an abstract
    argument tuple — what distinguishes compiled executables within one
    jit cache entry."""
    leaves, treedef = jax.tree.flatten(abstract_args)
    return (str(treedef),
            tuple((tuple(l.shape), jnp.dtype(l.dtype).name)
                  for l in leaves))


def aot_executable(kind: str, model: ModelLike, cfg, k_pad, ndev,
                   track_iso: bool, fused: bool, abstract_args
                   ) -> Tuple[Any, AotTimes]:
    """Lower + compile the batched core for ``abstract_args`` (a tuple
    of ``jax.ShapeDtypeStruct`` pytrees matching the concrete call) and
    cache the compiled executable under canonical key + aval signature.

    Returns ``(compiled, AotTimes)``; ``compiled(*concrete_args)`` is
    bit-identical to calling the jitted executable (it IS the same
    lowering — pinned by ``tests/test_aot.py``).  Thread-safe: the
    experiment layer compiles buckets on a worker pool while the host
    builds data arrays."""
    from repro.core import compilecache as _cc
    key = _exe_key(kind, model, cfg, k_pad, ndev, track_iso, fused)
    full_key = key + _avals_signature(abstract_args)
    with _AOT_LOCK:
        hit = _AOT_CACHE.get(full_key)
    if hit is not None:
        return hit, AotTimes(source="memory")
    # persistent executable cache: a warm fresh process deserialises
    # the whole compiled executable — no trace, no lower, no XLA
    fp = _cc.exe_fingerprint(full_key)
    t0 = time.perf_counter()
    loaded = _cc.load_executable(fp)
    if loaded is not None:
        with _AOT_LOCK:
            loaded = _AOT_CACHE.setdefault(full_key, loaded)
        return loaded, AotTimes(compile_s=time.perf_counter() - t0,
                                source="disk")
    jitted = _build_executable(*key)
    t0 = time.perf_counter()
    lowered = jitted.lower(*abstract_args)
    t1 = time.perf_counter()
    compiled = lowered.compile(compiler_options=_AOT_COMPILER_OPTIONS)
    t2 = time.perf_counter()
    _cc.store_executable(fp, compiled)
    with _AOT_LOCK:
        # a racing thread may have compiled the same key; keep the first
        compiled = _AOT_CACHE.setdefault(full_key, compiled)
    return compiled, AotTimes(lower_s=t1 - t0, compile_s=t2 - t1)


def clear_executable_caches() -> None:
    """Drop every in-process executable cache (jit lru, AOT compiled,
    the single-shot simulator's core cache) — cold-start benchmarking
    and cache-semantics tests use this to force genuine re-compiles.
    The persistent disk cache (:mod:`repro.core.compilecache`) is NOT
    touched; clear its directory to simulate a truly cold machine."""
    from repro.core import baselines as _bl
    from repro.core import simulate as _sim
    _build_executable.cache_clear()
    with _AOT_LOCK:
        _AOT_CACHE.clear()
    _sim._jitted_core_cached.cache_clear()
    _bl._jitted_multimodel_core_cached.cache_clear()
    jax.clear_caches()


def _run_batched(batched_call, bcast_args, mapped, plan: Optional[ExecPlan],
                 aot_resolve=None):
    """Dispatch a stacked scenario batch through ``batched_call`` with
    host-side chunking and batch padding per ``plan``; returns the
    outputs pytree as numpy arrays with the padding stripped.

    ``mapped`` is a tuple of pytrees sharing the scenario leading axis —
    (traces, seeds) for a single campaign, plus the stacked per-cell
    topology/model-mask operands on the fused sweep path.

    ``aot_resolve`` (the AOT path) maps the per-chunk abstract-argument
    tuple — derived here from the CONCRETE arrays, so it is exact by
    construction — to a compiled executable that replaces
    ``batched_call`` (every chunk shares one padded shape, so one
    executable serves them all)."""
    plan = plan or ExecPlan()
    B = int(jax.tree.leaves(mapped)[0].shape[0])
    chunk = min(plan.chunk_size or B, B)
    ndev = plan.resolved_devices(warn=False)  # entry points warn once
    if ndev:
        chunk = -(-chunk // ndev) * ndev      # device-divisible chunks
    n_chunks = -(-B // chunk)
    b_pad = n_chunks * chunk
    if b_pad != B:
        # pad by repeating scenario 0 — any valid scenario works, the
        # rows are stripped below before post-processing
        sel = np.concatenate([np.arange(B), np.zeros(b_pad - B, np.int64)])
        mapped = jax.tree.map(lambda x: x[sel], mapped)
    if aot_resolve is not None:
        sds = jax.ShapeDtypeStruct
        avals = tuple(
            jax.tree.map(lambda x: sds(x.shape, x.dtype), a)
            for a in bcast_args) + tuple(
            jax.tree.map(lambda x: sds((chunk,) + x.shape[1:], x.dtype),
                         m)
            for m in mapped)
        batched_call = aot_resolve(avals)
    outs = []
    for c in range(n_chunks):
        sl = slice(c * chunk, (c + 1) * chunk)
        out = batched_call(*bcast_args,
                           *jax.tree.map(lambda x: x[sl], mapped))
        # materialise on the host per chunk: device memory stays bounded
        # by chunk_size however large the grid is
        outs.append(jax.tree.map(np.asarray, out))
    if n_chunks == 1 and b_pad == B:
        return outs[0]
    full = jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *outs)
    return jax.tree.map(lambda x: x[:B], full)


def _padded_topology_arrays(topo, k_pad: int):
    """(cluster_ids, heads, head_valid) with the cluster axis padded to
    ``k_pad``: padding head slots point at device 0 but are masked
    invalid, and no device maps to a padded cluster."""
    assert k_pad >= topo.num_clusters, (k_pad, topo.num_clusters)
    heads = np.zeros(k_pad, np.int32)
    heads[:topo.num_clusters] = topo.heads
    head_valid = np.zeros(k_pad, np.float32)
    head_valid[:topo.num_clusters] = 1.0
    return (jnp.asarray(topo.device_cluster_array()), jnp.asarray(heads),
            jnp.asarray(head_valid))


def run_campaign(model: ModelLike, device_x: np.ndarray,
                 device_counts: np.ndarray, test_x: np.ndarray,
                 test_y: np.ndarray, cfg: SimConfig,
                 traces: Sequence[Failure], seeds: Sequence[int],
                 target_loss: Optional[float] = None,
                 exec_plan: Optional[ExecPlan] = None,
                 pad_k: Optional[int] = None) -> CampaignResult:
    """Run every (trace x seed) scenario through one compiled executable.

    ``traces`` may mix legacy :class:`FailureSpec`s and
    :class:`FailureTrace`s; all are normalised to traces and stacked.
    ``cfg.seed`` is ignored — seeds come from the grid.  ``exec_plan``
    chooses scenario sharding / host chunking (results are unchanged);
    ``pad_k`` (int >= cfg's cluster count) routes through the padded-k
    core so campaigns with different (scheme, k) share one executable —
    :func:`sweep_grid`'s per-cell path sets it to the grid's per-kind
    max k.  "batch" centralises the data (different array shapes), so
    it always builds statically and ``pad_k`` is ignored.

    A thin shim over the declarative pipeline
    (:mod:`repro.core.experiment`): one-cell spec, per-cell dispatch."""
    from repro.core import experiment as X
    spec = X.ExperimentSpec(
        data=X.DataSpec(model=model, device_x=device_x,
                        device_counts=device_counts, test_x=test_x,
                        test_y=test_y),
        base=cfg,
        cells=(X.CellSpec(scheme=cfg.scheme, k=cfg.num_clusters, cfg=cfg,
                          traces=traces),),
        seeds=X.SeedSpec(tuple(seeds)), exec_plan=exec_plan,
        target_loss=target_loss, fuse=False,
        pad_k=(pad_k is not None), k_pad=pad_k)
    return X.run_experiment(spec).results[0]


def _post_process(cfg, out, trace_idx, seed_arr, test_y, target_loss
                  ) -> CampaignResult:
    fields = _post_process_arrays(cfg.scheme == "fl", out, test_y,
                                  target_loss)
    return CampaignResult(cfg=cfg, trace_index=trace_idx, seed=seed_arr,
                          **fields)


def _post_process_arrays(track_iso: bool, out, test_y, target_loss
                         ) -> Dict[str, np.ndarray]:
    """Scenario-aligned result arrays of a stacked :class:`SimOutputs`
    batch (ONE ``auroc_batch`` sweep over the whole batch, however many
    sweep cells were flattened into it) — everything
    :class:`CampaignResult` stores except the grid bookkeeping."""
    losses = np.asarray(out.losses)                    # (B, R)
    iso_losses = np.asarray(out.iso_losses)
    finals = np.asarray(out.final_scores)              # (B, T)
    iso_scores = np.asarray(out.iso_final_scores)      # (B, N, T')
    final_alive = np.asarray(out.final_alive)          # (B, N)
    dead_rounds = np.asarray(out.server_dead_rounds) > 0   # (B, R)
    server_dead = np.asarray(out.server_dead) > 0      # (B,)
    B = losses.shape[0]

    test_y = np.asarray(test_y)
    final_auroc = auroc_batch(finals, test_y)
    iso_auroc = np.full(B, np.nan)
    iso_active = np.zeros(B, bool)
    if track_iso:
        # Fig 4 semantics (matching run_simulation): server-dead rounds
        # report the isolated-mean loss, not the frozen global model's
        losses = np.where(dead_rounds, iso_losses, losses)
        iso_active = server_dead.copy()
        hit = np.flatnonzero(iso_active)
        if len(hit) and iso_scores.shape[-1]:
            n_dev = iso_scores.shape[1]
            per_dev = auroc_batch(
                iso_scores[hit].reshape(len(hit) * n_dev, -1),
                test_y).reshape(len(hit), n_dev)
            alive = (final_alive[hit] > 0)
            denom = alive.sum(axis=1)
            num = np.where(alive, per_dev, 0.0).sum(axis=1)
            iso_auroc[hit] = np.where(denom > 0,
                                      num / np.maximum(denom, 1),
                                      np.nan)
    auroc_used = np.where(iso_active, iso_auroc, final_auroc)

    r2l = np.full(B, np.nan)
    if target_loss is not None:
        reached = losses <= target_loss                # (B, R)
        any_hit = reached.any(axis=1)
        first = reached.argmax(axis=1) + 1.0
        r2l = np.where(any_hit, first, np.nan)

    return dict(auroc_used=auroc_used, final_auroc=final_auroc,
                iso_auroc=iso_auroc, iso_active=iso_active,
                loss_curves=losses, iso_loss_curves=iso_losses,
                rounds_to_loss=r2l)


def run_multimodel_campaign(model: ModelLike,
                            device_x: np.ndarray,
                            device_counts: np.ndarray, test_x: np.ndarray,
                            test_y: np.ndarray, cfg: MultiModelConfig,
                            traces: Sequence[Failure],
                            seeds: Sequence[int],
                            exec_plan: Optional[ExecPlan] = None
                            ) -> MultiCampaignResult:
    """Every (trace x seed) scenario of a multi-model baseline in one
    jitted, vmapped call — the multi-model twin of :func:`run_campaign`
    (same cached-executable / sharding / chunking machinery).

    ``traces`` may mix legacy :class:`FailureSpec`s and
    :class:`FailureTrace`s; specs are normalised with the BASELINE
    default targets (see :func:`as_multimodel_trace`).  The client/group
    trace split happens in-graph inside the core, so one compiled
    executable covers the whole grid.  ``cfg.seed`` is ignored — seeds
    come from the grid.

    A thin shim over the declarative pipeline
    (:mod:`repro.core.experiment`): one-cell spec, per-cell dispatch."""
    from repro.core import experiment as X
    spec = X.ExperimentSpec(
        data=X.DataSpec(model=model, device_x=device_x,
                        device_counts=device_counts, test_x=test_x,
                        test_y=test_y),
        base=SimConfig(num_devices=cfg.num_devices),
        cells=(X.CellSpec(scheme=cfg.scheme, k=cfg.num_models, cfg=cfg,
                          traces=traces),),
        seeds=X.SeedSpec(tuple(seeds)), exec_plan=exec_plan, fuse=False)
    return X.run_experiment(spec).results[0]


def _multi_metrics(finals: np.ndarray, test_y,
                   model_valid: Optional[np.ndarray] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """(best, multi) AUROC columns of stacked (B, M, T) final scores in
    TWO ``auroc_batch`` sweeps total, however many sweep cells were
    flattened into the batch.  ``model_valid`` (B, M) masks padded model
    slots (fused padded-M cells): they never win ``best`` and stay out
    of the per-sample-min ``multi`` score."""
    B, M = finals.shape[0], finals.shape[1]
    test_y = np.asarray(test_y)
    per_model = auroc_batch(finals.reshape(B * M, -1),
                            test_y).reshape(B, M)
    if model_valid is None:
        return per_model.max(axis=1), auroc_batch(finals.min(axis=1),
                                                  test_y)
    live = model_valid > 0
    best = np.where(live, per_model, -np.inf).max(axis=1)
    min_scores = np.where(live[:, :, None], finals, np.inf).min(axis=1)
    return best, auroc_batch(min_scores, test_y)


def _single_trace_key(traces: Sequence[Failure], topo) -> tuple:
    """How a trace list resolves against a topology: pure
    :class:`FailureTrace` lists are topology-independent (one stacked
    batch serves every sweep cell), legacy specs default their targets
    from the heads / cluster-0 layout."""
    if all(isinstance(t, FailureTrace) for t in traces):
        return ()
    return (tuple(topo.heads), tuple(topo.clusters[0]))


def run_fused_campaigns(model: ModelLike, device_x: np.ndarray,
                        device_counts: np.ndarray, test_x: np.ndarray,
                        test_y: np.ndarray,
                        cells: Sequence[Tuple[SimConfig,
                                              Sequence[Failure]]],
                        seeds: Sequence[int],
                        target_loss: Optional[float] = None,
                        exec_plan: Optional[ExecPlan] = None,
                        k_pad: Optional[int] = None
                        ) -> List[CampaignResult]:
    """Many single-model campaign cells, fused into ONE dispatch per
    iso-tracking kind.

    ``cells`` pairs each :class:`SimConfig` with its trace list (lists
    may differ per cell — e.g. per-topology sampled grids — and may be
    the same object, in which case padding/stacking happens once, not
    per cell).  Cells whose static config agrees on everything but
    (scheme, num_clusters) are grouped by iso-tracking kind; each
    group's cluster arrays are padded to ``k_pad`` (default: the group
    max k), stacked per cell, repeated along the per-cell scenario
    batch, and the whole flattened (cell x trace x seed) axis runs
    through a single ``jit(vmap)`` — sharded/chunked as one batch by
    ``exec_plan`` — instead of one dispatch per cell.  Padded slots are
    exact no-ops, so per-cell results match :func:`run_campaign`.
    Post-processing is likewise vectorised: one ``auroc_batch`` sweep
    per group, sliced back into per-cell :class:`CampaignResult`\\ s
    (aligned with ``cells``).

    "batch" cells centralise the data (different array shapes) and are
    rejected — run them through :func:`run_campaign`.

    A thin shim over the declarative pipeline
    (:mod:`repro.core.experiment`): per-cell explicit trace lists,
    fused buckets grouped by :func:`plan`.
    """
    if not cells:
        return []
    for cfg, _ in cells:
        if cfg.scheme == "batch":
            raise ValueError("'batch' cells centralise the data onto one "
                             "device (different array shapes); run them "
                             "via run_campaign")
    from repro.core import experiment as X
    spec = X.ExperimentSpec(
        data=X.DataSpec(model=model, device_x=device_x,
                        device_counts=device_counts, test_x=test_x,
                        test_y=test_y),
        base=cells[0][0],
        cells=tuple(X.CellSpec(scheme=cfg.scheme, k=cfg.num_clusters,
                               cfg=cfg, traces=traces)
                    for cfg, traces in cells),
        seeds=X.SeedSpec(tuple(seeds)), exec_plan=exec_plan,
        target_loss=target_loss, k_pad=k_pad)
    return X.run_experiment(spec).results


def run_fused_multimodel_campaigns(model: ModelLike,
                                   device_x: np.ndarray,
                                   device_counts: np.ndarray,
                                   test_x: np.ndarray, test_y: np.ndarray,
                                   cells: Sequence[Tuple[MultiModelConfig,
                                                         Sequence[Failure]]],
                                   seeds: Sequence[int],
                                   exec_plan: Optional[ExecPlan] = None,
                                   pad_m: Optional[int] = None
                                   ) -> List[MultiCampaignResult]:
    """Many multi-model baseline cells, fused into ONE dispatch per
    scheme — the multi-model twin of :func:`run_fused_campaigns`.

    Cells whose static config agrees on everything but ``num_models``
    are grouped (the assignment rule is structural, so fedgroup / ifca /
    fesem cells always compile separately); each group's model axis is
    padded to ``pad_m`` (default: the group max M) with a
    ``model_valid`` mask stacked along the flattened (cell x trace x
    seed) axis, so cells with DIFFERENT model counts share one compiled
    executable and one dispatch.  Padded model slots are exact no-ops
    (never assigned, never aggregated, masked out of the loss/metrics),
    so per-cell results match :func:`run_multimodel_campaign`.

    A thin shim over the declarative pipeline
    (:mod:`repro.core.experiment`)."""
    if not cells:
        return []
    from repro.core import experiment as X
    spec = X.ExperimentSpec(
        data=X.DataSpec(model=model, device_x=device_x,
                        device_counts=device_counts, test_x=test_x,
                        test_y=test_y),
        base=SimConfig(num_devices=cells[0][0].num_devices),
        cells=tuple(X.CellSpec(scheme=cfg.scheme, k=cfg.num_models,
                               cfg=cfg, traces=traces)
                    for cfg, traces in cells),
        seeds=X.SeedSpec(tuple(seeds)), exec_plan=exec_plan,
        m_pad=pad_m)
    return X.run_experiment(spec).results


def sweep_grid(model: ModelLike, device_x: np.ndarray,
               device_counts: np.ndarray, test_x: np.ndarray,
               test_y: np.ndarray, base: SimConfig,
               scheme_ks: Sequence[Tuple[str, int]],
               traces: Sequence[Failure], seeds: Sequence[int],
               target_loss: Optional[float] = None,
               exec_plan: Optional[ExecPlan] = None,
               pad_k: bool = True, fuse: bool = True
               ) -> Dict[Tuple[str, int], CampaignResult]:
    """(scheme x k) grid of batched campaigns — fused by default: the
    whole grid runs in ONE dispatch per iso-tracking kind (plus one per
    multi-model scheme), not one per cell.

    Single-model schemes (fl/sbt/tolfl) interpret k as the cluster
    count.  With the default ``fuse`` their padded cluster arrays
    (heads, ``device_cluster_array``, ``head_valid`` — padded to the
    grid's max k) become VMAPPED operands stacked along the flattened
    (cell x trace x seed) scenario axis, so all cells of one
    iso-tracking kind share a single ``jit(vmap)`` dispatch as well as a
    single executable: all sbt/tolfl cells go in one call, all fl cells
    in another (their isolated-fallback branch is extra compute non-fl
    cells must not pay).  Trace arrays are built once per grid and the
    post-processing ``auroc_batch`` sweeps the stacked axis once per
    kind (:func:`run_fused_campaigns`).  Padded cluster slots are exact
    no-ops in the combine algebra, so results match the per-cell paths
    bit-for-bit: ``fuse=False`` restores one dispatch per cell (sharing
    executables via broadcast padded arrays — the PR 3 behaviour), and
    ``pad_k=False`` additionally restores the one-compile-per-cell
    static build (both pinned equal by tests).  "batch" cells
    centralise the data onto one device (different array shapes), so
    they always dispatch per cell.

    Multi-model baselines (:data:`MULTI_SCHEMES`) interpret k as the
    model count M; their cells return :class:`MultiCampaignResult`, and
    legacy specs in ``traces`` resolve to the baseline default targets.
    Under ``fuse`` every multi cell is padded to the grid's max M with a
    ``model_valid`` mask, so same-scheme cells with different M also
    share one executable and one dispatch
    (:func:`run_fused_multimodel_campaigns`); ``fuse=False`` dispatches
    each through :func:`run_multimodel_campaign`.  Every cell covers
    the full (trace x seed) scenario batch under ``exec_plan``.

    A thin shim over the declarative pipeline: the grid IS an
    :class:`repro.core.experiment.ExperimentSpec` (one
    :class:`CellSpec` per (scheme, k), configs derived from ``base``),
    and bucketing / padding decisions live in
    :func:`repro.core.experiment.plan`."""
    from repro.core import experiment as X
    spec = X.ExperimentSpec(
        data=X.DataSpec(model=model, device_x=device_x,
                        device_counts=device_counts, test_x=test_x,
                        test_y=test_y),
        base=base,
        cells=tuple(X.CellSpec(scheme=s, k=k) for s, k in scheme_ks),
        traces=X.TraceSpec(traces=tuple(traces)),
        seeds=X.SeedSpec(tuple(seeds)), exec_plan=exec_plan,
        target_loss=target_loss, fuse=fuse, pad_k=pad_k)
    res = X.run_experiment(spec)
    return dict(zip(tuple(scheme_ks), res.results))
