"""Batched Monte-Carlo failure-campaign engine.

The paper's robustness claims ("a range of realistic settings that
consider client as well as server failure") need *scenario diversity*:
grids of failure traces x seeds, not one hand-picked event per run.
This module sweeps such grids at hardware speed: for one static
``SimConfig`` (scheme, k, rounds, ...) every (trace, seed) scenario in
the batch runs through ONE ``jit(vmap(core))`` executable — the core is
the exact same round-loop :func:`repro.core.simulate.run_simulation`
uses, so per-scenario results match the single-shot simulator
(``tests/test_campaign.py`` asserts equality and the single compile).

Typical use::

    traces = [FailureTrace.none(),
              FailureTrace.from_spec(FailureSpec(10, "server"), topo)]
    res = run_campaign(ae_cfg, dx, counts, test_x, test_y,
                       SimConfig(scheme="tolfl", num_clusters=5),
                       traces, seeds=range(8))
    res.summary()["auroc_used_mean"]

The multi-model baselines (FedGroup / IFCA / FeSEM) get the same
treatment: :func:`run_multimodel_campaign` vmaps the pure core from
:mod:`repro.core.baselines` over a stacked (trace x seed) grid, so the
paper's Table III-V comparison columns also cost one compile per cell.

Execution scales along three independent axes (:class:`ExecPlan`):

* **compile amortisation** — data arrays are ARGUMENTS of an
  lru-cached jitted batched core, never closed over, so repeated
  campaigns on the same shapes (the benchmarks' inner loops) reuse the
  compiled executable instead of re-tracing per campaign;
* **scenario sharding** — ``ExecPlan(shard=True)`` pads the
  (trace x seed) batch to a device-divisible size and dispatches it
  through a ``shard_map`` over the local-device "scenario" mesh axis
  (:func:`repro.sharding.compat_shard_map`), so B scenarios run on D
  devices in B/D time;
* **host chunking** — ``ExecPlan(chunk_size=c)`` slices the batch into
  same-shape chunks (the last one padded, padding stripped after), so
  arbitrarily large grids run in bounded device memory with ONE compile.

Different schemes / k imply different topologies, so a (scheme x k) grid
is a Python loop of batched calls — :func:`sweep_grid`.  By default the
single-model cells pad their cluster arrays (head indices,
``device_cluster_array``) to the grid's max k and feed them to the core
as dynamic operands (:func:`repro.core.simulate._build_core_arrays`), so
single-model cells share one compiled executable PER ISO-TRACKING KIND —
all fl cells one, all sbt/tolfl cells another (the fl fallback branch
roughly doubles per-round compute, so non-fl cells never pay for it) —
instead of one compile per cell; padded cluster slots are exact no-ops
in the combine algebra, so results match the per-cell path bit-for-bit
(``pad_k=False`` keeps the legacy one-compile-per-cell build, pinned
equal by tests).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core.baselines import (MultiModelConfig, _build_multimodel_core,
                                  as_multimodel_trace,
                                  prepare_multimodel_arrays)
from repro.core.failure import Failure, as_trace, stack_traces
from repro.core.simulate import (SimConfig, _build_core, _build_core_arrays,
                                 _prepare_arrays)
from repro.sharding import compat_shard_map
from repro.training.metrics import auroc_batch

#: incremented each time a batched campaign core is (re)traced — lets
#: tests assert that a whole campaign costs exactly one compile.
TRACE_COUNT = 0

#: schemes dispatched to the multi-model engine by :func:`sweep_grid`
MULTI_SCHEMES = ("fedgroup", "ifca", "fesem")


@dataclass(frozen=True)
class ExecPlan:
    """How a campaign batch is executed (results never change with it).

    shard
        Split the scenario axis across the local JAX devices via
        ``shard_map`` (the batch is padded up to a device-divisible
        size; padding is stripped from the results).
    chunk_size
        Host-side chunking: at most this many scenarios are resident on
        the devices at once; every chunk has the same padded shape so
        the whole campaign still costs one compile.  ``None`` runs the
        batch in one shot.
    devices
        Cap on the number of local devices used when sharding
        (default: all of ``jax.local_device_count()``).
    """
    shard: bool = False
    chunk_size: Optional[int] = None
    devices: Optional[int] = None

    def num_devices(self) -> int:
        n = jax.local_device_count()
        return min(self.devices, n) if self.devices else n


def mean_ci95(vals: np.ndarray) -> Tuple[float, float, float]:
    """(mean, sample std, normal-approx 95% CI half-width) over seeds.

    Uses the SAMPLE standard deviation (ddof=1): campaigns estimate the
    spread of a seed population from few draws, and the ddof=0
    population formula reports over-tight intervals for small B.  A
    single scenario has no spread estimate: std 0, CI half-width nan."""
    b = len(vals)
    mean = float(np.mean(vals))
    if b <= 1:
        return mean, 0.0, float("nan")
    std = float(np.std(vals, ddof=1))
    return mean, std, 1.96 * std / np.sqrt(b)


@dataclass
class CampaignResult:
    """Stacked per-scenario results of one batched campaign.

    Scenario b is (trace ``trace_index[b]``, seed ``seed[b]``); arrays
    are aligned on that leading axis."""
    cfg: SimConfig
    trace_index: np.ndarray        # (B,) int — index into the trace list
    seed: np.ndarray               # (B,) int
    auroc_used: np.ndarray         # (B,) paper-reported AUROC
    final_auroc: np.ndarray        # (B,) global-model AUROC
    iso_auroc: np.ndarray          # (B,) isolated-mean AUROC (nan if n/a)
    iso_active: np.ndarray         # (B,) bool — FL fallback engaged
    loss_curves: np.ndarray        # (B, rounds) REPORTED loss: global,
    #                                but FL server-dead rounds carry the
    #                                isolated mean (Fig 4 semantics)
    iso_loss_curves: np.ndarray    # (B, rounds)
    rounds_to_loss: np.ndarray     # (B,) float, nan when never reached

    @property
    def num_scenarios(self) -> int:
        return len(self.auroc_used)

    def select(self, trace_index: int) -> np.ndarray:
        """auroc_used of every scenario using trace ``trace_index``."""
        return self.auroc_used[self.trace_index == trace_index]

    def summary(self) -> Dict[str, float]:
        """Mean / sample std / normal-approx 95% CI of the reported
        AUROC plus mean rounds-to-loss (over scenarios that reached the
        target)."""
        mean, std, half = mean_ci95(self.auroc_used)
        r2l = self.rounds_to_loss[np.isfinite(self.rounds_to_loss)]
        return {
            "num_scenarios": float(self.num_scenarios),
            "auroc_used_mean": mean,
            "auroc_used_std": std,
            "auroc_used_ci95_lo": mean - half,
            "auroc_used_ci95_hi": mean + half,
            "rounds_to_loss_mean": (float(np.mean(r2l)) if len(r2l)
                                    else float("nan")),
        }


@dataclass
class MultiCampaignResult:
    """Stacked per-scenario results of one batched multi-model campaign.

    Scenario b is (trace ``trace_index[b]``, seed ``seed[b]``)."""
    cfg: MultiModelConfig
    trace_index: np.ndarray        # (B,) int — index into the trace list
    seed: np.ndarray               # (B,) int
    best_auroc: np.ndarray         # (B,) the paper's * column
    multi_auroc: np.ndarray        # (B,) the paper's dagger column
    loss_curves: np.ndarray        # (B, rounds) per-sample-min test loss
    assignments: np.ndarray        # (B, N) final device -> model maps

    @property
    def num_scenarios(self) -> int:
        return len(self.best_auroc)

    def select(self, trace_index: int, column: str = "best") -> np.ndarray:
        """best/multi AUROC of every scenario using ``trace_index``."""
        vals = {"best": self.best_auroc, "multi": self.multi_auroc}[column]
        return vals[self.trace_index == trace_index]

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {"num_scenarios": float(self.num_scenarios)}
        for column in ("best", "multi"):
            vals = {"best": self.best_auroc,
                    "multi": self.multi_auroc}[column]
            mean, std, half = mean_ci95(vals)
            out[f"{column}_auroc_mean"] = mean
            out[f"{column}_auroc_std"] = std
            out[f"{column}_auroc_ci95_lo"] = mean - half
            out[f"{column}_auroc_ci95_hi"] = mean + half
        return out


def _scenario_grid(num_traces: int, seeds: Sequence[int]
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Full cross product: trace-major, seed-minor."""
    seeds = np.asarray(list(seeds), np.int32)
    trace_idx = np.repeat(np.arange(num_traces, dtype=np.int32),
                          len(seeds))
    seed_arr = np.tile(seeds, num_traces)
    return trace_idx, seed_arr


# ---------------------------------------------------------------------------
# Cached batched executables.  Data/topology arrays are ARGUMENTS (broadcast
# over the vmap), never closed over: the jit lives in an lru_cache keyed on
# the static config, so repeated campaigns with the same shapes reuse the
# compiled executable instead of re-tracing per campaign.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _executable(kind: str, ae_cfg: AutoencoderConfig, cfg, k_pad, ndev,
                track_iso: bool = False):
    """Batched scenario executable.

    kind
        "single" (SimConfig core) or "multi" (MultiModelConfig core).
    k_pad
        None -> topology closed over statically (4 broadcast args);
        int  -> topology enters as dynamic arrays padded to ``k_pad``
        (7 broadcast args) — the compile-amortised sweep path.  The
        ``cfg`` key is then scheme/k-normalised by the caller so every
        sweep cell of the same ``track_iso`` kind hits the SAME cache
        entry (``track_iso`` stays in the key: the fl fallback branch
        roughly doubles the per-round compute, so non-fl cells must not
        pay for it — one executable per kind, not per cell).
    ndev
        None -> plain ``jit``; int -> ``jit(shard_map(...))`` over an
        (ndev,)-device "scenario" mesh, batch axis sharded, data
        replicated.
    """
    if kind == "multi":
        core = _build_multimodel_core(ae_cfg, cfg)
        n_bcast = 4
    elif k_pad is None:
        core = _build_core(ae_cfg, cfg, score_history=False)
        n_bcast = 4
    else:
        core = _build_core_arrays(ae_cfg, cfg, cfg.num_devices, k_pad,
                                  track_iso=track_iso,
                                  score_history=False)
        n_bcast = 7

    def scenario(*args):
        global TRACE_COUNT
        TRACE_COUNT += 1          # runs at trace time only: 1 per compile
        return core(*args)

    vm = jax.vmap(scenario, in_axes=(None,) * n_bcast + (0, 0))
    if ndev is None:
        return jax.jit(vm)
    mesh = jax.make_mesh((ndev,), ("scenario",))
    specs = (P(),) * n_bcast + (P("scenario"), P("scenario"))
    return jax.jit(compat_shard_map(vm, mesh, in_specs=specs,
                                    out_specs=P("scenario")))


def _run_batched(batched_call, bcast_args, batch_traces, seed_arr,
                 plan: Optional[ExecPlan]):
    """Dispatch a stacked (trace x seed) batch through ``batched_call``
    with host-side chunking and batch padding per ``plan``; returns the
    outputs pytree as numpy arrays with the padding stripped."""
    plan = plan or ExecPlan()
    B = int(seed_arr.shape[0])
    chunk = min(plan.chunk_size or B, B)
    if plan.shard:
        ndev = plan.num_devices()
        chunk = -(-chunk // ndev) * ndev      # device-divisible chunks
    n_chunks = -(-B // chunk)
    b_pad = n_chunks * chunk
    # pad by repeating scenario 0 — any valid scenario works, the rows
    # are stripped below before post-processing
    sel = np.concatenate([np.arange(B), np.zeros(b_pad - B, np.int64)])
    traces_p = jax.tree.map(lambda x: x[sel], batch_traces)
    seeds_p = jnp.asarray(seed_arr)[sel]
    outs = []
    for c in range(n_chunks):
        sl = slice(c * chunk, (c + 1) * chunk)
        out = batched_call(*bcast_args,
                           jax.tree.map(lambda x: x[sl], traces_p),
                           seeds_p[sl])
        # materialise on the host per chunk: device memory stays bounded
        # by chunk_size however large the grid is
        outs.append(jax.tree.map(np.asarray, out))
    full = jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *outs)
    return jax.tree.map(lambda x: x[:B], full)


def _padded_topology_arrays(topo, k_pad: int):
    """(cluster_ids, heads, head_valid) with the cluster axis padded to
    ``k_pad``: padding head slots point at device 0 but are masked
    invalid, and no device maps to a padded cluster."""
    assert k_pad >= topo.num_clusters, (k_pad, topo.num_clusters)
    heads = np.zeros(k_pad, np.int32)
    heads[:topo.num_clusters] = topo.heads
    head_valid = np.zeros(k_pad, np.float32)
    head_valid[:topo.num_clusters] = 1.0
    return (jnp.asarray(topo.device_cluster_array()), jnp.asarray(heads),
            jnp.asarray(head_valid))


def run_campaign(ae_cfg: AutoencoderConfig, device_x: np.ndarray,
                 device_counts: np.ndarray, test_x: np.ndarray,
                 test_y: np.ndarray, cfg: SimConfig,
                 traces: Sequence[Failure], seeds: Sequence[int],
                 target_loss: Optional[float] = None,
                 exec_plan: Optional[ExecPlan] = None,
                 pad_k: Optional[int] = None) -> CampaignResult:
    """Run every (trace x seed) scenario through one compiled executable.

    ``traces`` may mix legacy :class:`FailureSpec`s and
    :class:`FailureTrace`s; all are normalised to traces and stacked.
    ``cfg.seed`` is ignored — seeds come from the grid.  ``exec_plan``
    chooses scenario sharding / host chunking (results are unchanged);
    ``pad_k`` (int >= cfg's cluster count) routes through the padded-k
    core so campaigns with different (scheme, k) share one executable —
    :func:`sweep_grid` sets it to the grid's max k."""
    topo = cfg.topology()
    norm = [as_trace(t, topo) for t in traces]
    trace_idx, seed_arr = _scenario_grid(len(norm), seeds)
    if len(trace_idx) == 0:
        raise ValueError("empty campaign: need >=1 trace and >=1 seed")
    stacked = stack_traces(norm)
    batch_traces = jax.tree.map(lambda x: x[trace_idx], stacked)

    dx, counts, valid = _prepare_arrays(cfg, device_x, device_counts)
    tx = jnp.asarray(test_x)
    assert dx.shape[0] == topo.num_devices, (dx.shape, topo.num_devices)

    track_iso = (cfg.scheme == "fl")
    if pad_k is None:
        key_cfg = dataclasses.replace(cfg, seed=0)
        bcast = (dx, counts, valid, tx)
    else:
        # scheme / num_clusters are normalised OUT of the cache key: the
        # padded core reads the topology from the arrays, so every
        # single-model sweep cell of the same track_iso kind resolves to
        # the same executable
        key_cfg = dataclasses.replace(cfg, seed=0, scheme="tolfl",
                                      num_clusters=1)
        bcast = (dx, counts, valid, tx) + _padded_topology_arrays(topo,
                                                                  pad_k)
    ndev = (exec_plan.num_devices()
            if exec_plan is not None and exec_plan.shard else None)
    batched = _executable("single", ae_cfg, key_cfg, pad_k, ndev,
                          track_iso)
    out = _run_batched(batched, bcast, batch_traces, seed_arr, exec_plan)

    return _post_process(cfg, out, trace_idx, seed_arr, test_y,
                         target_loss)


def _post_process(cfg, out, trace_idx, seed_arr, test_y, target_loss
                  ) -> CampaignResult:
    losses = np.asarray(out.losses)                    # (B, R)
    iso_losses = np.asarray(out.iso_losses)
    finals = np.asarray(out.final_scores)              # (B, T)
    iso_scores = np.asarray(out.iso_final_scores)      # (B, N, T')
    final_alive = np.asarray(out.final_alive)          # (B, N)
    dead_rounds = np.asarray(out.server_dead_rounds) > 0   # (B, R)
    server_dead = np.asarray(out.server_dead) > 0      # (B,)
    B = losses.shape[0]

    test_y = np.asarray(test_y)
    final_auroc = auroc_batch(finals, test_y)
    track_iso = (cfg.scheme == "fl")
    iso_auroc = np.full(B, np.nan)
    iso_active = np.zeros(B, bool)
    if track_iso:
        # Fig 4 semantics (matching run_simulation): server-dead rounds
        # report the isolated-mean loss, not the frozen global model's
        losses = np.where(dead_rounds, iso_losses, losses)
        iso_active = server_dead.copy()
        hit = np.flatnonzero(iso_active)
        if len(hit) and iso_scores.shape[-1]:
            n_dev = iso_scores.shape[1]
            per_dev = auroc_batch(
                iso_scores[hit].reshape(len(hit) * n_dev, -1),
                test_y).reshape(len(hit), n_dev)
            alive = (final_alive[hit] > 0)
            denom = alive.sum(axis=1)
            num = np.where(alive, per_dev, 0.0).sum(axis=1)
            iso_auroc[hit] = np.where(denom > 0,
                                      num / np.maximum(denom, 1),
                                      np.nan)
    auroc_used = np.where(iso_active, iso_auroc, final_auroc)

    r2l = np.full(B, np.nan)
    if target_loss is not None:
        reached = losses <= target_loss                # (B, R)
        any_hit = reached.any(axis=1)
        first = reached.argmax(axis=1) + 1.0
        r2l = np.where(any_hit, first, np.nan)

    return CampaignResult(cfg=cfg, trace_index=trace_idx, seed=seed_arr,
                          auroc_used=auroc_used, final_auroc=final_auroc,
                          iso_auroc=iso_auroc, iso_active=iso_active,
                          loss_curves=losses, iso_loss_curves=iso_losses,
                          rounds_to_loss=r2l)


def run_multimodel_campaign(ae_cfg: AutoencoderConfig,
                            device_x: np.ndarray,
                            device_counts: np.ndarray, test_x: np.ndarray,
                            test_y: np.ndarray, cfg: MultiModelConfig,
                            traces: Sequence[Failure],
                            seeds: Sequence[int],
                            exec_plan: Optional[ExecPlan] = None
                            ) -> MultiCampaignResult:
    """Every (trace x seed) scenario of a multi-model baseline in one
    jitted, vmapped call — the multi-model twin of :func:`run_campaign`
    (same cached-executable / sharding / chunking machinery).

    ``traces`` may mix legacy :class:`FailureSpec`s and
    :class:`FailureTrace`s; specs are normalised with the BASELINE
    default targets (see :func:`as_multimodel_trace`).  The client/group
    trace split happens in-graph inside the core, so one compiled
    executable covers the whole grid.  ``cfg.seed`` is ignored — seeds
    come from the grid."""
    norm = [as_multimodel_trace(t, cfg.num_devices) for t in traces]
    trace_idx, seed_arr = _scenario_grid(len(norm), seeds)
    if len(trace_idx) == 0:
        raise ValueError("empty campaign: need >=1 trace and >=1 seed")
    stacked = stack_traces(norm)
    batch_traces = jax.tree.map(lambda x: x[trace_idx], stacked)

    dx, counts, valid = prepare_multimodel_arrays(device_x, device_counts)
    tx = jnp.asarray(test_x)
    assert dx.shape[0] == cfg.num_devices, (dx.shape, cfg.num_devices)
    key_cfg = dataclasses.replace(cfg, seed=0)
    ndev = (exec_plan.num_devices()
            if exec_plan is not None and exec_plan.shard else None)
    batched = _executable("multi", ae_cfg, key_cfg, None, ndev)
    out = _run_batched(batched, (dx, counts, valid, tx), batch_traces,
                       seed_arr, exec_plan)

    finals = np.asarray(out.final_scores)              # (B, M, T)
    B, M = finals.shape[0], cfg.num_models
    test_y = np.asarray(test_y)
    per_model = auroc_batch(finals.reshape(B * M, -1),
                            test_y).reshape(B, M)
    best = per_model.max(axis=1)
    multi = auroc_batch(finals.min(axis=1), test_y)
    return MultiCampaignResult(cfg=cfg, trace_index=trace_idx,
                               seed=seed_arr, best_auroc=best,
                               multi_auroc=multi,
                               loss_curves=np.asarray(out.losses),
                               assignments=np.asarray(out.assignments))


def sweep_grid(ae_cfg: AutoencoderConfig, device_x: np.ndarray,
               device_counts: np.ndarray, test_x: np.ndarray,
               test_y: np.ndarray, base: SimConfig,
               scheme_ks: Sequence[Tuple[str, int]],
               traces: Sequence[Failure], seeds: Sequence[int],
               target_loss: Optional[float] = None,
               exec_plan: Optional[ExecPlan] = None,
               pad_k: bool = True
               ) -> Dict[Tuple[str, int], CampaignResult]:
    """(scheme x k) grid of batched campaigns.

    Single-model schemes (fl/sbt/tolfl) interpret k as the cluster
    count.  With ``pad_k`` (the default) their cluster arrays are padded
    to the grid's max k and passed to the core as dynamic operands, so
    such cells share one compiled executable PER ISO-TRACKING KIND: all
    sbt/tolfl cells compile once, all fl cells once more (their
    isolated-fallback branch is extra compute non-fl cells must not
    pay) — bounded compiles for the whole grid instead of one per cell,
    with results unchanged: padded cluster slots are exact no-ops.
    ``pad_k=False`` restores the one-compile-per-cell static build.
    "batch" cells centralise the data onto one device (different array
    shapes), so they always compile separately.

    Multi-model baselines (:data:`MULTI_SCHEMES`) interpret k as the
    model count M and run through :func:`run_multimodel_campaign`
    (their cells return :class:`MultiCampaignResult`, and legacy specs
    in ``traces`` resolve to the baseline default targets).  Every cell
    covers the full (trace x seed) scenario batch under ``exec_plan``.
    """
    single_ks = [k for scheme, k in scheme_ks
                 if scheme not in MULTI_SCHEMES and scheme != "batch"]
    k_common = max(single_ks) if (pad_k and single_ks) else None
    out: Dict[Tuple[str, int], CampaignResult] = {}
    for scheme, k in scheme_ks:
        if scheme in MULTI_SCHEMES:
            # multi-model engines take ONE local step per round: give
            # them the single-model cells' TOTAL local-step budget
            # (rounds x E) so grid columns compare equal work
            mcfg = MultiModelConfig(scheme=scheme,
                                    num_devices=base.num_devices,
                                    num_models=k,
                                    rounds=base.rounds * base.local_epochs,
                                    lr=base.lr, dropout=base.dropout)
            out[(scheme, k)] = run_multimodel_campaign(
                ae_cfg, device_x, device_counts, test_x, test_y, mcfg,
                traces, seeds, exec_plan=exec_plan)
        else:
            cfg = dataclasses.replace(base, scheme=scheme, num_clusters=k)
            cell_pad = k_common if scheme != "batch" else None
            out[(scheme, k)] = run_campaign(ae_cfg, device_x,
                                            device_counts, test_x, test_y,
                                            cfg, traces, seeds,
                                            target_loss,
                                            exec_plan=exec_plan,
                                            pad_k=cell_pad)
    return out
