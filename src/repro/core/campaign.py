"""Batched Monte-Carlo failure-campaign engine.

The paper's robustness claims ("a range of realistic settings that
consider client as well as server failure") need *scenario diversity*:
grids of failure traces x seeds, not one hand-picked event per run.
This module sweeps such grids at hardware speed: for one static
``SimConfig`` (scheme, k, rounds, ...) every (trace, seed) scenario in
the batch runs through ONE ``jit(vmap(core))`` executable — the core is
the exact same round-loop :func:`repro.core.simulate.run_simulation`
uses, so per-scenario results match the single-shot simulator
(``tests/test_campaign.py`` asserts equality and the single compile).

Typical use::

    traces = [FailureTrace.none(),
              FailureTrace.from_spec(FailureSpec(10, "server"), topo)]
    res = run_campaign(ae_cfg, dx, counts, test_x, test_y,
                       SimConfig(scheme="tolfl", num_clusters=5),
                       traces, seeds=range(8))
    res.summary()["auroc_used_mean"]

The multi-model baselines (FedGroup / IFCA / FeSEM) get the same
treatment: :func:`run_multimodel_campaign` vmaps the pure core from
:mod:`repro.core.baselines` over a stacked (trace x seed) grid, so the
paper's Table III-V comparison columns also cost one compile per cell.

Different schemes / k imply different topologies (different array
shapes), so a (scheme x k) grid is a Python loop of batched calls —
:func:`sweep_grid` — with one compile per cell, not per scenario.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core.baselines import (MultiModelConfig, _build_multimodel_core,
                                  as_multimodel_trace,
                                  prepare_multimodel_arrays)
from repro.core.failure import Failure, as_trace, stack_traces
from repro.core.simulate import (SimConfig, _build_core, _prepare_arrays,
                                 iso_mean_auroc)
from repro.training.metrics import auroc

#: incremented each time a batched campaign core is (re)traced — lets
#: tests assert that a whole campaign costs exactly one compile.
TRACE_COUNT = 0

#: schemes dispatched to the multi-model engine by :func:`sweep_grid`
MULTI_SCHEMES = ("fedgroup", "ifca", "fesem")


def mean_ci95(vals: np.ndarray) -> Tuple[float, float, float]:
    """(mean, sample std, normal-approx 95% CI half-width) over seeds.

    Uses the SAMPLE standard deviation (ddof=1): campaigns estimate the
    spread of a seed population from few draws, and the ddof=0
    population formula reports over-tight intervals for small B.  A
    single scenario has no spread estimate: std 0, CI half-width nan."""
    b = len(vals)
    mean = float(np.mean(vals))
    if b <= 1:
        return mean, 0.0, float("nan")
    std = float(np.std(vals, ddof=1))
    return mean, std, 1.96 * std / np.sqrt(b)


@dataclass
class CampaignResult:
    """Stacked per-scenario results of one batched campaign.

    Scenario b is (trace ``trace_index[b]``, seed ``seed[b]``); arrays
    are aligned on that leading axis."""
    cfg: SimConfig
    trace_index: np.ndarray        # (B,) int — index into the trace list
    seed: np.ndarray               # (B,) int
    auroc_used: np.ndarray         # (B,) paper-reported AUROC
    final_auroc: np.ndarray        # (B,) global-model AUROC
    iso_auroc: np.ndarray          # (B,) isolated-mean AUROC (nan if n/a)
    iso_active: np.ndarray         # (B,) bool — FL fallback engaged
    loss_curves: np.ndarray        # (B, rounds) REPORTED loss: global,
    #                                but FL server-dead rounds carry the
    #                                isolated mean (Fig 4 semantics)
    iso_loss_curves: np.ndarray    # (B, rounds)
    rounds_to_loss: np.ndarray     # (B,) float, nan when never reached

    @property
    def num_scenarios(self) -> int:
        return len(self.auroc_used)

    def select(self, trace_index: int) -> np.ndarray:
        """auroc_used of every scenario using trace ``trace_index``."""
        return self.auroc_used[self.trace_index == trace_index]

    def summary(self) -> Dict[str, float]:
        """Mean / sample std / normal-approx 95% CI of the reported
        AUROC plus mean rounds-to-loss (over scenarios that reached the
        target)."""
        mean, std, half = mean_ci95(self.auroc_used)
        r2l = self.rounds_to_loss[np.isfinite(self.rounds_to_loss)]
        return {
            "num_scenarios": float(self.num_scenarios),
            "auroc_used_mean": mean,
            "auroc_used_std": std,
            "auroc_used_ci95_lo": mean - half,
            "auroc_used_ci95_hi": mean + half,
            "rounds_to_loss_mean": (float(np.mean(r2l)) if len(r2l)
                                    else float("nan")),
        }


@dataclass
class MultiCampaignResult:
    """Stacked per-scenario results of one batched multi-model campaign.

    Scenario b is (trace ``trace_index[b]``, seed ``seed[b]``)."""
    cfg: MultiModelConfig
    trace_index: np.ndarray        # (B,) int — index into the trace list
    seed: np.ndarray               # (B,) int
    best_auroc: np.ndarray         # (B,) the paper's * column
    multi_auroc: np.ndarray        # (B,) the paper's dagger column
    loss_curves: np.ndarray        # (B, rounds) per-sample-min test loss
    assignments: np.ndarray        # (B, N) final device -> model maps

    @property
    def num_scenarios(self) -> int:
        return len(self.best_auroc)

    def select(self, trace_index: int, column: str = "best") -> np.ndarray:
        """best/multi AUROC of every scenario using ``trace_index``."""
        vals = {"best": self.best_auroc, "multi": self.multi_auroc}[column]
        return vals[self.trace_index == trace_index]

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {"num_scenarios": float(self.num_scenarios)}
        for column in ("best", "multi"):
            vals = {"best": self.best_auroc,
                    "multi": self.multi_auroc}[column]
            mean, std, half = mean_ci95(vals)
            out[f"{column}_auroc_mean"] = mean
            out[f"{column}_auroc_std"] = std
            out[f"{column}_auroc_ci95_lo"] = mean - half
            out[f"{column}_auroc_ci95_hi"] = mean + half
        return out


def _scenario_grid(num_traces: int, seeds: Sequence[int]
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Full cross product: trace-major, seed-minor."""
    seeds = np.asarray(list(seeds), np.int32)
    trace_idx = np.repeat(np.arange(num_traces, dtype=np.int32),
                          len(seeds))
    seed_arr = np.tile(seeds, num_traces)
    return trace_idx, seed_arr


def run_campaign(ae_cfg: AutoencoderConfig, device_x: np.ndarray,
                 device_counts: np.ndarray, test_x: np.ndarray,
                 test_y: np.ndarray, cfg: SimConfig,
                 traces: Sequence[Failure], seeds: Sequence[int],
                 target_loss: Optional[float] = None) -> CampaignResult:
    """Run every (trace x seed) scenario in one jitted, vmapped call.

    ``traces`` may mix legacy :class:`FailureSpec`s and
    :class:`FailureTrace`s; all are normalised to traces and stacked.
    ``cfg.seed`` is ignored — seeds come from the grid."""
    topo = cfg.topology()
    norm = [as_trace(t, topo) for t in traces]
    trace_idx, seed_arr = _scenario_grid(len(norm), seeds)
    if len(trace_idx) == 0:
        raise ValueError("empty campaign: need >=1 trace and >=1 seed")
    stacked = stack_traces(norm)
    batch_traces = jax.tree.map(lambda x: x[trace_idx], stacked)

    dx, counts, valid = _prepare_arrays(cfg, device_x, device_counts)
    tx = jnp.asarray(test_x)
    assert dx.shape[0] == topo.num_devices, (dx.shape, topo.num_devices)
    core = _build_core(ae_cfg, dataclasses.replace(cfg, seed=0),
                       score_history=False)

    def scenario(trace, seed):
        global TRACE_COUNT
        TRACE_COUNT += 1          # runs at trace time only: 1 per compile
        return core(dx, counts, valid, tx, trace, seed)

    # data arrays are closed over, so the jit is per-campaign: the whole
    # (trace x seed) batch shares ONE compile (asserted by tests), and a
    # fresh campaign with different data cannot see a stale closure.
    batched = jax.jit(jax.vmap(scenario, in_axes=(0, 0)))
    out = batched(batch_traces, jnp.asarray(seed_arr))

    return _post_process(cfg, out, trace_idx, seed_arr, test_y,
                         target_loss)


def _post_process(cfg, out, trace_idx, seed_arr, test_y, target_loss
                  ) -> CampaignResult:
    losses = np.asarray(out.losses)                    # (B, R)
    iso_losses = np.asarray(out.iso_losses)
    finals = np.asarray(out.final_scores)              # (B, T)
    iso_scores = np.asarray(out.iso_final_scores)      # (B, N, T')
    final_alive = np.asarray(out.final_alive)          # (B, N)
    dead_rounds = np.asarray(out.server_dead_rounds) > 0   # (B, R)
    server_dead = np.asarray(out.server_dead) > 0      # (B,)
    B = losses.shape[0]

    final_auroc = np.array([auroc(finals[b], test_y) for b in range(B)])
    track_iso = (cfg.scheme == "fl")
    iso_auroc = np.full(B, np.nan)
    iso_active = np.zeros(B, bool)
    if track_iso:
        # Fig 4 semantics (matching run_simulation): server-dead rounds
        # report the isolated-mean loss, not the frozen global model's
        losses = np.where(dead_rounds, iso_losses, losses)
        for b in range(B):
            if server_dead[b]:
                iso_active[b] = True
                iso_auroc[b] = iso_mean_auroc(iso_scores[b],
                                              final_alive[b], test_y)
    auroc_used = np.where(iso_active, iso_auroc, final_auroc)

    r2l = np.full(B, np.nan)
    if target_loss is not None:
        for b in range(B):
            hit = np.where(losses[b] <= target_loss)[0]
            if len(hit):
                r2l[b] = hit[0] + 1

    return CampaignResult(cfg=cfg, trace_index=trace_idx, seed=seed_arr,
                          auroc_used=auroc_used, final_auroc=final_auroc,
                          iso_auroc=iso_auroc, iso_active=iso_active,
                          loss_curves=losses, iso_loss_curves=iso_losses,
                          rounds_to_loss=r2l)


def run_multimodel_campaign(ae_cfg: AutoencoderConfig,
                            device_x: np.ndarray,
                            device_counts: np.ndarray, test_x: np.ndarray,
                            test_y: np.ndarray, cfg: MultiModelConfig,
                            traces: Sequence[Failure],
                            seeds: Sequence[int]) -> MultiCampaignResult:
    """Every (trace x seed) scenario of a multi-model baseline in one
    jitted, vmapped call — the multi-model twin of :func:`run_campaign`.

    ``traces`` may mix legacy :class:`FailureSpec`s and
    :class:`FailureTrace`s; specs are normalised with the BASELINE
    default targets (see :func:`as_multimodel_trace`).  The client/group
    trace split happens in-graph inside the core, so one compiled
    executable covers the whole grid.  ``cfg.seed`` is ignored — seeds
    come from the grid."""
    norm = [as_multimodel_trace(t, cfg.num_devices) for t in traces]
    trace_idx, seed_arr = _scenario_grid(len(norm), seeds)
    if len(trace_idx) == 0:
        raise ValueError("empty campaign: need >=1 trace and >=1 seed")
    stacked = stack_traces(norm)
    batch_traces = jax.tree.map(lambda x: x[trace_idx], stacked)

    dx, counts, valid = prepare_multimodel_arrays(device_x, device_counts)
    tx = jnp.asarray(test_x)
    assert dx.shape[0] == cfg.num_devices, (dx.shape, cfg.num_devices)
    core = _build_multimodel_core(ae_cfg,
                                  dataclasses.replace(cfg, seed=0))

    def scenario(trace, seed):
        global TRACE_COUNT
        TRACE_COUNT += 1          # runs at trace time only: 1 per compile
        return core(dx, counts, valid, tx, trace, seed)

    batched = jax.jit(jax.vmap(scenario, in_axes=(0, 0)))
    out = batched(batch_traces, jnp.asarray(seed_arr))

    finals = np.asarray(out.final_scores)              # (B, M, T)
    B = finals.shape[0]
    best = np.array([max(auroc(finals[b, j], test_y)
                         for j in range(cfg.num_models))
                     for b in range(B)])
    multi = np.array([auroc(finals[b].min(axis=0), test_y)
                      for b in range(B)])
    return MultiCampaignResult(cfg=cfg, trace_index=trace_idx,
                               seed=seed_arr, best_auroc=best,
                               multi_auroc=multi,
                               loss_curves=np.asarray(out.losses),
                               assignments=np.asarray(out.assignments))


def sweep_grid(ae_cfg: AutoencoderConfig, device_x: np.ndarray,
               device_counts: np.ndarray, test_x: np.ndarray,
               test_y: np.ndarray, base: SimConfig,
               scheme_ks: Sequence[Tuple[str, int]],
               traces: Sequence[Failure], seeds: Sequence[int],
               target_loss: Optional[float] = None
               ) -> Dict[Tuple[str, int], CampaignResult]:
    """(scheme x k) grid of batched campaigns — one compile per cell.

    Single-model schemes (batch/fl/sbt/tolfl) interpret k as the cluster
    count; multi-model baselines (:data:`MULTI_SCHEMES`) interpret k as
    the model count M and run through
    :func:`run_multimodel_campaign` (their cells return
    :class:`MultiCampaignResult`, and legacy specs in ``traces`` resolve
    to the baseline default targets).  Every cell covers the full
    (trace x seed) scenario batch."""
    out: Dict[Tuple[str, int], CampaignResult] = {}
    for scheme, k in scheme_ks:
        if scheme in MULTI_SCHEMES:
            # multi-model engines take ONE local step per round: give
            # them the single-model cells' TOTAL local-step budget
            # (rounds x E) so grid columns compare equal work
            mcfg = MultiModelConfig(scheme=scheme,
                                    num_devices=base.num_devices,
                                    num_models=k,
                                    rounds=base.rounds * base.local_epochs,
                                    lr=base.lr, dropout=base.dropout)
            out[(scheme, k)] = run_multimodel_campaign(
                ae_cfg, device_x, device_counts, test_x, test_y, mcfg,
                traces, seeds)
        else:
            cfg = dataclasses.replace(base, scheme=scheme, num_clusters=k)
            out[(scheme, k)] = run_campaign(ae_cfg, device_x,
                                            device_counts, test_x, test_y,
                                            cfg, traces, seeds,
                                            target_loss)
    return out
