"""Persistent XLA compilation-cache wiring + compile accounting.

Cold campaign runs pay XLA twice over: once to trace/lower each dispatch
bucket and once to compile it (BENCH_campaign.json: ``sweep_fused_cold``
at roughly half of ``sweep_fused`` steady-state throughput).  The AOT
path in :mod:`repro.core.experiment` kills the in-process share of that
tax; this module kills the cross-process share by pointing JAX's
persistent compilation cache at a stable on-disk directory, so a second
process running the same spec deserialises every executable instead of
invoking XLA.

* :func:`enable_persistent_cache` — point
  ``jax_compilation_cache_dir`` at :func:`default_cache_dir` (or an
  explicit path) and drop the min-compile-time threshold so every
  campaign core is cached.  Idempotent; safe to call repeatedly.
* :func:`ensure_persistent_cache` — the lazy entry point
  ``experiment.execute`` calls: enables the default cache once per
  process unless the user opted out (``REPRO_CACHE_DIR=off``) or
  already enabled a custom dir.
* :func:`xla_compile_stats` — process-wide counters of persistent-cache
  compile requests / hits / misses, fed by a ``jax.monitoring`` event
  listener.  ``misses`` counts ACTUAL XLA compiles; a warm-disk re-run
  of a spec reports ``misses == 0`` (pinned by
  ``tests/test_aot.py::test_disk_cache_second_process_zero_xla_compiles``).
* :func:`load_executable` / :func:`store_executable` — a persistent
  WHOLE-EXECUTABLE cache on top of XLA's module cache: the AOT path
  (:func:`repro.core.campaign.aot_executable`) serialises each compiled
  bucket executable (``jax.experimental.serialize_executable``) under
  ``<cache>/executables/<jax+backend fingerprint>/``, so a warm re-run
  in a fresh process skips TRACING as well as XLA — XLA's own cache
  only short-circuits compilation, and for campaign-sized programs the
  Python trace/lower step costs seconds of its own.

Environment:

``REPRO_CACHE_DIR``
    Overrides the cache directory.  The values ``off`` / ``none`` /
    ``0`` / empty disable the persistent cache entirely.  Unset, the
    cache lives under ``~/.cache/repro-jax``.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import threading
from typing import Any, Dict, Optional

import jax

ENV_VAR = "REPRO_CACHE_DIR"
#: set REPRO_CACHE_DEBUG=1 to surface the otherwise-swallowed
#: executable-cache store/load failures on stderr
DEBUG_VAR = "REPRO_CACHE_DEBUG"
_OFF_VALUES = {"", "0", "off", "none", "disabled", "false"}


def _debug(msg: str, exc: Exception) -> None:
    if os.environ.get(DEBUG_VAR):
        import sys
        import traceback
        print(f"[repro.compilecache] {msg}: {exc!r}", file=sys.stderr)
        traceback.print_exc(file=sys.stderr)

#: monitoring events the listener folds into :func:`xla_compile_stats`.
_REQUEST_EVENT = "/jax/compilation_cache/compile_requests_use_cache"
_HIT_EVENT = "/jax/compilation_cache/cache_hits"

_lock = threading.Lock()
_counts = {"requests": 0, "hits": 0, "exe_hits": 0, "exe_stores": 0}
_state = {"dir": None, "listening": False, "ensured": False}


def default_cache_dir() -> Optional[str]:
    """Resolved cache directory: ``REPRO_CACHE_DIR`` override first
    (``off``-like values -> None = disabled), else ``~/.cache/repro-jax``."""
    env = os.environ.get(ENV_VAR)
    if env is not None:
        if env.strip().lower() in _OFF_VALUES:
            return None
        return os.path.abspath(os.path.expanduser(env))
    return os.path.expanduser(os.path.join("~", ".cache", "repro-jax"))


def _listener(event: str, **kwargs) -> None:
    if event == _REQUEST_EVENT:
        with _lock:
            _counts["requests"] += 1
    elif event == _HIT_EVENT:
        with _lock:
            _counts["hits"] += 1


def _register_listener() -> None:
    if _state["listening"]:
        return
    from jax._src import monitoring
    monitoring.register_event_listener(_listener)
    _state["listening"] = True


def enable_persistent_cache(path: Optional[str] = None) -> Optional[str]:
    """Enable the on-disk compilation cache at ``path`` (default:
    :func:`default_cache_dir`); returns the directory in use, or None
    when disabled via ``REPRO_CACHE_DIR``.  Re-pointing at a new
    directory resets JAX's in-process handle so subsequent compiles hit
    the new location."""
    path = default_cache_dir() if path is None else os.path.abspath(
        os.path.expanduser(path))
    if path is None:
        return None
    _register_listener()
    if _state["dir"] == path:
        return path
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    # campaign cores compile in ~seconds but MUST be cached: drop the
    # default >1s threshold and the min-entry-size floor
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    _reset_jax_cache_handle()
    _state["dir"] = path
    return path


def disable_persistent_cache() -> None:
    """Turn the persistent cache off for this process (tests use this
    to time genuinely cold compiles)."""
    jax.config.update("jax_compilation_cache_dir", None)
    _reset_jax_cache_handle()
    _state["dir"] = None
    _state["ensured"] = True   # an explicit choice: ensure() stays out


def ensure_persistent_cache() -> Optional[str]:
    """Lazy default wiring: the first ``execute()`` of a process lands
    here and enables the default directory, honouring ``REPRO_CACHE_DIR``
    opt-out and never overriding an explicit
    :func:`enable_persistent_cache` / :func:`disable_persistent_cache`
    call made earlier."""
    if _state["ensured"] or _state["dir"] is not None:
        return _state["dir"]
    _state["ensured"] = True
    return enable_persistent_cache()


def persistent_cache_dir() -> Optional[str]:
    """The directory currently wired, or None when disabled."""
    return _state["dir"]


def _reset_jax_cache_handle() -> None:
    """Drop jax's in-process cache object so the next compile re-reads
    ``jax_compilation_cache_dir`` (private API; tolerated to be absent)."""
    try:
        from jax._src import compilation_cache as _cc
        _cc.reset_cache()
    except Exception:
        pass


# ---------------------------------------------------------------------------
# Whole-executable cache (the layer above XLA's module cache)
# ---------------------------------------------------------------------------
def _exe_dir() -> Optional[str]:
    """Executable-cache directory, fingerprinted by the jax/jaxlib
    version and backend — a serialized executable only loads into the
    runtime that produced it, so upgrades silently start a fresh
    namespace instead of failing deserialisation."""
    d = _state["dir"]
    if d is None:
        return None
    import jaxlib
    tag = (f"jax-{jax.__version__}-jaxlib-{jaxlib.__version__}"
           f"-{jax.default_backend()}")
    return os.path.join(d, "executables", tag)


def exe_fingerprint(parts: Any) -> str:
    """Stable file name of one executable-cache entry.  ``parts`` is
    the campaign layer's canonical key + abstract-argument signature —
    all reprs of frozen dataclasses, strings, ints and tuples, so the
    digest is deterministic across processes."""
    return hashlib.sha256(repr(parts).encode()).hexdigest()


def load_executable(fingerprint: str) -> Optional[Any]:
    """Deserialise a previously stored compiled executable, or None on
    any miss (absent dir, absent entry, stale/undeserialisable payload
    — the cache must never turn into a failure mode)."""
    d = _exe_dir()
    if d is None:
        return None
    path = os.path.join(d, fingerprint + ".pkl")
    try:
        with open(path, "rb") as f:
            payload = pickle.load(f)
        from jax.experimental import serialize_executable as _se
        exe = _se.deserialize_and_load(*payload)
    except Exception as e:
        _debug(f"load_executable({fingerprint[:12]}) miss", e)
        return None
    with _lock:
        _counts["exe_hits"] += 1
    return exe


def store_executable(fingerprint: str, compiled: Any) -> None:
    """Serialise a compiled executable into the cache (atomic rename;
    best-effort — storage failures are silent, the executable in hand
    still runs)."""
    d = _exe_dir()
    if d is None:
        return
    try:
        from jax.experimental import serialize_executable as _se
        payload = _se.serialize(compiled)
        # an entry on disk must be an entry that LOADS: round-trip the
        # payload before persisting.  Serialisation can silently produce
        # an unloadable payload (an executable served from XLA's module
        # cache drops its jit-compiled symbols — see
        # campaign._module_cache_disabled), and a poisoned entry would
        # force every future process through a fail-load + recompile.
        _se.deserialize_and_load(*payload)
        os.makedirs(d, exist_ok=True)
        path = os.path.join(d, fingerprint + ".pkl")
        tmp = f"{path}.tmp{os.getpid()}"
        with open(tmp, "wb") as f:
            pickle.dump(payload, f)
        os.replace(tmp, path)
    except Exception as e:
        _debug(f"store_executable({fingerprint[:12]}) failed", e)
        return
    with _lock:
        _counts["exe_stores"] += 1


def xla_compile_stats() -> Dict[str, int]:
    """Persistent-cache counters since process start (or the last
    :func:`reset_stats`): ``requests`` = compiles that consulted the
    disk cache, ``hits`` = served from disk, ``misses`` = actual XLA
    compiles that then populated it.  ``exe_hits`` / ``exe_stores``
    count whole-executable loads and writes (the AOT layer).  All zero
    while the cache is disabled (the events never fire)."""
    with _lock:
        c = dict(_counts)
    c["misses"] = c["requests"] - c["hits"]
    return c


def reset_xla_compile_stats() -> None:
    """Zero every counter behind :func:`xla_compile_stats`, opening a
    fresh observation window IN-PROCESS.  Analyzer runs and tests use
    this to assert zero-recompile windows (``misses == 0`` across a
    warm replay) without shelling out to a subprocess; pair with
    ``campaign.TRACE_COUNT`` deltas for the trace side."""
    with _lock:
        for k in _counts:
            _counts[k] = 0


#: legacy name (pre-plancheck); same window reset
reset_stats = reset_xla_compile_stats
