"""Tol-FL on the TPU mesh: the production train step.

Two interchangeable gradient-sync schedules (DESIGN.md section 2):

* ``tolfl_ring`` (paper-faithful, Algorithm 1): per-shard gradients inside
  a partial-manual ``shard_map`` (manual over the federated axes
  ("pod", "data"), auto over "model" so tensor-parallel sharding flows
  through GSPMD untouched).  Intra-cluster FedAvg = ``psum`` with
  ``axis_index_groups``; the inter-cluster SBT chain = k-1 sequential
  single-pair ``ppermute`` hops carrying the running (n, g); the final
  broadcast = one masked ``psum``.  The pod axis forms an outer SBT ring.
* ``tolfl_psum`` (beyond-paper optimisation): the algebraically-identical
  failure-weighted mean expressed as a weighted loss under plain GSPMD —
  one reduce-scatter/all-gather pair, compatible with FSDP parameter
  sharding for the 100B+ architectures.

Failure tolerance is in-graph for both: ``alive: (G,)`` enters the jitted
step; weights follow the paper's head-failure semantics
(:func:`repro.core.failure.effective_weights`).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.configs.base import ModelConfig, OptimizerConfig, TolFLConfig
from repro.core import aggregation as agg
from repro.core.failure import effective_weights
from repro.core.topology import Topology
from repro.models import params as P
from repro.models import transformer as T
from repro.optim.optimizers import apply_updates, make_optimizer
from repro.sharding import logical as L


#: version-portable shard_map shared with the campaign scenario-sharding
#: executor; see :mod:`repro.sharding.logical` for the CPU/0.4.x
#: full-manual fallback rationale.
_FULL_MANUAL_FALLBACK = L.FULL_MANUAL_FALLBACK
_partial_manual_shard_map = L.compat_shard_map


def data_axis_size(mesh: Mesh) -> int:
    sizes = L.mesh_axis_sizes(mesh)
    return sizes.get("data", 1)


def num_groups(mesh: Mesh) -> int:
    """Total federated groups = pod x data axis sizes."""
    sizes = L.mesh_axis_sizes(mesh)
    return sizes.get("pod", 1) * sizes.get("data", 1)


def global_topology(mesh: Mesh, tolfl: TolFLConfig) -> Topology:
    return Topology(num_groups(mesh), tolfl.num_clusters)


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------
def init_state(key, mcfg: ModelConfig, ocfg: OptimizerConfig,
               state_dtype: Optional[str] = None) -> Dict[str, Any]:
    params, axes = T.init_params(key, mcfg)
    opt = make_optimizer(ocfg, state_dtype=state_dtype)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def params_logical_axes(mcfg: ModelConfig):
    """Axes tree without materialising params (uses eval_shape on values;
    axes come from running init under eval_shape — init is cheap to trace)."""
    out = {}

    def capture(key):
        p, a = T.init_params(key, mcfg)
        out["axes"] = a
        return p

    jax.eval_shape(capture, jax.random.PRNGKey(0))
    return out["axes"]


def state_logical_axes(mcfg: ModelConfig, ocfg: OptimizerConfig):
    a = params_logical_axes(mcfg)
    if ocfg.name in ("adam", "adamw"):
        from repro.optim.optimizers import AdamState
        opt = AdamState(step=(), mu=a, nu=a)
    else:
        from repro.optim.optimizers import SGDState
        opt = SGDState(step=(), momentum=None)
    return {"params": a, "opt": opt, "step": ()}


def state_shardings(mesh: Mesh, mcfg: ModelConfig, ocfg: OptimizerConfig,
                    rules: dict):
    """NamedSharding tree for the train state."""
    shapes = jax.eval_shape(
        lambda k: init_state(k, mcfg, ocfg), jax.random.PRNGKey(0))
    axes = state_logical_axes(mcfg, ocfg)

    def mk(ax, shp):
        if P.is_axes_leaf(ax) and len(ax) == len(shp.shape):
            return NamedSharding(mesh, L.spec_for(ax, shp.shape, rules, mesh))
        return NamedSharding(mesh, PS())

    return jax.tree.map(mk, axes, shapes, is_leaf=P.is_axes_leaf)


# ---------------------------------------------------------------------------
# GSPMD weighted-psum schedule (optimised; FSDP-compatible)
# ---------------------------------------------------------------------------
def make_psum_train_step(mcfg: ModelConfig, tolfl: TolFLConfig,
                         ocfg: OptimizerConfig, mesh: Mesh,
                         use_pallas: bool = False,
                         state_dtype: Optional[str] = None) -> Callable:
    topo = global_topology(mesh, tolfl)
    G = topo.num_devices
    opt = make_optimizer(ocfg, state_dtype=state_dtype)

    def train_step(state, batch, alive):
        w = effective_weights(alive, topo)              # (G,)
        B = batch["tokens"].shape[0]
        rpg = B // G
        row_w = jnp.repeat(w, rpg)                      # (B,)
        S = batch["labels"].shape[1]
        mask = jnp.broadcast_to(row_w[:, None], (B, S))

        def grad_of(params, b, m):
            def f(p):
                if tolfl.param_cast_dtype:
                    # one explicit cast of the (sharded) master copy: FSDP
                    # all-gathers then move this dtype, not f32 (sec. Perf)
                    cd = jnp.dtype(tolfl.param_cast_dtype)
                    p = jax.tree.map(
                        lambda q: q.astype(cd)
                        if q.dtype == jnp.float32 else q, p)
                return T.loss_fn(p, mcfg, dict(b, mask=m), use_pallas)
            return jax.value_and_grad(f, has_aux=True)(params)

        mb = tolfl.microbatches
        if mb > 1:
            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
            bs = jax.tree.map(split, batch)
            ms = split(mask)

            def acc_step(carry, xs):
                g_acc, l_acc, w_acc = carry
                b_i, m_i = xs
                (lv, metrics), g = grad_of(state["params"], b_i, m_i)
                # weight each microbatch by its mask mass so the
                # accumulated gradient equals the single-batch weighted
                # mean EXACTLY, even when failures skew the masks
                wi = jnp.sum(m_i)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi * wi.astype(gi.dtype), g_acc, g)
                return (g_acc, l_acc + lv * wi, w_acc + wi), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (grads, lv, w_tot), ms_all = jax.lax.scan(
                acc_step, (g0, jnp.float32(0), jnp.float32(0)), (bs, ms))
            denom = jnp.maximum(w_tot, 1e-30)
            grads = jax.tree.map(lambda g: g / denom.astype(g.dtype), grads)
            lv = lv / denom
            metrics = jax.tree.map(lambda x: x[-1], ms_all)
        else:
            (lv, metrics), grads = grad_of(state["params"], batch, mask)
        updates, new_opt = opt.update(grads, state["opt"], state["params"])
        new_params = apply_updates(state["params"], updates)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": lv, **metrics}

    return train_step


# ---------------------------------------------------------------------------
# Paper-faithful ring schedule (shard_map)
# ---------------------------------------------------------------------------
def make_ring_train_step(mcfg: ModelConfig, tolfl: TolFLConfig,
                         ocfg: OptimizerConfig, mesh: Mesh,
                         use_pallas: bool = False,
                         state_dtype: Optional[str] = None) -> Callable:
    sizes = L.mesh_axis_sizes(mesh)
    d_sz = sizes.get("data", 1)
    p_sz = sizes.get("pod", 1)
    has_pod = "pod" in sizes and p_sz > 1
    topo_data = Topology(d_sz, min(tolfl.num_clusters, d_sz))
    topo_glob = Topology(p_sz * d_sz, min(tolfl.num_clusters * p_sz,
                                          p_sz * d_sz))
    heads = topo_data.heads
    last_head = heads[-1]
    opt = make_optimizer(ocfg, state_dtype=state_dtype)
    manual = tuple(ax for ax in ("pod", "data") if ax in sizes)

    def tree_ppermute(tree, axis, perm):
        return jax.tree.map(lambda t: jax.lax.ppermute(t, axis, perm), tree)

    # bf16 grad sync (beyond-paper, section Perf): the ppermute chain can
    # carry the narrow dtype on every backend; psum (carries an `add`
    # computation) only on TPU — XLA's CPU emitter rejects non-f32
    # all-reduce computations ("Invalid binary instruction opcode copy"),
    # so the CPU dry-run measures the chain saving and the psum saving is
    # realised on hardware.
    sync_dt = (jnp.dtype(tolfl.grad_sync_dtype)
               if tolfl.grad_sync_dtype else None)
    psum_dt = sync_dt if (sync_dt is not None
                          and jax.default_backend() == "tpu") else None

    def agg_shard(grads, n, loss, di, pi):
        """Runs per shard inside shard_map: hierarchical Tol-FL combine.

        ``di``/``pi`` are the shard's data/pod indices, fed in as a
        sharded iota operand rather than ``jax.lax.axis_index`` — the
        latter lowers to PartitionId, which XLA's SPMD partitioner
        rejects in the partial-manual (auto over "model") region on
        CPU."""
        # ---- intra-cluster FedAvg (parallel psum over member groups) ----
        # normalise BEFORE the reduce: r = n_i / sum n stays in [0, 1], so
        # the psum payload is well-scaled even under bf16 grad sync
        groups = topo_data.psum_index_groups()
        den = jax.lax.psum(n, "data", axis_index_groups=groups)
        r_w = n / jnp.maximum(den, 1e-30)
        g_c = jax.tree.map(
            lambda g_: jax.lax.psum(
                (g_ * r_w.astype(g_.dtype)).astype(psum_dt or g_.dtype),
                "data", axis_index_groups=groups), grads)
        loss_c = jax.lax.psum(loss * r_w, "data", axis_index_groups=groups)
        if sync_dt is not None:
            # the sequential chain payload (k-1 ppermute hops) in bf16
            g_c = jax.tree.map(lambda g_: g_.astype(sync_dt), g_c)
        carry_n, carry_g, carry_l = den, g_c, loss_c
        # ---- sequential SBT chain over cluster heads (Algorithm 1) ----
        for hop, perm in enumerate(topo_data.ring_perms()):
            recv_n = jax.lax.ppermute(carry_n, "data", perm)
            recv_g = tree_ppermute(carry_g, "data", perm)
            recv_l = jax.lax.ppermute(carry_l, "data", perm)
            is_tgt = (di == heads[hop + 1]).astype(jnp.float32)
            n_new, g_new = agg.combine_pair(recv_n, recv_g, carry_n, carry_g)
            _, l_new = agg.combine_pair(recv_n, recv_l, carry_n, carry_l)
            carry_n = is_tgt * n_new + (1 - is_tgt) * carry_n
            carry_g = jax.tree.map(
                lambda new, old: is_tgt.astype(old.dtype) * new
                + (1 - is_tgt).astype(old.dtype) * old, g_new, carry_g)
            carry_l = is_tgt * l_new + (1 - is_tgt) * carry_l
        # ---- outer SBT ring over pods ----
        at_last = (di == last_head).astype(jnp.float32)
        if has_pod and tolfl.pod_ring:
            for hop in range(p_sz - 1):
                perm = [(hop, hop + 1)]
                recv_n = jax.lax.ppermute(carry_n, "pod", perm)
                recv_g = tree_ppermute(carry_g, "pod", perm)
                recv_l = jax.lax.ppermute(carry_l, "pod", perm)
                is_tgt = ((pi == hop + 1).astype(jnp.float32) * at_last)
                n_new, g_new = agg.combine_pair(recv_n, recv_g,
                                                carry_n, carry_g)
                _, l_new = agg.combine_pair(recv_n, recv_l,
                                            carry_n, carry_l)
                carry_n = is_tgt * n_new + (1 - is_tgt) * carry_n
                carry_g = jax.tree.map(
                    lambda new, old: is_tgt.astype(old.dtype) * new
                    + (1 - is_tgt).astype(old.dtype) * old, g_new, carry_g)
                carry_l = is_tgt * l_new + (1 - is_tgt) * carry_l
            is_final = at_last * (pi == p_sz - 1).astype(jnp.float32)
        else:
            is_final = at_last
            if has_pod:
                if sync_dt is not None and psum_dt is None:
                    carry_g = jax.tree.map(
                        lambda g_: g_.astype(jnp.float32), carry_g)
                # no pod ring: weighted psum across pods at the heads
                carry_g = jax.tree.map(
                    lambda g_: jax.lax.psum(
                        g_ * (carry_n * is_final).astype(g_.dtype), "pod"),
                    carry_g)
                nsum = jax.lax.psum(carry_n * is_final, "pod")
                carry_g = jax.tree.map(
                    lambda g_: g_ / jnp.maximum(nsum, 1e-30).astype(g_.dtype),
                    carry_g)
                carry_l = jax.lax.psum(
                    carry_l * carry_n * is_final, "pod") / jnp.maximum(nsum, 1e-30)
                carry_n = nsum
        # ---- broadcast theta_{t+1} (masked all-reduce) ----
        axes = ("data", "pod") if has_pod else ("data",)
        if sync_dt is not None and psum_dt is None:
            # CPU backend: the broadcast psum must be f32 (see note above)
            carry_g = jax.tree.map(lambda g_: g_.astype(jnp.float32),
                                   carry_g)
        g_fin = jax.tree.map(
            lambda g_: jax.lax.psum(g_ * is_final.astype(g_.dtype), axes),
            carry_g)
        l_fin = jax.lax.psum(carry_l * is_final, axes)
        n_fin = jax.lax.psum(carry_n * is_final, axes)
        return g_fin, l_fin, n_fin

    # with the full-manual fallback every mesh axis is inside the
    # region, so sharding constraints must omit them all
    ctx_axes = (tuple(mesh.axis_names) if _FULL_MANUAL_FALLBACK
                else manual)

    def per_shard(params, batch, alive, gidx):
        with L.manual_axes(ctx_axes):
            return _per_shard(params, batch, alive, gidx)

    def _per_shard(params, batch, alive, gidx):
        gi = gidx[0]                  # this shard's global group index
        di = gi % d_sz
        pi = gi // d_sz

        def local_loss(p):
            total, metrics = T.loss_fn(p, mcfg, batch, use_pallas)
            return total, metrics

        if tolfl.local_epochs > 1:
            def sgd_step(p, _):
                (lv, m), g = jax.value_and_grad(local_loss, has_aux=True)(p)
                return jax.tree.map(
                    lambda a, b: a - ocfg.lr * b.astype(a.dtype), p, g), lv

            p_end, lvs = jax.lax.scan(sgd_step, params,
                                      jnp.arange(tolfl.local_epochs))
            grads = jax.tree.map(
                lambda a, b: (a - b).astype(jnp.float32) / ocfg.lr,
                params, p_end)
            lv = lvs[-1]
        elif tolfl.microbatches > 1:
            mb = tolfl.microbatches

            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

            def acc_step(carry, b_i):
                g_acc, l_acc = carry

                def f(p):
                    return T.loss_fn(p, mcfg, b_i, use_pallas)

                (lv_i, _), g_i = jax.value_and_grad(f, has_aux=True)(params)
                return (jax.tree.map(lambda a, gi: a + gi / mb, g_acc, g_i),
                        l_acc + lv_i / mb), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, lv), _ = jax.lax.scan(
                acc_step, (g0, jnp.float32(0)), jax.tree.map(split, batch))
        else:
            (lv, m), grads = jax.value_and_grad(local_loss, has_aux=True)(
                params)
        w = effective_weights(alive, topo_glob)[gi]
        n = w * batch["tokens"].size
        g_fin, l_fin, n_fin = agg_shard(grads, n, lv, di, pi)
        if tolfl.grad_sync_dtype:
            # restore f32 master grads for the optimizer
            g_fin = jax.tree.map(lambda g_: g_.astype(jnp.float32), g_fin)
        return g_fin, l_fin, n_fin

    out_specs = (PS(), PS(), PS())

    def train_step(state, batch, alive):
        # batch fields (tokens/labels/frames/prefix/...) all shard their
        # leading batch dim over the federated axes
        batch_specs = jax.tree.map(
            lambda v: PS(manual, *([None] * (v.ndim - 1))), batch)
        sm = _partial_manual_shard_map(per_shard, mesh,
                                       (PS(), batch_specs, PS(),
                                        PS(manual)),
                                       out_specs, manual)
        gidx = jnp.arange(p_sz * d_sz, dtype=jnp.int32)
        g, loss, n_tot = sm(state["params"], batch, alive, gidx)
        has_update = (n_tot > 0).astype(jnp.float32)
        g = jax.tree.map(lambda x: x * has_update.astype(x.dtype), g)
        updates, new_opt = opt.update(g, state["opt"], state["params"])
        new_params = apply_updates(state["params"], updates)
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1},
                {"loss": loss, "n_effective": n_tot})

    return train_step


def make_train_step(mcfg: ModelConfig, tolfl: TolFLConfig,
                    ocfg: OptimizerConfig, mesh: Mesh,
                    use_pallas: bool = False,
                    state_dtype: Optional[str] = None) -> Callable:
    if tolfl.schedule in ("tolfl_psum", "fedavg"):
        return make_psum_train_step(mcfg, tolfl, ocfg, mesh, use_pallas,
                                    state_dtype)
    if tolfl.schedule in ("tolfl_ring", "sbt_ring"):
        return make_ring_train_step(mcfg, tolfl, ocfg, mesh, use_pallas,
                                    state_dtype)
    raise ValueError(tolfl.schedule)
