"""Declarative experiment layer: one spec -> plan -> execute pipeline.

The paper's headline results (Figs. 4-6, Tables III-V) are grids over
{scheme, k, failure pattern, seed}.  The campaign engine can sweep such
grids at hardware speed (:mod:`repro.core.campaign`), but its entry
points grew organically — six functions with 8-12 positional arguments,
and every benchmark re-implemented data prep and cell bookkeeping.
This module is the single choke point instead:

* an :class:`ExperimentSpec` *declares* a study — dataset
  (:class:`DataSpec`), a grid of (scheme, k/M) cells
  (:class:`CellSpec`), the failure conditions (:class:`TraceSpec`:
  explicit traces and/or sampled failure-rate grids), the seed
  population (:class:`SeedSpec`) and the execution policy
  (:class:`repro.core.campaign.ExecPlan`);
* :func:`plan` *lowers* the spec to an :class:`ExecutionPlan` — groups
  cells into fused iso-tracking dispatch buckets, chooses per-kind
  pad-k / pad-M, resolves per-topology trace sampling, and computes the
  shard / chunk geometry.  Planning never builds or dispatches a
  compiled executable (``campaign.TRACE_COUNT`` stays put), so plans
  are printable (:meth:`ExecutionPlan.describe`) and unit-testable for
  free;
* :func:`execute` runs the plan through the batched campaign machinery
  — one ``jit(vmap)`` dispatch per bucket — and returns an
  :class:`ExperimentResult`: per-scenario arrays keyed by (cell, trace,
  seed) with ``.summary()`` / ``.per_cell()`` / ``.to_rows()``.

The legacy entry points (``run_campaign``, ``run_multimodel_campaign``,
``sweep_grid``, ``run_fused_campaigns``,
``run_fused_multimodel_campaigns``) are thin shims over this pipeline
(bit-identical results — the shims build the same executable-cache keys
and stacked operands, pinned by ``tests/test_experiment.py``), and
``run_simulation`` is the scalar core the pipeline vmaps.  The coming
multi-host work (``jax.distributed`` process meshes) extends exactly
one seam: the bucket geometry that :func:`plan` computes.

Typical use::

    from repro.api import (CellSpec, DataSpec, ExperimentSpec, SeedSpec,
                           TraceSpec, execute, plan)

    spec = ExperimentSpec(
        data=DataSpec(model=detector, device_x=dx, device_counts=counts,
                      test_x=tx, test_y=ty),
        base=SimConfig(num_devices=10, rounds=40, lr=1e-3),
        cells=(CellSpec("tolfl", 5), CellSpec("fl", 1),
               CellSpec("ifca", 3)),
        traces=TraceSpec(traces=(NO_FAILURE, FailureSpec(20, "server")),
                         p_grid=(0.1, 0.3), traces_per_p=4),
        seeds=SeedSpec.range(3))
    p = plan(spec)            # pure; inspect p.describe() before running
    res = execute(p)          # one fused dispatch per bucket
    res.per_cell()[("tolfl", 5)].summary()["auroc_used_mean"]
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core import campaign as _c
from repro.core import compilecache
from repro.core.baselines import (FaultyMultiModelConfig, MultiModelConfig,
                                  as_multimodel_trace,
                                  prepare_multimodel_arrays)
from repro.core.campaign import (MULTI_SCHEMES, CampaignResult, ExecPlan,
                                 MultiCampaignResult)
from repro.core.failure import (Failure, FailureSpec, FailureTrace, as_trace,
                                concat_traces, sample_rate_grid,
                                stack_traces)
from repro.core.processes import ProcessGrid, sample_process_grids
from repro.core.simulate import FaultySimConfig, SimConfig, _prepare_arrays
from repro.core.topology import Topology
from repro.models.detector import (AutoencoderDetector, DetectorModel,
                                   ModelLike, as_detector)

#: single-model schemes the simulator core understands
SINGLE_SCHEMES = ("batch", "fl", "sbt", "tolfl")


# ---------------------------------------------------------------------------
# Spec dataclasses (the declarative surface)
# ---------------------------------------------------------------------------
#: fired at most once per process; tests reset it to re-pin the warning
_AE_CFG_WARNED = False


@dataclass(frozen=True, eq=False)
class DataSpec:
    """Dataset + federated partition + detector body of one experiment.

    ``model`` is the detector spec the campaign trains — any
    :class:`repro.models.detector.DetectorModel` (or a raw
    :class:`AutoencoderConfig`, which normalises to the paper's
    :class:`~repro.models.detector.AutoencoderDetector`).  ``device_x``
    is the (N, n_max, D) padded per-device tensor, ``device_counts``
    the (N,) true sample counts — exactly the arrays
    :func:`repro.data.federated.pad_devices` returns.  ``name`` is
    cosmetic (it tags :meth:`ExperimentResult.to_rows`).

    ``ae_cfg`` is the deprecated pre-detector spelling of ``model``:
    constructing with it still works (one ``DeprecationWarning`` per
    process), and reading it back returns the underlying
    :class:`AutoencoderConfig` for autoencoder specs (None otherwise)
    so legacy call sites keep functioning."""
    model: Optional[ModelLike] = None
    device_x: Optional[np.ndarray] = None
    device_counts: Optional[np.ndarray] = None
    test_x: Optional[np.ndarray] = None
    test_y: Optional[np.ndarray] = None
    name: str = ""
    ae_cfg: Optional[AutoencoderConfig] = None

    def __post_init__(self):
        global _AE_CFG_WARNED
        model = self.model
        if model is None:
            if self.ae_cfg is None:
                raise TypeError(
                    "DataSpec needs a detector spec: pass model= (a "
                    "DetectorModel or AutoencoderConfig)")
            if not _AE_CFG_WARNED:
                warnings.warn(
                    "DataSpec(ae_cfg=...) is deprecated; pass model= "
                    "(any repro.models.detector.DetectorModel — a raw "
                    "AutoencoderConfig still normalises to the paper "
                    "autoencoder)", DeprecationWarning, stacklevel=3)
                _AE_CFG_WARNED = True
            model = self.ae_cfg
        det = as_detector(model)
        object.__setattr__(self, "model", det)
        object.__setattr__(
            self, "ae_cfg",
            det.cfg if isinstance(det, AutoencoderDetector) else None)


@dataclass(frozen=True, eq=False)
class CellSpec:
    """One grid cell: a scheme plus its k (clusters) or M (models).

    ``overrides`` are (field, value) pairs applied on top of the config
    derived from the experiment's base :class:`SimConfig` — e.g.
    ``(("lr", 1e-4),)``.  ``traces`` (optional) replaces the
    experiment-level explicit trace list for THIS cell only (sampled
    grids from the :class:`TraceSpec` still apply) — the escape hatch
    for ragged grids like "batch has no client-failure column".
    ``cfg`` (optional) bypasses derivation entirely with a fully-formed
    :class:`SimConfig` / :class:`MultiModelConfig` — the legacy shims
    use it; spec authors should not need it.

    Single-model schemes (batch / fl / sbt / tolfl) read k as the
    cluster count; multi-model schemes (fedgroup / ifca / fesem) read
    it as the model count M and inherit the single-model cells' TOTAL
    local-step budget (base.rounds x base.local_epochs) so grid columns
    compare equal work."""
    scheme: str
    k: int = 1
    overrides: Tuple[Tuple[str, Any], ...] = ()
    traces: Optional[Sequence[Failure]] = None
    label: Optional[str] = None
    cfg: Optional[Union[SimConfig, MultiModelConfig]] = None

    @property
    def kind(self) -> str:
        """"single" or "multi" — which engine runs this cell."""
        scheme = (self.cfg.scheme if self.cfg is not None else self.scheme)
        if scheme in MULTI_SCHEMES:
            return "multi"
        if scheme in SINGLE_SCHEMES:
            return "single"
        raise ValueError(
            f"unknown scheme {scheme!r}: single-model schemes are "
            f"{SINGLE_SCHEMES}, multi-model baselines {MULTI_SCHEMES}")

    def resolve(self, base: SimConfig
                ) -> Union[SimConfig, MultiModelConfig]:
        """The cell's full config, derived from ``base`` (or ``cfg``)."""
        if self.cfg is not None:
            return self.cfg
        if self.kind == "multi":
            cfg: Any = MultiModelConfig(
                scheme=self.scheme, num_devices=base.num_devices,
                num_models=self.k,
                rounds=base.rounds * base.local_epochs,
                lr=base.lr, dropout=base.dropout)
        else:
            cfg = dataclasses.replace(base, scheme=self.scheme,
                                      num_clusters=self.k)
        if self.overrides:
            cfg = dataclasses.replace(cfg, **dict(self.overrides))
        return cfg

    def key(self) -> Any:
        """Result-dict key: the label if given, else (scheme, k)."""
        if self.label is not None:
            return self.label
        if self.cfg is not None:
            k = (self.cfg.num_models if self.kind == "multi"
                 else self.cfg.num_clusters)
            return (self.cfg.scheme, k)
        return (self.scheme, self.k)


def cell(scheme: str, k: int = 1, traces: Optional[Sequence[Failure]] = None,
         label: Optional[str] = None, **overrides) -> CellSpec:
    """Sugar: ``cell("tolfl", 5, lr=1e-4)`` ==
    ``CellSpec("tolfl", 5, overrides=(("lr", 1e-4),))``."""
    return CellSpec(scheme, k, tuple(sorted(overrides.items())), traces,
                    label)


@dataclass(frozen=True, eq=False)
class TraceSpec:
    """Failure conditions of an experiment.

    Three composable parts:

    * ``traces`` — explicit conditions (legacy ``FailureSpec``s or
      ``FailureTrace``s), shared by every cell (a cell may override its
      list via :attr:`CellSpec.traces`).
    * ``p_grid`` — sampled failure-rate grids: for each rate p,
      ``traces_per_p`` multi-event failure-and-recovery scenarios are
      drawn via :func:`repro.core.failure.sample_rate_grid` against
      EACH CELL'S OWN topology (a tolfl head is a plain client under
      fl; multi-model baselines sample against the FL topology, device
      0 = the aggregator).  Draws are deduplicated per cell and the
      explicit traces join the dedup as the grid's base conditions, so
      an all-none draw aliases the no-failure condition instead of
      retraining it.  ``plan()`` records the draw -> trace-index map in
      :attr:`CellPlan.draws`.

    * ``processes`` — generative failure models
      (:class:`repro.core.processes.FailureProcess`): each
      :class:`~repro.core.processes.ProcessGrid` draws ``n_samples``
      scenarios of its process against each cell's own topology,
      deduplicated into the same trace pool; ``plan()`` records
      ``{grid index: [trace index per draw]}`` in
      :attr:`CellPlan.process_draws`.  A process with
      ``needs_faulty_engine`` (e.g.
      :class:`~repro.core.processes.FaultyUpdateProcess`) switches
      every cell onto the faulty-aware engine variant.

    When ``p_grid`` or ``processes`` is non-empty the explicit entries
    are normalised to traces at the slot budget (``max_events``,
    default 2N or the largest process default — enough for every device
    to fail AND recover); a "client" ``FailureSpec`` is dropped for
    batch cells (batch centralises the data: there are no clients),
    recorded as ``None`` in :attr:`CellPlan.explicit_index`.  Without
    sampling, explicit entries pass through to the engine verbatim —
    bit-compatible with the legacy entry points.

    Reproducibility: ALL sampling (rate grids and process grids)
    derives from ``sample_seed`` alone — ``plan()`` builds fresh
    generators from it (per cell for ``p_grid``; per (process, draw)
    via :func:`repro.core.processes.process_seed`), so equal specs
    lower to bit-identical trace grids in any process or session."""
    traces: Tuple[Failure, ...] = ()
    p_grid: Tuple[float, ...] = ()
    traces_per_p: int = 4
    recover_prob: float = 0.5
    sample_seed: int = 0
    max_events: Optional[int] = None
    processes: Tuple[ProcessGrid, ...] = ()

    @staticmethod
    def explicit(*traces: Failure) -> "TraceSpec":
        return TraceSpec(traces=tuple(traces))

    @staticmethod
    def sampled(p_grid: Sequence[float], traces_per_p: int = 4,
                base: Sequence[Failure] = (), recover_prob: float = 0.5,
                sample_seed: int = 0,
                max_events: Optional[int] = None) -> "TraceSpec":
        return TraceSpec(traces=tuple(base), p_grid=tuple(p_grid),
                         traces_per_p=traces_per_p,
                         recover_prob=recover_prob,
                         sample_seed=sample_seed, max_events=max_events)

    @staticmethod
    def generated(*grids: ProcessGrid, base: Sequence[Failure] = (),
                  sample_seed: int = 0,
                  max_events: Optional[int] = None) -> "TraceSpec":
        """A spec of generative failure-process grids (plus optional
        explicit base conditions)."""
        return TraceSpec(traces=tuple(base), processes=tuple(grids),
                         sample_seed=sample_seed, max_events=max_events)


@dataclass(frozen=True)
class SeedSpec:
    """The seed population every (cell, trace) pair crosses with."""
    seeds: Tuple[int, ...] = (0,)

    @staticmethod
    def range(n: int, start: int = 0) -> "SeedSpec":
        return SeedSpec(tuple(builtins_range(start, start + n)))


builtins_range = range


@dataclass(frozen=True, eq=False)
class ExperimentSpec:
    """A whole study, declaratively: see the module docstring example.

    ``base`` seeds every cell's config derivation
    (:meth:`CellSpec.resolve`); ``fuse`` / ``pad_k`` / ``k_pad`` /
    ``m_pad`` mirror the legacy ``sweep_grid`` execution knobs (the
    defaults — fuse with per-kind max pads — are what you want; the
    per-cell paths exist for parity pinning)."""
    data: DataSpec
    base: SimConfig
    cells: Tuple[CellSpec, ...]
    traces: TraceSpec = TraceSpec()
    seeds: SeedSpec = SeedSpec()
    exec_plan: Optional[ExecPlan] = None
    target_loss: Optional[float] = None
    fuse: bool = True
    pad_k: bool = True
    k_pad: Optional[int] = None      # explicit pad-k override (all buckets)
    m_pad: Optional[int] = None      # explicit pad-M override (all buckets)


# ---------------------------------------------------------------------------
# The lowered plan
# ---------------------------------------------------------------------------
@dataclass
class CellPlan:
    """One cell, resolved: full config + its trace list and draw map."""
    index: int
    spec: CellSpec
    cfg: Union[SimConfig, MultiModelConfig]
    kind: str                       # "single" | "multi"
    traces: Sequence[Failure]       # resolved per-cell trace list
    explicit_index: Dict[int, Optional[int]]   # explicit pos -> trace idx
    draws: Dict[float, List[int]]   # rate p -> one trace idx per draw
    num_scenarios: int              # len(traces) * len(seeds)
    #: process-grid index -> one trace idx per draw (TraceSpec.processes)
    process_draws: Dict[int, List[int]] = field(default_factory=dict)

    @property
    def key(self) -> Any:
        return self.spec.key()


@dataclass
class BucketPlan:
    """One dispatch bucket: the cells that share a compiled executable
    AND (when ``fused``) a single stacked ``jit(vmap)`` dispatch."""
    index: int
    kind: str                       # "single" | "multi"
    fused: bool
    cell_indices: List[int]
    key_cfg: Union[SimConfig, MultiModelConfig]   # executable-cache key
    track_iso: bool = False         # single: the fl fallback branch
    k_pad: Optional[int] = None     # single: padded cluster-axis length
    m_pad: Optional[int] = None     # multi fused: padded model-axis length
    num_scenarios: int = 0          # flattened (cell x trace x seed) B
    chunk: int = 0                  # scenarios resident per dispatch
    num_chunks: int = 0
    padded_scenarios: int = 0       # B rounded up to chunk * num_chunks
    devices: Optional[int] = None   # shard width (None = unsharded)

    def describe(self) -> str:
        mode = ("fused" if self.fused else
                "per-cell" if (self.k_pad or self.m_pad) else "static")
        pads = []
        if self.k_pad is not None:
            pads.append(f"pad_k={self.k_pad}")
        if self.m_pad is not None:
            pads.append(f"pad_m={self.m_pad}")
        if self.track_iso:
            pads.append("iso")
        geom = f"B={self.num_scenarios}"
        if self.padded_scenarios != self.num_scenarios:
            geom += f"(pad {self.padded_scenarios})"
        geom += f" chunks={self.num_chunks}x{self.chunk}"
        if self.devices:
            geom += f" shard={self.devices}dev"
        return (f"bucket {self.index}: {self.kind} {mode} "
                f"[{' '.join(pads) or '-'}] cells={self.cell_indices} "
                f"{geom}")


@dataclass
class ExecutionPlan:
    """What :func:`execute` will run — computed without building or
    dispatching any executable, so it is printable and testable for
    free (``plan()`` unit tests assert ``campaign.TRACE_COUNT`` never
    moves)."""
    spec: ExperimentSpec
    cells: List[CellPlan]
    buckets: List[BucketPlan]
    #: cached plancheck report (populated by static_report / check=True)
    report: Optional[object] = None

    @property
    def num_scenarios(self) -> int:
        return sum(c.num_scenarios for c in self.cells)

    @property
    def num_dispatch_buckets(self) -> int:
        return len(self.buckets)

    def cell(self, key) -> CellPlan:
        for c in self.cells:
            if c.key == key:
                return c
        raise KeyError(key)

    def static_report(self, budgets: bool = True):
        """Run the plan-time static analyzer (plancheck pass 1) over
        every bucket and cache the :class:`~repro.analysis.plancheck.
        findings.Report`.  Lowers (traces) each bucket exactly like a
        first compile would — zero execution, and the trace is shared
        with a later :func:`execute` of the same plan."""
        if self.report is None:
            from repro.analysis.plancheck import Report, check_plan
            self.report = Report(findings=check_plan(
                self, budgets=budgets))
        return self.report

    def describe(self) -> str:
        seeds = self.spec.seeds.seeds
        lines = [f"ExperimentPlan: {len(self.cells)} cells x "
                 f"{len(seeds)} seeds -> {self.num_scenarios} scenarios "
                 f"in {len(self.buckets)} dispatch buckets"]
        for c in self.cells:
            lines.append(f"  cell {c.index} {c.key}: {len(c.traces)} "
                         f"traces, {c.num_scenarios} scenarios")
        lines.extend("  " + b.describe() for b in self.buckets)
        if self.report is not None:
            status = ("clean" if self.report.clean else
                      f"{len(self.report.findings)} finding(s)")
            lines.append(f"  static analysis: {status}")
            lines.extend("    " + f.describe().replace("\n", "\n    ")
                         for f in self.report.findings)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# plan(): spec -> ExecutionPlan
# ---------------------------------------------------------------------------
def _resolve_cell_traces(spec: ExperimentSpec, cspec: CellSpec,
                         cfg, kind: str, shared_explicit: Sequence[Failure]):
    """(traces, explicit_index, draws, process_draws) of one cell per
    the TraceSpec."""
    ts = spec.traces
    explicit = (list(cspec.traces) if cspec.traces is not None
                else shared_explicit)
    if not ts.p_grid and not ts.processes:
        # verbatim pass-through: no normalisation, no dedup — the
        # legacy-compatible path every shim rides
        return explicit, {j: j for j in range(len(explicit))}, {}, {}

    if kind == "single":
        topo = cfg.topology()
        n = topo.num_devices
        rounds = cfg.rounds
    else:
        # baselines have no cluster heads: sample against the FL
        # topology (device 0 = the aggregator -> server events)
        topo = Topology(cfg.num_devices, 1)
        n = cfg.num_devices
        rounds = cfg.rounds
    # one cell-wide slot budget so every trace in the pool stacks: the
    # rate-grid default (2N) or the largest process default, whichever
    # is bigger (markov churn wants 4N for repeated outages)
    max_events = ts.max_events or max(
        [2 * n] + [pg.process.default_max_events(topo)
                   for pg in ts.processes])

    base_traces: List[FailureTrace] = []
    explicit_index: Dict[int, Optional[int]] = {}
    for j, f in enumerate(explicit):
        if (kind == "single" and cfg.scheme == "batch"
                and isinstance(f, FailureSpec) and f.kind == "client"):
            # batch centralises the data: there are no clients to fail
            explicit_index[j] = None
            continue
        if kind == "multi":
            t = as_multimodel_trace(f, n, max_events)
        else:
            t = as_trace(f, topo, max_events)
        explicit_index[j] = len(base_traces)
        base_traces.append(t)

    rng = np.random.default_rng(ts.sample_seed)
    traces, draws = sample_rate_grid(rng, topo, ts.p_grid, rounds,
                                     ts.traces_per_p,
                                     max_events=max_events,
                                     recover_prob=ts.recover_prob,
                                     base_traces=base_traces)
    process_draws = sample_process_grids(ts.processes, topo, rounds,
                                         ts.sample_seed, max_events,
                                         traces)
    return traces, explicit_index, draws, process_draws


def _faulty_variant(cfg):
    """The faulty-update engine twin of a resolved cell config
    (idempotent).  Subclass swap, not a field: plain-config reprs —
    and therefore every existing executable-cache key and persisted
    fingerprint — stay bit-identical, while the faulty cores key and
    bucket separately by class identity (``dataclasses.replace`` in
    the bucket grouping below preserves the subclass)."""
    if isinstance(cfg, (FaultySimConfig, FaultyMultiModelConfig)):
        return cfg
    cls = (FaultyMultiModelConfig if isinstance(cfg, MultiModelConfig)
           else FaultySimConfig)
    return cls(**{f.name: getattr(cfg, f.name)
                  for f in dataclasses.fields(cfg)})


def _geometry(bucket: BucketPlan, exec_plan: Optional[ExecPlan]) -> None:
    """Fill the bucket's shard / chunk geometry (mirrors
    ``campaign._run_batched`` arithmetic)."""
    plan_ = exec_plan or ExecPlan()
    B = bucket.num_scenarios
    chunk = min(plan_.chunk_size or B, B)
    # planning never warns: the shard-degradation warning fires exactly
    # once per execute() (the old bucket.index == 0 gate fired per
    # plan() call — zero times for entry points that bypassed plan(),
    # twice for plan()+execute())
    ndev = plan_.resolved_devices(warn=False)
    if ndev:
        chunk = -(-chunk // ndev) * ndev
    bucket.devices = ndev
    bucket.chunk = chunk
    bucket.num_chunks = -(-B // chunk)
    bucket.padded_scenarios = bucket.num_chunks * chunk


def plan(spec: ExperimentSpec, check: bool = False) -> ExecutionPlan:
    """Lower a spec to dispatch buckets — pure host-side work.

    Raises ``ValueError`` up front for empty grids, unknown schemes and
    invalid :class:`ExecPlan` values (the legacy paths failed deep
    inside ``_run_batched``).

    ``check=True`` additionally runs the plan-time static analyzer
    (:mod:`repro.analysis.plancheck`) over every bucket — this traces
    each bucket's executable (no execution; the trace is shared with a
    later compile) and attaches the report, which ``describe()`` then
    renders as a per-bucket static-analysis section."""
    if not spec.cells:
        raise ValueError("empty experiment: need >= 1 cell")
    if len(spec.seeds.seeds) == 0:
        raise ValueError("empty campaign: need >=1 trace and >=1 seed")

    shared_explicit = list(spec.traces.traces)
    needs_faulty = any(pg.process.needs_faulty_engine
                       for pg in spec.traces.processes)
    cells: List[CellPlan] = []
    for i, cspec in enumerate(spec.cells):
        kind = cspec.kind            # validates the scheme
        cfg = cspec.resolve(spec.base)
        if needs_faulty:
            cfg = _faulty_variant(cfg)
        traces, explicit_index, draws, process_draws = _resolve_cell_traces(
            spec, cspec, cfg, kind, shared_explicit)
        if len(traces) == 0:
            raise ValueError("empty campaign: need >=1 trace and "
                             ">=1 seed")
        cells.append(CellPlan(
            index=i, spec=cspec, cfg=cfg, kind=kind, traces=traces,
            explicit_index=explicit_index, draws=draws,
            num_scenarios=len(traces) * len(spec.seeds.seeds),
            process_draws=process_draws))

    buckets: List[BucketPlan] = []
    fused_mode = spec.fuse and spec.pad_k

    def add(bucket: BucketPlan) -> None:
        bucket.index = len(buckets)
        bucket.num_scenarios = sum(cells[i].num_scenarios
                                   for i in bucket.cell_indices)
        _geometry(bucket, spec.exec_plan)
        buckets.append(bucket)

    singles = [c for c in cells
               if c.kind == "single" and c.cfg.scheme != "batch"]
    multis = [c for c in cells if c.kind == "multi"]
    batches = [c for c in cells
               if c.kind == "single" and c.cfg.scheme == "batch"]

    if fused_mode:
        groups: Dict[Tuple[SimConfig, bool], List[int]] = {}
        for c in singles:
            key_cfg = dataclasses.replace(c.cfg, seed=0, scheme="tolfl",
                                          num_clusters=1)
            groups.setdefault((key_cfg, c.cfg.scheme == "fl"),
                              []).append(c.index)
        for (key_cfg, track_iso), idxs in groups.items():
            kp = spec.k_pad or max(
                cells[i].cfg.topology().num_clusters for i in idxs)
            add(BucketPlan(index=0, kind="single", fused=True,
                           cell_indices=idxs, key_cfg=key_cfg,
                           track_iso=track_iso, k_pad=kp))
        mgroups: Dict[MultiModelConfig, List[int]] = {}
        for c in multis:
            key_cfg = dataclasses.replace(c.cfg, seed=0, num_models=0)
            mgroups.setdefault(key_cfg, []).append(c.index)
        for key_cfg, idxs in mgroups.items():
            mp = spec.m_pad or max(cells[i].cfg.num_models for i in idxs)
            add(BucketPlan(index=0, kind="multi", fused=True,
                           cell_indices=idxs, key_cfg=key_cfg, m_pad=mp))
    else:
        # per-cell dispatch: pad cluster arrays to the PER-KIND max k
        # (each iso-tracking kind owns its executable either way)
        k_kind: Dict[bool, int] = {}
        if spec.pad_k:
            for c in singles:
                kind_key = (c.cfg.scheme == "fl")
                k_kind[kind_key] = max(k_kind.get(kind_key, 1),
                                       c.cfg.topology().num_clusters)
        for c in singles:
            kp = (spec.k_pad or k_kind.get(c.cfg.scheme == "fl")
                  if spec.pad_k else None)
            if kp is None:
                key_cfg = dataclasses.replace(c.cfg, seed=0)
            else:
                key_cfg = dataclasses.replace(c.cfg, seed=0,
                                              scheme="tolfl",
                                              num_clusters=1)
            add(BucketPlan(index=0, kind="single", fused=False,
                           cell_indices=[c.index], key_cfg=key_cfg,
                           track_iso=(c.cfg.scheme == "fl"), k_pad=kp))
        for c in multis:
            add(BucketPlan(index=0, kind="multi", fused=False,
                           cell_indices=[c.index],
                           key_cfg=dataclasses.replace(c.cfg, seed=0)))
    # "batch" cells centralise the data onto one device, so their array
    # SHAPES differ from every other cell: they can neither fuse nor
    # share the padded executable (whose cache key carries the
    # uncentralised device count) — k_pad is deliberately ignored and
    # each batch cell is its own static single-cell dispatch
    for c in batches:
        add(BucketPlan(index=0, kind="single", fused=False,
                       cell_indices=[c.index],
                       key_cfg=dataclasses.replace(c.cfg, seed=0)))

    out = ExecutionPlan(spec=spec, cells=cells, buckets=buckets)
    if check:
        out.static_report()
    return out


# ---------------------------------------------------------------------------
# execute(): ExecutionPlan -> ExperimentResult
# ---------------------------------------------------------------------------
@dataclass
class BucketCompileStats:
    """Compile/dispatch accounting of one bucket of an executed plan.

    ``lower_s`` / ``compile_s`` are wall times of the AOT lowering and
    XLA compile (0 on the jit path and on in-process AOT cache hits);
    ``execute_s`` is the bucket's whole dispatch — array builds,
    executable resolution and the batched call(s).  ``cache`` is
    ``""`` (jit path), ``"memory"`` (in-process AOT cache already held
    the executable), ``"disk"`` (deserialised whole from the persistent
    executable cache — no trace, no XLA; ``compile_s`` is the load
    time) or ``"compiled"`` (lower+compile ran this execute).
    ``aval_match``
    records whether the plan-time shape prediction matched the concrete
    arguments (a mismatch falls back to a synchronous compile — correct
    but un-overlapped; pinned True by ``tests/test_aot.py``)."""
    bucket: int
    kind: str
    fused: bool
    aot: bool
    lower_s: float = 0.0
    compile_s: float = 0.0
    execute_s: float = 0.0
    cache: str = ""
    aval_match: Optional[bool] = None


@dataclass
class CompileReport:
    """Where one ``execute()`` spent its compile budget.

    ``traces`` is the ``campaign.TRACE_COUNT`` delta across the whole
    execute (0 on a warm process); ``xla`` is the
    :func:`repro.core.compilecache.xla_compile_stats` delta —
    ``xla['misses']`` counts ACTUAL XLA compiles, so a warm-disk re-run
    of a spec in a fresh process shows ``misses == 0`` with
    ``hits > 0``.  ``cache_dir`` is the persistent cache directory in
    use (None when disabled)."""
    aot: bool
    buckets: List[BucketCompileStats]
    traces: int = 0
    xla: Dict[str, int] = field(default_factory=dict)
    cache_dir: Optional[str] = None

    @property
    def lower_s(self) -> float:
        return sum(b.lower_s for b in self.buckets)

    @property
    def compile_s(self) -> float:
        return sum(b.compile_s for b in self.buckets)

    @property
    def execute_s(self) -> float:
        return sum(b.execute_s for b in self.buckets)

    def describe(self) -> str:
        lines = [f"CompileReport: aot={self.aot} traces={self.traces} "
                 f"xla={self.xla or '{}'} cache_dir={self.cache_dir}"]
        for b in self.buckets:
            lines.append(
                f"  bucket {b.bucket} ({b.kind}"
                f"{' fused' if b.fused else ''}): "
                f"lower={b.lower_s:.3f}s compile={b.compile_s:.3f}s "
                f"execute={b.execute_s:.3f}s"
                + (f" cache={b.cache}" if b.cache else "")
                + (f" aval_match={b.aval_match}"
                   if b.aval_match is not None else ""))
        return "\n".join(lines)


def _bucket_exe_args(data: DataSpec, bucket: BucketPlan) -> tuple:
    """``(kind, model, cfg, k_pad, ndev, track_iso, fused)`` — the
    executable-cache key parts the bucket's ``_exec_*`` helper will
    resolve (fused multi buckets compile at the PADDED model count)."""
    if bucket.kind == "multi":
        cfg = (dataclasses.replace(bucket.key_cfg,
                                   num_models=bucket.m_pad)
               if bucket.fused else bucket.key_cfg)
        return ("multi", data.model, cfg, None, bucket.devices, False,
                bucket.fused)
    return ("single", data.model, bucket.key_cfg, bucket.k_pad,
            bucket.devices, bucket.track_iso, bucket.fused)


def _bucket_avals(data: DataSpec, bucket: BucketPlan,
                  cells: Sequence[CellPlan]) -> tuple:
    """Predicted abstract arguments of one bucket's batched call — the
    exact ``ShapeDtypeStruct`` tuple ``_run_batched`` will derive from
    the concrete arrays, computed from the plan alone so AOT compiles
    can start BEFORE the host builds data/trace arrays.  Mirrors the
    ``_exec_*`` arg builders: ``_prepare_arrays`` shapes (batch cells
    centralise onto one device), per-chunk mapped operands at
    ``bucket.chunk``, trace leaves from one normalised representative
    trace (``stack_traces``/``concat_traces`` assert the batch is
    uniform, so cell 0's first trace is authoritative)."""
    sds = jax.ShapeDtypeStruct
    canon = jax.dtypes.canonicalize_dtype
    f32, i32 = canon(np.float32), canon(np.int32)
    c0 = cells[0]
    cfg0 = c0.cfg
    chunk = bucket.chunk
    dxa = np.asarray(data.device_x)
    if bucket.kind == "single" and cfg0.scheme == "batch":
        n_dev, n_max = 1, int(np.sum(np.asarray(data.device_counts)))
    else:
        n_dev, n_max = int(dxa.shape[0]), int(dxa.shape[1])
    txa = np.asarray(data.test_x)
    bcast = (sds((n_dev, n_max, int(dxa.shape[2])), canon(dxa.dtype)),
             sds((n_dev,), f32),
             sds((n_dev, n_max), f32),
             sds(tuple(txa.shape), canon(txa.dtype)))

    if bucket.kind == "multi":
        t0 = as_multimodel_trace(c0.traces[0], cfg0.num_devices)
    else:
        t0 = as_trace(c0.traces[0], cfg0.topology())
    traces = jax.tree.map(
        lambda x: sds((chunk,) + tuple(x.shape), canon(x.dtype)), t0)
    seeds = sds((chunk,), i32)

    if bucket.kind == "multi":
        if bucket.fused:
            return bcast + (sds((chunk, bucket.m_pad), f32), traces,
                            seeds)
        return bcast + (sds((cfg0.num_models,), f32), traces, seeds)
    if bucket.fused:
        kp = bucket.k_pad
        return bcast + (sds((chunk, n_dev), i32), sds((chunk, kp), i32),
                        sds((chunk, kp), f32), traces, seeds)
    if bucket.k_pad is not None:
        kp = bucket.k_pad
        bcast = bcast + (sds((n_dev,), i32), sds((kp,), i32),
                        sds((kp,), f32))
    return bcast + (traces, seeds)


@dataclass
class ExperimentResult:
    """Per-cell campaign results of one executed plan, in cell order.

    ``results[i]`` is the :class:`CampaignResult` /
    :class:`MultiCampaignResult` of ``plan.cells[i]`` — every scenario
    keyed by (cell, trace index, seed).  ``compile_report`` accounts
    for where the execute spent its compile budget
    (:class:`CompileReport`)."""
    plan: ExecutionPlan
    results: List[Union[CampaignResult, MultiCampaignResult]]
    compile_report: Optional[CompileReport] = None

    @property
    def num_scenarios(self) -> int:
        return sum(r.num_scenarios for r in self.results)

    def per_cell(self) -> Dict[Any, Union[CampaignResult,
                                          MultiCampaignResult]]:
        """{cell key: result} — keys from :meth:`CellSpec.key`."""
        return {c.key: r for c, r in zip(self.plan.cells, self.results)}

    def __getitem__(self, key):
        return self.per_cell()[key]

    def summary(self) -> Dict[Any, Dict[str, float]]:
        """{cell key: that cell's summary dict} (see the result types).

        Cells planned from ``TraceSpec.processes`` additionally carry
        per-process-family keys ``E[auroc] <family>[<grid idx>]`` (the
        Monte-Carlo mean over that grid's draws x seeds); process-free
        cells are untouched."""
        out = {}
        for c, r in zip(self.plan.cells, self.results):
            s = dict(r.summary())
            if c.process_draws:
                procs = self.plan.spec.traces.processes
                for gi, aurocs in self._cell_process(c, r).items():
                    fam = procs[gi].process.family
                    s[f"E[auroc] {fam}[{gi}]"] = float(np.mean(aurocs))
            out[c.key] = s
        return out

    @staticmethod
    def _cell_process(c: CellPlan, r) -> Dict[int, np.ndarray]:
        """{grid index: per-(draw x seed) AUROCs} of one cell."""
        sel = (r.select if isinstance(r, CampaignResult)
               else (lambda i: r.select(i, "best")))
        return {gi: np.concatenate([np.asarray(sel(i)) for i in idxs])
                for gi, idxs in c.process_draws.items()}

    def per_process(self) -> Dict[Any, Dict[int, np.ndarray]]:
        """{cell key: {process-grid index: AUROC per draw x seed}} —
        the per-process-family axis of the result.  Duplicated draws
        repeat their deduplicated trace's values, so means equal the
        undeduplicated Monte-Carlo estimate (same contract as
        ``CellPlan.draws``).  Multi-model cells report their "best"
        (starred) AUROC."""
        return {c.key: self._cell_process(c, r)
                for c, r in zip(self.plan.cells, self.results)
                if c.process_draws}

    def process_summary(self) -> Dict[Any, Dict[str, float]]:
        """{cell key: {"<family>[<grid idx>]": E[AUROC]}} — the
        flattened per-family expected-performance table."""
        procs = self.plan.spec.traces.processes
        return {key: {f"{procs[gi].process.family}[{gi}]":
                      float(np.mean(aurocs))
                      for gi, aurocs in cell.items()}
                for key, cell in self.per_process().items()}

    def to_rows(self) -> List[Dict[str, Any]]:
        """One tidy dict per scenario — the benches' CSV fodder."""
        rows: List[Dict[str, Any]] = []
        name = self.plan.spec.data.name
        for c, r in zip(self.plan.cells, self.results):
            for b in builtins_range(r.num_scenarios):
                row: Dict[str, Any] = {
                    "dataset": name, "cell": c.key,
                    "scheme": r.cfg.scheme,
                    "trace": int(r.trace_index[b]),
                    "seed": int(r.seed[b]),
                }
                if isinstance(r, CampaignResult):
                    row.update(k=r.cfg.num_clusters,
                               auroc=float(r.auroc_used[b]),
                               final_auroc=float(r.final_auroc[b]),
                               iso_active=bool(r.iso_active[b]),
                               rounds_to_loss=float(r.rounds_to_loss[b]))
                else:
                    row.update(k=r.cfg.num_models,
                               auroc=float(r.best_auroc[b]),
                               multi_auroc=float(r.multi_auroc[b]))
                rows.append(row)
        return rows


def _exec_single_cell(data: DataSpec, cfg: SimConfig,
                      traces: Sequence[Failure], seeds: Sequence[int],
                      target_loss, exec_plan, pad_k: Optional[int],
                      aot_resolve=None) -> CampaignResult:
    """One single-model cell, unfused (the legacy ``run_campaign``
    body): topology closed over statically (``pad_k=None``) or entering
    as broadcast padded arrays (``pad_k=int``)."""
    topo = cfg.topology()
    norm = [as_trace(t, topo) for t in traces]
    trace_idx, seed_arr = _c._scenario_grid(len(norm), seeds)
    if len(trace_idx) == 0:
        raise ValueError("empty campaign: need >=1 trace and >=1 seed")
    stacked = stack_traces(norm)
    batch_traces = jax.tree.map(lambda x: x[trace_idx], stacked)

    dx, counts, valid = _prepare_arrays(cfg, data.device_x,
                                        data.device_counts)
    tx = jnp.asarray(data.test_x)
    assert dx.shape[0] == topo.num_devices, (dx.shape, topo.num_devices)

    track_iso = (cfg.scheme == "fl")
    if pad_k is None:
        key_cfg = dataclasses.replace(cfg, seed=0)
        bcast = (dx, counts, valid, tx)
    else:
        # scheme / num_clusters are normalised OUT of the cache key: the
        # padded core reads the topology from the arrays, so every
        # single-model sweep cell of the same track_iso kind resolves to
        # the same executable
        key_cfg = dataclasses.replace(cfg, seed=0, scheme="tolfl",
                                      num_clusters=1)
        bcast = (dx, counts, valid, tx) + _c._padded_topology_arrays(
            topo, pad_k)
    ndev = exec_plan.resolved_devices(warn=False) if exec_plan else None
    batched = _c._executable("single", data.model, key_cfg, pad_k, ndev,
                             track_iso)
    out = _c._run_batched(batched, bcast,
                          (batch_traces, jnp.asarray(seed_arr)),
                          exec_plan, aot_resolve=aot_resolve)
    return _c._post_process(cfg, out, trace_idx, seed_arr, data.test_y,
                            target_loss)


def _exec_multi_cell(data: DataSpec, cfg: MultiModelConfig,
                     traces: Sequence[Failure], seeds: Sequence[int],
                     exec_plan, aot_resolve=None) -> MultiCampaignResult:
    """One multi-model cell, unfused (the legacy
    ``run_multimodel_campaign`` body)."""
    norm = [as_multimodel_trace(t, cfg.num_devices) for t in traces]
    trace_idx, seed_arr = _c._scenario_grid(len(norm), seeds)
    if len(trace_idx) == 0:
        raise ValueError("empty campaign: need >=1 trace and >=1 seed")
    stacked = stack_traces(norm)
    batch_traces = jax.tree.map(lambda x: x[trace_idx], stacked)

    dx, counts, valid = prepare_multimodel_arrays(data.device_x,
                                                  data.device_counts)
    tx = jnp.asarray(data.test_x)
    assert dx.shape[0] == cfg.num_devices, (dx.shape, cfg.num_devices)
    key_cfg = dataclasses.replace(cfg, seed=0)
    ndev = exec_plan.resolved_devices(warn=False) if exec_plan else None
    batched = _c._executable("multi", data.model, key_cfg, None, ndev)
    model_valid = jnp.ones((cfg.num_models,), jnp.float32)
    out = _c._run_batched(batched, (dx, counts, valid, tx, model_valid),
                          (batch_traces, jnp.asarray(seed_arr)),
                          exec_plan, aot_resolve=aot_resolve)

    best, multi = _c._multi_metrics(np.asarray(out.final_scores),
                                    data.test_y)
    return MultiCampaignResult(cfg=cfg, trace_index=trace_idx,
                               seed=seed_arr, best_auroc=best,
                               multi_auroc=multi,
                               loss_curves=np.asarray(out.losses),
                               assignments=np.asarray(out.assignments))


def _stacked_scenarios(cells, seeds, trace_cache, trace_key_fn, norm_fn):
    """Per-cell (stacked traces, trace_idx, seed_arr, b) with the
    stacked batch shared between cells that pass the same trace list
    (one stacking per distinct resolution, not per cell)."""
    metas = []
    for cfg, traces in cells:
        ck = trace_key_fn(cfg, traces)
        if ck not in trace_cache:
            norm = norm_fn(cfg, traces)
            trace_idx, seed_arr = _c._scenario_grid(len(norm), seeds)
            if len(trace_idx) == 0:
                raise ValueError("empty campaign: need >=1 trace and "
                                 ">=1 seed")
            stacked = stack_traces(norm)
            trace_cache[ck] = (
                jax.tree.map(lambda x: x[trace_idx], stacked),
                trace_idx, seed_arr)
        batch_traces, trace_idx, seed_arr = trace_cache[ck]
        metas.append((cfg, batch_traces, trace_idx, seed_arr,
                      len(seed_arr)))
    return metas


def _exec_fused_single_group(data: DataSpec, cells, seeds, target_loss,
                             exec_plan, kp: int, key_cfg,
                             track_iso: bool, trace_cache,
                             aot_resolve=None) -> List[CampaignResult]:
    """One fused single-model bucket (the legacy ``run_fused_campaigns``
    group body): every cell's padded cluster arrays stacked as VMAPPED
    operands along the flattened (cell x trace x seed) axis — ONE
    dispatch for the whole bucket."""
    dx, counts, valid = _prepare_arrays(cells[0][0], data.device_x,
                                        data.device_counts)
    tx = jnp.asarray(data.test_x)
    ndev = exec_plan.resolved_devices(warn=False) if exec_plan else None

    def trace_key(cfg, traces):
        return (tuple(id(t) for t in traces),
                _c._single_trace_key(traces, cfg.topology()))

    def norm(cfg, traces):
        return [as_trace(t, cfg.topology()) for t in traces]

    metas = _stacked_scenarios(cells, seeds, trace_cache, trace_key, norm)
    cids_l, heads_l, hv_l, tr_l, seeds_l = [], [], [], [], []
    for cfg, batch_traces, trace_idx, seed_arr, b in metas:
        topo = cfg.topology()
        assert dx.shape[0] == topo.num_devices, (dx.shape,
                                                 topo.num_devices)
        cids, heads, hvalid = _c._padded_topology_arrays(topo, kp)
        cids_l.append(jnp.broadcast_to(cids, (b,) + cids.shape))
        heads_l.append(jnp.broadcast_to(heads, (b,) + heads.shape))
        hv_l.append(jnp.broadcast_to(hvalid, (b,) + hvalid.shape))
        tr_l.append(batch_traces)
        seeds_l.append(seed_arr)

    mapped = (jnp.concatenate(cids_l), jnp.concatenate(heads_l),
              jnp.concatenate(hv_l), concat_traces(tr_l),
              jnp.asarray(np.concatenate(seeds_l)))
    batched = _c._executable("single", data.model, key_cfg, kp, ndev,
                             track_iso, fused=True)
    out = _c._run_batched(batched, (dx, counts, valid, tx), mapped,
                          exec_plan, aot_resolve=aot_resolve)
    fields = _c._post_process_arrays(track_iso, out, data.test_y,
                                     target_loss)
    results, off = [], 0
    for cfg, _, trace_idx, seed_arr, b in metas:
        cell_fields = {name: arr[off:off + b]
                       for name, arr in fields.items()}
        results.append(CampaignResult(cfg=cfg, trace_index=trace_idx,
                                      seed=seed_arr, **cell_fields))
        off += b
    return results


def _exec_fused_multi_group(data: DataSpec, cells, seeds, exec_plan,
                            mp: int, key_cfg, trace_cache,
                            aot_resolve=None) -> List[MultiCampaignResult]:
    """One fused multi-model bucket (the legacy
    ``run_fused_multimodel_campaigns`` group body): cells with
    DIFFERENT model counts share one executable via the padded-M
    ``model_valid`` mask."""
    dx, counts, valid = prepare_multimodel_arrays(data.device_x,
                                                  data.device_counts)
    tx = jnp.asarray(data.test_x)
    ndev = exec_plan.resolved_devices(warn=False) if exec_plan else None

    def trace_key(cfg, traces):
        return (tuple(id(t) for t in traces), cfg.num_devices)

    def norm(cfg, traces):
        return [as_multimodel_trace(t, cfg.num_devices) for t in traces]

    metas = _stacked_scenarios(cells, seeds, trace_cache, trace_key, norm)
    mv_l, tr_l, seeds_l = [], [], []
    for cfg, batch_traces, trace_idx, seed_arr, b in metas:
        assert dx.shape[0] == cfg.num_devices, (dx.shape,
                                                cfg.num_devices)
        assert mp >= cfg.num_models, (mp, cfg.num_models)
        mv = np.zeros((mp,), np.float32)
        mv[:cfg.num_models] = 1.0
        mv_l.append(jnp.broadcast_to(jnp.asarray(mv), (b, mp)))
        tr_l.append(batch_traces)
        seeds_l.append(seed_arr)

    mapped = (jnp.concatenate(mv_l), concat_traces(tr_l),
              jnp.asarray(np.concatenate(seeds_l)))
    exe_cfg = dataclasses.replace(key_cfg, num_models=mp)
    batched = _c._executable("multi", data.model, exe_cfg, None, ndev,
                             fused=True)
    out = _c._run_batched(batched, (dx, counts, valid, tx), mapped,
                          exec_plan, aot_resolve=aot_resolve)
    model_valid = np.asarray(mapped[0])
    best, multi = _c._multi_metrics(np.asarray(out.final_scores),
                                    data.test_y, model_valid)
    losses = np.asarray(out.losses)
    assigns = np.asarray(out.assignments)
    results, off = [], 0
    for cfg, _, trace_idx, seed_arr, b in metas:
        sl = slice(off, off + b)
        results.append(MultiCampaignResult(
            cfg=cfg, trace_index=trace_idx, seed=seed_arr,
            best_auroc=best[sl], multi_auroc=multi[sl],
            loss_curves=losses[sl], assignments=assigns[sl]))
        off += b
    return results


def _make_resolver(stats: BucketCompileStats, key_args: tuple, future):
    """Per-bucket AOT executable resolver ``_run_batched`` calls with
    the concrete abstract-argument tuple.  Waits for the speculative
    plan-time compile (``future``), then resolves through the AOT cache:
    a hit means the prediction matched (the usual case — the compile
    overlapped the host-side array builds); a miss means the prediction
    drifted from the real shapes and we compile synchronously — slower
    but still bit-identical."""
    def resolve(avals):
        spec_times = None
        if future is not None:
            _, spec_times = future.result()
        compiled, times = _c.aot_executable(*key_args, avals)
        stats.aval_match = bool(times.cached and spec_times is not None)
        if times.cached and spec_times is not None:
            # the speculative compile did the work; report ITS times
            # and source (compiled / disk) rather than the memory hit
            times = spec_times
        stats.lower_s, stats.compile_s = times.lower_s, times.compile_s
        stats.cache = times.source
        return compiled
    return resolve


def execute(plan_: ExecutionPlan) -> ExperimentResult:
    """Run every bucket of a lowered plan; results align with
    ``plan_.cells`` (and with the spec's cell order).

    Wires the persistent XLA disk cache
    (:func:`repro.core.compilecache.ensure_persistent_cache`) and, when
    the spec's :class:`ExecPlan` sets ``aot=True``, launches every
    bucket's lower+compile on a thread pool BEFORE dispatching —
    ``plan()`` already knows each bucket's shapes
    (:func:`_bucket_avals`), so XLA overlaps the host-side data/trace
    array builds and the buckets dispatch through compiled executables.
    Either path attaches a :class:`CompileReport`."""
    spec = plan_.spec
    data, seeds = spec.data, spec.seeds.seeds
    exec_plan, target_loss = spec.exec_plan, spec.target_loss
    compilecache.ensure_persistent_cache()
    # exactly ONE shard-degradation warning per execute(), whatever the
    # entry point: plan() and every helper pass warn=False
    if exec_plan is not None:
        exec_plan.resolved_devices(warn=True)

    use_aot = bool(exec_plan is not None and exec_plan.aot)
    stats = [BucketCompileStats(bucket=b.index, kind=b.kind,
                                fused=b.fused, aot=use_aot)
             for b in plan_.buckets]
    traces0 = _c.TRACE_COUNT
    xla0 = compilecache.xla_compile_stats()

    pool = futures = None
    if use_aot:
        pool = ThreadPoolExecutor(
            max_workers=min(4, len(plan_.buckets)),
            thread_name_prefix="aot-compile")
        futures = {
            b.index: pool.submit(
                _c.aot_executable, *_bucket_exe_args(data, b),
                _bucket_avals(data, b,
                              [plan_.cells[i] for i in b.cell_indices]))
            for b in plan_.buckets}

    results: List[Optional[Any]] = [None] * len(plan_.cells)
    trace_cache: dict = {}   # one stacked batch per distinct resolution
    try:
        for bucket in plan_.buckets:
            cells = [plan_.cells[i] for i in bucket.cell_indices]
            pairs = [(c.cfg, c.traces) for c in cells]
            resolve = (_make_resolver(stats[bucket.index],
                                      _bucket_exe_args(data, bucket),
                                      futures[bucket.index])
                       if use_aot else None)
            t0 = time.perf_counter()
            if bucket.kind == "single" and bucket.fused:
                rs = _exec_fused_single_group(
                    data, pairs, seeds, target_loss, exec_plan,
                    bucket.k_pad, bucket.key_cfg, bucket.track_iso,
                    trace_cache, aot_resolve=resolve)
            elif bucket.kind == "multi" and bucket.fused:
                rs = _exec_fused_multi_group(
                    data, pairs, seeds, exec_plan, bucket.m_pad,
                    bucket.key_cfg, trace_cache, aot_resolve=resolve)
            elif bucket.kind == "single":
                rs = [_exec_single_cell(data, cells[0].cfg,
                                        cells[0].traces, seeds,
                                        target_loss, exec_plan,
                                        bucket.k_pad,
                                        aot_resolve=resolve)]
            else:
                rs = [_exec_multi_cell(data, cells[0].cfg,
                                       cells[0].traces, seeds, exec_plan,
                                       aot_resolve=resolve)]
            stats[bucket.index].execute_s = time.perf_counter() - t0
            for c, r in zip(cells, rs):
                results[c.index] = r
    finally:
        if pool is not None:
            pool.shutdown(wait=True)

    xla1 = compilecache.xla_compile_stats()
    report = CompileReport(
        aot=use_aot, buckets=stats, traces=_c.TRACE_COUNT - traces0,
        xla={k: xla1[k] - xla0[k] for k in xla1},
        cache_dir=compilecache.persistent_cache_dir())
    return ExperimentResult(plan=plan_, results=results,
                            compile_report=report)


def run_experiment(spec: ExperimentSpec) -> ExperimentResult:
    """``execute(plan(spec))`` — the one-call entry point."""
    return execute(plan(spec))
