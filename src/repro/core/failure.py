"""Failure model (paper Section II / IV-B) and timed failure traces.

Any networked device may become unreachable at any stage; failures are
client (cluster member) or server (cluster head / FL server).  The model
is *in-graph*: an ``alive`` mask enters the jitted step and per-device
effective weights are derived from it, so one compiled executable covers
every failure scenario — which is exactly the property the paper wants
(training persists without reconfiguration).

Two encodings exist:

* :class:`FailureSpec` — the legacy single-event form (kept for
  back-compat; every consumer that accepted a spec still does).
* :class:`FailureTrace` — a fixed-shape array encoding of up to ``M``
  timed failure/recovery events.  Because every field is a same-shape
  array, traces are a registered pytree: they can be stacked on a
  leading axis and ``vmap``-ed, which is what lets
  :mod:`repro.core.campaign` sweep whole grids of scenarios through ONE
  compiled executable.

Semantics (paper IV-B):
* dead member  -> its samples leave the weighted mean; cluster continues.
* dead head    -> the entire cluster leaves training (worst case).
* FL (k=1) head death == server death -> no aggregation is possible; the
  engine falls back to isolated local training (paper Section V-C).
* recovery (churn) -> a later event may bring a device back; the most
  recent event targeting a device wins.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology

#: default number of event slots in a trace (fixed shape => one compile)
MAX_EVENTS = 8
#: sentinel epoch for unused event slots — never fires
PAD_EPOCH = 1 << 30
#: event-kind codes carried in the trace arrays (baselines interpret
#: "server" events as aggregator death because they have no head devices).
#: "faulty" events (FedFm-style corrupted updates) live on a SHADOW
#: device range [N, 2N) with the delta scale in the alive_after channel:
#: alive masks compare devices == arange(N) and never match them, so
#: they are inert except under the faulty-aware engine variants, which
#: read them back via :func:`trace_faulty_scale`.
KIND_CODES = {"none": 0, "client": 1, "server": 2, "faulty": 3}


@dataclass(frozen=True)
class FailureSpec:
    """A single failure event injected during training (legacy form)."""
    epoch: int                 # fires at the START of this epoch/round
    kind: str                  # "client" | "server" | "none"
    device: Optional[int] = None   # explicit device id; defaults per kind

    def target(self, topo: Topology) -> int:
        if self.device is not None:
            return self.device
        if self.kind == "server":
            return topo.heads[0]          # a cluster head (the FL server)
        # a non-head member: last member of cluster 0 (or device 0 if all
        # devices are heads, i.e. SBT)
        c0 = topo.clusters[0]
        return c0[-1] if len(c0) > 1 else c0[0]


NO_FAILURE = FailureSpec(epoch=PAD_EPOCH, kind="none")


@dataclass(frozen=True)
class FailureEvent:
    """One timed event of a :class:`FailureTrace`."""
    epoch: int
    kind: str                      # "client" | "server"
    device: Optional[int] = None   # explicit device id; defaults per kind
    recover: bool = False          # True -> the device comes back

    def target(self, topo: Topology) -> int:
        return FailureSpec(self.epoch, self.kind, self.device).target(topo)


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class FailureTrace:
    """Up to M timed events as fixed-shape arrays (a vmappable pytree).

    Events are stored sorted by epoch (stable); unused slots carry
    ``PAD_EPOCH`` / device -1 and never match.  ``alive_after[j]`` is the
    device's state once event j fires (0 = dead, 1 = recovered)."""
    epochs: jax.Array       # (M,) int32
    devices: jax.Array      # (M,) int32, -1 in padding slots
    alive_after: jax.Array  # (M,) float32 in {0, 1}
    kinds: jax.Array        # (M,) int32 KIND_CODES

    @property
    def max_events(self) -> int:
        return self.epochs.shape[-1]

    @staticmethod
    def none(max_events: int = MAX_EVENTS) -> "FailureTrace":
        return FailureTrace(
            epochs=jnp.full((max_events,), PAD_EPOCH, jnp.int32),
            devices=jnp.full((max_events,), -1, jnp.int32),
            alive_after=jnp.ones((max_events,), jnp.float32),
            kinds=jnp.zeros((max_events,), jnp.int32))

    @classmethod
    def from_events(cls, events: Sequence[FailureEvent], topo: Topology,
                    max_events: int = MAX_EVENTS) -> "FailureTrace":
        """Build a trace; events are stably sorted by epoch, so events
        that target the same device AT THE SAME epoch apply in their
        list order — the LAST-listed one wins.  That tie-break is part
        of the contract (tests pin it); generated trace grids should
        avoid same-epoch duplicates unless they mean it."""
        events = [e for e in events if e.kind != "none"]
        assert len(events) <= max_events, (len(events), max_events)
        events = sorted(events, key=lambda e: e.epoch)   # stable
        ep = np.full((max_events,), PAD_EPOCH, np.int32)
        dev = np.full((max_events,), -1, np.int32)
        alv = np.ones((max_events,), np.float32)
        knd = np.zeros((max_events,), np.int32)
        for j, e in enumerate(events):
            ep[j] = e.epoch
            dev[j] = e.target(topo)
            alv[j] = 1.0 if e.recover else 0.0
            knd[j] = KIND_CODES[e.kind]
        return cls(jnp.asarray(ep), jnp.asarray(dev), jnp.asarray(alv),
                   jnp.asarray(knd))

    @classmethod
    def from_spec(cls, spec: FailureSpec, topo: Topology,
                  max_events: int = MAX_EVENTS) -> "FailureTrace":
        if spec.kind == "none":
            return cls.none(max_events)
        ev = FailureEvent(spec.epoch, spec.kind, spec.device)
        return cls.from_events([ev], topo, max_events)


Failure = Union[FailureSpec, FailureTrace]


def as_trace(failure: Failure, topo: Topology,
             max_events: int = MAX_EVENTS) -> FailureTrace:
    """Normalise either failure encoding to a trace."""
    if isinstance(failure, FailureTrace):
        return failure
    return FailureTrace.from_spec(failure, topo, max_events)


def stack_traces(traces: Sequence[FailureTrace]) -> FailureTrace:
    """Stack same-shape traces on a leading axis for ``vmap``."""
    if not traces:
        raise ValueError("stack_traces: empty trace list — a batch "
                         "needs at least one trace")
    ms = {t.max_events for t in traces}
    assert len(ms) == 1, f"mixed max_events: {ms}"
    return jax.tree.map(lambda *xs: jnp.stack(xs), *traces)


def concat_traces(batches: Sequence[FailureTrace]) -> FailureTrace:
    """Concatenate already-stacked trace batches along their leading
    scenario axis — the fused (cell x trace x seed) sweep flattens the
    per-cell batches of a grid into one with this (the batches must
    share ``max_events``; pass every trace through the same slot budget
    before stacking)."""
    if not batches:
        raise ValueError("concat_traces: empty batch list — nothing to "
                         "concatenate")
    ms = {t.max_events for t in batches}
    assert len(ms) == 1, f"mixed max_events: {ms}"
    if len(batches) == 1:
        return batches[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *batches)


def sample_traces(rng: np.random.Generator, topo: Topology,
                  failure_rate: float, max_events: int = MAX_EVENTS,
                  rounds: int = 100, num_traces: int = 1,
                  recover_prob: float = 0.5) -> list:
    """Random multi-event failure-and-recovery traces (Section IV-B).

    Monte-Carlo scenario generator for expected-performance sweeps:
    instead of hand-listing events, each of ``num_traces`` traces draws
    a scenario where every device independently fails with probability
    ``failure_rate`` during the run, at a uniform random epoch in
    ``[0, rounds)``.  A failed device is a *server* event when it is a
    cluster head of ``topo`` (head death takes its whole cluster, and
    for k=1 the FL server itself), else a *client* event.  With
    probability ``recover_prob`` a failed device comes back at a later
    uniform epoch (churn).  Events beyond ``max_events`` slots are
    dropped (device order randomised first, so the truncation is not
    biased toward low device ids).  Truncation near the slot budget
    degrades gracefully: a failure whose recovery no longer fits keeps
    the failure and drops only the recovery (a device is skipped
    entirely only when NO slot remains), so no trace ends on a dangling
    recovery and failures are never under-counted while slots are free
    — either every failed device appears in the trace, or all
    ``max_events`` slots are used (a pinned invariant).

    Returns a list of :class:`FailureTrace` (stackable via
    :func:`stack_traces` for one batched campaign).
    """
    assert 0.0 <= failure_rate <= 1.0, failure_rate
    assert rounds >= 1 and max_events >= 1
    head_set = set(topo.heads)
    traces = []
    for _ in range(num_traces):
        failed = np.flatnonzero(
            rng.random(topo.num_devices) < failure_rate)
        rng.shuffle(failed)
        events: list = []
        for d in failed:
            kind = "server" if int(d) in head_set else "client"
            epoch = int(rng.integers(rounds))
            recovers = (rng.random() < recover_prob) and epoch + 1 < rounds
            free = max_events - len(events)
            if free <= 0:
                continue
            if recovers and free < 2:
                recovers = False   # degrade: keep the failure, drop the
                #                    recovery — skipping both would
                #                    under-count failures near the budget
            events.append(FailureEvent(epoch, kind, device=int(d)))
            if recovers:
                rec = int(rng.integers(epoch + 1, rounds))
                events.append(FailureEvent(rec, kind, device=int(d),
                                           recover=True))
        traces.append(FailureTrace.from_events(events, topo, max_events))
    return traces


def _trace_key(t: FailureTrace) -> tuple:
    return tuple(np.asarray(leaf).tobytes()
                 for leaf in (t.epochs, t.devices, t.alive_after, t.kinds))


def sample_rate_grid(rng: np.random.Generator, topo: Topology,
                     p_grid: Sequence[float], rounds: int,
                     traces_per_p: int, max_events: Optional[int] = None,
                     recover_prob: float = 0.5,
                     base_traces: Sequence[FailureTrace] = ()):
    """Sampled traces for a failure-rate sweep, DEDUPLICATED.

    Draws ``traces_per_p`` scenarios per rate via :func:`sample_traces`
    and collapses byte-identical traces (e.g. the many all-none draws at
    low p) to a single trained scenario.  ``max_events`` defaults to
    ``2 * topo.num_devices`` — enough slots for every device to fail AND
    recover, so high-p draws are never silently truncated (the default
    ``MAX_EVENTS`` drops events for p near 1, biasing E[AUROC]
    optimistic exactly at the crossover end of the curve).

    ``base_traces`` (already at ``max_events``, e.g. canonical
    conditions the caller also wants in the batch) are prepended to the
    pool and join the dedup, so an all-none draw aliases a no-failure
    base trace instead of retraining it.  (This is the sampler behind a
    sampled :class:`repro.core.experiment.TraceSpec`: ``plan(spec)``
    calls it once per cell against the cell's own topology.)  Returns ``(traces, draws)``
    with the base traces first; ``draws[p]`` lists one trace index per
    original draw — a duplicated draw repeats its index, so per-p means
    over ``result.select(i) for i in draws[p]`` equal the
    undeduplicated Monte-Carlo estimate while each distinct trace
    trains once."""
    if max_events is None:
        max_events = 2 * topo.num_devices
    traces: list = []
    draws: dict = {}
    idx_of: dict = {}
    for t in base_traces:
        assert t.max_events == max_events, (t.max_events, max_events)
        idx_of.setdefault(_trace_key(t), len(traces))
        traces.append(t)
    for p in p_grid:
        idxs = []
        for t in sample_traces(rng, topo, p, max_events=max_events,
                               rounds=rounds, num_traces=traces_per_p,
                               recover_prob=recover_prob):
            key = _trace_key(t)
            if key not in idx_of:
                idx_of[key] = len(traces)
                traces.append(t)
            idxs.append(idx_of[key])
        draws[p] = idxs
    return traces, draws


def trace_alive_mask(trace: FailureTrace, num_devices: int, epoch: jax.Array
                     ) -> jax.Array:
    """(num_devices,) float alive mask at ``epoch`` (traced).

    Events are epoch-sorted (stably), so each device's state is the
    ``alive_after`` of the HIGHEST-indexed fired slot targeting it —
    found with one reversed argmax over the slot axis.  The graph is a
    fixed handful of ops regardless of ``max_events`` — a named budget
    (``"trace_alive_mask"`` in ``repro.analysis.plancheck.budgets``)
    pinned by ``tests/test_failure_trace.py``; the previous per-slot
    Python fold emitted O(M) ``where``s, which blew up compile time on
    sampled grids where M = 2 * num_devices."""
    fired = ((epoch >= trace.epochs)[:, None]              # (M, N)
             & (trace.devices[:, None]
                == jnp.arange(num_devices)[None, :]))
    any_fired = jnp.any(fired, axis=0)                     # (N,)
    # argmax on the reversed slot axis -> index of the LAST fired slot
    # (ties between same-epoch slots keep the list-order contract)
    last = (trace.max_events - 1) - jnp.argmax(fired[::-1], axis=0)
    return jnp.where(any_fired, trace.alive_after[last],
                     jnp.ones((num_devices,), jnp.float32))


def trace_faulty_scale(trace: FailureTrace, num_devices: int,
                       epoch: jax.Array) -> jax.Array:
    """(num_devices,) per-device delta scale at ``epoch`` (traced).

    The faulty-update channel: kind-3 events target shadow device ids
    ``N + d`` and carry the transmitted-delta scale in ``alive_after``
    (1.0 = clean).  Structurally :func:`trace_alive_mask` against the
    shadow range — same reversed-argmax last-event-wins, same O(1)
    graph size in ``max_events`` (named budget ``"trace_faulty_scale"``
    in ``plancheck.budgets``).  Traces without faulty events yield all
    ones, so the faulty-aware cores are no-ops on ordinary grids."""
    shadow = num_devices + jnp.arange(num_devices)
    fired = ((epoch >= trace.epochs)[:, None]              # (M, N)
             & (trace.devices[:, None] == shadow[None, :]))
    any_fired = jnp.any(fired, axis=0)                     # (N,)
    last = (trace.max_events - 1) - jnp.argmax(fired[::-1], axis=0)
    return jnp.where(any_fired, trace.alive_after[last],
                     jnp.ones((num_devices,), jnp.float32))


def _trace_alive_mask_unrolled(trace: FailureTrace, num_devices: int,
                               epoch: jax.Array) -> jax.Array:
    """Reference implementation: the per-slot fold :func:`trace_alive_mask`
    replaced.  Kept (test-only) to pin equality and the graph-size win."""
    active = (epoch >= trace.epochs)                       # (M,)
    hits = trace.devices[:, None] == jnp.arange(num_devices)[None, :]
    alive = jnp.ones((num_devices,), jnp.float32)
    for j in range(trace.max_events):
        fire = active[j] & hits[j]
        alive = jnp.where(fire, trace.alive_after[j], alive)
    return alive


def alive_mask(failure: Failure, topo: Topology, epoch: jax.Array
               ) -> jax.Array:
    """(N,) float mask of devices still alive at ``epoch`` (traced)."""
    n = topo.num_devices
    if isinstance(failure, FailureTrace):
        return trace_alive_mask(failure, n, epoch)
    if failure.kind == "none":
        return jnp.ones((n,), jnp.float32)
    tgt = failure.target(topo)
    dead = (jnp.arange(n) == tgt) & (epoch >= failure.epoch)
    return (~dead).astype(jnp.float32)


def effective_weights(alive: jax.Array, topo: Topology) -> jax.Array:
    """(N,) per-device weight given head-failure semantics.

    w_i = alive_i * alive_{head(cluster(i))}: a dead head zeroes its whole
    cluster; dead members zero only themselves."""
    cluster_ids = jnp.asarray(topo.device_cluster_array())
    heads = jnp.asarray(np.array(topo.heads))
    return effective_weights_arrays(alive, cluster_ids, heads)


def effective_weights_arrays(alive: jax.Array, cluster_ids: jax.Array,
                             heads: jax.Array) -> jax.Array:
    """:func:`effective_weights` with the topology as (possibly traced)
    arrays — ``heads`` may be padded past the real cluster count (the
    compile-amortised sweep path): padding slots are only reachable
    through ``cluster_ids``, which never names a padded cluster."""
    head_alive = alive[heads]                     # (k,) or (k_pad,)
    return alive * head_alive[cluster_ids]


def surviving_fraction(alive: np.ndarray, topo: Topology) -> float:
    w = effective_weights(jnp.asarray(alive), topo)
    return float(jnp.mean(w))
