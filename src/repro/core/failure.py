"""Failure model (paper Section II / IV-B).

Any one networked device may become unreachable at any stage; failures are
client (cluster member) or server (cluster head / FL server).  The model
is *in-graph*: an ``alive`` mask enters the jitted step and per-device
effective weights are derived from it, so one compiled executable covers
every failure scenario — which is exactly the property the paper wants
(training persists without reconfiguration).

Semantics (paper IV-B):
* dead member  -> its samples leave the weighted mean; cluster continues.
* dead head    -> the entire cluster leaves training (worst case).
* FL (k=1) head death == server death -> no aggregation is possible; the
  engine falls back to isolated local training (paper Section V-C).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology


@dataclass(frozen=True)
class FailureSpec:
    """A single failure event injected during training."""
    epoch: int                 # fires at the START of this epoch/round
    kind: str                  # "client" | "server" | "none"
    device: Optional[int] = None   # explicit device id; defaults per kind

    def target(self, topo: Topology) -> int:
        if self.device is not None:
            return self.device
        if self.kind == "server":
            return topo.heads[0]          # a cluster head (the FL server)
        # a non-head member: last member of cluster 0 (or device 0 if all
        # devices are heads, i.e. SBT)
        c0 = topo.clusters[0]
        return c0[-1] if len(c0) > 1 else c0[0]


NO_FAILURE = FailureSpec(epoch=1 << 30, kind="none")


def alive_mask(spec: FailureSpec, topo: Topology, epoch: jax.Array
               ) -> jax.Array:
    """(N,) float mask of devices still alive at ``epoch`` (traced)."""
    n = topo.num_devices
    if spec.kind == "none":
        return jnp.ones((n,), jnp.float32)
    tgt = spec.target(topo)
    dead = (jnp.arange(n) == tgt) & (epoch >= spec.epoch)
    return (~dead).astype(jnp.float32)


def effective_weights(alive: jax.Array, topo: Topology) -> jax.Array:
    """(N,) per-device weight given head-failure semantics.

    w_i = alive_i * alive_{head(cluster(i))}: a dead head zeroes its whole
    cluster; dead members zero only themselves."""
    cluster_ids = jnp.asarray(topo.device_cluster_array())
    heads = jnp.asarray(np.array(topo.heads))
    head_alive = alive[heads]                     # (k,)
    return alive * head_alive[cluster_ids]


def surviving_fraction(alive: np.ndarray, topo: Topology) -> float:
    w = effective_weights(jnp.asarray(alive), topo)
    return float(jnp.mean(w))
