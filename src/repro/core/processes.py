"""Generative failure processes: declarative fault injection.

A :class:`FailureProcess` is a frozen, hashable spec of a *stochastic
failure model* — not a scenario, a distribution over scenarios.  Each
process is a pure host-side sampler ``sample(rng, topo, n_rounds) ->
FailureTrace``: it lowers to the exact same fixed-shape
:class:`repro.core.failure.FailureTrace` arrays the jitted campaign
engine already sweeps, so declaring a process changes WHAT scenarios a
campaign draws, never what it compiles.  The families:

* :class:`IidRateProcess` — every device independently fails once at a
  uniform epoch (subsumes today's ``TraceSpec.p_grid``; same sampler).
* :class:`MarkovChurnProcess` — per-device two-state fail/recover
  chain, i.e. bursty outages with geometric up/down times ("Keep It
  Simple": unreliable clients, not just dead ones).
* :class:`ClusterCascadeProcess` — a head failure takes its members
  down with probability ``q`` and the cluster staggers back — the
  cascade from the paper's own motivation.
* :class:`StragglerProcess` — flaky clients miss a contiguous window
  of rounds via PAIRED failure+recovery events; they never die.
* :class:`FaultyUpdateProcess` — FedFm-style corrupted deltas: marked
  devices stay alive but transmit scaled/garbled updates for a window.

Faulty-update lowering: faulty events ride the SAME trace arrays on a
shadow device range ``[N, 2N)`` with kind code ``KIND_CODES["faulty"]``
and the delta scale in the ``alive_after`` float channel.  Alive masks
compare ``devices == arange(N)`` and therefore never match a shadow
row, so traces carrying faulty events are inert in every existing
core; only the faulty-aware engine variants
(:class:`repro.core.simulate.FaultySimConfig` /
:class:`repro.core.baselines.FaultyMultiModelConfig`) read the channel
back via :func:`repro.core.failure.trace_faulty_scale`.

Reproducibility: a process draw's numpy generator derives from
``(sample_seed, repr(process), draw index)`` via SHA-256
(:func:`process_seed`) — never Python's salted ``hash`` — so equal
specs lower to bit-identical trace grids in any process, pinned by
``tests/test_processes.py``.

Slot budgets: like :func:`repro.core.failure.sample_traces`, samplers
degrade gracefully near ``max_events`` — device/cluster order is
shuffled first and events pack group-wise so no trace ever ends on a
dangling recovery (:func:`_pack_groups`); stragglers pack all-or-
nothing per device because a lone failure would change their semantics
from "misses a window" to "dies".
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import ClassVar, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.failure import (KIND_CODES, PAD_EPOCH, FailureTrace,
                                _trace_key, sample_traces)
from repro.core.topology import Topology

#: (epoch, device, alive_after/scale, kind_code) — the raw row form the
#: samplers emit; shadow-device and scale-carrying rows have no
#: FailureEvent equivalent, hence this bypass of ``from_events``.
Row = Tuple[int, int, float, int]

#: canonical family names, the order benches/examples sweep them in
FAMILIES = ("iid", "markov", "cascade", "straggler", "faulty")


def trace_from_rows(rows: Sequence[Row], max_events: int) -> FailureTrace:
    """Pack raw event rows into a trace (stable epoch sort, PAD fill).

    The row form carries device ids and alive/scale values verbatim —
    unlike ``FailureTrace.from_events`` it can express shadow-device
    faulty rows and fractional scales.  Same tie-break contract:
    same-epoch rows apply in list order, the last-listed wins."""
    assert len(rows) <= max_events, (len(rows), max_events)
    rows = sorted(rows, key=lambda r: r[0])    # stable
    ep = np.full((max_events,), PAD_EPOCH, np.int32)
    dev = np.full((max_events,), -1, np.int32)
    alv = np.ones((max_events,), np.float32)
    knd = np.zeros((max_events,), np.int32)
    for j, (e, d, a, k) in enumerate(rows):
        ep[j], dev[j], alv[j], knd[j] = e, d, a, k
    return FailureTrace(jnp.asarray(ep), jnp.asarray(dev),
                        jnp.asarray(alv), jnp.asarray(knd))


def _pack_groups(groups: Sequence[Sequence[Row]], max_events: int,
                 pairs_only: bool = False) -> List[Row]:
    """Pack per-device/cluster event groups into a slot budget.

    Each group lists one device's (or one cascade's) events with every
    recovery AFTER its failure in list order, so any prefix is a valid
    history — truncating a group keeps the failure and drops only the
    recovery, exactly ``sample_traces``' degradation rule.  With
    ``pairs_only`` a group is kept whole or dropped whole (straggler
    semantics: a window-miss must never truncate into a death).  The
    caller shuffles the group order first so truncation is unbiased."""
    rows: List[Row] = []
    for g in groups:
        free = max_events - len(rows)
        if free <= 0:
            break
        if pairs_only:
            if len(g) <= free:
                rows.extend(g)
        else:
            rows.extend(list(g)[:free])
    return rows


@dataclass(frozen=True)
class FailureProcess:
    """Base spec: a pure host-side sampler of failure scenarios.

    Subclasses are frozen hashable dataclasses (their fields ARE the
    process identity — ``repr`` feeds :func:`process_seed`) and
    override :meth:`sample`.  ``needs_faulty_engine`` marks families
    whose traces only take effect under the faulty-aware engine
    variants; ``plan()`` swaps configs accordingly."""
    family: ClassVar[str] = "process"
    needs_faulty_engine: ClassVar[bool] = False

    def default_max_events(self, topo: Topology) -> int:
        """Slot budget when ``TraceSpec.max_events`` is unset — enough
        for every device to fail and recover once (matches
        ``sample_rate_grid``'s default)."""
        return 2 * topo.num_devices

    def sample(self, rng: np.random.Generator, topo: Topology,
               n_rounds: int,
               max_events: Optional[int] = None) -> FailureTrace:
        raise NotImplementedError


@dataclass(frozen=True)
class IidRateProcess(FailureProcess):
    """Every device independently fails with probability ``p`` at a
    uniform epoch, recovering later with ``recover_prob`` — exactly the
    ``TraceSpec.p_grid`` sampler (:func:`failure.sample_traces`, byte-
    identical draws given the same generator state), lifted to a
    process so rate sweeps and generative sweeps share one axis."""
    p: float = 0.2
    recover_prob: float = 0.5
    family: ClassVar[str] = "iid"

    def sample(self, rng, topo, n_rounds, max_events=None):
        m = max_events or self.default_max_events(topo)
        return sample_traces(rng, topo, self.p, max_events=m,
                             rounds=n_rounds, num_traces=1,
                             recover_prob=self.recover_prob)[0]


@dataclass(frozen=True)
class MarkovChurnProcess(FailureProcess):
    """Per-device two-state Markov chain: an alive device fails with
    ``p_fail`` per round, a dead one recovers with ``p_recover`` —
    geometric burst lengths, possibly many outages per device (bursty
    churn rather than one-shot death).  Each device's whole chain is
    drawn before packing, so slot-budget truncation of one device never
    shifts another's stream."""
    p_fail: float = 0.05
    p_recover: float = 0.25
    family: ClassVar[str] = "markov"

    def default_max_events(self, topo):
        return 4 * topo.num_devices     # room for repeated outages

    def sample(self, rng, topo, n_rounds, max_events=None):
        m = max_events or self.default_max_events(topo)
        head_set = set(topo.heads)
        order = np.arange(topo.num_devices)
        rng.shuffle(order)
        groups = []
        for d in order:
            kind = KIND_CODES["server" if int(d) in head_set else "client"]
            u = rng.random(n_rounds)
            alive, g = True, []
            for r in range(n_rounds):
                if alive and u[r] < self.p_fail:
                    g.append((r, int(d), 0.0, kind))
                    alive = False
                elif not alive and u[r] < self.p_recover:
                    g.append((r, int(d), 1.0, kind))
                    alive = True
            if g:
                groups.append(g)
        return trace_from_rows(_pack_groups(groups, m), m)


@dataclass(frozen=True)
class ClusterCascadeProcess(FailureProcess):
    """Correlated cluster-level outage — the paper's cascade scenario.

    Each cluster's head fails with ``p_head`` at a uniform epoch ``e``
    (a *server* event: the whole cluster already leaves training);
    each member then physically cascades down at ``e + 1`` with
    probability ``q`` (client events — they stay dead even if the head
    returns).  With ``recover_prob`` the cluster staggers back: head at
    ``e + recovery_lag``, then members one per ``stagger`` rounds, any
    recovery past the horizon dropped."""
    p_head: float = 0.2
    q: float = 0.9
    recover_prob: float = 0.5
    recovery_lag: int = 5
    stagger: int = 1
    family: ClassVar[str] = "cascade"

    def sample(self, rng, topo, n_rounds, max_events=None):
        m = max_events or self.default_max_events(topo)
        lag = max(1, int(self.recovery_lag))
        stag = max(1, int(self.stagger))
        order = np.arange(topo.num_clusters)
        rng.shuffle(order)
        groups = []
        for c in order:
            members = topo.clusters[int(c)]
            head = int(members[0])
            if rng.random() >= self.p_head:
                continue
            e = int(rng.integers(n_rounds))
            g = [(e, head, 0.0, KIND_CODES["server"])]
            fell = []
            for d in members[1:]:
                if rng.random() < self.q:
                    g.append((min(e + 1, n_rounds - 1), int(d), 0.0,
                              KIND_CODES["client"]))
                    fell.append(int(d))
            rec = e + lag
            if rng.random() < self.recover_prob and rec < n_rounds:
                g.append((rec, head, 1.0, KIND_CODES["server"]))
                for i, d in enumerate(fell):
                    rr = rec + stag * (i + 1)
                    if rr < n_rounds:
                        g.append((rr, d, 1.0, KIND_CODES["client"]))
            groups.append(g)
        return trace_from_rows(_pack_groups(groups, m), m)


@dataclass(frozen=True)
class StragglerProcess(FailureProcess):
    """Flaky clients: with probability ``p`` a device misses a
    contiguous ``window`` of rounds via a PAIRED (fail@e, recover@e+w)
    — it always comes back, never dies ("Keep It Simple"'s unreliable-
    client regime).  The window is clipped so recovery lands inside the
    horizon, and packing is all-or-nothing per device: under slot
    pressure a straggler is dropped entirely rather than truncated
    into a permanent death."""
    p: float = 0.3
    window: int = 5
    family: ClassVar[str] = "straggler"

    def sample(self, rng, topo, n_rounds, max_events=None):
        m = max_events or self.default_max_events(topo)
        if n_rounds < 2:        # no room for a window that returns
            return FailureTrace.none(m)
        w = max(1, min(int(self.window), n_rounds - 1))
        head_set = set(topo.heads)
        order = np.arange(topo.num_devices)
        rng.shuffle(order)
        groups = []
        for d in order:
            if rng.random() >= self.p:
                continue
            e = int(rng.integers(n_rounds - w))    # recover at e+w < rounds
            kind = KIND_CODES["server" if int(d) in head_set else "client"]
            groups.append([(e, int(d), 0.0, kind),
                           (e + w, int(d), 1.0, kind)])
        return trace_from_rows(_pack_groups(groups, m, pairs_only=True), m)


@dataclass(frozen=True)
class FaultyUpdateProcess(FailureProcess):
    """FedFm-style faulty updates: with probability ``p`` a device's
    transmitted deltas are scaled by ``scale`` from a uniform epoch on
    (for ``window`` rounds if set, else to the end) while the device
    stays fully alive — corruption, not death.  Lowers to shadow-device
    rows (``N + d``, kind ``"faulty"``, scale in the alive channel):
    inert everywhere except the faulty-aware engine variants, which
    read them back with ``trace_faulty_scale``."""
    p: float = 0.2
    scale: float = -1.0
    window: Optional[int] = None
    family: ClassVar[str] = "faulty"
    needs_faulty_engine: ClassVar[bool] = True

    def sample(self, rng, topo, n_rounds, max_events=None):
        m = max_events or self.default_max_events(topo)
        n = topo.num_devices
        faulty = KIND_CODES["faulty"]
        order = np.arange(n)
        rng.shuffle(order)
        groups = []
        for d in order:
            if rng.random() >= self.p:
                continue
            e = int(rng.integers(n_rounds))
            g = [(e, n + int(d), float(self.scale), faulty)]
            if self.window is not None and e + int(self.window) < n_rounds:
                g.append((e + int(self.window), n + int(d), 1.0, faulty))
            groups.append(g)
        return trace_from_rows(_pack_groups(groups, m), m)


@dataclass(frozen=True)
class ProcessGrid:
    """One generative axis of a ``TraceSpec``: ``n_samples`` Monte-
    Carlo draws of ``process``, deduplicated against the cell's whole
    trace pool at plan time."""
    process: FailureProcess
    n_samples: int = 4

    def __post_init__(self):
        assert self.n_samples >= 1, self.n_samples


def process_seed(sample_seed: int, process: FailureProcess,
                 draw: int) -> int:
    """Deterministic per-draw numpy seed from (spec seed, process
    identity, draw index) — SHA-256 of the dataclass repr, because
    Python's ``hash`` is salted per interpreter and would break the
    identical-traces-across-processes contract."""
    msg = f"{sample_seed}|{process!r}|{draw}".encode()
    return int.from_bytes(hashlib.sha256(msg).digest()[:8], "little")


def sample_process_grids(processes: Sequence[ProcessGrid], topo: Topology,
                         rounds: int, sample_seed: int, max_events: int,
                         traces: List[FailureTrace]
                         ) -> Dict[int, List[int]]:
    """Lower process grids into a cell's trace pool (in place).

    Appends each distinct draw to ``traces`` (dedup by trace bytes
    against everything already there — an all-none draw aliases a
    no-failure base trace instead of retraining) and returns
    ``{grid index: [trace index per draw]}``, the generative twin of
    ``sample_rate_grid``'s ``draws``.  Every draw gets a FRESH
    generator from :func:`process_seed`, so grids replay bit-identical
    regardless of draw order or what else the spec samples."""
    idx_of: dict = {}
    for i, t in enumerate(traces):
        idx_of.setdefault(_trace_key(t), i)
    out: Dict[int, List[int]] = {}
    for gi, pg in enumerate(processes):
        idxs = []
        for draw in range(pg.n_samples):
            rng = np.random.default_rng(
                process_seed(sample_seed, pg.process, draw))
            t = pg.process.sample(rng, topo, rounds, max_events=max_events)
            assert t.max_events == max_events, (t.max_events, max_events)
            key = _trace_key(t)
            if key not in idx_of:
                idx_of[key] = len(traces)
                traces.append(t)
            idxs.append(idx_of[key])
        out[gi] = idxs
    return out


def family_process(family: str, intensity: float) -> FailureProcess:
    """The canonical process of ``family`` at ``intensity`` in [0, 1]
    — the one knob the per-family E[AUROC] curves sweep (benches and
    ``examples/failure_scenarios.py --process``).  Intensity maps to
    each family's headline probability; markov scales the per-round
    hazard down by 10x so a full sweep spans comparable outage mass."""
    if family == "iid":
        return IidRateProcess(p=intensity)
    if family == "markov":
        return MarkovChurnProcess(p_fail=0.1 * intensity, p_recover=0.25)
    if family == "cascade":
        return ClusterCascadeProcess(p_head=intensity)
    if family == "straggler":
        return StragglerProcess(p=intensity)
    if family == "faulty":
        return FaultyUpdateProcess(p=intensity)
    raise ValueError(f"unknown process family {family!r}; "
                     f"one of {FAMILIES}")
