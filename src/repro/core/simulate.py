"""Paper-scale Tol-FL simulator (Tables III-VI, Figures 4-5).

Simulates N federated devices training the paper's autoencoder with the
single-model schemes — Batch (centralised), FL (k=1), SBT (k=N),
Tol-FL (1<k<N) — under client / server failures.  The whole federation is
one jitted ``lax.scan`` over rounds: device gradients via ``vmap``, the
Tol-FL combine via the shared algebra in :mod:`repro.core.aggregation`,
failures via in-graph masks from :mod:`repro.core.failure`.

The round loop is factored into a pure *core* function whose only
dynamic inputs are (data arrays, failure trace, seed).  The core is
built once per static configuration (``_core_cache``), jitted, and
shared by

* :func:`run_simulation` — the single-scenario entry point (a repeated
  call with a new trace/seed reuses the compiled executable), and
* :mod:`repro.core.campaign` — which ``vmap``s the same core over a
  stacked batch of (trace, seed) scenarios so an entire Monte-Carlo
  sweep is ONE compile.  Whole (scheme, k) grids are declared through
  the spec -> plan -> execute pipeline on top
  (:mod:`repro.core.experiment` / :mod:`repro.api`);
  :func:`run_simulation` stays the scalar core beneath it.

FL server failure triggers the paper's fallback: remaining devices
continue training *isolated* local models (Section V-C / Fig 4); the
reported metric then averages the independent devices, exactly as the
paper's Fig 4 caption describes.

Multi-model baselines (FedGroup / IFCA / FeSEM) live in
:mod:`repro.core.baselines`.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.failure import (Failure, FailureTrace, NO_FAILURE, as_trace,
                                effective_weights_arrays, trace_alive_mask,
                                trace_faulty_scale)
from repro.core.topology import Topology
from repro.models import detector as D
from repro.models.detector import ModelLike
from repro.training.metrics import auroc, auroc_batch


@dataclass(frozen=True)
class SimConfig:
    scheme: str = "tolfl"          # batch | fl | sbt | tolfl
    num_devices: int = 10
    num_clusters: int = 5          # k (tolfl); fl -> 1, sbt -> N
    rounds: int = 100
    lr: float = 1e-3
    local_epochs: int = 1          # E local steps per round
    combine: str = "streaming"     # streaming (faithful) | direct
    dropout: bool = True
    seed: int = 0

    def topology(self) -> Topology:
        if self.scheme == "batch":
            return Topology(1, 1)
        if self.scheme == "fl":
            return Topology(self.num_devices, 1)
        if self.scheme == "sbt":
            return Topology(self.num_devices, self.num_devices)
        return Topology(self.num_devices, self.num_clusters)


@dataclass(frozen=True)
class FaultySimConfig(SimConfig):
    """The faulty-update engine variant: identical training except the
    TRANSMITTED per-device deltas are scaled by the trace's faulty
    channel (:func:`repro.core.failure.trace_faulty_scale`) before the
    hierarchical combine — FedFm-style corrupted updates from devices
    that are otherwise alive.  Local/isolated training stays clean (the
    corruption happens on the wire, not on the device).

    A distinct SUBCLASS rather than a ``SimConfig`` field on purpose:
    dataclass ``__eq__``/``repr`` include the class, so faulty cores
    get their own cached-core and executable-fingerprint entries while
    every plain-config key and persisted fingerprint stays
    bit-identical.  ``plan()`` swaps cells onto this class whenever a
    ``TraceSpec`` declares a process with ``needs_faulty_engine``."""
    faulty_updates: bool = True


@dataclass
class SimResult:
    final_auroc: float
    iso_auroc: float               # mean of isolated devices (fl fallback)
    auroc_used: float              # what the paper would report
    loss_curve: np.ndarray         # (rounds,) REPORTED test loss: global
    #                                model, except that FL server-dead
    #                                rounds carry the isolated mean (Fig 4)
    auroc_curve: np.ndarray        # (rounds,) reported AUROC, same switch
    iso_loss_curve: np.ndarray     # (rounds,) alive-mean isolated loss
    iso_active: bool
    rounds_to_loss: Optional[int] = None


class SimOutputs(NamedTuple):
    """Raw in-graph outputs of one simulated scenario (pre-AUROC)."""
    losses: jax.Array            # (rounds,) global-model test loss
    iso_losses: jax.Array        # (rounds,) alive-mean isolated test loss
    final_scores: jax.Array      # (T,) anomaly scores of the final model
    iso_final_scores: jax.Array  # (N, T) per-device isolated scores
    final_alive: jax.Array       # (N,) alive mask at the last round
    server_dead: jax.Array       # () 1.0 iff every cluster head is dead
    server_dead_rounds: jax.Array  # (rounds,) 1.0 where all heads dead
    score_hist: jax.Array        # (rounds, T) or (rounds, 0) if untracked
    iso_score_hist: jax.Array    # (rounds, N, T) or (rounds, 0, 0)


def _device_grad_fn(model: ModelLike, dropout: bool):
    det = D.as_detector(model)

    def local_loss(params, x, valid, key):
        return det.loss(params, x, valid, key if dropout else None)
    return jax.grad(local_loss)


def _local_delta_fn(model: ModelLike, cfg: SimConfig):
    """E local SGD steps; returns the (negated-gradient-like) delta/lr.

    With E=1 this is exactly the local gradient (paper Algorithm 1)."""
    grad_fn = _device_grad_fn(model, cfg.dropout)

    def delta(params, x, valid, key):
        if cfg.local_epochs == 1:
            return grad_fn(params, x, valid, key)

        def step(p, i):
            g = grad_fn(p, x, valid, jax.random.fold_in(key, i))
            return jax.tree.map(lambda a, b: a - cfg.lr * b, p, g), None

        p_end, _ = jax.lax.scan(step, params, jnp.arange(cfg.local_epochs))
        # pseudo-gradient: (theta - theta_local) / lr
        return jax.tree.map(lambda a, b: (a - b) / cfg.lr, params, p_end)

    return delta


def _build_core_arrays(model: ModelLike, cfg: SimConfig,
                       num_devices: int, num_clusters: int,
                       track_iso: bool, score_history: bool,
                       return_params: bool = False):
    """Pure scenario function with the topology as DYNAMIC operands:
    (dx, counts, valid, tx, cluster_ids, heads, head_valid, trace, seed)
    -> :class:`SimOutputs`.

    ``num_clusters`` is only the STATIC length of the cluster axis; the
    actual structure arrives in the arrays, so cells of a (scheme, k)
    sweep that pad ``heads``/``head_valid`` to a common max-k share ONE
    compiled executable (:func:`repro.core.campaign.sweep_grid`).
    Padded cluster slots carry zero counts and an invalid head, which
    the combine algebra absorbs as exact no-ops — results are
    bit-identical to the per-cell build.  ``cfg.scheme`` is deliberately
    unread here (``track_iso`` replaces it) so one build serves
    fl/sbt/tolfl alike."""
    N = num_devices
    k = num_clusters
    det = D.as_detector(model)
    delta_fn = _local_delta_fn(det, cfg)
    # faulty-update engine gate: static (class-level), so plain configs
    # trace the byte-identical graph they always did
    faulty = bool(getattr(cfg, "faulty_updates", False))

    def core(dx, counts, valid, tx, cluster_ids, heads, head_valid,
             trace: FailureTrace, seed):
        key = jax.random.PRNGKey(seed)
        params = det.init_params(key)

        def test_loss(p):
            s = det.anomaly_scores(p, tx)
            return jnp.mean(s)

        def heads_alive_max(alive):
            """max over VALID heads only — padded head slots never argue
            the server back to life."""
            return jnp.max(jnp.where(head_valid > 0, alive[heads], 0.0))

        def round_fn(carry, epoch):
            params, iso_params, rkey = carry
            rkey, dkey = jax.random.split(rkey)
            alive = trace_alive_mask(trace, N, epoch)
            w = effective_weights_arrays(alive, cluster_ids, heads)
            dkeys = jax.random.split(dkey, N)
            head_dead = 1.0 - heads_alive_max(alive)     # all heads dead
            if track_iso:
                # One N-way delta vmap serves BOTH the global combine and
                # the isolated fallback.  Exactness: while any head is
                # alive, ``iso_params`` rows all equal ``params`` (the
                # where-reset below), so these ARE the global-path
                # gradients; on all-heads-dead rounds the values differ
                # but every effective weight is zero, so the combine is
                # gated off (``has_update == 0``) and the difference
                # never reaches ``new_params``.  This halves the
                # per-round gradient work of iso-tracking cores.
                iso_params = jax.tree.map(
                    lambda ip, p_: jnp.where(head_dead > 0, ip,
                                             jnp.broadcast_to(p_, ip.shape)),
                    iso_params, params)
                gs = jax.vmap(delta_fn, in_axes=(0, 0, 0, 0))(
                    iso_params, dx, valid, dkeys)
            else:
                gs = jax.vmap(delta_fn, in_axes=(None, 0, 0, 0))(
                    params, dx, valid, dkeys)
            gs_tx = gs
            if faulty:
                # corrupt the TRANSMITTED deltas only: the isolated
                # fallback below keeps the clean ``gs`` (faulty devices
                # train fine locally, they just send garbage)
                fscale = trace_faulty_scale(trace, N, epoch)
                gs_tx = jax.tree.map(
                    lambda g_: g_ * fscale.reshape(
                        (-1,) + (1,) * (g_.ndim - 1)), gs)
            ns = counts * w
            # ---- Tol-FL hierarchical combine (Algorithm 1) ----
            cluster_gs, n_c = agg.cluster_reduce(gs_tx, ns, cluster_ids, k)
            if cfg.combine == "streaming":
                n_tot, g = agg.stacked_streaming_mean(cluster_gs, n_c)
            else:
                g = agg.weighted_mean(cluster_gs, n_c)
                n_tot = jnp.sum(n_c)
            has_update = (n_tot > 0).astype(jnp.float32)
            new_params = jax.tree.map(
                lambda p_, g_: p_ - cfg.lr * has_update * g_, params, g)

            # ---- isolated fallback (fl server failure) ----
            if track_iso:
                # ``iso_params`` already track the global model until
                # failure (the where-reset above) and ``gs`` holds the
                # per-device gradients at exactly those params
                iso_step = head_dead * alive    # only alive devices train
                iso_params = jax.tree.map(
                    lambda ip, g_: ip - cfg.lr * iso_step.reshape(
                        (-1,) + (1,) * (g_.ndim - 1)) * g_,
                    iso_params, gs)
                # Fig 4 reporting averages the surviving devices only:
                # weight each device's test loss by its alive mask (the
                # dead server keeps a frozen model and is excluded)
                per_dev_tl = jax.vmap(test_loss)(iso_params)
                iso_tl = (jnp.sum(alive * per_dev_tl)
                          / jnp.maximum(jnp.sum(alive), 1.0))
            else:
                iso_tl = jnp.float32(0)

            tl = test_loss(new_params)
            if score_history:
                scores = det.anomaly_scores(new_params, tx)
            else:
                scores = jnp.zeros((0,), jnp.float32)
            if track_iso and score_history:
                iso_scores = jax.vmap(
                    lambda p: det.anomaly_scores(p, tx))(iso_params)
            else:
                iso_scores = jnp.zeros((0, 0), jnp.float32)
            return (new_params, iso_params, rkey), (tl, scores, iso_tl,
                                                    iso_scores, head_dead)

        iso0 = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (N,) + p.shape).copy(),
            params)
        (final_params, iso_params, _), \
            (losses, score_hist, iso_losses, iso_score_hist, dead_rounds) = \
            jax.lax.scan(round_fn, (params, iso0, key),
                         jnp.arange(cfg.rounds))

        final_alive = trace_alive_mask(trace, N, jnp.int32(cfg.rounds - 1))
        server_dead = 1.0 - heads_alive_max(final_alive)
        final_scores = det.anomaly_scores(final_params, tx)
        if track_iso:
            iso_final_scores = jax.vmap(
                lambda p: det.anomaly_scores(p, tx))(iso_params)
        else:
            iso_final_scores = jnp.zeros((N, 0), jnp.float32)
        outputs = SimOutputs(losses, iso_losses, final_scores,
                             iso_final_scores, final_alive, server_dead,
                             dead_rounds, score_hist, iso_score_hist)
        if return_params:
            # params export (the serving layer's model bank): the final
            # global params and the per-device isolated params leave the
            # graph alongside the metrics.  Static flag, default False,
            # so every pre-existing core traces the byte-identical
            # graph it always did.
            return outputs, final_params, iso_params
        return outputs

    return core


def _build_core(model: ModelLike, cfg: SimConfig,
                score_history: bool):
    """Pure scenario function: (dx, counts, valid, tx, trace, seed)
    -> :class:`SimOutputs`.  The topology is closed over statically (a
    thin wrapper over :func:`_build_core_arrays`); the FL
    isolated-fallback branch exists whenever scheme == "fl" and is
    gated in-graph by the trace, so one graph serves every trace."""
    topo = cfg.topology()
    cluster_ids = jnp.asarray(topo.device_cluster_array())
    heads = jnp.asarray(np.array(topo.heads))
    head_valid = jnp.ones((topo.num_clusters,), jnp.float32)
    arrays_core = _build_core_arrays(model, cfg, topo.num_devices,
                                     topo.num_clusters,
                                     track_iso=(cfg.scheme == "fl"),
                                     score_history=score_history)

    def core(dx, counts, valid, tx, trace: FailureTrace, seed):
        return arrays_core(dx, counts, valid, tx, cluster_ids, heads,
                           head_valid, trace, seed)

    return core


@functools.lru_cache(maxsize=32)
def _jitted_core_cached(model: ModelLike, cfg: SimConfig,
                        score_history: bool):
    return jax.jit(_build_core(model, cfg, score_history))


def _jitted_core(model: ModelLike, cfg: SimConfig,
                 score_history: bool):
    """Compiled single-scenario core, cached on static config (the seed
    field of ``cfg`` is ignored — seed is a dynamic argument; the model
    spec is canonicalised so the config and detector spellings of the
    same autoencoder share one cache entry)."""
    return _jitted_core_cached(D.canonical_model_key(model),
                               dataclasses.replace(cfg, seed=0),
                               score_history)


def _prepare_arrays(cfg: SimConfig, device_x: np.ndarray,
                    device_counts: np.ndarray):
    """Scheme-aware device arrays: batch centralises all data onto the
    single server device."""
    if cfg.scheme == "batch":
        flat = np.concatenate([device_x[i, :device_counts[i]]
                               for i in range(len(device_counts))], 0)
        device_x = flat[None]
        device_counts = np.array([len(flat)])
    dx = jnp.asarray(device_x)
    counts = jnp.asarray(device_counts, jnp.float32)
    valid = (jnp.arange(device_x.shape[1])[None, :]
             < counts[:, None]).astype(jnp.float32)     # (N, n_max)
    return dx, counts, valid


# ---------------------------------------------------------------------------
# Params export (the serving layer's model bank)
# ---------------------------------------------------------------------------
def _build_params_core(model: ModelLike, cfg: SimConfig):
    """Pure scenario function returning trained PARAMETERS instead of
    metrics: (dx, counts, valid, tx, cluster_ids, heads, head_valid,
    trace, seed) -> (global_params, iso_params, final_alive).

    Same round loop as every other core (``_build_core_arrays`` with
    ``return_params=True``), so the exported params are exactly what the
    campaign trained.  ``head_valid`` is a dynamic operand: passing the
    real mask trains the scheme's global model; passing ZEROS makes
    every round an all-heads-dead round, i.e. each device trains its own
    isolated model on its local shard from the shared init — the
    serving failover bank — through the SAME compiled executable."""
    topo = cfg.topology()
    arrays_core = _build_core_arrays(model, cfg, topo.num_devices,
                                     topo.num_clusters, track_iso=True,
                                     score_history=False,
                                     return_params=True)

    def core(dx, counts, valid, tx, cluster_ids, heads, head_valid,
             trace: FailureTrace, seed):
        out, params, iso_params = arrays_core(
            dx, counts, valid, tx, cluster_ids, heads, head_valid,
            trace, seed)
        return params, iso_params, out.final_alive

    return core


@functools.lru_cache(maxsize=16)
def _params_core_cached(model: ModelLike, cfg: SimConfig):
    return jax.jit(_build_params_core(model, cfg))


def trained_params(model: ModelLike, device_x: np.ndarray,
                   device_counts: np.ndarray, cfg: SimConfig,
                   failure: Failure = NO_FAILURE,
                   isolated: bool = False):
    """Train one scenario and export its parameters.

    Returns ``(global_params, iso_params, final_alive)`` as device
    arrays: the scheme's final global model, the per-device isolated
    models (leaves carry a leading ``(N,)`` axis), and the final alive
    mask.  With ``isolated=True`` the cluster-head validity mask is
    zeroed so every device trains its OWN model on its local shard from
    the shared init — the genuinely-isolated failover models the
    scoring service banks (:mod:`repro.serving.anomaly`)."""
    topo = cfg.topology()
    trace = as_trace(failure, topo)
    dx, counts, valid = _prepare_arrays(cfg, device_x, device_counts)
    assert dx.shape[0] == topo.num_devices, (dx.shape, topo.num_devices)
    cluster_ids = jnp.asarray(topo.device_cluster_array())
    heads = jnp.asarray(np.array(topo.heads))
    head_valid = (jnp.zeros if isolated else jnp.ones)(
        (topo.num_clusters,), jnp.float32)
    # the test-loss operand is unused by the params outputs; keep it to
    # one row so the export never pays for a test sweep
    tx = jnp.zeros((1, dx.shape[-1]), dx.dtype)
    core = _params_core_cached(D.canonical_model_key(model),
                               dataclasses.replace(cfg, seed=0))
    return core(dx, counts, valid, tx, cluster_ids, heads, head_valid,
                trace, jnp.int32(cfg.seed))


def iso_mean_auroc(iso_scores: np.ndarray, final_alive: np.ndarray,
                   test_y: np.ndarray) -> float:
    """Paper Fig 4 reporting: mean AUROC over the *alive* isolated
    devices (the dead server keeps its frozen model and is excluded)."""
    # loops over the bounded DEVICE axis (N, not scenarios) with a
    # data-dependent alive filter -- not the batched-metric bug class
    # plancheck: ignore[PC-AST-LOOPMETRIC]
    per_dev = [auroc(iso_scores[i], test_y)
               for i in range(iso_scores.shape[0]) if final_alive[i] > 0]
    return float(np.mean(per_dev)) if per_dev else float("nan")


def run_simulation(model: ModelLike, device_x: np.ndarray,
                   device_counts: np.ndarray, test_x: np.ndarray,
                   test_y: np.ndarray, cfg: SimConfig,
                   failure: Failure = NO_FAILURE,
                   target_loss: Optional[float] = None) -> SimResult:
    """device_x: (N, n_max, D) padded; device_counts: (N,).

    ``model`` is any :class:`repro.models.detector.DetectorModel` (a raw
    :class:`AutoencoderConfig` — the historical first argument — still
    works).  ``failure`` may be a legacy single-event
    :class:`FailureSpec` or a multi-event :class:`FailureTrace`."""
    topo = cfg.topology()
    N = topo.num_devices
    trace = as_trace(failure, topo)
    dx, counts, valid = _prepare_arrays(cfg, device_x, device_counts)
    assert dx.shape[0] == N, (dx.shape, N)
    tx = jnp.asarray(test_x)

    core = _jitted_core(model, cfg, True)
    out = core(dx, counts, valid, tx, trace, jnp.int32(cfg.seed))

    losses = np.asarray(out.losses).copy()
    iso_losses = np.asarray(out.iso_losses)
    scores_all = np.asarray(out.score_hist)
    aurocs = auroc_batch(scores_all, np.asarray(test_y))
    final = float(aurocs[-1])

    # isolated final AUROC: mean over alive devices of per-device AUROC
    track_iso = (cfg.scheme == "fl")
    dead_rounds = np.asarray(out.server_dead_rounds) > 0     # (rounds,)
    fl_server_fallback = track_iso and bool(dead_rounds[-1])
    iso_final = float("nan")
    if fl_server_fallback:
        iso_final = iso_mean_auroc(np.asarray(out.iso_final_scores),
                                   np.asarray(out.final_alive), test_y)

    # Fig 4 semantics: from the round the FL server dies the global
    # model is frozen and meaningless — the reported curves switch to
    # the isolated-mean curve for every server-dead round (a later
    # recovery switches back, matching ``auroc_used``'s round-wise
    # notion of "what the system can actually serve").
    if track_iso and dead_rounds.any():
        iso_hist = np.asarray(out.iso_score_hist)            # (R, N, T)
        for t in np.flatnonzero(dead_rounds):
            alive_t = np.asarray(trace_alive_mask(trace, N, jnp.int32(t)))
            aurocs[t] = iso_mean_auroc(iso_hist[t], alive_t, test_y)
            losses[t] = iso_losses[t]

    used = iso_final if fl_server_fallback else final
    r2l = None
    if target_loss is not None:
        hit = np.where(losses <= target_loss)[0]
        r2l = int(hit[0]) + 1 if len(hit) else None
    return SimResult(final, iso_final, used, losses, aurocs, iso_losses,
                     fl_server_fallback, r2l)


# ---------------------------------------------------------------------------
# Resource-usage models (Table II / VI, Fig 5)
# ---------------------------------------------------------------------------
def comm_transfers_per_round(scheme: str, n: int, k: int) -> int:
    """Model transfers per training round (Table VI accounting)."""
    if scheme == "batch":
        return 0
    if scheme == "fl":
        return 2 * n                       # broadcast + gather
    if scheme == "sbt":
        return n - 1                       # sequential ring pass
    if scheme == "tolfl":
        # members -> heads (n - k), head chain (k - 1), head broadcast (k)
        return n + k - 1
    raise ValueError(scheme)


def _resolve_model_bytes(model_bytes) -> int:
    """``model_bytes`` may be a raw byte count or any detector spec /
    AutoencoderConfig — sized via ``models.params.param_count`` over the
    actual parameter tree instead of hand-rolled arithmetic."""
    if isinstance(model_bytes, (int, float, np.integer, np.floating)):
        return int(model_bytes)
    return D.as_detector(model_bytes).param_bytes()


def comm_mb_per_round(scheme: str, n: int, k: int, model_bytes) -> float:
    return (comm_transfers_per_round(scheme, n, k)
            * _resolve_model_bytes(model_bytes) / 1e6)


def round_time_model(scheme: str, n: int, k: int, samples: int,
                     model_bytes, flops_per_sample: float,
                     device_flops: float = 5e9, link_bw: float = 10e6
                     ) -> float:
    """Seconds per round under the paper's Section IV-A task-sequencing
    model: parallel stages take the max over participants, sequential
    stages sum.  link_bw in bytes/s (wireless-ish)."""
    t_model = _resolve_model_bytes(model_bytes) / link_bw
    per_dev = samples / max(n, 1) * flops_per_sample / device_flops
    if scheme == "batch":
        return samples * flops_per_sample / device_flops
    if scheme == "fl":
        return per_dev + 2 * t_model
    if scheme == "sbt":
        return per_dev + (n - 1) * t_model
    if scheme == "tolfl":
        return per_dev + 2 * t_model + (k - 1) * t_model
    raise ValueError(scheme)
