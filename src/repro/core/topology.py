"""Tol-FL topology: cluster structure over the federated device set.

The paper's N devices map to the ``data`` mesh axis (each index = one
data-parallel federated group, DESIGN.md section 2).  k clusters partition
the axis into contiguous blocks; member 0 of each block is the cluster
head.  This module is pure bookkeeping — index groups for the
intra-cluster ``psum`` and the ppermute chain for the inter-cluster SBT
ring — shared by the mesh engine and the paper-scale simulator.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class Topology:
    num_devices: int          # size of the data axis (or simulated N)
    num_clusters: int         # k

    def __post_init__(self):
        assert 1 <= self.num_clusters <= self.num_devices
        assert self.num_devices % self.num_clusters == 0, \
            "clusters must evenly partition the device set"

    @property
    def members_per_cluster(self) -> int:
        return self.num_devices // self.num_clusters

    @property
    def clusters(self) -> List[List[int]]:
        m = self.members_per_cluster
        return [list(range(c * m, (c + 1) * m))
                for c in range(self.num_clusters)]

    @property
    def heads(self) -> List[int]:
        """Cluster-head device index per cluster (member 0)."""
        return [c[0] for c in self.clusters]

    def cluster_of(self, device: int) -> int:
        return device // self.members_per_cluster

    def is_head(self, device: int) -> bool:
        return device % self.members_per_cluster == 0

    # ---------------- collective plumbing ----------------
    def psum_index_groups(self) -> List[List[int]]:
        """axis_index_groups for the intra-cluster FedAvg psum."""
        return self.clusters

    def ring_perms(self) -> List[List[Tuple[int, int]]]:
        """One ppermute permutation per sequential SBT hop:
        hop i moves the running (n, g) pair from head_i to head_{i+1}."""
        h = self.heads
        return [[(h[i], h[i + 1])] for i in range(len(h) - 1)]

    def device_cluster_array(self) -> np.ndarray:
        """(N,) int cluster id per device."""
        return np.arange(self.num_devices) // self.members_per_cluster

    def head_mask(self) -> np.ndarray:
        """(N,) bool: True where device is a cluster head."""
        return np.arange(self.num_devices) % self.members_per_cluster == 0


def special_cases(n: int) -> dict:
    """The paper's named special cases of Tol-FL(k)."""
    return {"fl": Topology(n, 1), "sbt": Topology(n, n)}
