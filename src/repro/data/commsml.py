"""Synthetic Comms-ML-style wireless dataset generator.

The paper's Comms-ML tool [15] simulates SDR networks and emits per-sample
feature vectors of 112 features: indices 0-11 are network-statistics
features (packet rates, airtime occupancy, RSSI stats, MCS histogram
moments, ...) and indices 11+ are raw physical-signal readings (wideband
spectral magnitudes).  The public generator needs a full SDR simulation
stack; offline we reproduce its *statistical shape*: each traffic class is
a distinct stationary process over the 112 features — distinct spectral
occupancy patterns + correlated statistics — and anomaly classes perturb
the communication pattern (rate shifts) or add novel emitters (new
spectral lines), exactly the two anomaly families described in Section V-A.

Classes (4, as in Table VII; 3000 samples/class):
  0: wifi_sparse    — baseline WLAN, low duty cycle
  1: wifi_dense     — same emitters, high duty cycle
  2: rate_anomaly   — class-0 emitters with shifted tx pattern (resource
                      misuse anomaly)
  3: bluetooth_intrusion — novel narrowband hopper added (novel-device
                      anomaly)
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

N_FEATURES = 112
N_STATS = 12          # statistics features [0, 12)
N_SIGNAL = N_FEATURES - N_STATS
N_CLASSES = 4
SAMPLES_PER_CLASS = 3000


def _spectral_template(rng: np.random.Generator, centers, widths, powers
                       ) -> np.ndarray:
    """Mean wideband magnitude profile over N_SIGNAL bins."""
    f = np.arange(N_SIGNAL, dtype=np.float64)
    prof = np.full(N_SIGNAL, 0.05)
    for c, w, p in zip(centers, widths, powers):
        prof += p * np.exp(-0.5 * ((f - c) / w) ** 2)
    return prof


_CLASS_DEFS = {
    0: dict(duty=0.2, rate=10.0, centers=[20, 60], widths=[6, 8],
            powers=[1.0, 0.8]),
    1: dict(duty=0.7, rate=40.0, centers=[20, 60], widths=[6, 8],
            powers=[1.6, 1.3]),
    2: dict(duty=0.9, rate=120.0, centers=[20, 60], widths=[6, 8],
            powers=[1.1, 0.9]),                       # rate misuse
    3: dict(duty=0.25, rate=12.0, centers=[20, 60, 85], widths=[6, 8, 1.5],
            powers=[1.0, 0.8, 2.2]),                  # bluetooth hopper
}


def _stats_features(rng, duty, rate, n) -> np.ndarray:
    """12 correlated statistics features."""
    pkt_rate = rng.gamma(shape=rate, scale=1.0, size=n) / max(rate, 1)
    airtime = np.clip(duty + 0.08 * rng.standard_normal(n), 0, 1)
    rssi_mean = -55 + 8 * airtime + 1.5 * rng.standard_normal(n)
    rssi_std = 2.5 + 1.2 * airtime + 0.3 * rng.standard_normal(n)
    retries = rng.poisson(lam=2 + 8 * duty, size=n).astype(np.float64)
    mcs_lo = np.clip(0.6 - 0.4 * duty + 0.1 * rng.standard_normal(n), 0, 1)
    mcs_hi = 1.0 - mcs_lo
    iat_mean = 1.0 / np.maximum(pkt_rate * rate, 0.3)
    iat_cv = 0.8 + 0.5 * duty + 0.1 * rng.standard_normal(n)
    chan_util = np.clip(airtime + 0.05 * rng.standard_normal(n), 0, 1)
    n_src = np.round(2 + 3 * duty + rng.standard_normal(n) * 0.5)
    noise_floor = -95 + 1.0 * rng.standard_normal(n)
    return np.stack([pkt_rate, airtime, rssi_mean, rssi_std, retries,
                     mcs_lo, mcs_hi, iat_mean, iat_cv, chan_util,
                     n_src, noise_floor], axis=1)


def generate_class(cls: int, n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed * N_CLASSES + cls + 1)
    d = _CLASS_DEFS[cls]
    prof = _spectral_template(rng, d["centers"], d["widths"], d["powers"])
    # per-sample signal: template x log-normal fading + burst modulation
    fade = rng.lognormal(mean=0.0, sigma=0.25, size=(n, 1))
    bursts = d["duty"] + (1 - d["duty"]) * rng.beta(2, 5, size=(n, 1))
    sig = prof[None, :] * fade * bursts + 0.03 * rng.standard_normal(
        (n, N_SIGNAL))
    if cls == 3:   # hopping: the narrowband line moves around bin 85+-7
        hop = rng.integers(-7, 8, size=n)
        for i in range(n):
            sig[i] = np.roll(sig[i], hop[i])
    stats = _stats_features(rng, d["duty"], d["rate"], n)
    return np.concatenate([stats, sig], axis=1).astype(np.float32)


def generate(seed: int = 0, samples_per_class: int = SAMPLES_PER_CLASS
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X (N, 112) float32, y (N,) int class labels)."""
    xs, ys = [], []
    for c in range(N_CLASSES):
        xc = generate_class(c, samples_per_class, seed)
        xs.append(xc)
        ys.append(np.full(samples_per_class, c, np.int32))
    X = np.concatenate(xs, 0)
    y = np.concatenate(ys, 0)
    # standardise with class-0 statistics (the "typical" traffic)
    mu = X[y == 0].mean(0, keepdims=True)
    sd = X[y == 0].std(0, keepdims=True) + 1e-6
    return ((X - mu) / sd).astype(np.float32), y
