"""Federated data partitioning (paper Section V-A / Appendix B).

Datasets are cast as anomaly-detection tasks by designating one or more
classes "anomalous"; the remaining classes are divided amongst devices.
Where clusters are present, data is assigned one class (or class group)
per cluster, then subdivided equally amongst the cluster's devices —
|D_i| = N_i <= ceil(N/k) per the paper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np


@dataclass
class FederatedSplit:
    """Per-device training arrays + shared test set."""
    device_data: List[np.ndarray]            # N arrays (n_i, D)
    test_x: np.ndarray                       # (T, D)
    test_y: np.ndarray                       # (T,) 1 = anomalous
    clusters: List[List[int]]                # device ids per cluster

    @property
    def num_devices(self) -> int:
        return len(self.device_data)

    def sample_counts(self) -> np.ndarray:
        return np.array([len(d) for d in self.device_data])


def make_split(X: np.ndarray, y: np.ndarray, num_devices: int,
               num_clusters: int, anomaly_classes: Sequence[int],
               seed: int = 0, test_frac: float = 0.25) -> FederatedSplit:
    """Class-per-cluster partitioning.

    Normal classes are grouped round-robin over clusters; each cluster's
    pool is split equally over its devices.  The test set mixes held-out
    normal samples with all anomaly-class samples (labelled 1).
    """
    assert num_devices % num_clusters == 0, (num_devices, num_clusters)
    rng = np.random.default_rng(seed)
    anomaly_classes = set(anomaly_classes)
    normal_classes = [c for c in sorted(set(y.tolist()))
                      if c not in anomaly_classes]
    per_cluster = num_devices // num_clusters
    clusters = [list(range(i * per_cluster, (i + 1) * per_cluster))
                for i in range(num_clusters)]

    # assign normal classes to clusters round-robin
    cluster_pool: List[List[np.ndarray]] = [[] for _ in range(num_clusters)]
    test_norm = []
    for j, c in enumerate(normal_classes):
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        n_test = int(len(idx) * test_frac)
        test_norm.append(X[idx[:n_test]])
        cluster_pool[j % num_clusters].append(X[idx[n_test:]])

    device_data: List[np.ndarray] = [None] * num_devices  # type: ignore
    for ci, devs in enumerate(clusters):
        pool = (np.concatenate(cluster_pool[ci], 0) if cluster_pool[ci]
                else np.zeros((0, X.shape[1]), X.dtype))
        rng.shuffle(pool)
        parts = np.array_split(pool, len(devs))
        for d, part in zip(devs, parts):
            device_data[d] = part.astype(np.float32)

    anom = X[np.isin(y, list(anomaly_classes))]
    test_x = np.concatenate(test_norm + [anom], 0).astype(np.float32)
    test_y = np.concatenate([np.zeros(sum(len(t) for t in test_norm)),
                             np.ones(len(anom))]).astype(np.int32)
    return FederatedSplit(device_data, test_x, test_y, clusters)


def pad_devices(split: FederatedSplit) -> Tuple[np.ndarray, np.ndarray]:
    """Stack device datasets into a dense (N, max_n, D) tensor + count
    vector, so the whole federation vmaps (simulator engine)."""
    n_max = max(len(d) for d in split.device_data)
    D = split.device_data[0].shape[1]
    out = np.zeros((split.num_devices, n_max, D), np.float32)
    cnt = np.zeros((split.num_devices,), np.int32)
    for i, d in enumerate(split.device_data):
        out[i, :len(d)] = d
        cnt[i] = len(d)
    return out, cnt
