"""Token data pipeline for the large-architecture training path.

Offline container => synthetic corpus: a mixture of Zipfian unigram draws
and repeated n-gram motifs (so the LM loss actually decreases), sharded
per Tol-FL data group with disjoint motif inventories (the federated
non-IID layout at datacenter scale).  The pipeline yields host numpy
batches; ``shard_batch`` places them against the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.sharding import logical as L


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_groups: int = 1          # Tol-FL data groups (non-IID shards)
    zipf_a: float = 1.2
    n_motifs: int = 64
    motif_len: int = 16

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 50000)
        self.motifs = rng.integers(
            0, v, size=(self.num_groups, self.n_motifs, self.motif_len),
            dtype=np.int64)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_a)
        self.probs = p / p.sum()
        self.v = v

    def _sample_doc(self, rng, group: int) -> np.ndarray:
        out = rng.choice(self.v, size=self.seq_len + 1, p=self.probs)
        # splice in group-specific motifs (~30% of positions)
        n_splice = (self.seq_len // self.motif_len) // 3
        for _ in range(n_splice):
            m = self.motifs[group, rng.integers(self.n_motifs)]
            pos = rng.integers(0, self.seq_len + 1 - self.motif_len)
            out[pos:pos + self.motif_len] = m
        return out

    def batches(self, num_steps: Optional[int] = None
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.seed + 1)
        step = 0
        per_group = self.global_batch // self.num_groups
        while num_steps is None or step < num_steps:
            docs = np.stack([
                self._sample_doc(rng, g)
                for g in range(self.num_groups) for _ in range(per_group)])
            yield {"tokens": docs[:, :-1].astype(np.int32),
                   "labels": docs[:, 1:].astype(np.int32)}
            step += 1


def shard_batch(batch: Dict[str, np.ndarray], mesh) -> Dict[str, jax.Array]:
    """Place a host batch against the mesh (batch dim over pod+data)."""
    out = {}
    for k, v in batch.items():
        axes = ("batch",) + (None,) * (v.ndim - 1)
        out[k] = jax.device_put(v, L.sharding_for(mesh, axes, v.shape))
    return out
