"""Synthetic stand-ins for the paper's reference datasets (Table VII).

The container is offline, so FMNIST / CIFAR-10 / CIFAR-100 cannot be
downloaded.  We generate class-structured datasets with the same sample
shapes and class counts.  Each class occupies a *disjoint set of 2-D
Fourier components* (its "texture signature"); samples draw random
amplitudes/phases on their class's components plus pixel noise.  An
autoencoder trained on a subset of classes learns (a basis of) their
joint frequency subspace, so held-out classes — which live on unseen
frequencies — reconstruct badly.  This mirrors the property the paper's
experiments exercise (class-structured anomaly detection); AUROC
magnitudes will not numerically match the paper's tables (different
data); orderings between training schemes — the paper's actual claims —
are reproduced.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


@dataclass(frozen=True)
class RefSpec:
    name: str
    shape: Tuple[int, ...]
    n_classes: int
    samples_per_class: int


SPECS: Dict[str, RefSpec] = {
    "fmnist": RefSpec("fmnist", (28, 28), 10, 700),
    "cifar10": RefSpec("cifar10", (32, 32, 3), 10, 700),
    "cifar100": RefSpec("cifar100", (32, 32, 3), 100, 70),
    # paper uses 7000/class (FMNIST, CIFAR-10) and 500/class (CIFAR-100);
    # we scale down 10x for CPU-budget experiment runtime, preserving the
    # class structure.  Override samples_per_class to match exactly.
}

COMPONENTS_PER_CLASS = 6


def _class_basis(rng, h: int, w: int, n_comp: int, pool: np.ndarray
                 ) -> np.ndarray:
    """(n_comp, h, w) cosine basis images on class-specific frequencies."""
    idx = rng.choice(len(pool), n_comp, replace=False)
    basis = []
    for kx, ky in pool[idx]:
        ph = rng.uniform(0, 2 * np.pi)
        basis.append(np.cos(2 * np.pi * (kx * np.arange(h)[:, None] / h
                                         + ky * np.arange(w)[None, :] / w)
                            + ph))
    return np.stack(basis)


def generate(name: str, seed: int = 0, samples_per_class: int = 0
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X (N, prod(shape)) float32 standardised, y)."""
    spec = SPECS[name]
    n_per = samples_per_class or spec.samples_per_class
    rng = np.random.default_rng(seed + hash(name) % 65536)
    ch = spec.shape[2] if len(spec.shape) == 3 else 1
    h, w = spec.shape[0], spec.shape[1]
    # global frequency pool, partitioned DISJOINTLY over classes (large
    # enough that even 100 classes get non-overlapping signatures)
    freqs = np.array([(kx, ky) for kx in range(1, 16) for ky in range(1, 16)])
    rng.shuffle(freqs)
    per_class = max(len(freqs) // spec.n_classes, 1)
    xs, ys = [], []
    for c in range(spec.n_classes):
        lo = (c * per_class) % len(freqs)
        pool = freqs[lo:lo + per_class] if per_class > 1 else \
            freqs[[c % len(freqs)]]
        basis = np.stack([_class_basis(rng, h, w,
                                       min(COMPONENTS_PER_CLASS, len(pool)),
                                       pool)
                          for _ in range(ch)], -1)   # (n_comp, h, w, ch)
        n_comp = basis.shape[0]
        # class prototype: a fixed strong combination of the class's own
        # components (the "mean image" — what makes class c look like c)
        proto = np.tensordot(2.0 + rng.standard_normal(n_comp), basis,
                             axes=1)
        for_cls = []
        for _ in range(n_per):
            amps = 0.5 * rng.standard_normal(n_comp)
            img = proto + np.tensordot(amps, basis, axes=1)   # (h, w, ch)
            img = img * (1.0 + 0.1 * rng.standard_normal()) \
                + 0.1 * rng.standard_normal(img.shape)
            for_cls.append(img.ravel())
        xs.append(np.stack(for_cls).astype(np.float32))
        ys.append(np.full(n_per, c, np.int32))
    X = np.concatenate(xs, 0)
    y = np.concatenate(ys, 0)
    mu, sd = X.mean(0, keepdims=True), X.std(0, keepdims=True) + 1e-6
    return ((X - mu) / sd).astype(np.float32), y
