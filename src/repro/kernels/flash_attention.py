"""Flash attention Pallas TPU kernel.

Blocked online-softmax attention with explicit VMEM BlockSpecs.  TPU
adaptation of the classic GPU flash attention: instead of warp-level
softmax reductions, the kernel keeps a (q_block, kv_block) score tile
resident in VMEM, uses the MXU for the two contractions (tile sizes are
multiples of 128 on the contracting/lane dims), and carries the running
max / denominator in VMEM scratch across the kv grid dimension.  Causal
and sliding-window masks are applied from block-relative position ids;
fully-masked kv blocks are skipped via the grid (causal upper-triangle
blocks simply contribute zero — masked before exp).

Grid: (batch*kv_heads, q_blocks, kv_blocks); the kv dimension is the
innermost (sequential on TPU) so the scratch carries across it.

Validated in interpret mode against ``ref.attention_reference``
(tests/test_kernels.py sweeps shapes & dtypes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: Optional[int], q_block: int,
                  kv_block: int, num_kv_blocks: int, scale: float):
    kj = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                       # (qb, G, D)  G = heads per kv head
    k = k_ref[0]                       # (kb, D)
    v = v_ref[0]                       # (kb, D)
    qb, G, D = q.shape
    kb = k.shape[0]
    # scores: (qb*G, kb) via MXU
    s = jax.lax.dot_general(
        q.reshape(qb * G, D).astype(jnp.float32),
        k.astype(jnp.float32).T,
        (((1,), (0,)), ((), ()))) * scale
    s = s.reshape(qb, G, kb)
    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (qb, G, kb), 0)
    k_pos = kj * kv_block + jax.lax.broadcasted_iota(jnp.int32, (qb, G, kb), 2)
    d = q_pos - k_pos
    ok = jnp.ones(d.shape, jnp.bool_)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                # (qb, G)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    # fully-masked rows: m stays NEG_INF; exp(NEG_INF - NEG_INF)=1 would
    # pollute — zero those
    p = jnp.where((m_new <= NEG_INF / 2)[..., None], 0.0, p)
    alpha = jnp.exp(m_prev - m_new)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p.reshape(qb * G, kb), v.astype(jnp.float32),
        (((1,), (0,)), ((), ()))).reshape(qb, G, D)
    acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]  # noqa: E741 -- canonical FA accumulator name
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)[..., None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    q_block: int = 256, kv_block: int = 256,
                    interpret: bool = True) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, Sk, KVH, D); H % KVH == 0.

    interpret=True executes the kernel body on CPU (this container);
    interpret=False is the TPU target path.
    """
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    assert Sq % q_block == 0 and Sk % kv_block == 0
    nq, nk = Sq // q_block, Sk // kv_block
    scale = 1.0 / (D ** 0.5)

    # layout: (B*KVH, Sq, G, D) so one grid row sees one kv head
    qr = q.reshape(B, Sq, KVH, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B * KVH, Sq, G, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KVH, Sk, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KVH, Sk, D)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, q_block=q_block,
        kv_block=kv_block, num_kv_blocks=nk, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(B * KVH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, G, D), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, kv_block, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, G, D), lambda b, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KVH, Sq, G, D), q.dtype),
        scratch_shapes=[
            _scratch((q_block, G), jnp.float32),
            _scratch((q_block, G), jnp.float32),
            _scratch((q_block, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, KVH, Sq, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, Sq, H, D)


def _scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    try:
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        import jax.experimental.pallas as pl_
        return pl_.MemorySpace.ANY(shape, dtype)  # type: ignore
