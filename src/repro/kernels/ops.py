"""Jit'd public wrappers for the Pallas kernels.

One entry point per kernel with a ``backend`` switch:
  * "pallas"     — pl.pallas_call, interpret=True on CPU (this container),
                   interpret=False on real TPU.
  * "xla"        — the pure-jnp reference path (what the dry-run lowers;
                   also the oracle used by the parity tests).

The model code takes ``use_pallas`` flags and routes through these.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import rglru_scan as _rg
from repro.kernels import rwkv6_scan as _rw
from repro.kernels import tolfl_combine as _tc


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def attention(q, k, v, causal: bool = True, window: Optional[int] = None,
              backend: str = "pallas") -> jax.Array:
    if backend == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   interpret=_interpret())
    return _ref.attention_reference(q, k, v, causal=causal, window=window)


def rwkv6(r, k, v, w, u, state0, backend: str = "pallas"
          ) -> Tuple[jax.Array, jax.Array]:
    if backend == "pallas":
        return _rw.rwkv6_scan(r, k, v, w, u, state0,
                              interpret=_interpret())
    return _ref.rwkv6_reference(r, k, v, w, u, state0)


def rglru(a_t, b_t, h0=None, backend: str = "pallas") -> jax.Array:
    if backend == "pallas":
        return _rg.rglru_scan(a_t, b_t, h0, interpret=_interpret())
    if h0 is not None:
        b_t = b_t.at[:, 0, :].add(a_t[:, 0, :] * h0)
    return _ref.rglru_reference(a_t, b_t)


def tolfl_combine(gs, ns, backend: str = "pallas") -> jax.Array:
    if backend == "pallas":
        return _tc.tolfl_combine(gs, ns, interpret=_interpret())
    return _ref.tolfl_combine_reference(gs, ns)
