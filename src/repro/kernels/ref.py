"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def attention_reference(q, k, v, causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """Naive O(S^2) attention.  q (B,Sq,H,D); k,v (B,Sk,KVH,D)."""
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    dpos = jnp.arange(Sq)[:, None] - jnp.arange(Sk)[None, :]
    ok = jnp.ones(dpos.shape, bool)
    if causal:
        ok &= dpos >= 0
    if window is not None:
        ok &= dpos < window
    s = jnp.where(ok[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def rwkv6_reference(r, k, v, w, u, state0) -> Tuple[jax.Array, jax.Array]:
    """Sequential WKV6.  r,k,v,w: (B,S,H,N) f32; u: (H,N); state0: (B,H,N,N).

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S + k v^T."""
    def step(S, rkvw):
        rt, kt, vt, wt = rkvw
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhn,bhnm->bhm", rt, S + u[None, :, :, None] * kv)
        return wt[..., :, None] * S + kv, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), state


def rglru_reference(a_t, b_t, h0=None) -> jax.Array:
    """Sequential diagonal linear recurrence h_t = a_t h_{t-1} + b_t.
    a_t, b_t: (B,S,W) f32; h0: (B,W) or None."""
    B, S, W = a_t.shape
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0

    def step(h, ab):
        a, b = ab
        h = a * h + b
        return h, h

    _, hs = jax.lax.scan(step, h, (jnp.moveaxis(a_t, 1, 0),
                                   jnp.moveaxis(b_t, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def tolfl_combine_reference(gs, ns) -> jax.Array:
    """Streaming weighted mean over the leading axis of a stacked gradient
    block.  gs: (K, ...) f32; ns: (K,) f32.  Equals the direct weighted
    mean (the paper's k-invariance)."""
    K = gs.shape[0]
    n = jnp.zeros(())
    g = jnp.zeros_like(gs[0])
    for i in range(K):
        n_new = n + ns[i]
        r = jnp.where(n_new > 0, ns[i] / jnp.maximum(n_new, 1e-30), 0.0)
        g = (1 - r) * g + r * gs[i]
        n = n_new
    return g
