"""RG-LRU diagonal linear recurrence as a Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t, purely element-wise over the channel dim — the
kernel blocks channels across the grid (embarrassingly parallel) and steps
time inside with a ``fori_loop``, carrying h in a VMEM scratch vector
across time blocks.  This is the Pallas counterpart of the
``associative_scan`` jnp path: the scan does O(log S) depth with O(S)
memory traffic multiplier; the kernel does one sequential sweep with every
operand touched exactly once — the classic latency-vs-traffic trade the
perf log discusses.

Validated against ``ref.rglru_reference`` in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import _scratch


def _lru_kernel(a_ref, b_ref, h0_ref, h_ref, h_scr, *, t_block: int):
    tj = pl.program_id(1)

    @pl.when(tj == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    a = a_ref[0]          # (T, Wb)
    b = b_ref[0]

    def step(t, h):
        at = jax.lax.dynamic_slice_in_dim(a, t, 1, 0)[0]
        bt = jax.lax.dynamic_slice_in_dim(b, t, 1, 0)[0]
        h = at * h + bt
        h_ref[0, t, :] = h
        return h

    h_scr[...] = jax.lax.fori_loop(0, t_block, step, h_scr[...])


@functools.partial(jax.jit, static_argnames=("t_block", "w_block",
                                             "interpret"))
def rglru_scan(a_t: jax.Array, b_t: jax.Array,
               h0: Optional[jax.Array] = None, t_block: int = 128,
               w_block: int = 512, interpret: bool = True) -> jax.Array:
    """a_t, b_t: (B, S, W) f32; h0: (B, W) or None.  Returns h (B, S, W)."""
    B, S, W = a_t.shape
    t_block = min(t_block, S)
    w_block = min(w_block, W)
    assert S % t_block == 0 and W % w_block == 0
    nt, nw = S // t_block, W // w_block
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)

    kernel = functools.partial(_lru_kernel, t_block=t_block)
    h = pl.pallas_call(
        kernel,
        grid=(B * nw, nt),
        in_specs=[
            pl.BlockSpec((1, t_block, w_block),
                         lambda bw, t: (bw // nw, t, bw % nw)),
            pl.BlockSpec((1, t_block, w_block),
                         lambda bw, t: (bw // nw, t, bw % nw)),
            pl.BlockSpec((1, w_block), lambda bw, t: (bw // nw, bw % nw)),
        ],
        out_specs=pl.BlockSpec((1, t_block, w_block),
                               lambda bw, t: (bw // nw, t, bw % nw)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[_scratch((w_block,), jnp.float32)],
        interpret=interpret,
    )(a_t, b_t, h0)
    return h
