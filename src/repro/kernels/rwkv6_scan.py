"""RWKV6 (Finch) WKV recurrence as a Pallas TPU kernel.

TPU adaptation of the CUDA WKV kernel: the GPU version assigns one thread
per channel and shuffles within warps; on TPU we instead keep the
(N x N) per-head state resident in VMEM scratch and step time inside the
kernel with a ``fori_loop`` of VPU (element-wise / outer-product) ops —
no MXU needed, the recurrence is rank-1 per step.  The grid walks
(batch*heads, time-blocks) with time innermost-sequential so the state
scratch carries across blocks; r/k/v/w stream through VMEM one
(time_block, N) tile at a time.

Validated against ``ref.rwkv6_reference`` in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import _scratch


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                s_scr, *, t_block: int, num_t_blocks: int):
    tj = pl.program_id(1)

    @pl.when(tj == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    r = r_ref[0]          # (T, N)
    k = k_ref[0]
    v = v_ref[0]
    w = w_ref[0]
    u = u_ref[0]          # (N,)

    def step(t, S):
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)[0]     # (N,)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)[0]
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)[0]
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)[0]
        kv = kt[:, None] * vt[None, :]                       # (N, N)
        y = jnp.sum(rt[:, None] * (S + u[:, None] * kv), axis=0)
        y_ref[0, t, :] = y
        return wt[:, None] * S + kv

    S = jax.lax.fori_loop(0, t_block, step, s_scr[...])
    s_scr[...] = S

    @pl.when(tj == num_t_blocks - 1)
    def _done():
        sout_ref[0] = S


@functools.partial(jax.jit, static_argnames=("t_block", "interpret"))
def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, state0: jax.Array, t_block: int = 64,
               interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B,S,H,N) f32; u: (H,N); state0: (B,H,N,N).

    Returns (y (B,S,H,N), final state (B,H,N,N))."""
    B, S, H, N = r.shape
    t_block = min(t_block, S)
    assert S % t_block == 0
    nt = S // t_block
    # (B*H, S, N) layout so one grid row owns one head's state
    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    rr, kk, vv, ww = map(to_bh, (r, k, v, w))
    uu = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N)
    s0 = state0.reshape(B * H, N, N).astype(jnp.float32)

    kernel = functools.partial(_wkv_kernel, t_block=t_block,
                               num_t_blocks=nt)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(B * H, nt),
        in_specs=[
            pl.BlockSpec((1, t_block, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, t_block, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, t_block, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, t_block, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, N), lambda b, t: (b, 0)),
            pl.BlockSpec((1, N, N), lambda b, t: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t_block, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, N, N), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, N), jnp.float32),
            jax.ShapeDtypeStruct((B * H, N, N), jnp.float32),
        ],
        scratch_shapes=[_scratch((N, N), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu, s0)
    y = y.reshape(B, H, S, N).transpose(0, 2, 1, 3)
    return y, s_out.reshape(B, H, N, N)
