"""Tol-FL streaming weighted-mean combine as a Pallas TPU kernel.

The paper's core arithmetic (Algorithm 1/2): fold k cluster gradients into
a running sample-weighted mean.  On the cluster-head chip this is a pure
bandwidth op over the flattened gradient; the fused kernel streams one
(k, block) tile of the stacked gradients through VMEM and performs the
whole k-step recurrence per block — gradients are read exactly once and
no (k x P) intermediate or k separate elementwise kernels exist (the
XLA fallback materialises the scan carries).  Weights live in SMEM-like
small blocks.

Validated against ``ref.tolfl_combine_reference`` in interpret mode; the
k-invariance property (streaming == direct weighted mean) is
hypothesis-tested in tests/test_tolfl_invariance.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(g_ref, n_ref, o_ref):
    g = g_ref[...]                 # (k, block) f32
    n = n_ref[...]                 # (k,) f32 (whole vector, replicated)
    k = g.shape[0]

    def step(i, carry):
        acc, tot = carry
        ni = n[i]
        tot_new = tot + ni
        r = jnp.where(tot_new > 0, ni / jnp.maximum(tot_new, 1e-30), 0.0)
        acc = (1.0 - r) * acc + r * g[i]
        return acc, tot_new

    acc, _ = jax.lax.fori_loop(
        0, k, step, (jnp.zeros_like(g[0]), jnp.zeros((), jnp.float32)))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def tolfl_combine(gs: jax.Array, ns: jax.Array, block: int = 4096,
                  interpret: bool = True) -> jax.Array:
    """gs: (k, P) stacked flattened cluster gradients (f32);
    ns: (k,) sample counts.  Returns the Tol-FL combined gradient (P,)."""
    k, P = gs.shape
    block = min(block, P)
    pad = (-P) % block
    if pad:
        gs = jnp.pad(gs, ((0, 0), (0, pad)))
    nb = gs.shape[1] // block
    out = pl.pallas_call(
        _combine_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((k, block), lambda j: (0, j)),
            pl.BlockSpec((k,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((gs.shape[1],), jnp.float32),
        interpret=interpret,
    )(gs.astype(jnp.float32), ns.astype(jnp.float32))
    return out[:P]


def tolfl_combine_tree(gs_tree, ns, interpret: bool = True):
    """Apply the fused combine leaf-wise over a stacked gradient pytree."""
    def leaf(g):
        k = g.shape[0]
        flat = g.reshape(k, -1).astype(jnp.float32)
        return tolfl_combine(flat, ns, interpret=interpret).reshape(
            g.shape[1:]).astype(g.dtype)
    return jax.tree.map(leaf, gs_tree)
