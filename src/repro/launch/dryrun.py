import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks
# the device count on first backend initialisation (see brief, step 0).

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) combination, lower + compile the
appropriate step (train_step for train_4k, prefill for prefill_32k,
serve/decode step for decode_32k & long_500k) against the production mesh:
16x16 single-pod and 2x16x16 multi-pod, with ShapeDtypeStruct inputs (no
allocation).  Prints memory_analysis / cost_analysis and writes a JSON
record (incl. HLO collective-bytes breakdown) per combo for the roofline
bench.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch qwen3-8b] [--shape train_4k] [--mesh single|multi|both]
        [--schedule ring|psum|auto] [--out results/dryrun]
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.analysis import costmodel as CM
from repro.analysis.roofline import Roofline as R_Roofline
from repro.analysis.roofline import build_roofline, model_flops_for
from repro.configs import ARCHS, ASSIGNED, INPUT_SHAPES, OptimizerConfig, \
    TolFLConfig
from repro.core import distributed as D
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.serving.decode import decode_step, prefill
from repro.sharding import logical as L

# archs that must store params FSDP-sharded over the data axis (100B+ or
# >16GB/chip replicated; DESIGN.md section 2) -> weighted-psum schedule
FSDP_ARCHS = {"llama4-maverick-400b-a17b", "llama4-scout-17b-a16e",
              "internvl2-26b"}
# optimizer-moment dtype override for the giants (memory budget)
BF16_STATE_ARCHS = {"llama4-maverick-400b-a17b", "llama4-scout-17b-a16e"}

# long_500k is skipped for pure full-attention archs (brief; DESIGN.md
# section 4): whisper (enc-dec full attn) and internvl2 (full-attn VLM).
LONG_SKIP = {"whisper-large-v3", "internvl2-26b"}


def pick_schedule(arch: str, requested: str) -> str:
    if requested == "ring":
        return "tolfl_ring"
    if requested == "psum":
        return "tolfl_psum"
    return "tolfl_psum" if arch in FSDP_ARCHS else "tolfl_ring"


def rules_for_arch(arch: str) -> dict:
    return L.rules_for("fsdp" if arch in FSDP_ARCHS else "replicated_data")


def dryrun_one(arch: str, shape_name: str, mesh, mesh_name: str,
               schedule: str = "auto", verbose: bool = True,
               clusters: int = 4, grad_sync_dtype=None, microbatches: int = 1,
               param_cast_dtype=None) -> dict:
    cfg = ARCHS[arch]
    shape = INPUT_SHAPES[shape_name]
    rules = rules_for_arch(arch)
    chips = mesh.devices.size
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": chips, "mode": shape.mode}

    if shape_name == "long_500k" and arch in LONG_SKIP:
        rec["status"] = "skipped"
        rec["reason"] = "pure full-attention arch (DESIGN.md section 4)"
        return rec

    t0 = time.time()
    with L.activate_mesh(mesh, rules):
        if shape.mode == "train":
            sched = pick_schedule(arch, schedule)
            rec["schedule"] = sched
            rec["perf_knobs"] = {"clusters": clusters,
                                 "grad_sync_dtype": grad_sync_dtype,
                                 "microbatches": microbatches,
                                 "param_cast_dtype": param_cast_dtype}
            tolfl = TolFLConfig(num_clusters=clusters, schedule=sched,
                                grad_sync_dtype=grad_sync_dtype,
                                microbatches=microbatches,
                                param_cast_dtype=param_cast_dtype)
            ocfg = OptimizerConfig()
            sdt = "bfloat16" if arch in BF16_STATE_ARCHS else None
            step = D.make_train_step(cfg, tolfl, ocfg, mesh,
                                     state_dtype=sdt)
            state = SP.state_specs(cfg, ocfg, mesh, rules)
            batch = SP.train_batch_specs(cfg, shape, mesh, rules)
            alive = SP.alive_spec(mesh)
            lowered = jax.jit(step).lower(state, batch, alive)
        elif shape.mode == "prefill":
            batch = SP.prefill_specs(cfg, shape, mesh, rules)
            params = SP.params_specs(cfg, mesh, rules)
            lowered = jax.jit(
                lambda p, b: prefill(p, cfg, b)).lower(params, batch)
        else:  # decode
            long_ctx = shape_name == "long_500k"
            dspec = SP.decode_specs(cfg, shape, mesh, rules,
                                    long_context=long_ctx)
            params = SP.params_specs(cfg, mesh, rules)
            lowered = jax.jit(
                lambda p, t, c, pos: decode_step(p, cfg, t, c, pos)).lower(
                    params, dspec["tokens"], dspec["cache"],
                    dspec["position"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_bytes = getattr(mem, "temp_size_in_bytes", None)
        mem_str = str(mem)
    except Exception:
        mem_bytes, mem_str = None, "n/a"
    hlo = compiled.as_text()
    # HLO-derived record (raw; scan bodies counted once — see costmodel.py)
    rl_hlo = build_roofline(arch, shape_name, mesh_name, chips, cost, hlo,
                            model_flops_for(cfg, shape, shape.mode),
                            memory_per_device=mem_bytes)
    # analytic roofline (used for the section-Roofline table)
    sizes = L.mesh_axis_sizes(mesh)
    cb = CM.step_costs(
        cfg, shape, chips, model_shards=sizes.get("model", 1),
        data_shards=sizes.get("data", 1),
        schedule=rec.get("schedule", "tolfl_ring"),
        num_clusters=clusters, pods=sizes.get("pod", 1),
        long_ctx=(shape_name == "long_500k"), fsdp=arch in FSDP_ARCHS,
        grad_sync_dtype=grad_sync_dtype, microbatches=microbatches,
        param_cast_dtype=param_cast_dtype)
    rl = R_Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_chip=cb.flops, bytes_per_chip=cb.hbm_bytes,
        coll_bytes_per_chip=cb.coll_bytes,
        coll_breakdown=rl_hlo.coll_breakdown,
        model_flops=model_flops_for(cfg, shape, shape.mode),
        memory_per_device=mem_bytes)
    rec.update(status="ok", t_lower=round(t_lower, 1),
               t_compile=round(t_compile, 1), roofline=rl.to_dict(),
               roofline_hlo=rl_hlo.to_dict(), memory_analysis=mem_str,
               cost_flops=cost.get("flops"),
               cost_bytes=cost.get("bytes accessed"))
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] OK "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s")
        print(f"  memory_analysis: {mem_str}")
        print(f"  cost_analysis(raw HLO): flops={cost.get('flops'):.3e} "
              f"bytes={cost.get('bytes accessed'):.3e}")
        print(f"  HLO collectives: "
              f"{ {k: v for k, v in rl_hlo.coll_breakdown.items() if v} }")
        print(f"  roofline(analytic): comp={rl.t_compute:.3e}s "
              f"mem={rl.t_memory:.3e}s coll={rl.t_collective:.3e}s "
              f"-> {rl.bottleneck}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ASSIGNED),
                    help="default: all assigned archs")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--schedule", default="auto",
                    choices=["auto", "ring", "psum"])
    ap.add_argument("--out", default="results/dryrun")
    # perf-iteration knobs (EXPERIMENTS.md section Perf)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--grad-dtype", default=None,
                    choices=[None, "bfloat16"], dest="grad_dtype")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--param-cast", default=None,
                    choices=[None, "bfloat16"], dest="param_cast")
    ap.add_argument("--tag", default="",
                    help="suffix for the output JSON (perf variants)")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("2pod16x16", make_production_mesh(multi_pod=True)))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}__{mesh_name}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = dryrun_one(arch, shape, mesh, mesh_name,
                                     args.schedule,
                                     clusters=args.clusters,
                                     grad_sync_dtype=args.grad_dtype,
                                     microbatches=args.microbatches,
                                     param_cast_dtype=args.param_cast)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": repr(e)}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
    print(f"dry-run complete; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
