"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device state.  Single pod: 16x16 = 256 chips
(data x model).  Multi-pod: 2x16x16 = 512 chips (pod x data x model); the
"pod" axis is the outer Tol-FL SBT ring (DESIGN.md section 2).
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_from_config(cfg: MeshConfig):
    if cfg.multi_pod:
        return jax.make_mesh((cfg.pods, cfg.data, cfg.model),
                             ("pod", "data", "model"))
    return jax.make_mesh((cfg.data, cfg.model), ("data", "model"))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices the host actually has (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))
