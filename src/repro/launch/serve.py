"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``.

Runs real batched prefill + decode on the host mesh (reduced configs on
CPU; the full-size path is what the decode_32k / long_500k dry-run
lowers).  Reports prefill latency and per-token decode latency — the
serving-side counterpart of launch/train.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.serving.decode import decode_step, pad_cache, prefill
from repro.serving.inputs import synthetic_batch
from repro.sharding import logical as L


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--greedy", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for the synthetic prompt batch "
                         "(equal seeds reproduce latency inputs exactly)")
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(data=1, model=1)
    rules = L.rules_for("replicated_data")

    with L.activate_mesh(mesh, rules):
        params, _ = T.init_params(jax.random.PRNGKey(0), cfg)
        batch = synthetic_batch(cfg, args.batch, args.prompt,
                                seed=args.seed)

        print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
              f"batch={args.batch} prompt={args.prompt} gen={args.tokens}")
        t0 = time.time()
        logits, cache = jax.jit(
            lambda p, b: prefill(p, cfg, b))(params, batch)
        logits.block_until_ready()
        print(f"prefill: {(time.time() - t0) * 1000:.1f} ms "
              f"({args.batch * args.prompt} tokens)")

        cache = pad_cache(cache, cfg, prompt_len=args.prompt,
                          target_len=args.prompt + args.tokens)
        step = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
        base = args.prompt
        if cfg.frontend.kind == "vision":
            base += batch["prefix"].shape[1]
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None] \
            .astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for i in range(args.tokens - 1):
            logits, cache = step(params, tok, cache, jnp.int32(base + i))
            tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None] \
                .astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(out[-1])
        dt = time.time() - t0
        print(f"decode: {dt / max(args.tokens - 1, 1) * 1000:.1f} ms/token "
              f"({args.tokens - 1} steps)")
        gen = jnp.concatenate(out, axis=1)
        print(f"sample[0]: {gen[0, :12].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
