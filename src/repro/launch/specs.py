"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) combo.

No device allocation — the dry-run lowers against these.  Shardings are
attached to the structs so ``jit(...).lower()`` sees the production layout.

Modality carve-out (brief): for audio/vlm the frontend is a stub —
``input_specs`` hands precomputed frame/patch embeddings of the right
shape instead of raw audio/pixels.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import InputShape, ModelConfig, OptimizerConfig
from repro.core import distributed as D
from repro.serving.decode import cache_logical_axes, cache_shape
from repro.sharding import logical as L

# vlm: number of (stubbed) patch-embedding prefix tokens
VLM_PREFIX = 256


def _sds(shape, dtype, mesh, axes, rules):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=L.sharding_for(mesh, axes, shape, rules))


def train_batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                      rules: dict) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    tok_axes = ("batch", None)
    if cfg.frontend.kind == "vision":
        P_ = VLM_PREFIX
        batch["prefix"] = _sds((B, P_, cfg.d_model), jnp.dtype(cfg.dtype),
                               mesh, ("batch", None, None), rules)
        batch["tokens"] = _sds((B, S - P_), jnp.int32, mesh, tok_axes, rules)
        batch["labels"] = _sds((B, S - P_), jnp.int32, mesh, tok_axes, rules)
    elif cfg.is_encdec:
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                               jnp.dtype(cfg.dtype), mesh,
                               ("batch", None, None), rules)
        batch["tokens"] = _sds((B, S), jnp.int32, mesh, tok_axes, rules)
        batch["labels"] = _sds((B, S), jnp.int32, mesh, tok_axes, rules)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32, mesh, tok_axes, rules)
        batch["labels"] = _sds((B, S), jnp.int32, mesh, tok_axes, rules)
    return batch


def state_specs(cfg: ModelConfig, ocfg: OptimizerConfig, mesh: Mesh,
                rules: dict):
    shapes = jax.eval_shape(
        lambda k: D.init_state(k, cfg, ocfg), jax.random.PRNGKey(0))
    shardings = D.state_shardings(mesh, cfg, ocfg, rules)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def alive_spec(mesh: Mesh) -> jax.ShapeDtypeStruct:
    g = D.num_groups(mesh)
    return jax.ShapeDtypeStruct((g,), jnp.float32)


def decode_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                 rules: dict, long_context: bool = False) -> Dict[str, Any]:
    B = shape.global_batch
    cs = cache_shape(cfg, B, shape.seq_len, long_context)
    axes = cache_logical_axes(cs)
    cache = jax.tree.map(
        lambda s, a: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=L.sharding_for(mesh, a, s.shape, rules)),
        cs, axes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    tokens = _sds((B, 1), jnp.int32, mesh, ("batch", None), rules)
    position = jax.ShapeDtypeStruct((), jnp.int32)
    return {"tokens": tokens, "cache": cache, "position": position}


def prefill_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                  rules: dict) -> Dict[str, Any]:
    return train_batch_specs(cfg, shape, mesh, rules)


def params_specs(cfg: ModelConfig, mesh: Mesh, rules: dict):
    from repro.models import transformer as T
    shapes = jax.eval_shape(
        lambda k: T.init_params(k, cfg)[0], jax.random.PRNGKey(0))
    axes = D.params_logical_axes(cfg)

    def mk(s, a):
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=L.sharding_for(mesh, a, s.shape,
                                                      rules))

    import jax.tree_util as jtu
    flat_s, treedef = jtu.tree_flatten(shapes)
    flat_a = treedef.flatten_up_to(axes)
    return jtu.tree_unflatten(treedef, [mk(s, a)
                                        for s, a in zip(flat_s, flat_a)])
