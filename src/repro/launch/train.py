"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Runs real steps on the host mesh (CPU container: a small data x model mesh
over however many devices exist; on TPU: the production mesh).  The same
code path the dry-run lowers — make_train_step (ring or psum schedule),
failure injection via the alive mask, checkpointing, metrics.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, OptimizerConfig, TolFLConfig
from repro.core import distributed as D
from repro.core.failure import FailureSpec, NO_FAILURE, alive_mask
from repro.core.topology import Topology
from repro.data.pipeline import TokenPipeline, shard_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.sharding import logical as L
from repro.training.checkpoint import CheckpointManager


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced config (CPU-runnable); --no-reduced for full")
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--schedule", default="tolfl_ring",
                    choices=["tolfl_ring", "tolfl_psum", "fedavg",
                             "sbt_ring"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fail-epoch", type=int, default=-1,
                    help="inject a failure at this step (-1: none)")
    ap.add_argument("--fail-kind", default="server",
                    choices=["server", "client"])
    ap.add_argument("--data-axis", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (dry-run scale)")
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(data=args.data_axis, model=1))
    sizes = L.mesh_axis_sizes(mesh)
    G = sizes.get("pod", 1) * sizes.get("data", 1)
    clusters = min(args.clusters, G)
    if args.schedule == "sbt_ring":
        clusters = G
    print(f"mesh={sizes} groups={G} clusters={clusters} arch={cfg.name} "
          f"params={cfg.param_count()/1e6:.1f}M schedule={args.schedule}")

    tolfl = TolFLConfig(num_clusters=clusters, schedule=args.schedule)
    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=5,
                           total_steps=args.steps)
    topo = Topology(G, clusters)
    failure = (NO_FAILURE if args.fail_epoch < 0 else
               FailureSpec(epoch=args.fail_epoch, kind=args.fail_kind))

    rules = L.rules_for("replicated_data")
    with L.activate_mesh(mesh, rules):
        step_fn = D.make_train_step(cfg, tolfl, ocfg, mesh)
        state = D.init_state(jax.random.PRNGKey(0), cfg, ocfg)
        jit_step = jax.jit(step_fn, donate_argnums=0)

        pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                             global_batch=args.batch, num_groups=G)
        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        t0 = time.time()
        for step, host_batch in enumerate(pipe.batches(args.steps)):
            alive = np.asarray(alive_mask(failure, topo, jnp.int32(step)))
            batch = shard_batch(host_batch, mesh)
            state, metrics = jit_step(state, batch, jnp.asarray(alive))
            loss = float(metrics["loss"])
            extras = ""
            if "n_effective" in metrics:
                extras = f" n_eff={float(metrics['n_effective']):.0f}"
            print(f"step {step:4d} loss {loss:8.4f}{extras} "
                  f"({time.time()-t0:5.1f}s)")
            if ckpt and (step + 1) % 10 == 0:
                ckpt.save({"params": state["params"],
                           "step": state["step"]}, step + 1)
        print(f"done: {args.steps} steps in {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
