"""Attention: GQA projections, RoPE, memory-efficient blocked attention.

The training/prefill path is a two-level ``lax.scan`` (outer over query
blocks, inner over key/value blocks) computing online-softmax — the pure-XLA
analogue of flash attention, keeping peak memory at
O(q_block x kv_block x heads) instead of O(seq^2).  The Pallas kernel in
``repro.kernels.flash_attention`` implements the same contraction with
explicit VMEM BlockSpecs; ``use_pallas=True`` routes through it.

Masks: causal, sliding-window (RecurrentGemma local attention / the
long-context dense variant), or full (whisper encoder & cross-attention).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models import params as P
from repro.sharding import logical as L

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables: positions (...,) -> (..., head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2)."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------
def attn_init(key, d_model: int, cfg: AttentionConfig, dtype: str
              ) -> Tuple[P.Params, P.Axes]:
    ks = jax.random.split(key, 6)
    q_dim = cfg.num_heads * cfg.head_dim
    kv_dim = cfg.num_kv_heads * cfg.head_dim
    p, a = {}, {}
    p["q"], a["q"] = P.dense_init(ks[0], d_model, q_dim, "embed", "heads",
                                  dtype, bias=cfg.qkv_bias)
    p["k"], a["k"] = P.dense_init(ks[1], d_model, kv_dim, "embed", "kv_heads",
                                  dtype, bias=cfg.qkv_bias)
    p["v"], a["v"] = P.dense_init(ks[2], d_model, kv_dim, "embed", "kv_heads",
                                  dtype, bias=cfg.qkv_bias)
    p["o"], a["o"] = P.dense_init(ks[3], q_dim, d_model, "heads", "embed", dtype)
    if cfg.qk_norm:
        p["q_norm"], a["q_norm"] = {"scale": jnp.ones((cfg.head_dim,))}, {"scale": ("head_dim",)}
        p["k_norm"], a["k_norm"] = {"scale": jnp.ones((cfg.head_dim,))}, {"scale": ("head_dim",)}
    return p, a


def _qk_norm(p, x, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
            ).astype(x.dtype)


def project_qkv(p: P.Params, x: jax.Array, cfg: AttentionConfig,
                positions: jax.Array, norm_eps: float = 1e-6,
                compute_dtype=None):
    """x: (B,S,E) -> q (B,S,H,D), k/v (B,S,KVH,D) with rope + optional qk-norm."""
    B, S, _ = x.shape
    q = P.dense_apply(p["q"], x, compute_dtype).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = P.dense_apply(p["k"], x, compute_dtype).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = P.dense_apply(p["v"], x, compute_dtype).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = _qk_norm(p["q_norm"], q, norm_eps)
        k = _qk_norm(p["k_norm"], k, norm_eps)
    if cfg.rope_theta > 0:
        cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = L.constrain(q, ("batch", "seq", "heads", None))
    k = L.constrain(k, ("batch", "seq", "kv_heads", None))
    v = L.constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (training / prefill)
# ---------------------------------------------------------------------------
def _block_mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(qb, kb) additive mask from absolute positions."""
    d = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(d.shape, dtype=bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF)


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, window: Optional[int] = None,
                      q_block: int = 512, kv_block: int = 512,
                      q_offset: int = 0) -> jax.Array:
    """Memory-efficient attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KVH, D) with H % KVH == 0.
    Returns (B, Sq, H, D).  Peak intermediate is (B, qb, H, kb).
    """
    from repro.models.transformer import divisor_block
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    q_block = divisor_block(Sq, q_block)
    kv_block = divisor_block(Sk, kv_block)
    nq, nk = Sq // q_block, Sk // kv_block
    scale = 1.0 / (D ** 0.5)

    # (nq, B, qb, KVH, G, D)
    qs = q.reshape(B, nq, q_block, KVH, G, D).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, kv_block, KVH, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_block, KVH, D).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)

        def kv_step(carry, kj_blk):
            kj, kblk, vblk = kj_blk
            acc, m, l = carry
            k_pos = kj * kv_block + jnp.arange(kv_block)
            # scores: (B, qb, KVH, G, kb)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = s + _block_mask(q_pos, k_pos, causal, window)[None, :, None, None, :]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, q_block, KVH, G, D), jnp.float32)
        m0 = jnp.full((B, q_block, KVH, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_block, KVH, G), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    # (nq, B, qb, KVH, G, D) -> (B, Sq, H, D)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, D)
    return out


def naive_attention(q, k, v, causal=True, window=None, q_offset=0):
    """O(S^2)-memory reference used by tests as the oracle."""
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k,
                   preferred_element_type=jnp.float32) / (D ** 0.5)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    s = s + _block_mask(q_pos, k_pos, causal, window)[None, :, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (one new token against a cache)
# ---------------------------------------------------------------------------
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     k_new: jax.Array, v_new: jax.Array,
                     cache_valid: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,1,H,D); caches: (B,Sc,KVH,D); new k/v: (B,1,KVH,D).

    The new token attends to every cached position plus itself.  The cache
    seq dim may be sharded (sequence-parallel flash-decoding): the softmax
    reduction over it is handled by the SPMD partitioner (all-reduce of
    max / sum-exp), which is exactly the flash-decoding combine.

    ``cache_valid``: (Sc,) bool mask — False for empty / out-of-window
    slots (see :func:`cache_slot_validity`)."""
    B, _, H, D = q.shape
    KVH = k_cache.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, D)
    scale = 1.0 / (D ** 0.5)
    s_c = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                     preferred_element_type=jnp.float32) * scale
    if cache_valid is not None:
        s_c = jnp.where(cache_valid[None, None, None, :], s_c, NEG_INF)
    s_n = jnp.einsum("bhgd,bkhd->bhgk", qg, k_new,
                     preferred_element_type=jnp.float32) * scale
    m = jnp.maximum(jnp.max(s_c, axis=-1, keepdims=True),
                    jnp.max(s_n, axis=-1, keepdims=True))
    p_c = jnp.exp(s_c - m)
    p_n = jnp.exp(s_n - m)
    l = jnp.sum(p_c, axis=-1, keepdims=True) + jnp.sum(  # noqa: E741
        p_n, axis=-1, keepdims=True)
    o = (jnp.einsum("bhgk,bkhd->bhgd", p_c.astype(v_cache.dtype), v_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhgk,bkhd->bhgd", p_n.astype(v_new.dtype), v_new,
                      preferred_element_type=jnp.float32))
    out = (o / jnp.maximum(l.astype(jnp.float32), 1e-30)).astype(q.dtype)
    return out.reshape(B, 1, H, D)


def attn_apply(p: P.Params, x: jax.Array, cfg: AttentionConfig,
               norm_eps: float = 1e-6, window: Optional[int] = None,
               causal: Optional[bool] = None, use_pallas: bool = False,
               q_block: int = 512, kv_block: int = 512,
               positions: Optional[jax.Array] = None) -> jax.Array:
    """Full self-attention block for train/prefill: x (B,S,E) -> (B,S,E)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    causal = cfg.causal if causal is None else causal
    window = cfg.sliding_window if window is None else window
    q, k, v = project_qkv(p, x, cfg, positions, norm_eps,
                          compute_dtype=x.dtype)
    if use_pallas:
        from repro.kernels import flash_attention as fa
        out = fa.flash_attention(q, k, v, causal=causal, window=window)
    else:
        out = blocked_attention(q, k, v, causal=causal, window=window,
                                q_block=q_block, kv_block=kv_block)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = P.dense_apply(p["o"], out, x.dtype)
    return L.constrain(out, ("batch", "seq", "embed"))


def cache_slot_validity(Sc: int, position: jax.Array,
                        window: Optional[int]) -> jax.Array:
    """(Sc,) bool: which circular-cache slots hold attendable positions.

    Ring invariant: slot i holds the largest absolute position p_i < position
    with p_i = i (mod Sc).  A slot is valid iff that position exists
    (p_i >= 0 — empty slots of a fresh or padded cache are excluded) and,
    for windowed layers, iff its distance is inside the window
    (position - p_i < window)."""
    idx = jnp.arange(Sc)
    pm1 = jnp.asarray(position, jnp.int32) - 1
    p_i = pm1 - jnp.mod(pm1 - idx, Sc)
    valid = p_i >= 0
    if window is not None:
        valid &= (jnp.asarray(position, jnp.int32) - p_i) < window
    return valid


def attn_decode(p: P.Params, x: jax.Array, cache: dict, cfg: AttentionConfig,
                position: jax.Array, norm_eps: float = 1e-6,
                window: Optional[int] = None) -> Tuple[jax.Array, dict]:
    """One-token decode: x (B,1,E), cache {'k','v': (B,Sc,KVH,D)}.

    The cache is circular (vLLM-style): the new k/v overwrite slot
    ``position % Sc`` via dynamic_update_slice — O(1) update for both the
    full-cache and sliding-window cases (for a window cache, Sc == window
    and the modulo implements the ring).  Empty or out-of-window slots are
    masked via :func:`cache_slot_validity`.
    """
    B, _, _ = x.shape
    positions = jnp.broadcast_to(jnp.asarray(position, jnp.int32), (B, 1))
    q, k_new, v_new = project_qkv(p, x, cfg, positions, norm_eps,
                                  compute_dtype=x.dtype)
    valid = cache_slot_validity(cache["k"].shape[1], position, window)
    out = decode_attention(q, cache["k"], cache["v"], k_new, v_new,
                           cache_valid=valid)
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    out = P.dense_apply(p["o"], out, x.dtype)
    Sc = cache["k"].shape[1]
    slot = jnp.asarray(position, jnp.int32) % Sc
    new_cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, 1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, 1),
    }
    return L.constrain(out, ("batch", "seq", "embed")), new_cache
