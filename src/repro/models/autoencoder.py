"""The paper's anomaly-detection autoencoder (Section V-A).

Fully-connected encoder/decoder; hidden layers 128-64 / 64-128 around a
32-wide code; ReLU hidden activations, linear output; dropout 0.2 on hidden
layers during training.  Anomaly score = squared reconstruction error.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.models import params as P


def init_params(key, cfg: AutoencoderConfig) -> Tuple[P.Params, P.Axes]:
    dims = ([cfg.input_dim] + list(cfg.hidden) + [cfg.code_dim]
            + list(reversed(cfg.hidden)) + [cfg.input_dim])
    p, a = {}, {}
    ks = jax.random.split(key, len(dims) - 1)
    for i in range(len(dims) - 1):
        p[f"fc{i}"], a[f"fc{i}"] = P.dense_init(
            ks[i], dims[i], dims[i + 1], None, None, "float32", bias=True)
    return p, a


def num_layers(cfg: AutoencoderConfig) -> int:
    return 2 * (len(cfg.hidden) + 1)


def forward(params: P.Params, cfg: AutoencoderConfig, x: jax.Array,
            dropout_key: Optional[jax.Array] = None) -> jax.Array:
    """x: (B, input_dim) -> reconstruction (B, input_dim).

    Pass ``dropout_key`` during training to enable dropout on hidden
    layers (paper: p=0.2)."""
    act = P.activation(cfg.act)
    n = num_layers(cfg)
    h = x
    for i in range(n):
        h = P.dense_apply(params[f"fc{i}"], h)
        if i < n - 1:                      # hidden layers
            h = act(h)
            if dropout_key is not None and cfg.dropout > 0:
                dk = jax.random.fold_in(dropout_key, i)
                keep = jax.random.bernoulli(dk, 1.0 - cfg.dropout, h.shape)
                h = jnp.where(keep, h / (1.0 - cfg.dropout), 0.0)
    return h


def recon_loss(params: P.Params, cfg: AutoencoderConfig, x: jax.Array,
               dropout_key: Optional[jax.Array] = None) -> jax.Array:
    """Mean squared reconstruction error J(x) = ||x - x_hat||^2."""
    x_hat = forward(params, cfg, x, dropout_key)
    return jnp.mean(jnp.sum(jnp.square(x - x_hat), axis=-1))


def anomaly_scores(params: P.Params, cfg: AutoencoderConfig, x: jax.Array
                   ) -> jax.Array:
    """Per-sample anomaly score (no dropout at eval)."""
    x_hat = forward(params, cfg, x)
    return jnp.sum(jnp.square(x - x_hat), axis=-1)
