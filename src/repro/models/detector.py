"""Pluggable per-device detector bodies for the Tol-FL campaign engine.

The paper's engine is model-agnostic: any detector with a masked training
loss and a per-sample anomaly score slots into the flat/star hybrid.  A
:class:`DetectorModel` is a **frozen, hashable spec** (a dataclass, like
the engine configs) exposing

* ``init_params(key)``          -> params pytree
* ``loss(params, x, valid, key)``  masked mean reconstruction loss;
  ``key=None`` disables dropout (evaluation / dropout-free training)
* ``anomaly_scores(params, x)`` -> (B,) per-sample scores
* ``param_count()`` / ``param_bytes()``  for the comm-cost models

Specs are closed over by the jitted campaign cores and are part of the
executable cache key (``campaign._exe_key``), so they MUST be frozen
dataclasses with value equality — ``plancheck.cachekey`` enforces this
structurally for every registered spec class.

Two bodies ship by default:

* :class:`AutoencoderDetector` — the paper's fully-connected autoencoder
  (the default; ``canonical_model_key`` maps it back to its raw
  :class:`AutoencoderConfig` so pre-existing cache keys, disk
  fingerprints and result digests are bit-identical).
* :class:`SeqDetector` — a reduced windowed sequence detector: features
  are folded into (seq, window) patches and reconstructed through a
  single RG-LRU recurrent block (``models/rglru.py``).

Budget note: each spec names a ``budget_family`` ("ae", "seq", ...);
``plancheck.budgets`` carries named eqn ceilings per family so campaign
cores built from a new body stay under a measured budget.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.autoencoder_paper import AutoencoderConfig, CONFIG
from repro.configs.base import ModelConfig, RecurrentConfig
from repro.models import autoencoder as AE
from repro.models import params as P
from repro.models import rglru as R


# ---------------------------------------------------------------------------
# Interface
# ---------------------------------------------------------------------------
class DetectorModel:
    """Base class for detector specs (concrete specs are frozen dataclasses).

    Methods are pure functions of (params, data) given the frozen spec, so
    they can be closed over by jitted cores; the spec itself never holds
    arrays."""

    #: plancheck budget family — selects the named eqn ceiling the
    #: campaign cores built from this body are checked against.
    budget_family: str = "ae"

    def init_params(self, key) -> P.Params:
        raise NotImplementedError

    def loss(self, params: P.Params, x: jax.Array, valid: jax.Array,
             key: Optional[jax.Array] = None) -> jax.Array:
        raise NotImplementedError

    def anomaly_scores(self, params: P.Params, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    # ---- derived sizes (comm-cost models / benches) ----
    def param_count(self) -> int:
        return _spec_sizes(self)[0]

    def param_bytes(self) -> int:
        return _spec_sizes(self)[1]


@functools.lru_cache(maxsize=64)
def _spec_sizes(det: DetectorModel) -> Tuple[int, int]:
    """(param_count, param_bytes) of a spec, via one eager tiny init."""
    params = det.init_params(jax.random.PRNGKey(0))
    return P.param_count(params), P.param_bytes(params)


# ---------------------------------------------------------------------------
# Paper autoencoder (default body)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AutoencoderDetector(DetectorModel):
    """The paper's fully-connected autoencoder, behind the interface."""

    cfg: AutoencoderConfig = CONFIG

    budget_family = "ae"

    def init_params(self, key) -> P.Params:
        params, _ = AE.init_params(key, self.cfg)
        return params

    def loss(self, params, x, valid, key=None):
        x_hat = AE.forward(params, self.cfg, x, dropout_key=key)
        err = jnp.sum(jnp.square(x - x_hat), axis=-1) * valid
        return jnp.sum(err) / jnp.maximum(jnp.sum(valid), 1.0)

    def anomaly_scores(self, params, x):
        return AE.anomaly_scores(params, self.cfg, x)


# ---------------------------------------------------------------------------
# Reduced windowed sequence detector (RG-LRU body)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SeqDetector(DetectorModel):
    """Windowed sequence reconstruction through one RG-LRU block.

    The (B, input_dim) feature rows are zero-padded to a multiple of
    ``window`` and folded into (B, seq, window) patches; each patch is
    embedded, run through the Griffin-style RG-LRU recurrence
    (``models/rglru.py``), decoded back to window space, and scored by
    squared reconstruction error — the same loss/score contract as the
    paper autoencoder, so it trains under identical FailureTrace
    campaigns."""

    input_dim: int = 112
    window: int = 16
    d_model: int = 16
    lru_width: Optional[int] = None
    conv1d_width: int = 2
    dropout: float = 0.0
    act: str = "gelu"
    name: str = "seq-rglru"

    budget_family = "seq"

    @property
    def seq_len(self) -> int:
        return -(-self.input_dim // self.window)

    def _model_cfg(self) -> ModelConfig:
        return ModelConfig(
            name=self.name, d_model=self.d_model,
            recurrent=RecurrentConfig(lru_width=self.lru_width,
                                      conv1d_width=self.conv1d_width))

    def _windows(self, x: jax.Array) -> jax.Array:
        pad = self.seq_len * self.window - self.input_dim
        xp = jnp.pad(x, ((0, 0), (0, pad)))
        return xp.reshape(x.shape[0], self.seq_len, self.window)

    def init_params(self, key) -> P.Params:
        k_enc, k_core, k_dec = jax.random.split(key, 3)
        enc, _ = P.dense_init(k_enc, self.window, self.d_model, None, None,
                              "float32", bias=True)
        core, _ = R.rglru_init(k_core, self._model_cfg())
        dec, _ = P.dense_init(k_dec, self.d_model, self.window, None, None,
                              "float32", bias=True)
        return {"enc": enc, "rglru": core, "dec": dec}

    def _reconstruct(self, params, x, dropout_key=None):
        h = P.activation(self.act)(P.dense_apply(params["enc"],
                                                 self._windows(x)))
        h, _ = R.rglru_apply(params["rglru"], h, self._model_cfg())
        if dropout_key is not None and self.dropout > 0.0:
            keep = jax.random.bernoulli(dropout_key, 1.0 - self.dropout,
                                        h.shape)
            h = jnp.where(keep, h / (1.0 - self.dropout), 0.0)
        y = P.dense_apply(params["dec"], h).reshape(x.shape[0], -1)
        return y[:, :self.input_dim]

    def loss(self, params, x, valid, key=None):
        x_hat = self._reconstruct(params, x, dropout_key=key)
        err = jnp.sum(jnp.square(x - x_hat), axis=-1) * valid
        return jnp.sum(err) / jnp.maximum(jnp.sum(valid), 1.0)

    def anomaly_scores(self, params, x):
        x_hat = self._reconstruct(params, x)
        return jnp.sum(jnp.square(x - x_hat), axis=-1)


# ---------------------------------------------------------------------------
# Normalisation / cache-key canonicalisation
# ---------------------------------------------------------------------------
ModelLike = Union[DetectorModel, AutoencoderConfig]


def as_detector(model: ModelLike) -> DetectorModel:
    """Normalise user-facing model specs to a :class:`DetectorModel`.

    Raw :class:`AutoencoderConfig` values (the historical spelling) wrap
    into an :class:`AutoencoderDetector`."""
    if isinstance(model, DetectorModel):
        return model
    if isinstance(model, AutoencoderConfig):
        return AutoencoderDetector(model)
    raise TypeError(
        f"expected a DetectorModel or AutoencoderConfig, got {model!r}")


def canonical_model_key(model: ModelLike):
    """Canonical executable-cache-key component for a model spec.

    Autoencoder specs canonicalise to the raw :class:`AutoencoderConfig`
    — whichever spelling the caller used — so every pre-refactor
    ``_exe_key`` tuple, lru entry and persistent-cache fingerprint
    (``compilecache.exe_fingerprint`` hashes ``repr``) stays
    bit-identical.  Other bodies key on the frozen spec itself."""
    if isinstance(model, AutoencoderDetector):
        return model.cfg
    if isinstance(model, (DetectorModel, AutoencoderConfig)):
        return model
    raise TypeError(
        f"expected a DetectorModel or AutoencoderConfig, got {model!r}")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[..., DetectorModel]] = {}


def register_detector(name: str, factory: Callable[..., DetectorModel]
                      ) -> None:
    """Register a detector body under ``name`` (idempotent re-register of
    the same factory is allowed; silent replacement is not)."""
    prior = _REGISTRY.get(name)
    if prior is not None and prior is not factory:
        raise ValueError(f"detector {name!r} already registered")
    _REGISTRY[name] = factory


def make_detector(name: str, **kwargs) -> DetectorModel:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown detector {name!r}; known: {detector_names()}")
    det = _REGISTRY[name](**kwargs)
    return as_detector(det)


def detector_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def spec_classes() -> Tuple[type, ...]:
    """Registered spec classes (for plancheck's frozen/eq containment
    check over everything that can land inside ``_exe_key``)."""
    return tuple(f for f in _REGISTRY.values() if isinstance(f, type))


register_detector("autoencoder", AutoencoderDetector)
register_detector("seq-rglru", SeqDetector)
