"""Dense MLP (optionally gated) with tensor-parallel ff sharding."""
from __future__ import annotations

from typing import Tuple

import jax

from repro.models import params as P
from repro.sharding import logical as L


def mlp_init(key, d_model: int, d_ff: int, glu: bool, dtype: str
             ) -> Tuple[P.Params, P.Axes]:
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["up"], a["up"] = P.dense_init(ks[0], d_model, d_ff, "embed", "ff", dtype)
    if glu:
        p["gate"], a["gate"] = P.dense_init(ks[1], d_model, d_ff,
                                            "embed", "ff", dtype)
    p["down"], a["down"] = P.dense_init(ks[2], d_ff, d_model,
                                        "ff", "embed", dtype)
    return p, a


def mlp_apply(p: P.Params, x: jax.Array, act: str, glu: bool) -> jax.Array:
    f = P.activation(act)
    h = P.dense_apply(p["up"], x, x.dtype)
    if glu:
        g = P.dense_apply(p["gate"], x, x.dtype)
        h = f(g) * h
    else:
        h = f(h)
    h = L.constrain(h, ("batch", "seq", "ff"))
    out = P.dense_apply(p["down"], h, x.dtype)
    return L.constrain(out, ("batch", "seq", "embed"))
