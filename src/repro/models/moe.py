"""Mixture-of-Experts layer (Llama-4-style top-k routing, GShard-style
capacity dispatch) with expert-parallel sharding over the "model" mesh axis.

Design (DESIGN.md section 4): activations are replicated over the model axis
(batch over data), expert weights are sharded over experts.  Dispatch is a
pair of one-hot einsums computed chunk-by-chunk over the sequence (a
``lax.scan``), so the (tokens x experts x capacity) tensor never exceeds
(B, chunk, E, C).  Each model-shard computes its slice of the expert dim
locally; the combine contraction over the expert dim produces the single
all-reduce over "model" (the TPU analogue of the MoE all-to-all for this
activation layout).  Capacity overflow drops tokens (standard GShard
semantics); the residual path keeps their values.

Aux losses: load-balance (Switch) + router z-loss, returned for logging and
added to the train objective.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import params as P
from repro.models.mlp import mlp_apply, mlp_init
from repro.sharding import logical as L


def moe_init(key, d_model: int, d_ff: int, cfg: MoEConfig, glu: bool,
             dtype: str) -> Tuple[P.Params, P.Axes]:
    k_router, k_exp, k_shared = jax.random.split(key, 3)
    p, a = {}, {}
    p["router"], a["router"] = P.dense_init(
        k_router, d_model, cfg.num_experts, "embed", "experts", dtype,
        scale=0.02)
    # experts: stacked mlp params with a leading 'experts' dim
    exp_keys = jax.random.split(k_exp, cfg.num_experts)
    per, ax = [], None
    for ek in exp_keys:
        ep, ax = mlp_init(ek, d_model, d_ff, glu, dtype)
        per.append(ep)
    p["experts"] = P.stack_layer_trees(per)
    a["experts"] = jax.tree.map(lambda t: ("experts",) + t, ax,
                                is_leaf=P.is_axes_leaf)
    if cfg.shared_expert:
        p["shared"], a["shared"] = mlp_init(k_shared, d_model, d_ff, glu, dtype)
    return p, a


def _capacity(chunk: int, cfg: MoEConfig) -> int:
    c = int(chunk * cfg.num_experts_per_tok * cfg.capacity_factor
            / cfg.num_experts)
    return max(c, 1)


def _dispatch_mask(logits: jax.Array, cfg: MoEConfig, capacity: int):
    """logits: (B, T, E) -> dispatch (B,T,E,C) bool-ish, combine (B,T,E,C).

    Top-k selection with per-expert capacity enforced by a running cumsum
    over the chunk (GShard position-in-expert)."""
    B, T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    dispatch = jnp.zeros((B, T, E, capacity), jnp.float32)
    combine = jnp.zeros((B, T, E, capacity), jnp.float32)
    # running per-expert fill count
    fill = jnp.zeros((B, E), jnp.int32)
    masked = probs
    for _ in range(cfg.num_experts_per_tok):
        idx = jnp.argmax(masked, axis=-1)                    # (B,T)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)   # (B,T,E)
        gate = jnp.sum(probs * onehot, axis=-1)              # (B,T)
        # position of each token within its expert's buffer
        pos_in_exp = (jnp.cumsum(onehot, axis=1) - onehot)   # (B,T,E)
        pos = jnp.sum(pos_in_exp * onehot, axis=-1) + \
            jnp.sum(fill[:, None, :] * onehot, axis=-1)      # (B,T)
        keep = pos < capacity
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                                dtype=jnp.float32)           # (B,T,C)
        d = onehot[..., None] * pos_oh[:, :, None, :] * \
            keep[:, :, None, None].astype(jnp.float32)
        dispatch = dispatch + d
        combine = combine + d * gate[:, :, None, None]
        fill = fill + jnp.sum(onehot, axis=1).astype(jnp.int32)
        masked = masked * (1.0 - onehot)                     # exclude chosen
    return dispatch, combine, probs


def _expert_mlp(exp_p: P.Params, h: jax.Array, act: str, glu: bool
                ) -> jax.Array:
    """h: (B, E, C, d); expert weights carry a leading E dim."""
    f = P.activation(act)
    up = jnp.einsum("becd,edf->becf", h, exp_p["up"]["w"].astype(h.dtype))
    if glu:
        gate = jnp.einsum("becd,edf->becf", h,
                          exp_p["gate"]["w"].astype(h.dtype))
        mid = f(gate) * up
    else:
        mid = f(up)
    mid = L.constrain(mid, ("batch", "experts", None, "ff"))
    return jnp.einsum("becf,efd->becd", mid,
                      exp_p["down"]["w"].astype(h.dtype))


def moe_apply(p: P.Params, x: jax.Array, cfg: MoEConfig, act: str, glu: bool,
              chunk: int = 512) -> Tuple[jax.Array, dict]:
    """x: (B, S, d) -> (out, aux) with aux = {'lb_loss', 'z_loss'}."""
    from repro.models.transformer import divisor_block
    B, S, d = x.shape
    chunk = divisor_block(S, chunk)
    C = _capacity(chunk, cfg)
    xs = x.reshape(B, S // chunk, chunk, d).transpose(1, 0, 2, 3)

    def step(_, xc):
        logits = P.dense_apply(p["router"], xc, jnp.float32)     # (B,T,E)
        dispatch, combine, probs = _dispatch_mask(logits, cfg, C)
        h = jnp.einsum("btec,btd->becd", dispatch.astype(xc.dtype), xc)
        h = L.constrain(h, ("batch", "experts", None, "embed"))
        o = _expert_mlp(p["experts"], h, act, glu)
        out = jnp.einsum("btec,becd->btd", combine.astype(xc.dtype), o)
        out = L.constrain(out, ("batch", "seq", "embed"))
        # aux losses (Switch LB + z-loss)
        frac_tokens = jnp.mean(
            jnp.sum(dispatch, axis=-1), axis=(0, 1))             # (E,)
        frac_probs = jnp.mean(probs, axis=(0, 1))                # (E,)
        lb = cfg.num_experts * jnp.sum(frac_tokens * frac_probs)
        z = jnp.mean(jnp.square(jax.scipy.special.logsumexp(
            logits.astype(jnp.float32), axis=-1)))
        return None, (out, lb, z)

    _, (outs, lbs, zs) = jax.lax.scan(step, None, xs)
    out = outs.transpose(1, 0, 2, 3).reshape(B, S, d)
    if cfg.shared_expert:
        out = out + mlp_apply(p["shared"], x, act, glu)
    aux = {"lb_loss": jnp.mean(lbs), "z_loss": jnp.mean(zs)}
    return out, aux
