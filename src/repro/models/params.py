"""Minimal functional parameter system (no flax).

Params are nested dicts of jnp arrays; a parallel ``axes`` tree (same
structure, leaves = tuples of logical axis names) drives sharding.  Layer
init functions return ``(params, axes)`` pairs and are composed by hand.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]
Axes = Dict[str, Any]

def is_axes_leaf(x) -> bool:
    """A logical-axes leaf: a (possibly empty) plain tuple of axis names.

    NamedTuples (optimizer states) are containers, not leaves."""
    return (isinstance(x, tuple) and not hasattr(x, "_fields")
            and all(e is None or isinstance(e, str) for e in x))


def _dtype(name: str):
    return jnp.dtype(name)


def normal_init(key, shape, dtype, stddev: float):
    return (jax.random.normal(key, shape) * stddev).astype(dtype)


def dense_init(key, in_dim: int, out_dim: int, in_ax: Optional[str],
               out_ax: Optional[str], dtype: str = "float32",
               bias: bool = False, scale: Optional[float] = None
               ) -> Tuple[Params, Axes]:
    """Kernel (in,out) with fan-in scaled init."""
    stddev = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"w": normal_init(key, (in_dim, out_dim), _dtype(dtype), stddev)}
    a = {"w": (in_ax, out_ax)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), _dtype(dtype))
        a["b"] = (out_ax,)
    return p, a


def dense_apply(p: Params, x: jax.Array, compute_dtype=None) -> jax.Array:
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        b = p["b"]
        if compute_dtype is not None:
            b = b.astype(compute_dtype)
        y = y + b
    return y


def embed_init(key, vocab: int, dim: int, dtype: str = "float32"
               ) -> Tuple[Params, Axes]:
    p = {"table": normal_init(key, (vocab, dim), _dtype(dtype), 0.02)}
    a = {"table": ("vocab", "embed")}
    return p, a


def rmsnorm_init(dim: int, dtype: str = "float32") -> Tuple[Params, Axes]:
    return ({"scale": jnp.ones((dim,), _dtype(dtype))}, {"scale": ("embed",)})


def rmsnorm_apply(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(dim: int, dtype: str = "float32") -> Tuple[Params, Axes]:
    return ({"scale": jnp.ones((dim,), _dtype(dtype)),
             "bias": jnp.zeros((dim,), _dtype(dtype))},
            {"scale": ("embed",), "bias": ("embed",)})


def layernorm_apply(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Gradient-communication dtype boundary (beyond-paper perf lever)
# ---------------------------------------------------------------------------
@jax.custom_vjp
def grad_bf16_boundary(x: jax.Array) -> jax.Array:
    """Identity in the forward pass; rounds the COTANGENT to bfloat16 on
    the way back.

    Placed on the residual stream at tensor-parallel layer boundaries so
    the backward-pass partial-sum all-reduces over the "model" axis carry
    bf16 instead of f32 — halving the dominant TP collective payload
    (EXPERIMENTS.md section Perf).  Megatron-style grad-comm compression;
    numerics validated in tests/test_grad_comm.py."""
    return x


def _gb_fwd(x):
    return x, None


def _gb_bwd(_, g):
    if g.dtype == jnp.float32:
        g = g.astype(jnp.bfloat16).astype(jnp.float32)
    return (g,)


grad_bf16_boundary.defvjp(_gb_fwd, _gb_bwd)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------
def activation(name: str):
    return {
        "relu": jax.nn.relu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "silu": jax.nn.silu,
        "sqrelu": lambda x: jnp.square(jax.nn.relu(x)),
        "linear": lambda x: x,
        "tanh": jnp.tanh,
    }[name]


# ---------------------------------------------------------------------------
# Tree utilities
# ---------------------------------------------------------------------------
def stack_layer_trees(trees: Sequence[Params]) -> Params:
    """Stack per-layer param trees along a new leading 'layers' dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def add_layers_axis(axes: Axes) -> Axes:
    return jax.tree.map(lambda a: ("layers",) + a, axes, is_leaf=is_axes_leaf)


def param_count(params: Params) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))


def param_bytes(params: Params) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(params)))


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree.map(lambda x: x.astype(dtype), params)


def tree_zeros_like(params: Params) -> Params:
    return jax.tree.map(jnp.zeros_like, params)


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))
