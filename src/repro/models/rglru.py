"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

    r_t = sigmoid(W_a x_t)                    (recurrence gate)
    i_t = sigmoid(W_x x_t)                    (input gate)
    a_t = a ** (c * r_t),  a = sigmoid(lambda)   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is diagonal-linear, so the pure-JAX path uses
``jax.lax.associative_scan`` (log-depth — the TPU-friendly formulation);
``repro.kernels.rglru_scan`` is the time-blocked Pallas version.  The block
wraps the LRU with the Griffin residual structure: gelu gate branch x conv1d
+ LRU branch, then an output projection.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.sharding import logical as L

C_EXP = 8.0


def rglru_init(key, cfg: ModelConfig) -> Tuple[P.Params, P.Axes]:
    d = cfg.d_model
    w = cfg.recurrent.lru_width or d
    cw = cfg.recurrent.conv1d_width
    dt = cfg.param_dtype
    ks = jax.random.split(key, 7)
    p, a = {}, {}
    p["in_x"], a["in_x"] = P.dense_init(ks[0], d, w, "embed", "state", dt)
    p["in_gate"], a["in_gate"] = P.dense_init(ks[1], d, w, "embed", "state", dt)
    p["conv_w"] = P.normal_init(ks[2], (cw, w), jnp.dtype(dt), 0.02)
    a["conv_w"] = ("conv", "state")
    p["conv_b"] = jnp.zeros((w,), jnp.dtype(dt))
    a["conv_b"] = ("state",)
    p["gate_a"], a["gate_a"] = P.dense_init(ks[3], w, w, "state", None, dt,
                                            scale=0.02)
    p["gate_x"], a["gate_x"] = P.dense_init(ks[4], w, w, "state", None, dt,
                                            scale=0.02)
    # lambda init so that a = sigmoid(lambda) in [0.9, 0.999]
    u = jax.random.uniform(ks[5], (w,), minval=0.9, maxval=0.999)
    p["lam"] = jnp.log(u / (1 - u)).astype(jnp.dtype(dt))
    a["lam"] = ("state",)
    p["out"], a["out"] = P.dense_init(ks[6], w, d, "state", "embed", dt)
    return p, a


def _causal_conv1d(xw: jax.Array, w: jax.Array, b: jax.Array,
                   state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time.  xw: (B,S,W); w: (cw,W).

    state: (B, cw-1, W) trailing context from the previous segment."""
    B, S, W = xw.shape
    cw = w.shape[0]
    pad = (jnp.zeros((B, cw - 1, W), xw.dtype) if state is None
           else state.astype(xw.dtype))
    xp = jnp.concatenate([pad, xw], axis=1)
    # NOT zeros_like: that would inherit xw's (full-mesh) sharding, which
    # is rejected inside partial-manual shard_map regions
    out = jnp.zeros(xw.shape, xw.dtype)
    for i in range(cw):
        out = out + xp[:, i:i + S, :] * w[i].astype(xw.dtype)
    out = out + b.astype(xw.dtype)
    return out, xp[:, -(cw - 1):, :] if cw > 1 else jnp.zeros((B, 0, W), xw.dtype)


def _lru_scan(a_t: jax.Array, b_t: jax.Array, h0: Optional[jax.Array],
              use_pallas: bool = False) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t over axis 1.  a_t, b_t: (B,S,W) float32."""
    if use_pallas:
        from repro.kernels import rglru_scan as ker
        return ker.rglru_scan(a_t, b_t, h0)
    if h0 is not None:
        # fold the carried state into the first step
        b_t = b_t.at[:, 0, :].add(a_t[:, 0, :] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a_t, b_t), axis=1)
    return h


def rglru_apply(p: P.Params, x: jax.Array, cfg: ModelConfig,
                state: Optional[dict] = None, use_pallas: bool = False
                ) -> Tuple[jax.Array, dict]:
    """x: (B,S,d) -> (out, new_state).

    state: {'h': (B,W) f32, 'conv': (B,cw-1,W)} or None."""
    B, S, d = x.shape
    gate_branch = jax.nn.gelu(P.dense_apply(p["in_gate"], x, x.dtype))
    xw = P.dense_apply(p["in_x"], x, x.dtype)
    xw = L.constrain(xw, ("batch", "seq", "state"))
    conv_state = None if state is None else state["conv"]
    xw, new_conv = _causal_conv1d(xw, p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid(P.dense_apply(p["gate_a"], xw, jnp.float32))
    i = jax.nn.sigmoid(P.dense_apply(p["gate_x"], xw, jnp.float32))
    log_a = C_EXP * r * jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))
    a_t = jnp.exp(log_a)
    # sqrt(1 - a^2) normaliser, clamped for stability
    norm = jnp.sqrt(jnp.clip(1.0 - jnp.square(a_t), 1e-12, None))
    b_t = norm * (i * xw.astype(jnp.float32))
    h0 = None if state is None else state["h"]
    h = _lru_scan(a_t, b_t, h0, use_pallas)
    h = L.constrain(h, ("batch", "seq", "state"))
    out = P.dense_apply(p["out"], (h.astype(x.dtype)) * gate_branch, x.dtype)
    out = L.constrain(out, ("batch", "seq", "embed"))
    new_state = {"h": h[:, -1, :], "conv": new_conv}
    return out, new_state


def rglru_decode(p: P.Params, x: jax.Array, cfg: ModelConfig, state: dict
                 ) -> Tuple[jax.Array, dict]:
    """Single-token step: x (B,1,d)."""
    return rglru_apply(p, x, cfg, state=state, use_pallas=False)
