"""RWKV6 "Finch" time-mix / channel-mix blocks [arXiv:2404.05892].

Attention-free: per-head matrix-valued state S (N x N) with data-dependent
diagonal decay w_t:

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Token-shift interpolation (ddlerp) uses learned mus plus LoRA adapters on
the shifted mix, per the Finch paper.  The sequential recurrence is a
``lax.scan`` over time in the pure-JAX path; ``repro.kernels.rwkv6_scan``
is the time-blocked Pallas version.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.sharding import logical as L

DDLERP_RANK = 32
DECAY_RANK = 64
MIX_NAMES = ("w", "k", "v", "r", "g")


def timemix_init(key, cfg: ModelConfig) -> Tuple[P.Params, P.Axes]:
    d = cfg.d_model
    H, N = cfg.recurrent.num_heads, cfg.recurrent.head_size
    assert H * N == d, (H, N, d)
    ks = jax.random.split(key, 12)
    p, a = {}, {}
    dt = cfg.param_dtype
    # token-shift base mus: one per mix target + the ddlerp input mix
    p["mu"] = P.normal_init(ks[0], (len(MIX_NAMES) + 1, d), jnp.dtype(dt), 0.02)
    a["mu"] = (None, "embed")
    # ddlerp LoRA: (d -> rank -> 5*d)
    p["ddlerp_a"], a["ddlerp_a"] = P.dense_init(
        ks[1], d, DDLERP_RANK * len(MIX_NAMES), "embed", None, dt, scale=0.02)
    p["ddlerp_b"], a["ddlerp_b"] = P.dense_init(
        ks[2], DDLERP_RANK * len(MIX_NAMES), len(MIX_NAMES) * d, None, "embed",
        dt, scale=0.02)
    for i, nm in enumerate(("r", "k", "v", "g")):
        p[nm], a[nm] = P.dense_init(ks[3 + i], d, d, "embed", "heads", dt)
    p["o"], a["o"] = P.dense_init(ks[7], d, d, "heads", "embed", dt)
    # data-dependent decay: w_t = exp(-exp(decay_base + lora(x_w)))
    p["decay_base"] = P.normal_init(ks[8], (d,), jnp.dtype(dt), 0.02)
    a["decay_base"] = ("embed",)
    p["decay_a"], a["decay_a"] = P.dense_init(ks[9], d, DECAY_RANK, "embed",
                                              None, dt, scale=0.02)
    p["decay_b"], a["decay_b"] = P.dense_init(ks[10], DECAY_RANK, d, None,
                                              "embed", dt, scale=0.02)
    p["bonus"] = P.normal_init(ks[11], (d,), jnp.dtype(dt), 0.02)  # u
    a["bonus"] = ("embed",)
    # group-norm over heads on the output
    p["ln_x"] = {"scale": jnp.ones((d,), jnp.dtype(dt)),
                 "bias": jnp.zeros((d,), jnp.dtype(dt))}
    a["ln_x"] = {"scale": ("embed",), "bias": ("embed",)}
    return p, a


def _ddlerp(p, x, sx):
    """Finch data-dependent token-shift: returns dict name->mixed input."""
    B, S, d = x.shape
    diff = sx - x
    xx = x + diff * p["mu"][len(MIX_NAMES)].astype(x.dtype)
    lora = jnp.tanh(P.dense_apply(p["ddlerp_a"], xx, x.dtype))
    lora = P.dense_apply(p["ddlerp_b"], lora, x.dtype)
    lora = lora.reshape(B, S, len(MIX_NAMES), d)
    out = {}
    for i, nm in enumerate(MIX_NAMES):
        mix = p["mu"][i].astype(x.dtype) + lora[:, :, i]
        out[nm] = x + diff * mix
    return out


def _wkv_scan(r, k, v, w, u, state0):
    """Sequential WKV: r,k,v,w (B,S,H,N); u (H,N); state (B,H,N,N).

    Returns y (B,S,H,N), final state."""
    def step(S, rkvw):
        rt, kt, vt, wt = rkvw          # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt,
                       S + u[None, :, :, None] * kv)
        S_new = wt[..., :, None] * S + kv
        return S_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), state


def timemix_apply(p: P.Params, x: jax.Array, cfg: ModelConfig,
                  state: Optional[dict] = None, use_pallas: bool = False
                  ) -> Tuple[jax.Array, dict]:
    """x: (B,S,d).  state: {'shift': (B,d), 'wkv': (B,H,N,N)} or None."""
    B, S, d = x.shape
    H, N = cfg.recurrent.num_heads, cfg.recurrent.head_size
    if state is None:
        shift0 = jnp.zeros((B, d), x.dtype)
        wkv0 = jnp.zeros((B, H, N, N), jnp.float32)
    else:
        shift0, wkv0 = state["shift"], state["wkv"]
    sx = jnp.concatenate([shift0[:, None, :], x[:, :-1, :]], axis=1)
    mixed = _ddlerp(p, x, sx)
    r = P.dense_apply(p["r"], mixed["r"], x.dtype).reshape(B, S, H, N)
    k = P.dense_apply(p["k"], mixed["k"], x.dtype).reshape(B, S, H, N)
    v = P.dense_apply(p["v"], mixed["v"], x.dtype).reshape(B, S, H, N)
    g = jax.nn.silu(P.dense_apply(p["g"], mixed["g"], x.dtype))
    decay = (p["decay_base"].astype(jnp.float32)
             + P.dense_apply(p["decay_b"],
                             jnp.tanh(P.dense_apply(p["decay_a"], mixed["w"],
                                                    jnp.float32)),
                             jnp.float32))
    w = jnp.exp(-jnp.exp(decay)).reshape(B, S, H, N)
    u = p["bonus"].astype(jnp.float32).reshape(H, N)
    r32, k32, v32, w32 = (t.astype(jnp.float32) for t in (r, k, v, w))
    if use_pallas:
        from repro.kernels import rwkv6_scan as ker
        y, wkv = ker.rwkv6_scan(r32, k32, v32, w32, u, wkv0)
    else:
        y, wkv = _wkv_scan(r32, k32, v32, w32, u, wkv0)
    y = y.reshape(B, S, d)
    # group-norm per head
    y = y.reshape(B, S, H, N)
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, d)
    y = (y * p["ln_x"]["scale"].astype(jnp.float32)
         + p["ln_x"]["bias"].astype(jnp.float32)).astype(x.dtype)
    out = P.dense_apply(p["o"], y * g, x.dtype)
    out = L.constrain(out, ("batch", "seq", "embed"))
    new_state = {"shift": x[:, -1, :], "wkv": wkv}
    return out, new_state


def channelmix_init(key, cfg: ModelConfig) -> Tuple[P.Params, P.Axes]:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    dt = cfg.param_dtype
    p["mu"] = P.normal_init(ks[0], (2, d), jnp.dtype(dt), 0.02)
    a["mu"] = (None, "embed")
    p["key"], a["key"] = P.dense_init(ks[1], d, f, "embed", "ff", dt)
    p["value"], a["value"] = P.dense_init(ks[2], f, d, "ff", "embed", dt)
    return p, a


def channelmix_apply(p: P.Params, x: jax.Array,
                     state: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """RWKV channel-mix: squared-relu mlp with token shift.

    state: (B, d) previous token (decode) or None (train)."""
    B, S, d = x.shape
    shift0 = jnp.zeros((B, d), x.dtype) if state is None else state
    sx = jnp.concatenate([shift0[:, None, :], x[:, :-1, :]], axis=1)
    diff = sx - x
    xk = x + diff * p["mu"][0].astype(x.dtype)
    k = jnp.square(jax.nn.relu(P.dense_apply(p["key"], xk, x.dtype)))
    k = L.constrain(k, ("batch", "seq", "ff"))
    out = P.dense_apply(p["value"], k, x.dtype)
    return L.constrain(out, ("batch", "seq", "embed")), x[:, -1, :]
