"""Generic multi-family transformer backbone.

One code path covers all six assigned families:

* dense / moe   — (attn | moe-or-mlp) decoder layers
* ssm (rwkv6)   — time-mix + channel-mix layers
* hybrid        — RecurrentGemma (rec, rec, local-attn) pattern units
* audio (enc-dec) — whisper backbone; conv/mel frontend stubbed upstream
* vlm           — early-fusion prefix of (stubbed) patch embeddings

The layer stack is scanned over *pattern units* (the repeating block of the
layer pattern — 1 layer for dense, 2 for interleaved MoE, 3 for
RecurrentGemma), keeping the HLO size O(unit) instead of O(num_layers).
Remainder layers that don't fill a unit are applied unrolled ("tail").

Modes: ``train`` (loss), ``prefill`` (build cache, last-token logits),
``decode`` (one token against a cache).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN, LOCAL_ATTN, ModelConfig, RECURRENT,
                                RWKV)
from repro.models import attention as A
from repro.models import params as P
from repro.models import rglru as G
from repro.models import rwkv6 as R
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init
from repro.sharding import logical as L

VOCAB_PAD = 256


def padded_vocab(cfg: ModelConfig) -> int:
    return ((cfg.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def divisor_block(S: int, target: int) -> int:
    """Largest block size <= target that divides S (chunked scans need
    exact tiling; e.g. whisper's encoder S=1500 -> 500)."""
    b = max(1, min(target, S))
    while S % b:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Pattern units
# ---------------------------------------------------------------------------
def unit_pattern(cfg: ModelConfig) -> Tuple[Tuple[str, bool], ...]:
    """The repeating unit as ((kind, use_moe), ...)."""
    pat = cfg.layer_pattern
    if cfg.moe.num_experts > 0:
        unit_len = cfg.moe.interleave
    elif cfg.recurrent.block_pattern:
        unit_len = len(cfg.recurrent.block_pattern)
    else:
        unit_len = 1
    unit_len = min(unit_len, cfg.num_layers)
    unit = []
    for i in range(unit_len):
        kind = pat[i]
        use_moe = cfg.moe.num_experts > 0 and i % cfg.moe.interleave == 0
        unit.append((kind, use_moe))
    return tuple(unit)


def unit_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(num_scanned_units, num_tail_layers)."""
    u = len(unit_pattern(cfg))
    return cfg.num_layers // u, cfg.num_layers % u


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _layer_init(key, cfg: ModelConfig, kind: str, use_moe: bool
                ) -> Tuple[P.Params, P.Axes]:
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["norm1"], a["norm1"] = P.rmsnorm_init(cfg.d_model, cfg.param_dtype)
    p["norm2"], a["norm2"] = P.rmsnorm_init(cfg.d_model, cfg.param_dtype)
    if kind in (ATTN, LOCAL_ATTN):
        p["mix"], a["mix"] = A.attn_init(ks[0], cfg.d_model, cfg.attention,
                                         cfg.param_dtype)
    elif kind == RECURRENT:
        p["mix"], a["mix"] = G.rglru_init(ks[0], cfg)
    elif kind == RWKV:
        p["mix"], a["mix"] = R.timemix_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind == RWKV:
        p["mlp"], a["mlp"] = R.channelmix_init(ks[1], cfg)
    elif use_moe:
        p["mlp"], a["mlp"] = moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.moe,
                                      cfg.glu, cfg.param_dtype)
    else:
        p["mlp"], a["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.glu,
                                      cfg.param_dtype)
    return p, a


def _unit_init(key, cfg: ModelConfig) -> Tuple[P.Params, P.Axes]:
    unit = unit_pattern(cfg)
    p, a = {}, {}
    for i, (kind, use_moe) in enumerate(unit):
        ki = jax.random.fold_in(key, i)
        p[f"l{i}"], a[f"l{i}"] = _layer_init(ki, cfg, kind, use_moe)
    return p, a


def _encoder_layer_init(key, cfg: ModelConfig) -> Tuple[P.Params, P.Axes]:
    """Whisper encoder layer: bidirectional self-attn + mlp."""
    ks = jax.random.split(key, 2)
    p, a = {}, {}
    p["norm1"], a["norm1"] = P.rmsnorm_init(cfg.d_model, cfg.param_dtype)
    p["norm2"], a["norm2"] = P.rmsnorm_init(cfg.d_model, cfg.param_dtype)
    p["attn"], a["attn"] = A.attn_init(ks[0], cfg.d_model, cfg.attention,
                                       cfg.param_dtype)
    p["mlp"], a["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.glu,
                                  cfg.param_dtype)
    return p, a


def _cross_layer_init(key, cfg: ModelConfig) -> Tuple[P.Params, P.Axes]:
    p, a = {}, {}
    p["norm"], a["norm"] = P.rmsnorm_init(cfg.d_model, cfg.param_dtype)
    p["attn"], a["attn"] = A.attn_init(key, cfg.d_model, cfg.attention,
                                       cfg.param_dtype)
    return p, a


def init_params(key, cfg: ModelConfig) -> Tuple[P.Params, P.Axes]:
    keys = jax.random.split(key, 8)
    Vp = padded_vocab(cfg)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    p["embed"], a["embed"] = P.embed_init(keys[0], Vp, cfg.d_model,
                                          cfg.param_dtype)
    n_units, n_tail = unit_counts(cfg)
    per, ax = [], None
    for i in range(n_units):
        up, ax = _unit_init(jax.random.fold_in(keys[1], i), cfg)
        per.append(up)
    p["units"] = P.stack_layer_trees(per)
    a["units"] = P.add_layers_axis(ax)
    if n_tail:
        tail_p, tail_a = {}, {}
        unit = unit_pattern(cfg)
        for i in range(n_tail):
            kind, use_moe = unit[i]
            tail_p[f"l{i}"], tail_a[f"l{i}"] = _layer_init(
                jax.random.fold_in(keys[2], i), cfg, kind, use_moe)
        p["tail"], a["tail"] = tail_p, tail_a
    p["final_norm"], a["final_norm"] = P.rmsnorm_init(cfg.d_model,
                                                      cfg.param_dtype)
    if not cfg.tie_embeddings:
        p["head"], a["head"] = P.dense_init(keys[3], cfg.d_model, Vp,
                                            "embed", "vocab", cfg.param_dtype)
    if cfg.is_encdec:
        enc_p, enc_ax = [], None
        for i in range(cfg.num_encoder_layers):
            ep, enc_ax = _encoder_layer_init(jax.random.fold_in(keys[4], i),
                                             cfg)
            enc_p.append(ep)
        cross_p, cross_ax = [], None
        for i in range(cfg.num_layers):
            cp, cross_ax = _cross_layer_init(jax.random.fold_in(keys[5], i),
                                             cfg)
            cross_p.append(cp)
        p["encoder"] = {"layers": P.stack_layer_trees(enc_p)}
        a["encoder"] = {"layers": P.add_layers_axis(enc_ax)}
        p["encoder"]["norm"], a["encoder"]["norm"] = P.rmsnorm_init(
            cfg.d_model, cfg.param_dtype)
        p["cross"] = {"layers": P.stack_layer_trees(cross_p)}
        a["cross"] = {"layers": P.add_layers_axis(cross_ax)}
    return p, a


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------
def embed_tokens(params: P.Params, cfg: ModelConfig, tokens: jax.Array
                 ) -> jax.Array:
    x = params["embed"]["table"].astype(jnp.dtype(cfg.dtype))[tokens]
    x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return L.constrain(x, ("batch", "seq", "embed"))


def sinusoidal_positions(S: int, d: int, offset: int = 0) -> jax.Array:
    pos = np.arange(offset, offset + S)[:, None]
    div = np.exp(np.arange(0, d, 2) * (-np.log(10000.0) / d))
    pe = np.zeros((S, d), np.float32)
    pe[:, 0::2] = np.sin(pos * div)
    pe[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(pe)


def logits_fn(params: P.Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """h: (..., d) -> (..., Vp)."""
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(h.dtype)
        out = h @ w.T
    else:
        out = P.dense_apply(params["head"], h, h.dtype)
    return out


def xent_loss(params: P.Params, cfg: ModelConfig, h: jax.Array,
              labels: jax.Array, mask: Optional[jax.Array] = None,
              chunk: int = 512) -> jax.Array:
    """Chunked softmax cross-entropy.  h: (B,S,d), labels: (B,S) int32.

    Padded vocab entries are excluded via a -inf additive mask; the seq dim
    is processed in chunks so per-step logits stay (B, chunk, Vp)."""
    B, S, d = h.shape
    Vp = padded_vocab(cfg)
    V = cfg.vocab_size
    chunk = divisor_block(S, chunk)
    pad_mask = jnp.where(jnp.arange(Vp) < V, 0.0, A.NEG_INF)
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    hs = h.reshape(B, S // chunk, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, S // chunk, chunk).transpose(1, 0, 2)
    ms = mask.reshape(B, S // chunk, chunk).transpose(1, 0, 2)

    def step(carry, xs):
        hc, lc, mc = xs
        logits = logits_fn(params, cfg, hc).astype(jnp.float32) + pad_mask
        logits = L.constrain(logits, ("batch", "seq", "vocab"))
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------
def _apply_layer_train(p: P.Params, x: jax.Array, cfg: ModelConfig,
                       kind: str, use_moe: bool, use_pallas: bool
                       ) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, moe_aux_loss)."""
    aux = jnp.float32(0)
    h = P.rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
    h, _ = _mix_train(p["mix"], h, cfg, kind, use_pallas)
    x = x + h
    h = P.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
    if kind == RWKV:
        h, _ = R.channelmix_apply(p["mlp"], h)
    elif use_moe:
        h, moe_aux = moe_apply(p["mlp"], h, cfg.moe, cfg.act, cfg.glu)
        aux = aux + cfg.moe.router_aux_loss_coef * moe_aux["lb_loss"] \
            + 1e-3 * moe_aux["z_loss"]
    else:
        h = mlp_apply(p["mlp"], h, cfg.act, cfg.glu)
    return x + h, aux


def _mix_train(p, h, cfg: ModelConfig, kind: str, use_pallas: bool):
    if kind == ATTN:
        return A.attn_apply(p, h, cfg.attention, cfg.norm_eps,
                            window=cfg.attention.sliding_window,
                            use_pallas=use_pallas), None
    if kind == LOCAL_ATTN:
        return A.attn_apply(p, h, cfg.attention, cfg.norm_eps,
                            window=cfg.attention.sliding_window or
                            cfg.attention.long_context_window,
                            use_pallas=use_pallas), None
    if kind == RECURRENT:
        out, _ = G.rglru_apply(p, h, cfg, use_pallas=use_pallas)
        return out, None
    if kind == RWKV:
        out, _ = R.timemix_apply(p, h, cfg, use_pallas=use_pallas)
        return out, None
    raise ValueError(kind)


def _apply_unit_train(unit_p: P.Params, x: jax.Array, cfg: ModelConfig,
                      use_pallas: bool) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.float32(0)
    for i, (kind, use_moe) in enumerate(unit_pattern(cfg)):
        x, a = _apply_layer_train(unit_p[f"l{i}"], x, cfg, kind, use_moe,
                                  use_pallas)
        aux = aux + a
    return x, aux


# ---------------------------------------------------------------------------
# Forward: train
# ---------------------------------------------------------------------------
def forward_train(params: P.Params, cfg: ModelConfig, batch: Dict[str, Any],
                  use_pallas: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden (B,S,d), moe_aux_scalar).

    batch: tokens (B,S_text); optional 'prefix' (B,P,d) early-fusion
    embeddings (vlm); optional 'frames' (B,F,d) encoder stub input (audio).
    """
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens)
    if cfg.frontend.kind == "vision" and "prefix" in batch:
        pre = batch["prefix"].astype(x.dtype)
        x = jnp.concatenate([pre, x], axis=1)
        x = L.constrain(x, ("batch", "seq", "embed"))
    if cfg.attention.rope_theta == 0:
        S = x.shape[1]
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["frames"], use_pallas)

    n_units, n_tail = unit_counts(cfg)
    unit_fn = functools.partial(_apply_unit_train, cfg=cfg,
                                use_pallas=use_pallas)
    if cfg.remat == "full":
        unit_fn = jax.checkpoint(unit_fn)

    if cfg.is_encdec:
        # enc-dec: cross-attention between self-attn and mlp; scan over
        # (unit, cross) pairs.  Units are single layers for whisper.
        def body(carry, ps):
            x, aux = carry
            up, cp = ps
            h = P.rmsnorm_apply(up["l0"]["norm1"], x, cfg.norm_eps)
            h, _ = _mix_train(up["l0"]["mix"], h, cfg, ATTN, use_pallas)
            x = x + h
            h = P.rmsnorm_apply(cp["norm"], x, cfg.norm_eps)
            x = x + cross_attend(cp["attn"], h, enc_out, cfg, use_pallas)
            h = P.rmsnorm_apply(up["l0"]["norm2"], x, cfg.norm_eps)
            x = x + mlp_apply(up["l0"]["mlp"], h, cfg.act, cfg.glu)
            return (x, aux), None

        if cfg.remat == "full":
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)),
                                   (params["units"],
                                    params["cross"]["layers"]))
    else:
        def body(carry, up):
            x, aux = carry
            x, a = unit_fn(up, x)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)),
                                   params["units"])
        for i in range(n_tail):
            kind, use_moe = unit_pattern(cfg)[i]
            x, a = _apply_layer_train(params["tail"][f"l{i}"], x, cfg, kind,
                                      use_moe, use_pallas)
            aux = aux + a
    x = P.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def cross_attend(p: P.Params, h: jax.Array, enc_out: jax.Array,
                 cfg: ModelConfig, use_pallas: bool) -> jax.Array:
    """Decoder cross-attention: queries from h, keys/values from enc_out."""
    B, S, _ = h.shape
    F = enc_out.shape[1]
    acfg = cfg.attention
    q = P.dense_apply(p["q"], h, h.dtype).reshape(B, S, acfg.num_heads,
                                                  acfg.head_dim)
    k = P.dense_apply(p["k"], enc_out, h.dtype).reshape(
        B, F, acfg.num_kv_heads, acfg.head_dim)
    v = P.dense_apply(p["v"], enc_out, h.dtype).reshape(
        B, F, acfg.num_kv_heads, acfg.head_dim)
    out = A.blocked_attention(q, k, v, causal=False, window=None,
                              q_block=512, kv_block=min(512, F))
    out = out.reshape(B, S, acfg.num_heads * acfg.head_dim)
    return P.dense_apply(p["o"], out, h.dtype)


def encode(params: P.Params, cfg: ModelConfig, frames: jax.Array,
           use_pallas: bool = False) -> jax.Array:
    """Whisper encoder over stubbed frame embeddings (B,F,d)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    x = L.constrain(x, ("batch", "seq", "embed"))

    def body(x, lp):
        h = P.rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
        h = A.attn_apply(lp["attn"], h, cfg.attention, cfg.norm_eps,
                         causal=False, window=None, use_pallas=use_pallas)
        x = x + h
        h = P.rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, cfg.act, cfg.glu)
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return P.rmsnorm_apply(params["encoder"]["norm"], x, cfg.norm_eps)


def loss_fn(params: P.Params, cfg: ModelConfig, batch: Dict[str, Any],
            use_pallas: bool = False) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h, aux = forward_train(params, cfg, batch, use_pallas)
    labels = batch["labels"]
    if cfg.frontend.kind == "vision" and "prefix" in batch:
        # loss only over text positions (after the patch prefix)
        Ptok = batch["prefix"].shape[1]
        h = h[:, Ptok:, :]
    loss = xent_loss(params, cfg, h, labels, batch.get("mask"))
    total = loss + aux
    return total, {"xent": loss, "moe_aux": aux}
