"""Optimizers from scratch (no optax): SGD, Adam, AdamW + schedules + clip.

API mirrors optax minimally: ``opt.init(params) -> state``,
``opt.update(grads, state, params) -> (updates, state)``; apply with
``apply_updates``.  All state lives in pytrees so the whole thing jits and
shards like the params do.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.models.params import global_norm


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------
def make_schedule(cfg: OptimizerConfig) -> Callable[[jax.Array], jax.Array]:
    base = cfg.lr

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / jnp.maximum(cfg.warmup_steps, 1))
        if cfg.schedule == "constant":
            decay = 1.0
        elif cfg.schedule == "linear":
            frac = jnp.clip((step - cfg.warmup_steps)
                            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                            0.0, 1.0)
            decay = 1.0 - frac
        elif cfg.schedule == "cosine":
            frac = jnp.clip((step - cfg.warmup_steps)
                            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                            0.0, 1.0)
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            raise ValueError(cfg.schedule)
        return base * warm * decay

    return sched


# ---------------------------------------------------------------------------
# Gradient clipping
# ---------------------------------------------------------------------------
def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------
class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]


class SGDState(NamedTuple):
    step: jax.Array
    momentum: Any


def sgd(cfg: OptimizerConfig, momentum: float = 0.0) -> Optimizer:
    sched = make_schedule(cfg)

    def init(params):
        mom = (jax.tree.map(jnp.zeros_like, params) if momentum else None)
        return SGDState(jnp.zeros((), jnp.int32), mom)

    def update(grads, state, params=None):
        if cfg.grad_clip:
            grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        lr = sched(state.step)
        if momentum:
            new_m = jax.tree.map(lambda m, g: momentum * m + g,
                                 state.momentum, grads)
            upd = jax.tree.map(lambda m: (-lr * m).astype(m.dtype), new_m)
            return upd, SGDState(state.step + 1, new_m)
        upd = jax.tree.map(lambda g: (-lr * g).astype(g.dtype), grads)
        return upd, SGDState(state.step + 1, None)

    return Optimizer(init, update)


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adam(cfg: OptimizerConfig, weight_decay: Optional[float] = None,
         state_dtype: Optional[str] = None) -> Optimizer:
    """Adam/AdamW.  ``state_dtype`` overrides the moment dtype (bf16 for the
    very large architectures — see DESIGN.md memory budget)."""
    sched = make_schedule(cfg)
    wd = cfg.weight_decay if weight_decay is None else weight_decay

    def init(params):
        dt = jnp.dtype(state_dtype) if state_dtype else None
        z = lambda p: jnp.zeros(p.shape, dt or p.dtype)  # noqa: E731
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(z, params), jax.tree.map(z, params))

    def update(grads, state, params=None):
        if cfg.grad_clip:
            grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        step = state.step + 1
        lr = sched(state.step)
        b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
        mu = jax.tree.map(lambda m, g: (b1 * m + (1 - b1) * g).astype(m.dtype),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: (b2 * v + (1 - b2) * jnp.square(
                g.astype(jnp.float32))).astype(v.dtype),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def u(m, v, p):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v.astype(jnp.float32) / bc2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            if wd and p is not None:
                step_ = step_ + wd * p.astype(jnp.float32)
            return (-lr * step_).astype(p.dtype if p is not None else m.dtype)

        if params is None:
            params = jax.tree.map(lambda m: None, mu)
        upd = jax.tree.map(u, mu, nu, params)
        return upd, AdamState(step, mu, nu)

    return Optimizer(init, update)


def make_optimizer(cfg: OptimizerConfig, state_dtype: Optional[str] = None
                   ) -> Optimizer:
    if cfg.name == "sgd":
        return sgd(cfg)
    if cfg.name in ("adam", "adamw"):
        return adam(cfg, state_dtype=state_dtype)
    raise ValueError(cfg.name)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)
