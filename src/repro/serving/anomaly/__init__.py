"""Live anomaly-scoring service under failure (the Tol-FL serving layer).

Surface:

* :func:`~repro.serving.anomaly.bank.train_model_bank` /
  :class:`~repro.serving.anomaly.bank.ModelBank` — params export from a
  training scenario (global + isolated-per-client models);
* :class:`~repro.serving.anomaly.service.AnomalyService` /
  :class:`~repro.serving.anomaly.service.ServiceConfig` — the batched
  failover scoring service (fixed-size buckets, coalescing work queue,
  trace-driven liveness routing);
* :class:`~repro.serving.anomaly.service.ServiceReport` — sustained
  throughput, latency percentiles, failover/failback counts and
  per-regime AUROC.

See the module docstrings (and "Anomaly scoring service" in
``tests/README.md``) for semantics and the extension recipe.
"""
from repro.serving.anomaly.bank import ModelBank, train_model_bank
from repro.serving.anomaly.engine import (clear_score_cache,
                                          score_budget_name,
                                          score_executable)
from repro.serving.anomaly.service import (AnomalyService, ScoredWindow,
                                           ServiceConfig, ServiceReport)

__all__ = [
    "ModelBank", "train_model_bank",
    "AnomalyService", "ServiceConfig", "ServiceReport", "ScoredWindow",
    "score_executable", "score_budget_name", "clear_score_cache",
]
