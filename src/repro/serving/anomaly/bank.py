"""The service's parameter bank: trained models a scoring router picks.

A :class:`ModelBank` holds, for one finished training scenario,

* ``global_params`` — the scheme's final global model, the one every
  cluster head serves (Tol-FL trains ONE global model hierarchically;
  "route to the cluster-head model" means this row);
* ``iso_params`` — N genuinely-isolated per-client models, each trained
  on its own local shard from the shared init with NO communication
  (``simulate.trained_params(..., isolated=True)``) — the ResiliNet-
  style failover targets served while a client's head is dead;
* ``row_params`` — the two stacked into an ``(N + 1, ...)``-leaved
  pytree (row 0 global, row ``c + 1`` client ``c``'s isolated model)
  so a compiled bucket entry point selects per-request rows with one
  gather (:mod:`repro.serving.anomaly.engine`).

Both exports run through the simulator's own round loop
(:func:`repro.core.simulate.trained_params`), sharing one compiled
executable — the bank costs two dispatches of the training core, not a
new engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.failure import Failure, NO_FAILURE
from repro.core.simulate import SimConfig, trained_params
from repro.core.topology import Topology
from repro.models import detector as D
from repro.models.detector import DetectorModel, ModelLike


@dataclass(frozen=True, eq=False)
class ModelBank:
    """Trained params + routing geometry for one deployed detector."""

    detector: DetectorModel
    topology: Topology
    input_dim: int               # feature dim D of a window row
    global_params: Any           # pytree, the served cluster-head model
    iso_params: Any              # pytree, leaves (N, ...) isolated models
    row_params: Any              # pytree, leaves (N + 1, ...): stacked

    @property
    def num_clients(self) -> int:
        return self.topology.num_devices

    def row_index(self, client: int, failover: bool) -> int:
        """Bank row serving ``client``: the global model while its head
        is alive, its isolated model (row ``client + 1``) on failover."""
        assert 0 <= client < self.num_clients, client
        return client + 1 if failover else 0

    def client_iso_params(self, client: int):
        """Client ``client``'s isolated model (a host-side convenience
        for parity checks; the service gathers from ``row_params``)."""
        return jax.tree.map(lambda p: p[client], self.iso_params)


def train_model_bank(model: ModelLike, device_x: np.ndarray,
                     device_counts: np.ndarray, cfg: SimConfig,
                     failure: Failure = NO_FAILURE) -> ModelBank:
    """Train one scenario and bank its params for serving.

    The global model trains under ``cfg``/``failure`` exactly as the
    campaign engine would; the isolated failover models train clean
    (pre-deployment provisioning: each client's fallback is its own
    local model, independent of whatever outage the global run saw).
    Both runs share one compiled params-export executable."""
    det = D.as_detector(model)
    topo = cfg.topology()
    global_params, _, _ = trained_params(det, device_x, device_counts,
                                         cfg, failure=failure)
    _, iso_params, _ = trained_params(det, device_x, device_counts,
                                      cfg, isolated=True)
    row_params = jax.tree.map(
        lambda g, i: jnp.concatenate([g[None], i], axis=0),
        global_params, iso_params)
    return ModelBank(detector=det, topology=topo,
                     input_dim=int(device_x.shape[-1]),
                     global_params=global_params, iso_params=iso_params,
                     row_params=row_params)
