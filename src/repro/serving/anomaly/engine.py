"""Pre-compiled batched score entry points for the anomaly service.

One bucket = one fixed batch size = ONE compiled executable, in the
style of SHARK-Engine's ``BatchGenerateService`` (fixed-size entry
points per batch bucket).  The core is deliberately tiny::

    (row_params, row, x) -> (B, W) anomaly scores

``row_params`` is the service's stacked parameter bank — row 0 the
global (cluster-head) model, rows ``1..N`` the isolated per-client
models (:class:`repro.serving.anomaly.bank.ModelBank`) — and ``row``
(a scalar operand) selects the ONE model the whole batch scores
against; the service groups a tick's windows by routed row and
dispatches one bucket call per distinct row.  Row selection is a
gather, so scoring a failed-over group against row ``c + 1`` is
bit-identical to scoring the isolated model directly: the routing
decision never touches the arithmetic.  Keeping the weights UNIFORM
across the batch is what makes the bucket as fast as a direct
``anomaly_scores`` call — XLA folds the shared-weight vmap into the
same big GEMMs (a per-request weight gather would materialise B copies
of the model and lower to strided per-example GEMMs, measured ~1.4x
slower at B=64).

Executables resolve through the same three-layer discipline as the
campaign's AOT path (:func:`repro.core.campaign.aot_executable`):
in-process memory cache -> persistent serialized-executable cache
(:mod:`repro.core.compilecache`, so a fresh process serves warm with
zero traces and zero XLA) -> lower + compile (and store).  The cache
key is the canonical detector-model key plus the abstract-argument
signature — shapes (bucket size, window length, feature dim, bank
height) live in the avals, everything program-changing lives in the
detector spec, mirroring ``campaign._exe_key``'s contract.
"""
from __future__ import annotations

import functools
import threading
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.campaign import (_AOT_COMPILER_OPTIONS, AotTimes,
                                 _avals_signature)
from repro.core.failure import trace_alive_mask
from repro.models import detector as D
from repro.models.detector import ModelLike

_SCORE_CACHE: Dict[tuple, Any] = {}
_SCORE_LOCK = threading.Lock()


def _build_score_core(model: ModelLike):
    """(row_params, row, x) -> (B, W) scores; scalar ``row`` gathers one
    bank row, ``x`` is the (B, W, D) window batch scored against it
    (per-window via vmap — sequence detectors see each window whole)."""
    det = D.as_detector(model)

    def score(row_params, row, x):
        rows = jax.tree.map(lambda p: p[row], row_params)
        return jax.vmap(det.anomaly_scores, in_axes=(None, 0))(rows, x)

    return score


@functools.lru_cache(maxsize=32)
def _jitted_score(model: ModelLike):
    """Jitted score core, lru-cached on the CANONICAL model key (the
    caller normalises) — the trace cache the AOT path lowers through,
    so an AOT compile followed by a jit call retraces nothing."""
    return jax.jit(_build_score_core(model))


def score_executable(model: ModelLike, abstract_args
                     ) -> Tuple[Any, AotTimes]:
    """Compiled bucket entry point for ``abstract_args`` (the
    ``(row_params, row, x)`` aval tuple): memory -> disk -> compile,
    exactly the campaign AOT resolution.  Returns
    ``(compiled, AotTimes)``; ``compiled(*concrete)`` is bit-identical
    to the jitted call (same lowering)."""
    from repro.core import compilecache as _cc
    _cc.ensure_persistent_cache()
    key = (("serve_score", D.canonical_model_key(model))
           + _avals_signature(abstract_args))
    with _SCORE_LOCK:
        hit = _SCORE_CACHE.get(key)
    if hit is not None:
        return hit, AotTimes(source="memory")
    fp = _cc.exe_fingerprint(key)
    t0 = time.perf_counter()
    loaded = _cc.load_executable(fp)
    if loaded is not None:
        with _SCORE_LOCK:
            loaded = _SCORE_CACHE.setdefault(key, loaded)
        return loaded, AotTimes(compile_s=time.perf_counter() - t0,
                                source="disk")
    jitted = _jitted_score(D.canonical_model_key(model))
    t0 = time.perf_counter()
    lowered = jitted.lower(*abstract_args)
    t1 = time.perf_counter()
    compiled = lowered.compile(compiler_options=_AOT_COMPILER_OPTIONS)
    t2 = time.perf_counter()
    _cc.store_executable(fp, compiled)
    with _SCORE_LOCK:
        compiled = _SCORE_CACHE.setdefault(key, compiled)
    return compiled, AotTimes(lower_s=t1 - t0, compile_s=t2 - t1)


def alive_table(trace, num_devices: int, n_epochs: int):
    """(n_epochs, num_devices) float liveness table — every epoch's
    :func:`~repro.core.failure.trace_alive_mask` in ONE vmapped device
    call, precomputed at service construction so a tick indexes a host
    array instead of dispatching eager ops (measured ~1 ms/tick,
    comparable to a whole 64-bucket dispatch)."""
    return np.asarray(jax.vmap(
        lambda e: trace_alive_mask(trace, num_devices, e))(
            jnp.arange(n_epochs, dtype=jnp.int32)))


def score_budget_name(family: str = "ae") -> str:
    """The plancheck eqn budget governing the batched score core (one
    named ceiling per detector ``budget_family``, like
    :func:`repro.analysis.plancheck.budgets.bucket_budget_name`)."""
    return ("serving_score_core" if family == "ae"
            else f"serving_score_core:{family}")


def clear_score_cache() -> None:
    """Drop the in-process compiled-bucket cache (the persistent disk
    cache is untouched, mirroring ``campaign.clear_executable_caches``)."""
    with _SCORE_LOCK:
        _SCORE_CACHE.clear()
    _jitted_score.cache_clear()
