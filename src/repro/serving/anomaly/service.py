"""Batched anomaly-scoring service with inference-time failover.

The live half of the paper's failure-tolerance story: clients stream
traffic windows, the service coalesces them into fixed-size batch
buckets (each bucket ONE pre-compiled entry point,
:mod:`repro.serving.anomaly.engine`), routes every window to its
cluster-head model, and — reusing :func:`~repro.core.failure.
trace_alive_mask` semantics at inference time — fails over
ResiliNet-style to the client's isolated model while its head is dead,
failing back on recovery.

Mechanics, SHARK-Engine ``BatchGenerateService`` style:

* **buckets** — ``ServiceConfig.bucket_sizes`` (default 1/8/64), each
  compiled ahead of time at construction; a warm persistent cache
  (:mod:`repro.core.compilecache`) means a FRESH process stands up the
  whole bucket set with zero traces and zero XLA compiles.
* **work queue** — :meth:`AnomalyService.submit` enqueues
  ``(client, window)`` FIFO; :meth:`AnomalyService.tick` drains it,
  grouping each drained chunk by ROUTED MODEL ROW and packing every
  group into the smallest bucket that fits (padding the remainder).
  Results reassemble in submission order, so a client's windows are
  never reordered and no submitted window is ever dropped.  The
  per-row grouping is the throughput move: a bucket with uniform
  weights lowers to the same big GEMMs as a direct
  ``anomaly_scores`` call (see :mod:`~repro.serving.anomaly.engine`).
* **liveness** — one :class:`~repro.core.failure.FailureTrace` (or a
  sampled :class:`~repro.core.processes.FailureProcess`) drives the
  per-tick alive mask (precomputed over the horizon, so a tick costs
  no eager device ops); the service tick IS the trace epoch.
* **routing** — head alive: bank row 0 (the global model); head dead:
  row ``client + 1`` (the isolated model).  Row selection is a gather
  inside the compiled core, so failover scores are bit-identical to
  scoring the isolated model directly.

:meth:`AnomalyService.report` summarises a served stream: sustained
windows/sec, p50/p99 latency, failover/failback counts and — when
submissions carry labels — per-regime AUROC (windows served by a head
vs. windows served by an isolated fallback).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.failure import Failure, NO_FAILURE, PAD_EPOCH, as_trace
from repro.core.processes import FailureProcess, process_seed
from repro.serving.anomaly import engine
from repro.serving.anomaly.bank import ModelBank
from repro.training.metrics import auroc


@dataclass(frozen=True)
class ServiceConfig:
    """Static service shape: everything here is shape-only (it lands in
    the compiled buckets' abstract-argument signatures, never in the
    program), classified as such in ``plancheck.cachekey``."""

    bucket_sizes: Tuple[int, ...] = (1, 8, 64)   # one executable each
    window: int = 16                             # rows per traffic window

    def __post_init__(self):
        assert self.bucket_sizes, "at least one batch bucket"
        assert all(b > 0 for b in self.bucket_sizes), self.bucket_sizes
        assert self.window > 0, self.window


class ScoredWindow(NamedTuple):
    """One scored traffic window, as returned by :meth:`tick`."""
    client: int
    seq: int                 # per-client submission sequence number
    epoch: int               # service tick it was scored at
    scores: np.ndarray       # (window,) per-row anomaly scores
    served_by: str           # "head" | "isolated"
    latency_s: float         # submit -> scored wall clock


@dataclass
class ServiceReport:
    """Summary of a served stream (the serving-side companion of the
    campaign's AUROC tables)."""
    windows: int             # windows scored
    dropped: int             # submitted but never scored (always 0)
    batches: int             # compiled-bucket dispatches
    windows_per_s: float     # sustained: windows / busy wall
    p50_ms: float            # per-window submit->scored latency
    p99_ms: float
    failovers: int           # head->isolated transitions
    failbacks: int           # isolated->head transitions
    auroc_head: float        # AUROC of head-served windows (nan: no labels)
    auroc_isolated: float    # AUROC of failover-served windows
    bucket_batches: Dict[int, int] = field(default_factory=dict)

    def describe(self) -> str:
        return (f"{self.windows} windows in {self.batches} batches "
                f"({self.windows_per_s:.0f} win/s, p50 "
                f"{self.p50_ms:.2f} ms, p99 {self.p99_ms:.2f} ms), "
                f"{self.failovers} failovers / {self.failbacks} "
                f"failbacks, AUROC head={self.auroc_head:.3f} "
                f"isolated={self.auroc_isolated:.3f}, "
                f"dropped={self.dropped}")


class _Request(NamedTuple):
    client: int
    seq: int
    window: np.ndarray
    labels: Optional[np.ndarray]
    t_submit: float


class AnomalyService:
    """Batched failover scoring service over a trained
    :class:`~repro.serving.anomaly.bank.ModelBank`.

    ``failure`` may be ``None`` (nothing ever fails), any legacy
    :class:`FailureSpec` / explicit :class:`FailureTrace`, or a
    :class:`FailureProcess` — sampled once at construction with the
    same SHA-256-derived seeding as campaign trace grids, so a service
    stood up twice replays the identical outage."""

    def __init__(self, bank: ModelBank,
                 config: ServiceConfig = ServiceConfig(),
                 failure: Union[None, Failure, FailureProcess] = None,
                 sample_seed: int = 0, horizon: int = 256):
        self.bank = bank
        self.config = config
        topo = bank.topology
        if failure is None:
            self._trace = as_trace(NO_FAILURE, topo)
        elif isinstance(failure, FailureProcess):
            rng = np.random.default_rng(
                process_seed(sample_seed, failure, 0))
            self._trace = failure.sample(rng, topo, horizon)
        else:
            self._trace = as_trace(failure, topo)
        self._heads = np.asarray(topo.heads)
        self._cluster_of = np.asarray(topo.device_cluster_array())
        # liveness precomputed over the horizon in ONE device call:
        # a tick then indexes a host table instead of dispatching eager
        # trace_alive_mask ops (measured ~1 ms/tick, comparable to a
        # whole 64-bucket dispatch)
        ep = np.asarray(self._trace.epochs)
        real = ep[ep < PAD_EPOCH]
        n_epochs = int(max(horizon, (int(real.max()) + 2) if real.size
                           else 1))
        self._alive_table = engine.alive_table(self._trace,
                                               topo.num_devices, n_epochs)
        self._buckets = tuple(sorted(set(config.bucket_sizes)))
        self._pending: deque = deque()
        self._seq: Dict[int, int] = {}
        self._mode: Dict[int, str] = {}       # last served_by per client
        self.epoch = 0
        self.timeline: List[Tuple[int, int, str]] = []
        # counters the report aggregates
        self._submitted = 0
        self._scored = 0
        self._batches = 0
        self._bucket_batches: Dict[int, int] = {b: 0 for b in self._buckets}
        self._failovers = 0
        self._failbacks = 0
        self._busy_s = 0.0
        self._latencies: List[float] = []
        self._regime_scores: Dict[str, list] = {"head": [], "isolated": []}
        self._regime_labels: Dict[str, list] = {"head": [], "isolated": []}
        # pre-compile every bucket (memory -> disk -> compile)
        self._compiled: Dict[int, object] = {}
        self.compile_sources: Dict[int, str] = {}
        param_avals = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype),
            bank.row_params)
        for bs in self._buckets:
            avals = (param_avals,
                     jax.ShapeDtypeStruct((), jnp.int32),
                     jax.ShapeDtypeStruct((bs, config.window,
                                           bank.input_dim), jnp.float32))
            compiled, times = engine.score_executable(bank.detector, avals)
            self._compiled[bs] = compiled
            self.compile_sources[bs] = times.source

    # ------------------------------------------------------------------
    # client side
    # ------------------------------------------------------------------
    def submit(self, client: int, window: np.ndarray,
               labels: Optional[np.ndarray] = None) -> int:
        """Enqueue one traffic window for ``client``; returns the
        client's submission sequence number.  ``window`` is
        ``(config.window, input_dim)`` float32; optional ``labels``
        (one per row, 1 = anomalous) feed the per-regime AUROC."""
        window = np.asarray(window, np.float32)
        expect = (self.config.window, self.bank.input_dim)
        assert window.shape == expect, (window.shape, expect)
        seq = self._seq.get(client, 0)
        self._seq[client] = seq + 1
        self._pending.append(_Request(
            int(client), seq, window,
            None if labels is None else np.asarray(labels),
            time.perf_counter()))
        self._submitted += 1
        return seq

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ------------------------------------------------------------------
    # service side
    # ------------------------------------------------------------------
    def alive_mask(self, epoch: Optional[int] = None) -> np.ndarray:
        """(N,) liveness at a service tick (default: the current one).
        Liveness is a step function of the epoch, so epochs past the
        precomputed table clamp to its last (post-final-event) row."""
        e = self.epoch if epoch is None else epoch
        return self._alive_table[min(e, len(self._alive_table) - 1)]

    def _pick_bucket(self, n: int) -> int:
        for b in self._buckets:
            if b >= n:
                return b
        return self._buckets[-1]

    def tick(self) -> List[ScoredWindow]:
        """Drain the queue at the current epoch, then advance it.

        Every pending window is scored (zero drops): the queue is
        consumed FIFO in chunks of at most the largest bucket; each
        chunk is grouped by ROUTED BANK ROW and every group dispatched
        through the smallest bucket that fits (remainder rows padded
        with zero windows — padding is sliced off before results are
        reassembled in submission order)."""
        alive = self.alive_mask()
        out: List[ScoredWindow] = []
        while self._pending:
            t0 = time.perf_counter()
            n = min(len(self._pending), self._buckets[-1])
            chunk = [self._pending.popleft() for _ in range(n)]
            modes: List[str] = []
            groups: Dict[int, List[int]] = {}
            for i, r in enumerate(chunk):
                head = int(self._heads[self._cluster_of[r.client]])
                failover = alive[head] <= 0.0
                modes.append("isolated" if failover else "head")
                row = self.bank.row_index(r.client, failover)
                groups.setdefault(row, []).append(i)
            scores = np.empty((n, self.config.window), np.float32)
            for row, members in groups.items():
                bs = self._pick_bucket(len(members))
                x = np.zeros((bs, self.config.window,
                              self.bank.input_dim), np.float32)
                for j, i in enumerate(members):
                    x[j] = chunk[i].window
                got = np.asarray(self._compiled[bs](
                    self.bank.row_params, np.int32(row), x))
                scores[np.asarray(members)] = got[:len(members)]
                self._batches += 1
                self._bucket_batches[bs] += 1
            t1 = time.perf_counter()
            self._busy_s += t1 - t0
            for i, (r, mode) in enumerate(zip(chunk, modes)):
                prev = self._mode.get(r.client, "head")
                if mode != prev:
                    if mode == "isolated":
                        self._failovers += 1
                        self.timeline.append((self.epoch, r.client,
                                              "failover"))
                    else:
                        self._failbacks += 1
                        self.timeline.append((self.epoch, r.client,
                                              "failback"))
                self._mode[r.client] = mode
                self._scored += 1
                self._latencies.append(t1 - r.t_submit)
                if r.labels is not None:
                    self._regime_scores[mode].append(scores[i])
                    self._regime_labels[mode].append(r.labels)
                out.append(ScoredWindow(r.client, r.seq, self.epoch,
                                        scores[i], mode, t1 - r.t_submit))
        self.epoch += 1
        return out

    def run(self, epochs: int) -> List[ScoredWindow]:
        """Tick ``epochs`` times (anything queued between ticks by the
        caller is scored on the next one)."""
        out: List[ScoredWindow] = []
        for _ in range(epochs):
            out.extend(self.tick())
        return out

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def _regime_auroc(self, regime: str) -> float:
        if not self._regime_scores[regime]:
            return float("nan")
        s = np.concatenate([np.ravel(v)
                            for v in self._regime_scores[regime]])
        y = np.concatenate([np.ravel(v)
                            for v in self._regime_labels[regime]])
        return auroc(s, y)

    def report(self) -> ServiceReport:
        lat = np.asarray(self._latencies) * 1e3 if self._latencies \
            else np.zeros((1,))
        return ServiceReport(
            windows=self._scored,
            dropped=self._submitted - self._scored - len(self._pending),
            batches=self._batches,
            windows_per_s=(self._scored / self._busy_s
                           if self._busy_s > 0 else 0.0),
            p50_ms=float(np.percentile(lat, 50)),
            p99_ms=float(np.percentile(lat, 99)),
            failovers=self._failovers,
            failbacks=self._failbacks,
            auroc_head=self._regime_auroc("head"),
            auroc_isolated=self._regime_auroc("isolated"),
            bucket_batches=dict(self._bucket_batches))
