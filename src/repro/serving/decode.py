"""Serving: KV/recurrent-state caches, prefill, and single-token decode.

``serve_step`` (decode) is what the decode_32k / long_500k input shapes
lower: ONE new token against a cache of ``seq_len`` (full-attention archs),
``window`` (sliding-window variants — the sub-quadratic long-context path),
or O(1) recurrent state (ssm / hybrid).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, LOCAL_ATTN, ModelConfig, RECURRENT,
                                RWKV)
from repro.models import attention as A
from repro.models import params as P
from repro.models import rglru as G
from repro.models import rwkv6 as R
from repro.models.mlp import mlp_apply
from repro.models.moe import moe_apply
from repro.models.transformer import (embed_tokens, logits_fn,
                                      sinusoidal_positions,
                                      unit_counts, unit_pattern)
from repro.sharding import logical as L


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------
def layer_cache_shape(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                      long_context: bool = False) -> Dict[str, Any]:
    """ShapeDtypeStructs for one layer's cache entry."""
    a = cfg.attention
    dt = jnp.dtype(cfg.dtype)
    if kind in (ATTN, LOCAL_ATTN):
        if kind == LOCAL_ATTN and a.sliding_window:
            Sc = min(seq_len, a.sliding_window)
        elif long_context:
            Sc = min(seq_len, a.long_context_window)
        else:
            Sc = seq_len
        kv = jax.ShapeDtypeStruct((batch, Sc, a.num_kv_heads, a.head_dim), dt)
        return {"k": kv, "v": kv}
    if kind == RECURRENT:
        W = cfg.recurrent.lru_width or cfg.d_model
        cw = cfg.recurrent.conv1d_width
        return {"h": jax.ShapeDtypeStruct((batch, W), jnp.float32),
                "conv": jax.ShapeDtypeStruct((batch, cw - 1, W), dt)}
    if kind == RWKV:
        H, N = cfg.recurrent.num_heads, cfg.recurrent.head_size
        return {"shift_tm": jax.ShapeDtypeStruct((batch, cfg.d_model), dt),
                "wkv": jax.ShapeDtypeStruct((batch, H, N, N), jnp.float32),
                "shift_cm": jax.ShapeDtypeStruct((batch, cfg.d_model), dt)}
    raise ValueError(kind)


def cache_shape(cfg: ModelConfig, batch: int, seq_len: int,
                long_context: bool = False) -> Dict[str, Any]:
    """Full-model cache ShapeDtypeStruct tree (stacked over scan units)."""
    unit = unit_pattern(cfg)
    n_units, n_tail = unit_counts(cfg)
    per_unit = {f"l{i}": layer_cache_shape(cfg, kind, batch, seq_len,
                                           long_context)
                for i, (kind, _) in enumerate(unit)}
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_units,) + s.shape, s.dtype),
        per_unit)
    cache: Dict[str, Any] = {"units": stacked}
    if n_tail:
        cache["tail"] = {f"l{i}": layer_cache_shape(cfg, unit[i][0], batch,
                                                    seq_len, long_context)
                         for i in range(n_tail)}
    if cfg.is_encdec:
        a = cfg.attention
        xkv = jax.ShapeDtypeStruct(
            (cfg.num_layers, batch, cfg.encoder_seq, a.num_kv_heads,
             a.head_dim), jnp.dtype(cfg.dtype))
        cache["cross"] = {"k": xkv, "v": xkv}
    return cache


def cache_logical_axes(tree) -> Any:
    """Logical sharding axes for a cache tree built by ``cache_shape``."""
    def leaf_axes(path, s):
        names = [p.key for p in path if hasattr(p, "key")]
        nd = len(s.shape)
        if names[-1] in ("k", "v"):
            if names[0] == "cross" or names[0] == "units":
                # (L?, B, Sc, KVH, D)
                base = ("batch", "cache_seq", "kv_heads", None)
                return ("layers",) + base if nd == 5 else base
            return ("batch", "cache_seq", "kv_heads", None)
        if names[-1] == "wkv":
            base = ("batch", "heads", None, None)
            return ("layers",) + base if nd == 5 else base
        if names[-1] == "h":
            base = ("batch", "state")
            return ("layers",) + base if nd == 3 else base
        if names[-1] == "conv":
            base = ("batch", None, "state")
            return ("layers",) + base if nd == 4 else base
        if names[-1] in ("shift_tm", "shift_cm"):
            base = ("batch", "embed")
            return ("layers",) + base if nd == 3 else base
        return (None,) * nd

    return jax.tree_util.tree_map_with_path(leaf_axes, tree)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               long_context: bool = False):
    """Zero-initialised concrete cache."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shape(cfg, batch, seq_len, long_context))


def pad_cache(cache, cfg: ModelConfig, prompt_len: int, target_len: int):
    """Extend a prefill-produced cache so decode can run past the prompt.

    Attention k/v entries are padded with zero slots up to ``target_len``
    (windowed layers stay at their window size) and rolled so the ring
    invariant — slot i holds position = i (mod Sc) — is restored; the
    padded slots are excluded by :func:`cache_slot_validity` until they
    are written.  Recurrent / cross entries are O(1) state: untouched."""
    a = cfg.attention
    unit = unit_pattern(cfg)

    def leaf(path, x):
        names = [p.key for p in path if hasattr(p, "key")]
        if names[-1] not in ("k", "v") or names[0] == "cross":
            return x
        li = int(names[1][1:]) if names[1].startswith("l") else 0
        kind = unit[li % len(unit)][0]
        cap = (a.sliding_window
               if (kind == LOCAL_ATTN and a.sliding_window) else None)
        tgt = min(target_len, cap) if cap else target_len
        axis = x.ndim - 3                       # the cache_seq dim
        Sc = x.shape[axis]
        if Sc >= tgt:
            # already at (or beyond) target; restore ring alignment if the
            # prefill truncated to a window (slot j held prompt_len-Sc+j)
            if prompt_len > Sc:
                return jnp.roll(x, prompt_len % Sc, axis=axis)
            return x
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, tgt - Sc)
        return jnp.pad(x, pad)

    return jax.tree_util.tree_map_with_path(leaf, cache)


# ---------------------------------------------------------------------------
# Decode layer application
# ---------------------------------------------------------------------------
def _decode_window(cfg: ModelConfig, entry: Dict[str, Any]) -> Optional[int]:
    """Effective attention window for a decode cache entry.

    A layer decodes against a ring of size Sc; the window is Sc whenever the
    cache was sized BY a window (sliding_window or the long-context
    variant), and None (full attention over valid slots) otherwise."""
    a = cfg.attention
    Sc = entry["k"].shape[1]
    if a.sliding_window and Sc == a.sliding_window:
        return a.sliding_window
    if Sc == a.long_context_window:
        return a.long_context_window
    return None


def _sinusoidal_at(position: jax.Array, d: int) -> jax.Array:
    half = jnp.arange(0, d, 2, dtype=jnp.float32)
    div = jnp.exp(half * (-jnp.log(10000.0) / d))
    ang = position.astype(jnp.float32) * div
    pe = jnp.zeros((d,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang))
    pe = pe.at[1::2].set(jnp.cos(ang))
    return pe


def _apply_layer_decode(p: P.Params, x: jax.Array, cfg: ModelConfig,
                        kind: str, use_moe: bool, entry: Dict[str, Any],
                        position: jax.Array
                        ) -> Tuple[jax.Array, Dict[str, Any]]:
    h = P.rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
    if kind in (ATTN, LOCAL_ATTN):
        h, new_entry = A.attn_decode(p["mix"], h, entry, cfg.attention,
                                     position, cfg.norm_eps,
                                     window=_decode_window(cfg, entry))
    elif kind == RECURRENT:
        h, st = G.rglru_apply(p["mix"], h, cfg,
                              state={"h": entry["h"], "conv": entry["conv"]})
        new_entry = st
    elif kind == RWKV:
        h, st = R.timemix_apply(p["mix"], h, cfg,
                                state={"shift": entry["shift_tm"],
                                       "wkv": entry["wkv"]})
        new_entry = {"shift_tm": st["shift"], "wkv": st["wkv"],
                     "shift_cm": entry["shift_cm"]}
    else:
        raise ValueError(kind)
    x = x + h
    h = P.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
    if kind == RWKV:
        h, shift_cm = R.channelmix_apply(p["mlp"], h, state=entry["shift_cm"])
        new_entry = dict(new_entry, shift_cm=shift_cm)
    elif use_moe:
        h, _ = moe_apply(p["mlp"], h, cfg.moe, cfg.act, cfg.glu, chunk=1)
    else:
        h = mlp_apply(p["mlp"], h, cfg.act, cfg.glu)
    return x + h, new_entry


def _cross_decode(p: P.Params, x: jax.Array, xk: jax.Array, xv: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    a = cfg.attention
    B = x.shape[0]
    h = P.rmsnorm_apply(p["norm"], x, cfg.norm_eps)
    q = P.dense_apply(p["attn"]["q"], h, h.dtype).reshape(
        B, 1, a.num_heads, a.head_dim)
    # cross k/v are precomputed: attend directly
    KVH = xk.shape[2]
    G_ = a.num_heads // KVH
    qg = q.reshape(B, KVH, G_, a.head_dim)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, xk,
                   preferred_element_type=jnp.float32) / (a.head_dim ** 0.5)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", pr.astype(xv.dtype), xv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, a.num_heads * a.head_dim).astype(x.dtype)
    return x + P.dense_apply(p["attn"]["o"], o, x.dtype)


# ---------------------------------------------------------------------------
# serve_step: one-token decode
# ---------------------------------------------------------------------------
def decode_step(params: P.Params, cfg: ModelConfig, tokens: jax.Array,
                cache, position: jax.Array
                ) -> Tuple[jax.Array, Any]:
    """tokens: (B, 1) int32; returns (logits (B, Vp), new cache)."""
    x = embed_tokens(params, cfg, tokens)
    if cfg.attention.rope_theta == 0:
        x = x + _sinusoidal_at(position, cfg.d_model).astype(x.dtype)[None, None]
    unit = unit_pattern(cfg)
    n_units, n_tail = unit_counts(cfg)

    if cfg.is_encdec:
        def body(x, ps):
            up, cp, xk, xv, entry = ps
            h = P.rmsnorm_apply(up["l0"]["norm1"], x, cfg.norm_eps)
            h, new_entry = A.attn_decode(up["l0"]["mix"], h, entry["l0"],
                                         cfg.attention, position,
                                         cfg.norm_eps)
            x = x + h
            x = _cross_decode(cp, x, xk, xv, cfg)
            h = P.rmsnorm_apply(up["l0"]["norm2"], x, cfg.norm_eps)
            x = x + mlp_apply(up["l0"]["mlp"], h, cfg.act, cfg.glu)
            return x, {"l0": new_entry}

        x, new_units = jax.lax.scan(
            body, x, (params["units"], params["cross"]["layers"],
                      cache["cross"]["k"], cache["cross"]["v"],
                      cache["units"]))
        new_cache = dict(cache, units=new_units)
    else:
        def body(x, ps):
            up, entries = ps
            new_entries = {}
            for i, (kind, use_moe) in enumerate(unit):
                x, ne = _apply_layer_decode(up[f"l{i}"], x, cfg, kind,
                                            use_moe, entries[f"l{i}"],
                                            position)
                new_entries[f"l{i}"] = ne
            return x, new_entries

        x, new_units = jax.lax.scan(body, x, (params["units"],
                                              cache["units"]))
        new_cache = dict(cache, units=new_units)
        for i in range(n_tail):
            kind, use_moe = unit[i]
            x, ne = _apply_layer_decode(params["tail"][f"l{i}"], x, cfg,
                                        kind, use_moe, cache["tail"][f"l{i}"],
                                        position)
            new_cache["tail"] = dict(new_cache.get("tail", {}), **{f"l{i}": ne})
    x = P.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, cfg, x[:, 0, :])
    logits = L.constrain(logits, ("batch", "vocab"))
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill: process a prompt, build the cache, return last-token logits
# ---------------------------------------------------------------------------
def _attn_prefill(p, h, cfg: ModelConfig, kind: str, use_pallas: bool):
    a = cfg.attention
    B, S, _ = h.shape
    window = a.sliding_window if kind == LOCAL_ATTN else a.sliding_window
    q, k, v = A.project_qkv(p, h, a, jnp.arange(S), cfg.norm_eps,
                            compute_dtype=h.dtype)
    if use_pallas:
        from repro.kernels import flash_attention as fa
        out = fa.flash_attention(q, k, v, causal=True, window=window)
    else:
        out = A.blocked_attention(q, k, v, causal=True, window=window)
    out = out.reshape(B, S, a.num_heads * a.head_dim)
    out = P.dense_apply(p["o"], out, h.dtype)
    if kind == LOCAL_ATTN and a.sliding_window and S > a.sliding_window:
        k, v = k[:, -a.sliding_window:], v[:, -a.sliding_window:]
    return L.constrain(out, ("batch", "seq", "embed")), {"k": k, "v": v}


def _apply_layer_prefill(p, x, cfg: ModelConfig, kind: str, use_moe: bool,
                         use_pallas: bool):
    h = P.rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
    if kind in (ATTN, LOCAL_ATTN):
        h, entry = _attn_prefill(p["mix"], h, cfg, kind, use_pallas)
    elif kind == RECURRENT:
        h, st = G.rglru_apply(p["mix"], h, cfg, use_pallas=use_pallas)
        entry = st
    elif kind == RWKV:
        h, st = R.timemix_apply(p["mix"], h, cfg, use_pallas=use_pallas)
        entry = {"shift_tm": st["shift"], "wkv": st["wkv"]}
    else:
        raise ValueError(kind)
    x = x + h
    h = P.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
    if kind == RWKV:
        h, shift_cm = R.channelmix_apply(p["mlp"], h)
        entry = dict(entry, shift_cm=shift_cm)
    elif use_moe:
        h, _ = moe_apply(p["mlp"], h, cfg.moe, cfg.act, cfg.glu)
    else:
        h = mlp_apply(p["mlp"], h, cfg.act, cfg.glu)
    return x + h, entry


def prefill(params: P.Params, cfg: ModelConfig, batch: Dict[str, Any],
            use_pallas: bool = False) -> Tuple[jax.Array, Any]:
    """batch: {'tokens': (B,S)} (+ 'frames'/'prefix').

    Returns (last-token logits (B, Vp), cache)."""
    from repro.models.transformer import encode
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens)
    if cfg.frontend.kind == "vision" and "prefix" in batch:
        x = jnp.concatenate([batch["prefix"].astype(x.dtype), x], axis=1)
    if cfg.attention.rope_theta == 0:
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model
                                     ).astype(x.dtype)[None]
    unit = unit_pattern(cfg)
    n_units, n_tail = unit_counts(cfg)
    cache: Dict[str, Any] = {}

    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["frames"], use_pallas)
        a = cfg.attention

        def body(x, ps):
            up, cp = ps
            h = P.rmsnorm_apply(up["l0"]["norm1"], x, cfg.norm_eps)
            h, entry = _attn_prefill(up["l0"]["mix"], h, cfg, ATTN,
                                     use_pallas)
            x = x + h
            from repro.models.transformer import cross_attend
            h2 = P.rmsnorm_apply(cp["norm"], x, cfg.norm_eps)
            x = x + cross_attend(cp["attn"], h2, enc_out, cfg, use_pallas)
            B, F = enc_out.shape[0], enc_out.shape[1]
            xk = P.dense_apply(cp["attn"]["k"], enc_out, x.dtype).reshape(
                B, F, a.num_kv_heads, a.head_dim)
            xv = P.dense_apply(cp["attn"]["v"], enc_out, x.dtype).reshape(
                B, F, a.num_kv_heads, a.head_dim)
            h = P.rmsnorm_apply(up["l0"]["norm2"], x, cfg.norm_eps)
            x = x + mlp_apply(up["l0"]["mlp"], h, cfg.act, cfg.glu)
            return x, ({"l0": entry}, xk, xv)

        x, (units_cache, xks, xvs) = jax.lax.scan(
            body, x, (params["units"], params["cross"]["layers"]))
        cache["units"] = units_cache
        cache["cross"] = {"k": xks, "v": xvs}
    else:
        def body(x, up):
            entries = {}
            for i, (kind, use_moe) in enumerate(unit):
                x, e = _apply_layer_prefill(up[f"l{i}"], x, cfg, kind,
                                            use_moe, use_pallas)
                entries[f"l{i}"] = e
            return x, entries

        x, units_cache = jax.lax.scan(body, x, params["units"])
        cache["units"] = units_cache
        if n_tail:
            cache["tail"] = {}
            for i in range(n_tail):
                kind, use_moe = unit[i]
                x, e = _apply_layer_prefill(params["tail"][f"l{i}"], x, cfg,
                                            kind, use_moe, use_pallas)
                cache["tail"][f"l{i}"] = e
    x = P.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, cfg, x[:, -1, :])
    return L.constrain(logits, ("batch", "vocab")), cache
