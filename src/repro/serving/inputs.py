"""Synthetic serving inputs shared by the LLM serving entry points.

``examples/serve_batch.py`` and ``repro.launch.serve`` used to build
their random prompt/frames/prefix batches with duplicated inline code
(and fixed PRNG keys, so latency numbers could never be re-drawn);
this is the one helper both call, seeded explicitly for
run-to-run-reproducible benchmarks.
"""
from __future__ import annotations

from typing import Dict

import jax


def synthetic_batch(cfg, batch_size: int, prompt_len: int,
                    seed: int = 0) -> Dict[str, jax.Array]:
    """The prefill input batch for one architecture config: random
    ``tokens`` always, ``frames`` for encoder-decoder archs, ``prefix``
    for vision frontends.  Distinct streams derive from one ``seed``
    via ``fold_in``, so equal seeds reproduce the batch exactly."""
    root = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(
        jax.random.fold_in(root, 1), (batch_size, prompt_len), 0,
        cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(root, 2),
            (batch_size, cfg.encoder_seq or 16, cfg.d_model))
    if cfg.frontend.kind == "vision":
        batch["prefix"] = jax.random.normal(
            jax.random.fold_in(root, 3),
            (batch_size, cfg.frontend.frontend_seq or 16, cfg.d_model))
    return batch
