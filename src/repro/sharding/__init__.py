from repro.sharding.logical import (FULL_MANUAL_FALLBACK, activate_mesh,
                                    compat_shard_map, constrain,
                                    current_mesh, current_rules,
                                    mesh_axis_sizes, rules_for,
                                    scenario_shard_map, sharding_for,
                                    spec_for)

__all__ = ["FULL_MANUAL_FALLBACK", "activate_mesh", "compat_shard_map",
           "constrain", "current_mesh", "current_rules", "mesh_axis_sizes",
           "rules_for", "scenario_shard_map", "sharding_for", "spec_for"]
