from repro.sharding.logical import (activate_mesh, constrain, current_mesh,
                                    current_rules, mesh_axis_sizes, rules_for,
                                    sharding_for, spec_for)

__all__ = ["activate_mesh", "constrain", "current_mesh", "current_rules",
           "mesh_axis_sizes", "rules_for", "sharding_for", "spec_for"]
