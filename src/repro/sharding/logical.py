"""Logical-axis sharding rules (MaxText-style).

Every parameter/activation dimension gets a *logical* axis name; a rules
table maps logical names to physical mesh axes ("pod", "data", "model").
``spec_for(axes, shape)`` builds a PartitionSpec, dropping any mapping whose
dimension is not divisible by the mesh-axis size (GSPMD/jit reject uneven
shardings — e.g. 40 heads over a 16-wide model axis stay replicated and the
partitioner shards the surrounding matmuls instead).

Two storage modes (DESIGN.md section 2):

* ``replicated_data`` — params sharded over "model" only, replicated over
  the Tol-FL data axis.  Required for the paper-faithful ring schedule
  (each federated group holds a full model replica) and for E>1 local
  epochs (per-group params diverge between syncs).
* ``fsdp`` — params additionally sharded over "data" on the d_model dims.
  Required for the 100B+ architectures; only compatible with the
  weighted-psum schedule at E=1 (gradient sync every step).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

# Logical axis vocabulary.
BATCH = "batch"
SEQ = "seq"
EMBED = "embed"          # d_model dims
FF = "ff"                # mlp hidden
HEADS = "heads"
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
VOCAB = "vocab"
EXPERTS = "experts"
LAYERS = "layers"        # stacked scan dim
CACHE_SEQ = "cache_seq"  # kv-cache length dim (sequence-parallel decode)
STATE = "state"          # recurrent state width
CONV = "conv"

# rules: logical -> physical mesh axis (or tuple, or None)
BASE_RULES = {
    BATCH: ("pod", "data"),
    SEQ: None,
    EMBED: None,
    FF: "model",
    HEADS: "model",
    KV_HEADS: "model",
    HEAD_DIM: None,
    VOCAB: "model",
    EXPERTS: "model",
    LAYERS: None,
    CACHE_SEQ: ("pod", "data"),   # flash-decoding style sequence-parallel cache
    STATE: "model",
    CONV: None,
}

# FSDP overlay: additionally shard the d_model dims over the data axis
# (storage + all-gather at use; gradient reduce-scatter is the weighted-psum
# Tol-FL schedule).
FSDP_RULES = dict(BASE_RULES)
FSDP_RULES.update({
    EMBED: ("pod", "data"),
})


def rules_for(mode: str = "replicated_data") -> dict:
    return FSDP_RULES if mode == "fsdp" else dict(BASE_RULES)


# ---------------------------------------------------------------------------
# Active mesh plumbing.  Launchers call ``activate_mesh``; model code calls
# ``constrain`` which is a no-op when no mesh is active (CPU tests).
# ---------------------------------------------------------------------------
_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def current_rules() -> dict:
    return getattr(_STATE, "rules", BASE_RULES)


@contextlib.contextmanager
def activate_mesh(mesh: Mesh, rules: Optional[dict] = None):
    # NOTE: deliberately NOT entering `with mesh:`/use_mesh — an ambient
    # mesh makes array-creation ops inherit context shardings, which
    # conflicts inside partial-manual shard_map regions (Manual vs Auto
    # axis types).  All shardings here are explicit NamedShardings.
    prev = (current_mesh(), current_rules())
    _STATE.mesh, _STATE.rules = mesh, (rules or BASE_RULES)
    try:
        yield mesh
    finally:
        _STATE.mesh, _STATE.rules = prev


def current_manual() -> frozenset:
    return getattr(_STATE, "manual", frozenset())


@contextlib.contextmanager
def manual_axes(names):
    """Mark mesh axes as shard_map-manual: ``constrain`` then omits them
    (inside the manual region each shard only sees its slice, and
    with_sharding_constraint may not mention manual axes)."""
    prev = current_manual()
    _STATE.manual = prev | frozenset(names)
    try:
        yield
    finally:
        _STATE.manual = prev


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def spec_for(axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None,
             rules: Optional[dict] = None,
             mesh: Optional[Mesh] = None) -> PS:
    """PartitionSpec from logical axis names, dropping uneven shardings.

    Mesh axes absent from the active mesh (e.g. "pod" on single-pod) are
    dropped; a physical axis is used at most once per spec.
    """
    rules = rules or current_rules()
    mesh = mesh or current_mesh()
    if mesh is None:
        return PS()
    avail = set(mesh.axis_names)
    used = set()
    out = []
    for i, ax in enumerate(axes):
        phys = rules.get(ax) if ax is not None else None
        if phys is None:
            out.append(None)
            continue
        if isinstance(phys, str):
            phys = (phys,)
        keep = []
        prod = 1
        for p in phys:
            if p not in avail or p in used:
                continue
            sz = _axis_size(mesh, p)
            if shape is not None and shape[i] % (prod * sz) != 0:
                continue
            keep.append(p)
            prod *= sz
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    return PS(*out)


def constrain(x: jax.Array, axes: Sequence[Optional[str]],
              rules: Optional[dict] = None) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op without one)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(axes, np.shape(x), rules, mesh)
    manual = current_manual()
    if manual:
        spec = PS(*[
            (None if p is None or (isinstance(p, str) and p in manual)
             else (tuple(q for q in p if q not in manual) or None
                   if isinstance(p, tuple) else p))
            for p in spec])
    if all(p is None for p in spec):
        # fully-replicated constraint is a no-op; skipping it also keeps
        # fully-manual shard_map regions (every axis manual) legal
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_for(mesh: Mesh, axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None,
                 rules: Optional[dict] = None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(axes, shape, rules, mesh))


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# ---------------------------------------------------------------------------
# shard_map across jax versions (shared by the mesh train step and the
# campaign scenario-sharding executor).
# ---------------------------------------------------------------------------
#: Newer jax exposes ``jax.shard_map(..., axis_names=...)`` whose
#: partial-manual lowering is robust.  On 0.4.x the experimental API's
#: partial-auto mode fatally trips XLA:CPU's SPMD partitioner on any
#: ``ppermute`` inside the region (manual-subgroup reshard check), so
#: there we fall back to a FULLY manual region: the non-manual axes are
#: replicated into every shard (in_specs never mention them) and each
#: shard redundantly computes the whole model — correct, but without
#: model-parallel compute savings on that legacy path.
FULL_MANUAL_FALLBACK = not hasattr(jax, "shard_map")


def scenario_shard_map(f, ndev: int, n_bcast: int, n_mapped: int):
    """Shard a batched campaign executable over an ``(ndev,)``-device
    "scenario" mesh axis: the leading ``n_bcast`` arguments (data /
    topology broadcasts) are replicated, the trailing ``n_mapped``
    arguments (the flattened (cell x trace x seed) scenario operands —
    stacked topology arrays, trace pytrees, seeds) are split on their
    leading axis, as are all outputs.  Centralised here so the campaign
    engine's fused and per-cell dispatches shard through one spec
    builder (the caller pads the batch to a device-divisible size)."""
    mesh = jax.make_mesh((ndev,), ("scenario",))
    specs = (PS(),) * n_bcast + (PS("scenario"),) * n_mapped
    return compat_shard_map(f, mesh, in_specs=specs,
                            out_specs=PS("scenario"))


def compat_shard_map(f, mesh: Mesh, in_specs, out_specs, manual=None):
    """Version-portable shard_map: manual over the ``manual`` axes (all
    mesh axes when None), auto (GSPMD) over the rest where the backend
    supports it (see :data:`FULL_MANUAL_FALLBACK`)."""
    if not FULL_MANUAL_FALLBACK:
        names = (set(manual) if manual is not None
                 else set(mesh.axis_names))
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=names,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)
