"""Checkpointing: msgpack-serialised pytrees with shape/dtype manifest.

No orbax offline; this covers the need: save/restore params + optimizer
state + step, atomically (tmp + rename), with a keep-last-k policy.
"""
from __future__ import annotations

import os
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x):
    arr = np.asarray(jax.device_get(x))
    return {b"dtype": str(arr.dtype).encode(),
            b"shape": list(arr.shape),
            b"data": arr.tobytes()}


def _unpack_leaf(d):
    arr = np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"].decode()))
    return jnp.asarray(arr.reshape(d[b"shape"]))


def save(path: str, tree: Any, step: int = 0) -> str:
    """Atomic save of a pytree; returns the final path."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    payload = {b"step": step,
               b"treedef": str(treedef).encode(),
               b"leaves": [_pack_leaf(x) for x in leaves]}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload))
    os.replace(tmp, path)
    return path


def restore(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    leaves, treedef = jax.tree.flatten(like)
    got = [_unpack_leaf(d) for d in payload[b"leaves"]]
    assert len(got) == len(leaves), (len(got), len(leaves))
    for a, b in zip(got, leaves):
        assert a.shape == b.shape, (a.shape, b.shape)
    return jax.tree.unflatten(treedef, got), int(payload[b"step"])


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.msgpack")

    def save(self, tree: Any, step: int) -> str:
        p = save(self._path(step), tree, step)
        self._gc()
        return p

    def latest_step(self) -> Optional[int]:
        steps = sorted(int(f[5:13]) for f in os.listdir(self.dir)
                       if f.startswith("ckpt_") and f.endswith(".msgpack"))
        return steps[-1] if steps else None

    def restore_latest(self, like: Any) -> Optional[Tuple[Any, int]]:
        s = self.latest_step()
        if s is None:
            return None
        return restore(self._path(s), like)

    def _gc(self):
        steps = sorted(int(f[5:13]) for f in os.listdir(self.dir)
                       if f.startswith("ckpt_") and f.endswith(".msgpack"))
        for s in steps[:-self.keep]:
            os.remove(self._path(s))
