"""Evaluation metrics: exact AUROC (rank statistic), ROC curve, loss stats.

AUROC is the paper's headline metric (Tables III-V).  Computed via the
Mann-Whitney U statistic with average ranks for ties — exact, O(n log n),
implemented in pure numpy/jnp (no sklearn available offline).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def auroc(scores: np.ndarray, labels: np.ndarray) -> float:
    """labels: 1 = anomalous (positive), 0 = normal.  Higher score => more
    anomalous.  Returns P(score_pos > score_neg) + 0.5 P(equal)."""
    scores = np.asarray(scores, np.float64).ravel()
    labels = np.asarray(labels).ravel().astype(bool)
    n_pos = int(labels.sum())
    n_neg = labels.size - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(scores)
    ranks[order] = np.arange(1, scores.size + 1, dtype=np.float64)
    # average ranks for ties
    sorted_scores = scores[order]
    i = 0
    while i < scores.size:
        j = i
        while j + 1 < scores.size and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        if j > i:
            avg = 0.5 * (i + 1 + j + 1)
            ranks[order[i:j + 1]] = avg
        i = j + 1
    r_pos = ranks[labels].sum()
    u = r_pos - n_pos * (n_pos + 1) / 2.0
    return float(u / (n_pos * n_neg))


def auroc_batch(scores: np.ndarray, labels: np.ndarray) -> np.ndarray:
    """(B,) AUROC of every row of ``scores`` (B, T) against one shared
    ``labels`` (T,) — exactly :func:`auroc` (Mann-Whitney U with average
    ranks for ties), vectorized across rows.

    Campaign post-processing scores thousands of scenarios; the per-row
    Python tie-walk of the scalar version made host-side sorts dominate
    wall-clock at large B.  Here the tie groups of every row are found
    with running max/min scans over the sorted axis, so the whole batch
    is O(B T log T) numpy with no Python loop."""
    scores = np.asarray(scores, np.float64)
    assert scores.ndim == 2, scores.shape
    labels = np.asarray(labels).ravel().astype(bool)
    B, T = scores.shape
    assert labels.shape == (T,), (labels.shape, T)
    n_pos = int(labels.sum())
    n_neg = T - n_pos
    if n_pos == 0 or n_neg == 0:
        return np.full(B, np.nan)
    order = np.argsort(scores, axis=1, kind="mergesort")
    srt = np.take_along_axis(scores, order, axis=1)
    pos = np.broadcast_to(np.arange(T, dtype=np.float64), (B, T))
    is_start = np.ones((B, T), bool)
    is_start[:, 1:] = srt[:, 1:] != srt[:, :-1]
    is_end = np.ones((B, T), bool)
    is_end[:, :-1] = is_start[:, 1:]
    # each sorted position's tie group spans [start, end]: running max of
    # group-start indices / reversed running min of group-end indices
    start = np.maximum.accumulate(np.where(is_start, pos, 0.0), axis=1)
    end = np.minimum.accumulate(
        np.where(is_end, pos, T - 1.0)[:, ::-1], axis=1)[:, ::-1]
    avg_rank_sorted = 0.5 * (start + end) + 1.0   # 1-based average rank
    ranks = np.empty_like(avg_rank_sorted)
    np.put_along_axis(ranks, order, avg_rank_sorted, axis=1)
    r_pos = ranks[:, labels].sum(axis=1)
    u = r_pos - n_pos * (n_pos + 1) / 2.0
    return u / (n_pos * n_neg)


def roc_curve(scores: np.ndarray, labels: np.ndarray, points: int = 200
              ) -> Tuple[np.ndarray, np.ndarray]:
    """(fpr, tpr) arrays at ascending thresholds, closed at BOTH ends.

    Thresholds are score quantiles (the curve resolves where the score
    mass is) plus the exact minimum (``>= min`` admits everything: the
    (1, 1) corner) and a +inf sentinel (nothing scores ``>= inf``: the
    (0, 0) corner).  Without the sentinel the curve stopped at the max
    score — where fpr/tpr can still be positive — and trapezoid areas
    under it were biased; with it, the trapezoid area matches
    :func:`auroc` on tie-free data once the quantile grid is dense
    enough to separate adjacent scores."""
    scores = np.asarray(scores, np.float64).ravel()
    labels = np.asarray(labels).ravel().astype(bool)
    thr = np.quantile(scores, np.linspace(0, 1, points))
    thr = np.unique(np.concatenate([[scores.min()], thr, [np.inf]]))
    tpr = np.array([(scores[labels] >= t).mean() for t in thr])
    fpr = np.array([(scores[~labels] >= t).mean() for t in thr])
    return fpr, tpr


def reconstruction_error(x: jax.Array, x_hat: jax.Array) -> jax.Array:
    """Per-sample squared L2 reconstruction error (the anomaly score)."""
    d = (x - x_hat).reshape(x.shape[0], -1).astype(jnp.float32)
    return jnp.sum(jnp.square(d), axis=-1)
