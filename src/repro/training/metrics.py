"""Evaluation metrics: exact AUROC (rank statistic), ROC curve, loss stats.

AUROC is the paper's headline metric (Tables III-V).  Computed via the
Mann-Whitney U statistic with average ranks for ties — exact, O(n log n),
implemented in pure numpy/jnp (no sklearn available offline).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def auroc(scores: np.ndarray, labels: np.ndarray) -> float:
    """labels: 1 = anomalous (positive), 0 = normal.  Higher score => more
    anomalous.  Returns P(score_pos > score_neg) + 0.5 P(equal)."""
    scores = np.asarray(scores, np.float64).ravel()
    labels = np.asarray(labels).ravel().astype(bool)
    n_pos = int(labels.sum())
    n_neg = labels.size - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(scores)
    ranks[order] = np.arange(1, scores.size + 1, dtype=np.float64)
    # average ranks for ties
    sorted_scores = scores[order]
    i = 0
    while i < scores.size:
        j = i
        while j + 1 < scores.size and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        if j > i:
            avg = 0.5 * (i + 1 + j + 1)
            ranks[order[i:j + 1]] = avg
        i = j + 1
    r_pos = ranks[labels].sum()
    u = r_pos - n_pos * (n_pos + 1) / 2.0
    return float(u / (n_pos * n_neg))


def roc_curve(scores: np.ndarray, labels: np.ndarray, points: int = 200
              ) -> Tuple[np.ndarray, np.ndarray]:
    """(fpr, tpr) arrays at evenly spaced thresholds."""
    scores = np.asarray(scores, np.float64).ravel()
    labels = np.asarray(labels).ravel().astype(bool)
    thr = np.quantile(scores, np.linspace(0, 1, points))
    tpr = np.array([(scores[labels] >= t).mean() for t in thr])
    fpr = np.array([(scores[~labels] >= t).mean() for t in thr])
    return fpr, tpr


def reconstruction_error(x: jax.Array, x_hat: jax.Array) -> jax.Array:
    """Per-sample squared L2 reconstruction error (the anomaly score)."""
    d = (x - x_hat).reshape(x.shape[0], -1).astype(jnp.float32)
    return jnp.sum(jnp.square(d), axis=-1)
