"""Shared fixtures for the Tol-FL test suite.

NOTE: deliberately NO XLA_FLAGS device-count override here — smoke tests
and benches must see the single real CPU device (brief, step 0).  The
multi-device distributed tests spawn subprocesses that set the flag
themselves (tests/test_distributed.py).
"""
import os

import pytest

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core import compilecache
from repro.data import commsml, federated


@pytest.fixture(scope="session", autouse=True)
def _hermetic_compile_cache(tmp_path_factory):
    """Point the persistent compilation cache at a per-session temp
    directory so the suite never writes ``~/.cache/repro-jax``.  An
    explicit ``REPRO_CACHE_DIR`` still wins (the cross-process
    disk-cache tests set it in their subprocess env, not here)."""
    if os.environ.get(compilecache.ENV_VAR):
        yield
        return
    compilecache.enable_persistent_cache(
        str(tmp_path_factory.mktemp("repro-jax-cache")))
    yield


@pytest.fixture(scope="session")
def tiny_ae_cfg():
    """Small autoencoder for fast simulator tests."""
    return AutoencoderConfig(input_dim=commsml.N_FEATURES,
                             hidden=(32, 16), code_dim=8, dropout=0.2)


@pytest.fixture(scope="session")
def tiny_commsml():
    """Small Comms-ML draw: (X, y) with 200 samples/class."""
    return commsml.generate(seed=0, samples_per_class=200)


@pytest.fixture(scope="session")
def tiny_split(tiny_commsml):
    """10 devices, 5 clusters, class 3 anomalous."""
    X, y = tiny_commsml
    return federated.make_split(X, y, num_devices=10, num_clusters=5,
                                anomaly_classes=[3], seed=0)


@pytest.fixture(scope="session")
def tiny_padded(tiny_split):
    return federated.pad_devices(tiny_split)
