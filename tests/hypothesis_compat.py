"""Optional-``hypothesis`` shim: the property tests run either way.

``hypothesis`` is an *optional* test dependency (see tests/README.md).
When it is installed, this module re-exports the real ``given`` /
``settings`` / ``st`` unchanged.  When it is missing, a minimal
deterministic fallback stands in so the suite still COLLECTS and the
invariants are still EXERCISED: each ``@given`` test runs a bounded
number of examples drawn from a PRNG seeded by the test name (stable
across runs — failures are reproducible, there is no shrinking).

Only the strategy surface this suite uses is implemented:
``st.integers``, ``st.booleans``, ``st.sampled_from``, ``st.data``,
``st.composite``.
"""
from __future__ import annotations

import zlib

try:
    from hypothesis import given, settings, strategies as st   # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    #: fallback example budget — enough to exercise the property, small
    #: enough to keep tier-1 fast (real hypothesis uses max_examples)
    FALLBACK_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def example_from(self, rng):
            return self._draw_fn(rng)

    class _DataObject:
        """Stand-in for hypothesis's interactive ``data`` fixture."""
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example_from(self._rng)

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    class st:                                        # noqa: N801
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def data():
            return _DataStrategy()

        @staticmethod
        def composite(fn):
            def build(*args, **kwargs):
                def draw_with(rng):
                    return fn(lambda s: s.example_from(rng),
                              *args, **kwargs)
                return _Strategy(draw_with)
            return build

    def given(**strategies):
        def decorate(fn):
            # NOT functools.wraps: __wrapped__ would make pytest
            # introspect the original signature and demand fixtures
            # for the strategy parameters.
            def wrapper():
                n = min(getattr(wrapper, "_max_examples", 1 << 30),
                        FALLBACK_MAX_EXAMPLES)
                # deterministic per-test seed: reproducible failures
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    fn(**{name: s.example_from(rng)
                          for name, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return decorate

    def settings(max_examples=None, **_ignored):
        def decorate(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return decorate
