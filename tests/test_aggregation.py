"""Unit + property tests for the Tol-FL aggregation algebra.

The paper's central mathematical claim (Section III): the hierarchical
streaming weighted mean equals the direct sample-weighted mean regardless
of the clustering k — model updates are *independent of k*.  We
property-test exactly that.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import aggregation as agg

jax.config.update("jax_enable_x64", False)


def direct_mean(gs, ns):
    tot = np.sum(ns)
    return np.tensordot(ns / tot, gs, axes=1)


# ---------------------------------------------------------------------------
# combine_pair
# ---------------------------------------------------------------------------
def test_combine_pair_basic():
    n, g = agg.combine_pair(jnp.float32(2), jnp.ones(3),
                            jnp.float32(2), 3 * jnp.ones(3))
    assert float(n) == 4.0
    np.testing.assert_allclose(np.asarray(g), 2.0, rtol=1e-6)


def test_combine_pair_zero_absorbed():
    """A zero-count operand is a no-op (the failure-masking path)."""
    n, g = agg.combine_pair(jnp.float32(5), 2 * jnp.ones(4),
                            jnp.float32(0), 99 * jnp.ones(4))
    assert float(n) == 5.0
    np.testing.assert_allclose(np.asarray(g), 2.0, rtol=1e-6)


def test_combine_pair_both_zero():
    n, g = agg.combine_pair(jnp.float32(0), jnp.zeros(2),
                            jnp.float32(0), jnp.ones(2))
    assert float(n) == 0.0
    assert np.all(np.isfinite(np.asarray(g)))


def test_combine_pair_pytree():
    tree_a = {"w": jnp.ones((2, 2)), "b": jnp.zeros(2)}
    tree_b = {"w": 3 * jnp.ones((2, 2)), "b": 2 * jnp.ones(2)}
    n, g = agg.combine_pair(jnp.float32(1), tree_a, jnp.float32(1), tree_b)
    np.testing.assert_allclose(np.asarray(g["w"]), 2.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g["b"]), 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# streaming == direct (the k-invariance core)
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(
    k=st.integers(1, 8),
    dim=st.integers(1, 16),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_streaming_equals_direct(k, dim, seed):
    rng = np.random.default_rng(seed)
    gs = rng.standard_normal((k, dim)).astype(np.float32)
    ns = rng.uniform(0.5, 100.0, k).astype(np.float32)
    _, g_stream = agg.streaming_weighted_mean(list(jnp.asarray(gs)),
                                              list(jnp.asarray(ns)))
    want = direct_mean(gs, ns)
    np.testing.assert_allclose(np.asarray(g_stream), want,
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=50, deadline=None)
@given(
    k=st.integers(2, 8),
    dim=st.integers(1, 16),
    n_zero=st.integers(1, 3),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_streaming_with_dead_devices(k, dim, n_zero, seed):
    """Zero-weighted (failed) devices drop out of the mean exactly."""
    rng = np.random.default_rng(seed)
    gs = rng.standard_normal((k, dim)).astype(np.float32)
    ns = rng.uniform(0.5, 100.0, k).astype(np.float32)
    dead = rng.choice(k, size=min(n_zero, k - 1), replace=False)
    ns[dead] = 0.0
    _, g_stream = agg.streaming_weighted_mean(list(jnp.asarray(gs)),
                                              list(jnp.asarray(ns)))
    live = ns > 0
    want = direct_mean(gs[live], ns[live])
    np.testing.assert_allclose(np.asarray(g_stream), want,
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=30, deadline=None)
@given(k=st.integers(1, 10), seed=st.integers(0, 2 ** 31 - 1))
def test_stacked_matches_sequential(k, seed):
    rng = np.random.default_rng(seed)
    gs = jnp.asarray(rng.standard_normal((k, 7)).astype(np.float32))
    ns = jnp.asarray(rng.uniform(0.1, 10.0, k).astype(np.float32))
    n1, g1 = agg.streaming_weighted_mean(list(gs), list(ns))
    n2, g2 = agg.stacked_streaming_mean(gs, ns)
    np.testing.assert_allclose(float(n1), float(n2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5,
                               atol=1e-6)


def test_weighted_mean_direct():
    gs = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    ns = jnp.asarray([1.0, 3.0])
    g = agg.weighted_mean(gs, ns)
    np.testing.assert_allclose(np.asarray(g), [2.5, 3.5], rtol=1e-6)


# ---------------------------------------------------------------------------
# cluster_reduce + full hierarchy == flat mean (paper k-invariance)
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(
    members=st.integers(1, 4),
    k=st.integers(1, 5),
    dim=st.integers(1, 8),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_k_invariance_hierarchical(members, k, dim, seed):
    """cluster_reduce -> streaming chain == flat weighted mean over all
    devices, for ANY clustering (the paper's 'independent of k')."""
    n_dev = members * k
    rng = np.random.default_rng(seed)
    gs = rng.standard_normal((n_dev, dim)).astype(np.float32)
    ns = rng.uniform(0.5, 50.0, n_dev).astype(np.float32)
    cluster_ids = jnp.asarray(np.arange(n_dev) // members)
    cg, cn = agg.cluster_reduce(jnp.asarray(gs), jnp.asarray(ns),
                                cluster_ids, k)
    _, g_hier = agg.stacked_streaming_mean(cg, cn)
    want = direct_mean(gs, ns)
    np.testing.assert_allclose(np.asarray(g_hier), want, rtol=5e-4,
                               atol=5e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_k_invariance_across_k(seed):
    """The combined gradient is numerically identical for every divisor k
    of N (FL k=1 == Tol-FL k=2,4 == SBT k=N up to float error)."""
    n_dev, dim = 8, 12
    rng = np.random.default_rng(seed)
    gs = jnp.asarray(rng.standard_normal((n_dev, dim)).astype(np.float32))
    ns = jnp.asarray(rng.uniform(1.0, 20.0, n_dev).astype(np.float32))
    results = []
    for k in (1, 2, 4, 8):
        ids = jnp.asarray(np.arange(n_dev) // (n_dev // k))
        cg, cn = agg.cluster_reduce(gs, ns, ids, k)
        _, g = agg.stacked_streaming_mean(cg, cn)
        results.append(np.asarray(g))
    for r in results[1:]:
        np.testing.assert_allclose(r, results[0], rtol=5e-4, atol=5e-5)


def test_cluster_reduce_counts():
    gs = jnp.ones((4, 3))
    ns = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    ids = jnp.asarray([0, 0, 1, 1])
    cg, cn = agg.cluster_reduce(gs, ns, ids, 2)
    np.testing.assert_allclose(np.asarray(cn), [3.0, 7.0], rtol=1e-6)
    assert cg.shape == (2, 3)


def test_cluster_reduce_weighting():
    gs = jnp.asarray([[0.0], [10.0]])
    ns = jnp.asarray([9.0, 1.0])
    ids = jnp.asarray([0, 0])
    cg, cn = agg.cluster_reduce(gs, ns, ids, 1)
    np.testing.assert_allclose(np.asarray(cg), [[1.0]], rtol=1e-6)
