"""Roofline / cost-model analysis layer tests."""
import pytest

from repro.analysis import costmodel as CM
from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                     Roofline, collective_bytes,
                                     model_flops_for)
from repro.configs import ARCHS, INPUT_SHAPES


# ---------------------------------------------------------------------------
# HLO collective-bytes parser
# ---------------------------------------------------------------------------
SAMPLE_HLO = """
HloModule test
  %x = f32[16,128]{1,0} all-reduce(%a), replica_groups={}
  %y = bf16[8,64]{1,0} all-gather(%b), dimensions={0}
  %z = (f32[4]{0}, f32[4]{0}) all-reduce(%c, %d), to_apply=%add
  %w = f32[32]{0} collective-permute(%e), source_target_pairs={{0,1}}
  %s = f32[2,2]{1,0} all-reduce-start(%f), to_apply=%add
  %t = f32[2,2]{1,0} all-reduce-done(%s)
  %u = f32[100]{0} reduce-scatter(%g), dimensions={0}
"""


def test_collective_bytes_parses_kinds():
    out = collective_bytes(SAMPLE_HLO)
    assert out["all-reduce"] == 16 * 128 * 4 + 2 * 4 * 4 + 2 * 2 * 4
    assert out["all-gather"] == 8 * 64 * 2
    assert out["collective-permute"] == 32 * 4
    assert out["reduce-scatter"] == 100 * 4


def test_collective_bytes_counts_async_once():
    """-start/-done pairs are one transfer."""
    out = collective_bytes(SAMPLE_HLO)
    # the start op contributes 2x2x4; the done op must not double it
    assert out["all-reduce"] - (16 * 128 * 4 + 2 * 4 * 4) == 16


def test_collective_bytes_empty():
    assert sum(collective_bytes("HloModule empty").values()) == 0


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------
def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="a", shape="s", mesh="m", chips=256,
                 flops_per_chip=PEAK_FLOPS,       # 1 second of compute
                 bytes_per_chip=HBM_BW / 2,       # 0.5 s memory
                 coll_bytes_per_chip=ICI_BW / 4,  # 0.25 s collective
                 coll_breakdown={}, model_flops=PEAK_FLOPS * 256 / 2)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.t_collective == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.useful_flops_ratio == pytest.approx(0.5)


def test_model_flops_modes():
    cfg = ARCHS["qwen1.5-0.5b"]
    tr = model_flops_for(cfg, INPUT_SHAPES["train_4k"], "train")
    pf = model_flops_for(cfg, INPUT_SHAPES["prefill_32k"], "prefill")
    dc = model_flops_for(cfg, INPUT_SHAPES["decode_32k"], "decode")
    # train: 6ND over B*S tokens; decode: 2ND over B tokens
    n = cfg.active_param_count()
    assert tr == pytest.approx(6.0 * n * 4096 * 256)
    assert pf == pytest.approx(2.0 * n * 32768 * 32)
    assert dc == pytest.approx(2.0 * n * 128)


# ---------------------------------------------------------------------------
# Analytic cost model invariants
# ---------------------------------------------------------------------------
def costs(arch="qwen3-8b", shape="train_4k", **kw):
    args = dict(model_shards=16, data_shards=16, schedule="tolfl_ring",
                num_clusters=4, pods=1)
    args.update(kw)
    return CM.step_costs(ARCHS[arch], INPUT_SHAPES[shape], 256, **args)


def test_ring_chain_cost_linear_in_k():
    """coll(k) - coll(k') == (k - k') * grad_share for the ring chain."""
    c2 = costs(num_clusters=2).coll_bytes
    c4 = costs(num_clusters=4).coll_bytes
    c8 = costs(num_clusters=8).coll_bytes
    step1 = c4 - c2
    step2 = (c8 - c4) / 2
    assert step1 == pytest.approx(step2, rel=1e-6)
    assert step1 > 0


def test_bf16_sync_halves_grad_payload():
    base = costs().coll_bytes
    narrow = costs(grad_sync_dtype="bfloat16").coll_bytes
    assert narrow < base
    # grad-dependent share exactly halves: delta = grad_terms/2
    gshare_f32 = ARCHS["qwen3-8b"].param_count() * 4 / 16
    k_terms = 2.0 + (4 - 1) + 2.0       # psum + chain + broadcast, k=4
    assert base - narrow == pytest.approx(k_terms * gshare_f32 / 2, rel=1e-6)


def test_microbatch_divides_activation_bytes():
    b1 = costs().hbm_bytes
    b4 = costs(microbatches=4).hbm_bytes
    assert b4 < b1


def test_param_cast_halves_fsdp_gather():
    base = costs(arch="llama4-maverick-400b-a17b", schedule="tolfl_psum",
                 fsdp=True).coll_bytes
    cast = costs(arch="llama4-maverick-400b-a17b", schedule="tolfl_psum",
                 fsdp=True, param_cast_dtype="bfloat16").coll_bytes
    P = ARCHS["llama4-maverick-400b-a17b"].param_count()
    passes = 4.0    # remat=full
    assert base - cast == pytest.approx(passes * P * 2 / 16, rel=1e-6)


def test_decode_memory_dominated_by_weights_and_cache():
    cb = costs(shape="decode_32k")
    # decode flops tiny relative to train
    assert cb.flops < costs().flops / 100


def test_psum_schedule_cheaper_than_ring():
    ring = costs(schedule="tolfl_ring").coll_bytes
    psum = costs(schedule="tolfl_psum").coll_bytes
    assert psum <= ring


def test_long_context_caps_attention_flops():
    full = CM.forward_flops(ARCHS["qwen3-8b"], 1, 524288, "decode",
                            long_ctx=False)
    capped = CM.forward_flops(ARCHS["qwen3-8b"], 1, 524288, "decode",
                              long_ctx=True)
    assert capped["attention"] < full["attention"]
