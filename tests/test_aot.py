"""AOT execution-path contracts (kill-the-cold-compile-tax PR).

What is proven:

* **bit-identical parity** — ``ExecPlan(aot=True)`` routes every bucket
  through plan-time ``lower().compile()`` executables and produces the
  SAME result arrays as the jit path (it is the same lowering), with
  exactly one trace per bucket cold and ZERO traces warm
  (``cache == "memory"``); the speculative plan-time aval predictions
  match the concrete arrays (``aval_match``).
* **disk-warm in-process** — after :func:`clear_executable_caches` the
  AOT path deserialises whole executables from the persistent cache
  (``cache == "disk"``, zero traces) and the results stay identical.
* **warn-once** — ``ExecPlan(shard=True)`` on a single-device host
  emits its degrade warning EXACTLY once per ``execute()``, and never
  during ``plan()`` (it used to fire zero or twice depending on the
  entry point).
* **cross-process disk cache** — a second process running the same spec
  against the same ``REPRO_CACHE_DIR`` reports zero XLA compiles, zero
  traces and a bit-identical result digest.
"""
import dataclasses
import json
import os
import subprocess
import sys
import warnings

import jax
import numpy as np
import pytest

from repro.api import (AutoencoderConfig, CellSpec, DataSpec, ExecPlan,
                       ExperimentSpec, SeedSpec, SimConfig, TraceSpec,
                       execute, plan, run_experiment)
from repro.core import campaign
from repro.core.failure import sample_traces
from repro.data import commsml, federated

# distinct from every other campaign test in the suite: the executable
# cache is global and the cold-trace-count assertions need cold keys
ROUNDS = 6


@pytest.fixture(scope="module")
def small_ae():
    return AutoencoderConfig(input_dim=commsml.N_FEATURES, hidden=(16,),
                             code_dim=4, dropout=0.2)


@pytest.fixture(scope="module")
def small_data():
    X, y = commsml.generate(seed=0, samples_per_class=60)
    split = federated.make_split(X, y, num_devices=10, num_clusters=5,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    return dx, counts, split.test_x, split.test_y


def _spec(small_ae, small_data, *, aot, shard=False):
    dx, counts, tx, ty = small_data
    base = SimConfig(num_devices=10, rounds=ROUNDS, lr=1e-3,
                     dropout=False)
    tcfg = dataclasses.replace(base, scheme="tolfl", num_clusters=5)
    traces = sample_traces(np.random.default_rng(3), tcfg.topology(), 0.5,
                           max_events=8, rounds=ROUNDS, num_traces=2)
    return ExperimentSpec(
        data=DataSpec(model=small_ae, device_x=dx, device_counts=counts,
                      test_x=tx, test_y=ty, name="commsml"),
        base=base,
        # one fused non-fl single bucket + one fl iso bucket + one
        # multi bucket = all three executable kinds, 3 buckets
        cells=(CellSpec("tolfl", 2), CellSpec("fl", 1),
               CellSpec("ifca", 2)),
        traces=TraceSpec(traces=tuple(traces)), seeds=SeedSpec((0,)),
        exec_plan=ExecPlan(shard=shard, aot=aot) if (aot or shard)
        else None)


def _assert_identical(res_a, res_b):
    for a, b in zip(res_a.results, res_b.results):
        assert type(a) is type(b)
        for f in dataclasses.fields(a):
            va = getattr(a, f.name)
            if isinstance(va, np.ndarray):
                np.testing.assert_array_equal(va, getattr(b, f.name),
                                              err_msg=f.name)


# ---------------------------------------------------------------------------
# parity: AOT == jit, bit for bit
# ---------------------------------------------------------------------------
def test_aot_bit_identical_to_jit(small_ae, small_data):
    before = campaign.TRACE_COUNT
    res_jit = run_experiment(_spec(small_ae, small_data, aot=False))
    assert campaign.TRACE_COUNT - before == 3      # one per bucket
    rep = res_jit.compile_report
    assert rep is not None and not rep.aot
    assert [b.cache for b in rep.buckets] == ["", "", ""]
    assert rep.execute_s > 0

    # cold AOT: plan-time lowering, same trace budget, same bits
    campaign.clear_executable_caches()
    before = campaign.TRACE_COUNT
    res_aot = run_experiment(_spec(small_ae, small_data, aot=True))
    assert campaign.TRACE_COUNT - before == 3
    rep = res_aot.compile_report
    assert rep.aot and rep.traces == 3
    assert all(b.aot for b in rep.buckets)
    assert [b.cache for b in rep.buckets] == ["compiled"] * 3
    # the speculative plan-time avals matched the concrete arrays: the
    # thread-pool compiles were the ones actually used
    assert [b.aval_match for b in rep.buckets] == [True] * 3
    assert all(b.compile_s > 0 for b in rep.buckets)
    _assert_identical(res_jit, res_aot)

    # warm AOT: zero traces, executables straight from process memory
    before = campaign.TRACE_COUNT
    res_warm = run_experiment(_spec(small_ae, small_data, aot=True))
    assert campaign.TRACE_COUNT - before == 0
    assert [b.cache for b in res_warm.compile_report.buckets] == \
        ["memory"] * 3
    _assert_identical(res_jit, res_warm)


def test_aot_disk_warm_skips_tracing(small_ae, small_data):
    """In-process fresh-process simulation: empty executable caches +
    the populated persistent directory (written by the cold run above,
    pointed at the suite's hermetic tmp dir) -> whole executables
    deserialise, zero traces, identical bits."""
    res_ref = run_experiment(_spec(small_ae, small_data, aot=True))
    campaign.clear_executable_caches()
    before = campaign.TRACE_COUNT
    res_disk = run_experiment(_spec(small_ae, small_data, aot=True))
    assert campaign.TRACE_COUNT - before == 0, \
        "disk-warm AOT re-traced instead of deserialising"
    rep = res_disk.compile_report
    assert [b.cache for b in rep.buckets] == ["disk"] * 3
    assert rep.cache_dir is not None
    _assert_identical(res_ref, res_disk)


# ---------------------------------------------------------------------------
# warn-once (shard degrade)
# ---------------------------------------------------------------------------
def test_shard_degrade_warns_exactly_once_per_execute(small_ae, small_data):
    if jax.local_device_count() > 1:
        pytest.skip("host has multiple devices")
    spec = _spec(small_ae, small_data, aot=False, shard=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        p = plan(spec)                    # planning never warns
    for _ in range(2):                    # ... every execute warns ONCE
        with pytest.warns(UserWarning) as rec:
            execute(p)
        hits = [w for w in rec
                if "single local device" in str(w.message)]
        assert len(hits) == 1, [str(w.message) for w in rec]


# ---------------------------------------------------------------------------
# cross-process persistent cache
# ---------------------------------------------------------------------------
_SUBPROCESS_SCRIPT = r"""
import dataclasses, hashlib, json
import numpy as np
from repro.api import (AutoencoderConfig, CellSpec, DataSpec, ExecPlan,
                       ExperimentSpec, SeedSpec, SimConfig, TraceSpec,
                       run_experiment, xla_compile_stats)
from repro.core import campaign
from repro.core.failure import sample_traces
from repro.data import commsml, federated

X, y = commsml.generate(seed=0, samples_per_class=40)
split = federated.make_split(X, y, num_devices=6, num_clusters=2,
                             anomaly_classes=[3], seed=0)
dx, counts = federated.pad_devices(split)
ae = AutoencoderConfig(input_dim=commsml.N_FEATURES, hidden=(8,),
                       code_dim=3, dropout=0.2)
base = SimConfig(num_devices=6, rounds=3, lr=1e-3, dropout=False)
tcfg = dataclasses.replace(base, scheme="tolfl", num_clusters=2)
traces = sample_traces(np.random.default_rng(5), tcfg.topology(), 0.4,
                       max_events=6, rounds=3, num_traces=2)
spec = ExperimentSpec(
    data=DataSpec(model=ae, device_x=dx, device_counts=counts,
                  test_x=split.test_x, test_y=split.test_y,
                  name="commsml"),
    base=base, cells=(CellSpec("tolfl", 2),),
    traces=TraceSpec(traces=tuple(traces)), seeds=SeedSpec((0,)),
    exec_plan=ExecPlan(aot=True))
res = run_experiment(spec)
r = res.results[0]
h = hashlib.sha256()
h.update(np.ascontiguousarray(r.auroc_used).tobytes())
h.update(np.ascontiguousarray(r.final_auroc).tobytes())
h.update(np.ascontiguousarray(r.loss_curves).tobytes())
print(json.dumps({
    "traces": campaign.TRACE_COUNT,
    "stats": xla_compile_stats(),
    "caches": [b.cache for b in res.compile_report.buckets],
    "digest": h.hexdigest(),
}))
"""


def test_disk_cache_second_process_zero_xla_compiles(tmp_path):
    """The PR's headline contract: a fresh process re-running a spec
    against a populated ``REPRO_CACHE_DIR`` performs ZERO XLA compiles
    and ZERO traces — every bucket executable deserialises whole — and
    the results are bit-identical to the process that compiled them."""
    # repro is a namespace package (__file__ is None): derive src/ from
    # a real module inside it
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(campaign.__file__))))
    env = dict(os.environ,
               REPRO_CACHE_DIR=str(tmp_path / "cache"),
               PYTHONPATH=os.pathsep.join(
                   p for p in (src, os.environ.get("PYTHONPATH")) if p))

    def run_once():
        out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                             env=env, capture_output=True, text=True,
                             timeout=600)
        assert out.returncode == 0, out.stderr
        return json.loads(out.stdout.strip().splitlines()[-1])

    first = run_once()
    assert first["traces"] == 1                   # one bucket, compiled
    assert first["caches"] == ["compiled"]
    assert first["stats"]["exe_stores"] >= 1      # ... and persisted

    second = run_once()
    assert second["traces"] == 0, "second process re-traced"
    assert second["stats"]["misses"] == 0, \
        f"second process invoked XLA: {second['stats']}"
    assert second["stats"]["exe_hits"] >= 1
    assert second["caches"] == ["disk"]
    assert second["digest"] == first["digest"]
