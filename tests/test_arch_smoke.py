"""Per-architecture smoke tests (brief deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
of the same family (2 layers, d_model<=512, <=4 experts) and run one
forward/train step on CPU asserting output shapes + no NaNs.  Decode and
prefill are exercised per family as well.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED
from repro.configs.base import AUDIO, VLM
from repro.models import transformer as T
from repro.serving.decode import decode_step, init_cache, prefill

B, S = 2, 32


def make_batch(cfg, key):
    Vp = T.padded_vocab(cfg)
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == AUDIO:
        F = cfg.encoder_seq or 16
        batch["frames"] = jax.random.normal(ks[2], (B, F, cfg.d_model),
                                            jnp.float32)
    if cfg.family == VLM:
        P_ = cfg.frontend.frontend_seq or 16
        batch["prefix"] = jax.random.normal(ks[2], (B, P_, cfg.d_model),
                                            jnp.float32)
        # labels cover only text positions
    return batch


@pytest.fixture(scope="module")
def reduced_params():
    out = {}
    for arch in ASSIGNED:
        cfg = ARCHS[arch].reduced()
        params, _ = T.init_params(jax.random.PRNGKey(0), cfg)
        out[arch] = (cfg, params)
    return out


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_config_constraints(arch):
    cfg = ARCHS[arch].reduced()
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.moe.num_experts <= 4
    assert cfg.family == ARCHS[arch].family


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_train_step(arch, reduced_params):
    """One loss+grad step: finite loss, finite grads, correct shapes."""
    cfg, params = reduced_params[arch]
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    def loss(p):
        total, metrics = T.loss_fn(p, cfg, batch)
        return total, metrics

    (lv, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
    assert np.isfinite(float(lv)), arch
    assert float(lv) > 0
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_hidden_shapes(arch, reduced_params):
    cfg, params = reduced_params[arch]
    batch = make_batch(cfg, jax.random.PRNGKey(2))
    h, aux = T.forward_train(params, cfg, batch)
    S_out = h.shape[1]
    if cfg.family == VLM:
        assert S_out == S + batch["prefix"].shape[1]
    else:
        assert S_out == S
    assert h.shape == (B, S_out, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_and_decode(arch, reduced_params):
    """prefill builds a cache; decode_step advances one token."""
    cfg, params = reduced_params[arch]
    batch = make_batch(cfg, jax.random.PRNGKey(3))
    batch.pop("labels")
    logits, cache = prefill(params, cfg, batch)
    Vp = T.padded_vocab(cfg)
    assert logits.shape == (B, Vp)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch

    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
    # decode against a fresh fixed-size cache (the serving layout)
    dcache = init_cache(cfg, B, S)
    if cfg.is_encdec:
        dcache["cross"] = cache["cross"]
    logits2, new_cache = decode_step(params, cfg, tok.astype(jnp.int32),
                                     dcache, jnp.int32(0))
    assert logits2.shape == (B, Vp)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch
    # cache tree structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(dcache)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_count_positive_and_consistent(arch):
    cfg = ARCHS[arch]
    n = cfg.param_count()
    na = cfg.active_param_count()
    assert n > 0
    assert 0 < na <= n
    if cfg.moe.num_experts > 1:
        assert na < n          # MoE: active strictly fewer


def test_assigned_covers_six_families():
    fams = {ARCHS[a].family for a in ASSIGNED}
    assert fams == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}
