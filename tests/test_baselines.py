"""Clustered-FL baseline tests (FedGroup / IFCA / FeSEM)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import (MultiModelConfig, _kmeans_groups,
                                  run_multimodel)
from repro.core.failure import NO_FAILURE, FailureSpec

ROUNDS = 30


def run(ae_cfg, padded, split, scheme, failure=NO_FAILURE):
    dx, counts = padded
    cfg = MultiModelConfig(scheme=scheme, num_devices=10, num_models=3,
                           rounds=ROUNDS, lr=1e-3, dropout=True, seed=0)
    return run_multimodel(ae_cfg, dx, counts, split.test_x, split.test_y,
                          cfg, failure)


@pytest.mark.parametrize("scheme", ["fedgroup", "ifca", "fesem"])
def test_baseline_learns(scheme, tiny_ae_cfg, tiny_padded, tiny_split):
    res = run(tiny_ae_cfg, tiny_padded, tiny_split, scheme)
    assert res.best_auroc > 0.6, (scheme, res.best_auroc)
    assert res.multi_auroc > 0.6, (scheme, res.multi_auroc)
    assert res.loss_curve[-1] < res.loss_curve[0]
    assert res.assignments.shape == (10,)
    assert set(np.unique(res.assignments)).issubset({0, 1, 2})


@pytest.mark.parametrize("scheme", ["fedgroup", "ifca", "fesem"])
def test_baseline_multi_geq_best_usually(scheme, tiny_ae_cfg, tiny_padded,
                                         tiny_split):
    """Paper Tables: the dagger (multi-model oracle) column is at least
    close to the starred (best single instance) column."""
    res = run(tiny_ae_cfg, tiny_padded, tiny_split, scheme)
    assert res.multi_auroc > res.best_auroc - 0.1


@pytest.mark.parametrize("scheme", ["ifca", "fesem"])
def test_baseline_survives_failures(scheme, tiny_ae_cfg, tiny_padded,
                                    tiny_split):
    for kind in ("client", "server"):
        res = run(tiny_ae_cfg, tiny_padded, tiny_split, scheme,
                  FailureSpec(epoch=ROUNDS // 2, kind=kind))
        assert np.isfinite(res.best_auroc)
        assert res.best_auroc > 0.5, (scheme, kind, res.best_auroc)


# ---------------------------------------------------------------------------
# FedGroup k-means edge cases
# ---------------------------------------------------------------------------
def test_kmeans_rejects_more_models_than_devices():
    vecs = jnp.asarray(np.random.default_rng(0).normal(size=(3, 5)),
                       jnp.float32)
    with pytest.raises(ValueError, match="num_models"):
        _kmeans_groups(vecs, 4, jax.random.PRNGKey(0))


def test_multimodel_fedgroup_more_models_than_devices_raises(
        tiny_ae_cfg, tiny_padded, tiny_split):
    dx, counts = tiny_padded
    cfg = MultiModelConfig(scheme="fedgroup", num_devices=10,
                           num_models=11, rounds=1)
    with pytest.raises(ValueError, match="num_models"):
        run_multimodel(tiny_ae_cfg, dx, counts, tiny_split.test_x,
                       tiny_split.test_y, cfg)


def test_kmeans_reseeds_empty_centers():
    """Three duplicate points + one outlier, M=2, with an init key whose
    permutation seeds BOTH centers on duplicates: the second center wins
    no points and must be RE-SEEDED onto a data point (the stale-center
    bug kept it forever, merging the outlier into group 0)."""
    v = np.zeros((4, 3), np.float32)
    v[:3, 0] = 1.0                  # three copies of e1
    v[3, 1] = 1.0                   # one outlier at e2
    key = None
    for k in range(50):             # find an all-duplicate init
        perm = np.asarray(jax.random.permutation(
            jax.random.split(jax.random.PRNGKey(k))[0], 4))
        if 3 not in perm[:2]:
            key = jax.random.PRNGKey(k)
            break
    assert key is not None
    assign = np.asarray(_kmeans_groups(jnp.asarray(v), 2, key))
    assert len(set(assign[:3].tolist())) == 1     # duplicates together
    assert assign[3] not in assign[:3]            # outlier got its own
    #                                               (re-seeded) center
