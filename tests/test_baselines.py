"""Clustered-FL baseline tests (FedGroup / IFCA / FeSEM)."""
import numpy as np
import pytest

from repro.core.baselines import MultiModelConfig, run_multimodel
from repro.core.failure import NO_FAILURE, FailureSpec

ROUNDS = 30


def run(ae_cfg, padded, split, scheme, failure=NO_FAILURE):
    dx, counts = padded
    cfg = MultiModelConfig(scheme=scheme, num_devices=10, num_models=3,
                           rounds=ROUNDS, lr=1e-3, dropout=True, seed=0)
    return run_multimodel(ae_cfg, dx, counts, split.test_x, split.test_y,
                          cfg, failure)


@pytest.mark.parametrize("scheme", ["fedgroup", "ifca", "fesem"])
def test_baseline_learns(scheme, tiny_ae_cfg, tiny_padded, tiny_split):
    res = run(tiny_ae_cfg, tiny_padded, tiny_split, scheme)
    assert res.best_auroc > 0.6, (scheme, res.best_auroc)
    assert res.multi_auroc > 0.6, (scheme, res.multi_auroc)
    assert res.loss_curve[-1] < res.loss_curve[0]
    assert res.assignments.shape == (10,)
    assert set(np.unique(res.assignments)).issubset({0, 1, 2})


@pytest.mark.parametrize("scheme", ["fedgroup", "ifca", "fesem"])
def test_baseline_multi_geq_best_usually(scheme, tiny_ae_cfg, tiny_padded,
                                         tiny_split):
    """Paper Tables: the dagger (multi-model oracle) column is at least
    close to the starred (best single instance) column."""
    res = run(tiny_ae_cfg, tiny_padded, tiny_split, scheme)
    assert res.multi_auroc > res.best_auroc - 0.1


@pytest.mark.parametrize("scheme", ["ifca", "fesem"])
def test_baseline_survives_failures(scheme, tiny_ae_cfg, tiny_padded,
                                    tiny_split):
    for kind in ("client", "server"):
        res = run(tiny_ae_cfg, tiny_padded, tiny_split, scheme,
                  FailureSpec(epoch=ROUNDS // 2, kind=kind))
        assert np.isfinite(res.best_auroc)
        assert res.best_auroc > 0.5, (scheme, kind, res.best_auroc)
