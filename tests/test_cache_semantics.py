"""Serving-cache semantics unit tests: slot validity, ring wraps,
pad_cache alignment."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.attention import cache_slot_validity
from repro.serving.decode import cache_shape, init_cache, pad_cache


# ---------------------------------------------------------------------------
# cache_slot_validity
# ---------------------------------------------------------------------------
def valid(Sc, pos, window=None):
    return np.asarray(cache_slot_validity(Sc, jnp.int32(pos), window))


def test_fresh_cache_all_invalid():
    np.testing.assert_array_equal(valid(8, 0), np.zeros(8, bool))


def test_partially_filled():
    v = valid(8, 3)
    np.testing.assert_array_equal(v, [1, 1, 1, 0, 0, 0, 0, 0])


def test_full_cache_no_wrap():
    np.testing.assert_array_equal(valid(8, 8), np.ones(8, bool))


def test_wrapped_ring_all_slots_hold_recent():
    """After wrapping, every slot holds one of the last Sc positions."""
    v = valid(8, 13)          # positions 5..12 live in slots 5..12 mod 8
    np.testing.assert_array_equal(v, np.ones(8, bool))


def test_window_excludes_stale():
    # window 4, position 6, Sc 8: slots 2..5 (positions 2..5) valid,
    # positions 0,1 are out of window, slots 6,7 empty
    v = valid(8, 6, window=4)
    np.testing.assert_array_equal(v, [0, 0, 0, 1, 1, 1, 0, 0])


def test_window_ring_steady_state():
    # Sc == window: at any wrapped position exactly window-1 cached
    # positions are in-window (self occupies distance 0)
    for pos in (9, 17, 100):
        v = valid(8, pos, window=8)
        assert v.sum() == 7


# ---------------------------------------------------------------------------
# pad_cache
# ---------------------------------------------------------------------------
def test_pad_cache_extends_attention_only():
    cfg = ARCHS["recurrentgemma-9b"].reduced()   # rec, rec, local pattern
    cache = init_cache(cfg, 2, 16)
    padded = pad_cache(cache, cfg, prompt_len=16, target_len=24)
    for path, leaf in jax.tree_util.tree_flatten_with_path(padded)[0]:
        names = [p.key for p in path if hasattr(p, "key")]
        orig = jax.tree_util.tree_flatten_with_path(cache)[0]
        if names[-1] in ("k", "v"):
            axis = leaf.ndim - 3
            assert leaf.shape[axis] in (24, 16)   # full target or window cap
        if names[-1] in ("h", "conv", "wkv"):
            # recurrent state untouched
            pass


def test_pad_cache_respects_window_cap():
    cfg = ARCHS["recurrentgemma-9b"].reduced()
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, sliding_window=8))
    cache = init_cache(cfg, 1, 8)
    padded = pad_cache(cache, cfg, prompt_len=8, target_len=100)
    for path, leaf in jax.tree_util.tree_flatten_with_path(padded)[0]:
        names = [p.key for p in path if hasattr(p, "key")]
        if names[-1] in ("k", "v"):
            axis = leaf.ndim - 3
            assert leaf.shape[axis] == 8          # stays at the window


def test_pad_cache_rolls_truncated_prompt():
    """A prefill that truncated to the window must be rolled back onto the
    ring invariant (slot i = position mod Sc)."""
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    # hand-build a cache entry: Sc=4, prompt_len=6 -> slots hold pos 2..5
    # at indices 0..3; ring wants pos p at slot p%4: pos4->0,5->1,2->2,3->3
    k = jnp.arange(2, 6, dtype=jnp.float32).reshape(1, 4, 1, 1)
    cache = {"units": {"l0": {"k": k[None], "v": k[None]}}}
    rolled = pad_cache(cache, cfg, prompt_len=6, target_len=4)
    got = np.asarray(rolled["units"]["l0"]["k"]).ravel()
    np.testing.assert_array_equal(got, [4, 5, 2, 3])


def test_cache_shape_families():
    """Cache entries match family semantics."""
    # dense: k/v per layer
    cs = cache_shape(ARCHS["qwen3-8b"].reduced(), 2, 32)
    leaves = jax.tree.leaves(cs)
    assert all(l.shape[-3] == 32 or l.ndim < 3 for l in leaves
               if hasattr(l, "shape"))
    # ssm: constant-size state
    cs = cache_shape(ARCHS["rwkv6-7b"].reduced(), 2, 32)
    for l in jax.tree.leaves(cs):
        assert 32 not in l.shape[1:]  # no seq-length dim
