"""Cache-semantics unit tests.

* Serving caches: slot validity, ring wraps, pad_cache alignment.
* Campaign executable cache: the canonical lru key
  (``campaign._exe_key``) must normalise redundant call spellings to
  ONE entry (no duplicate compiles) while keeping every degree of
  freedom that changes the lowered program distinct (no stale-program
  collisions), and the AOT layer's abstract-argument signature must
  separate executables per shape/dtype/tree.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core import campaign as C
from repro.core.baselines import MultiModelConfig
from repro.core.campaign import ExecPlan
from repro.core.simulate import SimConfig
from repro.models.attention import cache_slot_validity
from repro.serving.decode import cache_shape, init_cache, pad_cache


# ---------------------------------------------------------------------------
# cache_slot_validity
# ---------------------------------------------------------------------------
def valid(Sc, pos, window=None):
    return np.asarray(cache_slot_validity(Sc, jnp.int32(pos), window))


def test_fresh_cache_all_invalid():
    np.testing.assert_array_equal(valid(8, 0), np.zeros(8, bool))


def test_partially_filled():
    v = valid(8, 3)
    np.testing.assert_array_equal(v, [1, 1, 1, 0, 0, 0, 0, 0])


def test_full_cache_no_wrap():
    np.testing.assert_array_equal(valid(8, 8), np.ones(8, bool))


def test_wrapped_ring_all_slots_hold_recent():
    """After wrapping, every slot holds one of the last Sc positions."""
    v = valid(8, 13)          # positions 5..12 live in slots 5..12 mod 8
    np.testing.assert_array_equal(v, np.ones(8, bool))


def test_window_excludes_stale():
    # window 4, position 6, Sc 8: slots 2..5 (positions 2..5) valid,
    # positions 0,1 are out of window, slots 6,7 empty
    v = valid(8, 6, window=4)
    np.testing.assert_array_equal(v, [0, 0, 0, 1, 1, 1, 0, 0])


def test_window_ring_steady_state():
    # Sc == window: at any wrapped position exactly window-1 cached
    # positions are in-window (self occupies distance 0)
    for pos in (9, 17, 100):
        v = valid(8, pos, window=8)
        assert v.sum() == 7


# ---------------------------------------------------------------------------
# pad_cache
# ---------------------------------------------------------------------------
def test_pad_cache_extends_attention_only():
    cfg = ARCHS["recurrentgemma-9b"].reduced()   # rec, rec, local pattern
    cache = init_cache(cfg, 2, 16)
    padded = pad_cache(cache, cfg, prompt_len=16, target_len=24)
    for path, leaf in jax.tree_util.tree_flatten_with_path(padded)[0]:
        names = [p.key for p in path if hasattr(p, "key")]
        orig = jax.tree_util.tree_flatten_with_path(cache)[0]
        if names[-1] in ("k", "v"):
            axis = leaf.ndim - 3
            assert leaf.shape[axis] in (24, 16)   # full target or window cap
        if names[-1] in ("h", "conv", "wkv"):
            # recurrent state untouched
            pass


def test_pad_cache_respects_window_cap():
    cfg = ARCHS["recurrentgemma-9b"].reduced()
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, sliding_window=8))
    cache = init_cache(cfg, 1, 8)
    padded = pad_cache(cache, cfg, prompt_len=8, target_len=100)
    for path, leaf in jax.tree_util.tree_flatten_with_path(padded)[0]:
        names = [p.key for p in path if hasattr(p, "key")]
        if names[-1] in ("k", "v"):
            axis = leaf.ndim - 3
            assert leaf.shape[axis] == 8          # stays at the window


def test_pad_cache_rolls_truncated_prompt():
    """A prefill that truncated to the window must be rolled back onto the
    ring invariant (slot i = position mod Sc)."""
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    # hand-build a cache entry: Sc=4, prompt_len=6 -> slots hold pos 2..5
    # at indices 0..3; ring wants pos p at slot p%4: pos4->0,5->1,2->2,3->3
    k = jnp.arange(2, 6, dtype=jnp.float32).reshape(1, 4, 1, 1)
    cache = {"units": {"l0": {"k": k[None], "v": k[None]}}}
    rolled = pad_cache(cache, cfg, prompt_len=6, target_len=4)
    got = np.asarray(rolled["units"]["l0"]["k"]).ravel()
    np.testing.assert_array_equal(got, [4, 5, 2, 3])


def test_cache_shape_families():
    """Cache entries match family semantics."""
    # dense: k/v per layer
    cs = cache_shape(ARCHS["qwen3-8b"].reduced(), 2, 32)
    leaves = jax.tree.leaves(cs)
    assert all(l.shape[-3] == 32 or l.ndim < 3 for l in leaves
               if hasattr(l, "shape"))
    # ssm: constant-size state
    cs = cache_shape(ARCHS["rwkv6-7b"].reduced(), 2, 32)
    for l in jax.tree.leaves(cs):
        assert 32 not in l.shape[1:]  # no seq-length dim


# ---------------------------------------------------------------------------
# campaign executable cache keys (cache-correctness bugfix satellite)
# ---------------------------------------------------------------------------
AE = AutoencoderConfig(input_dim=8, hidden=(4,), code_dim=2)


def _scfg(scheme="tolfl", k=2):
    return SimConfig(scheme=scheme, num_devices=6, num_clusters=k,
                     rounds=2, dropout=False)


def test_exe_key_spelling_invariance():
    """Omitted trailing defaults and explicit kwargs must land on the
    SAME lru entry — raw ``functools.lru_cache`` would key them apart
    and silently compile the identical program twice."""
    cfg = _scfg()
    a = C._executable("single", AE, cfg, 4, None)
    b = C._executable("single", AE, cfg, 4, None, track_iso=False,
                      fused=False)
    assert a is b


def test_exe_key_static_path_normalises_flags():
    """Static builds (k_pad=None) derive the iso branch from
    ``cfg.scheme`` inside ``_build_core``; a caller flag disagreeing
    with the scheme must not mint a second identical executable."""
    cfg = _scfg("tolfl")
    assert (C._exe_key("single", AE, cfg, None, None, True, True)
            == C._exe_key("single", AE, cfg, None, None, False, False))
    # fl statically builds the isolated-fallback branch: forced on
    kf = C._exe_key("single", AE, _scfg("fl", 1), None, None, False, False)
    assert kf[-2] is True
    # ... and there is no fused-static path
    assert kf[-1] is False
    # object identity through the public wrapper
    assert (C._executable("single", AE, cfg, None, None,
                          track_iso=True, fused=True)
            is C._executable("single", AE, cfg, None, None))


def test_exe_key_multi_normalises_track_iso():
    mcfg = MultiModelConfig(num_devices=6, num_models=2, rounds=2,
                            dropout=False)
    assert (C._exe_key("multi", AE, mcfg, None, None, True, False)
            == C._exe_key("multi", AE, mcfg, None, None, False, False))


def test_exe_key_multi_rejects_k_pad():
    mcfg = MultiModelConfig(num_devices=6, num_models=2, rounds=2,
                            dropout=False)
    with pytest.raises(AssertionError, match="multi-model"):
        C._exe_key("multi", AE, mcfg, 3, None, False, False)


def test_exe_key_distinct_programs_stay_distinct():
    """Every knob that changes the lowered program must stay in the
    key: collapsing any pair would serve a stale/wrong executable."""
    cfg = _scfg()
    keys = [
        C._exe_key("single", AE, cfg, 4, None, False, True),
        C._exe_key("single", AE, cfg, 8, None, False, True),     # k_pad
        C._exe_key("single", AE, cfg, 4, 2, False, True),        # ndev
        C._exe_key("single", AE, cfg, 4, None, True, True),      # iso kind
        C._exe_key("single", AE, cfg, 4, None, False, False),    # op split
        C._exe_key("single", AE, _scfg(k=3), 4, None, False, True),  # cfg
        C._exe_key("single", AE, dataclasses.replace(cfg, rounds=3),
                   4, None, False, True),                        # cfg
    ]
    assert len(set(keys)) == len(keys)


def test_exe_key_shard_degrade_shares_unsharded_entry():
    """``ExecPlan(shard=True)`` on a single-device host degrades to
    ``ndev=None`` — the same cache entry as the unsharded path, never a
    duplicate compile."""
    if jax.local_device_count() > 1:
        pytest.skip("host has multiple devices")
    ndev = ExecPlan(shard=True).resolved_devices(warn=False)
    assert ndev is None
    cfg = _scfg()
    assert (C._exe_key("single", AE, cfg, 4, ndev, False, True)
            == C._exe_key("single", AE, cfg, 4, None, False, True))


def test_avals_signature_separates_executables():
    """The AOT key extension: same canonical key, different chunk
    shapes / dtypes / pytree structure -> different compiled
    executables (and equal avals -> equal signature, so re-planning the
    same spec hits the cache)."""
    sds = jax.ShapeDtypeStruct
    a = (sds((4, 3), jnp.float32),)
    assert C._avals_signature(a) == C._avals_signature(
        (sds((4, 3), jnp.float32),))
    assert C._avals_signature(a) != C._avals_signature(
        (sds((8, 3), jnp.float32),))
    assert C._avals_signature(a) != C._avals_signature(
        (sds((4, 3), jnp.int32),))
    assert C._avals_signature(a) != C._avals_signature(
        ((sds((4, 3), jnp.float32),),))   # same leaves, deeper tree
    # dict pytrees: insertion order must not split the cache
    assert (C._avals_signature({"x": sds((2,), jnp.float32),
                                "y": sds((3,), jnp.int32)})
            == C._avals_signature({"y": sds((3,), jnp.int32),
                                   "x": sds((2,), jnp.float32)}))
