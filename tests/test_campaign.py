"""Batched campaign engine == a loop of single-scenario calls.

The acceptance contract (ISSUE 1): a campaign of >= 32 (trace x seed)
scenarios for scheme="tolfl" runs through ONE jitted/vmapped executable
(compile-count assertion) and matches the per-scenario simulator to
<= 1e-5 on ``auroc_used``.  ISSUE 2 extends the same contract to the
multi-model baselines: a (trace x seed) grid per (scheme, M) cell equals
a Python loop of ``run_multimodel`` calls and costs exactly one compile.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core import campaign
from repro.core.baselines import MultiModelConfig, run_multimodel
from repro.core.campaign import (CampaignResult, mean_ci95, run_campaign,
                                 run_multimodel_campaign, sweep_grid)
from repro.core.failure import (NO_FAILURE, FailureEvent, FailureSpec,
                                FailureTrace)
from repro.core.simulate import SimConfig, run_simulation
from repro.data import commsml, federated

ROUNDS = 5
SEEDS = range(4)


@pytest.fixture(scope="module")
def small_ae():
    return AutoencoderConfig(input_dim=commsml.N_FEATURES, hidden=(16,),
                             code_dim=4, dropout=0.2)


@pytest.fixture(scope="module")
def small_data():
    X, y = commsml.generate(seed=0, samples_per_class=60)
    split = federated.make_split(X, y, num_devices=10, num_clusters=5,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    return dx, counts, split.test_x, split.test_y


def _tolfl_cfg():
    return SimConfig(scheme="tolfl", num_devices=10, num_clusters=5,
                     rounds=ROUNDS, lr=1e-3, dropout=False)


def _traces(cfg):
    """8 scenarios: none, timed client/server failures, multi-event and
    recovery traces — the kind of grid the single-event seed could not
    express."""
    topo = cfg.topology()
    return [
        NO_FAILURE,
        FailureSpec(epoch=1, kind="client"),
        FailureSpec(epoch=3, kind="client"),
        FailureSpec(epoch=1, kind="server"),
        FailureSpec(epoch=3, kind="server"),
        FailureTrace.from_events([FailureEvent(1, "client"),
                                  FailureEvent(2, "server")], topo),
        FailureTrace.from_events(
            [FailureEvent(1, "client"),
             FailureEvent(3, "client", recover=True)], topo),
        FailureTrace.from_events([FailureEvent(0, "server", device=2),
                                  FailureEvent(2, "client", device=7)],
                                 topo),
    ]


@pytest.fixture(scope="module")
def tolfl_campaign(small_ae, small_data):
    dx, counts, tx, ty = small_data
    cfg = _tolfl_cfg()
    before = campaign.TRACE_COUNT
    res = run_campaign(small_ae, dx, counts, tx, ty, cfg, _traces(cfg),
                       seeds=SEEDS, target_loss=2430.0)
    return res, campaign.TRACE_COUNT - before


def test_campaign_covers_grid(tolfl_campaign):
    res, _ = tolfl_campaign
    assert res.num_scenarios == 8 * len(SEEDS) >= 32
    assert res.loss_curves.shape == (res.num_scenarios, ROUNDS)
    assert np.isfinite(res.auroc_used).all()


def test_campaign_single_compile(tolfl_campaign):
    """The whole >=32-scenario batch must trace the scenario core
    exactly once: one compiled executable serves every trace."""
    res, n_traces = tolfl_campaign
    assert res.num_scenarios >= 32
    assert n_traces == 1, f"core traced {n_traces}x; expected 1"


def test_campaign_matches_per_scenario_simulate(tolfl_campaign, small_ae,
                                                small_data):
    """Same seeds -> same results as a Python loop of single runs."""
    res, _ = tolfl_campaign
    dx, counts, tx, ty = small_data
    cfg = _tolfl_cfg()
    traces = _traces(cfg)
    for b in range(res.num_scenarios):
        scfg = dataclasses.replace(cfg, seed=int(res.seed[b]))
        single = run_simulation(small_ae, dx, counts, tx, ty, scfg,
                                traces[res.trace_index[b]])
        np.testing.assert_allclose(res.auroc_used[b], single.auroc_used,
                                   atol=1e-5)
        np.testing.assert_allclose(res.loss_curves[b], single.loss_curve,
                                   rtol=1e-4, atol=1e-4)


def test_campaign_fl_fallback_matches_simulate(small_ae, small_data):
    """The in-graph FL server-death fallback survives vmapping."""
    dx, counts, tx, ty = small_data
    cfg = SimConfig(scheme="fl", num_devices=10, num_clusters=1,
                    rounds=ROUNDS, lr=1e-3, dropout=False)
    traces = [NO_FAILURE, FailureSpec(epoch=1, kind="server")]
    res = run_campaign(small_ae, dx, counts, tx, ty, cfg, traces,
                       seeds=[0, 1])
    assert list(res.iso_active) == [False, False, True, True]
    for b in range(res.num_scenarios):
        scfg = dataclasses.replace(cfg, seed=int(res.seed[b]))
        single = run_simulation(small_ae, dx, counts, tx, ty, scfg,
                                traces[res.trace_index[b]])
        assert single.iso_active == bool(res.iso_active[b])
        np.testing.assert_allclose(res.auroc_used[b], single.auroc_used,
                                   atol=1e-5)


def test_summary_statistics(tolfl_campaign):
    res, _ = tolfl_campaign
    s = res.summary()
    assert s["num_scenarios"] == res.num_scenarios
    np.testing.assert_allclose(s["auroc_used_mean"],
                               res.auroc_used.mean(), rtol=1e-12)
    assert (s["auroc_used_ci95_lo"] <= s["auroc_used_mean"]
            <= s["auroc_used_ci95_hi"])
    # target_loss was chosen reachable for at least some scenarios
    assert np.isfinite(res.rounds_to_loss).any()
    assert s["rounds_to_loss_mean"] >= 1


def test_select_by_trace(tolfl_campaign):
    res, _ = tolfl_campaign
    per_trace = [res.select(i) for i in range(8)]
    assert all(len(p) == len(SEEDS) for p in per_trace)
    # the failure-free scenarios should not be the worst of the grid
    assert per_trace[0].mean() >= res.auroc_used.min()


def test_summary_sample_std_known_values():
    """ddof=1 regression: the campaign reports the SAMPLE std, so a
    two-scenario campaign must report std sqrt(2)x larger than the
    ddof=0 population formula would."""
    vals = np.array([0.8, 0.9])
    mean, std, half = mean_ci95(vals)
    np.testing.assert_allclose(mean, 0.85)
    np.testing.assert_allclose(std, 0.07071067811865478)   # ddof=1
    assert std > np.std(vals) + 0.02                       # not ddof=0
    np.testing.assert_allclose(half, 1.96 * std / np.sqrt(2))
    # single scenario: no spread estimate, nan CI instead of false zero
    mean1, std1, half1 = mean_ci95(np.array([0.7]))
    assert (mean1, std1) == (0.7, 0.0) and np.isnan(half1)

    r = len(vals)
    res = CampaignResult(
        cfg=SimConfig(), trace_index=np.zeros(r, int),
        seed=np.arange(r), auroc_used=vals, final_auroc=vals,
        iso_auroc=np.full(r, np.nan), iso_active=np.zeros(r, bool),
        loss_curves=np.zeros((r, 1)), iso_loss_curves=np.zeros((r, 1)),
        rounds_to_loss=np.full(r, np.nan))
    s = res.summary()
    np.testing.assert_allclose(s["auroc_used_std"], 0.07071067811865478)
    np.testing.assert_allclose(
        s["auroc_used_ci95_hi"] - s["auroc_used_ci95_lo"], 2 * half)


# ---------------------------------------------------------------------------
# multi-model baselines on the same vmapped contract (ISSUE 2 tentpole)
# ---------------------------------------------------------------------------
MM_ROUNDS = 4
MM_SEEDS = range(2)


def _mm_traces(n_devices):
    topo = SimConfig(scheme="fl", num_devices=n_devices).topology()
    return [
        NO_FAILURE,
        FailureSpec(epoch=1, kind="client"),
        FailureSpec(epoch=2, kind="server"),
        FailureTrace.from_events(
            [FailureEvent(1, "client", device=3),
             FailureEvent(3, "client", device=3, recover=True),
             FailureEvent(2, "server")], topo),
    ]


@pytest.mark.parametrize("scheme", ["fedgroup", "ifca", "fesem"])
def test_multimodel_campaign_matches_looped_runs(scheme, small_ae,
                                                 small_data):
    """Batched (trace x seed) grid == a loop of run_multimodel calls,
    in ONE compile per (scheme, M) cell."""
    dx, counts, tx, ty = small_data
    cfg = MultiModelConfig(scheme=scheme, num_devices=10, num_models=3,
                           rounds=MM_ROUNDS, lr=1e-3)
    traces = _mm_traces(10)
    before = campaign.TRACE_COUNT
    res = run_multimodel_campaign(small_ae, dx, counts, tx, ty, cfg,
                                  traces, seeds=MM_SEEDS)
    n_traces = campaign.TRACE_COUNT - before
    assert n_traces == 1, f"core traced {n_traces}x; expected 1"
    assert res.num_scenarios == len(traces) * len(MM_SEEDS)
    assert res.loss_curves.shape == (res.num_scenarios, MM_ROUNDS)
    assert res.assignments.shape == (res.num_scenarios, 10)
    assert np.isfinite(res.best_auroc).all()
    for b in range(res.num_scenarios):
        scfg = dataclasses.replace(cfg, seed=int(res.seed[b]))
        single = run_multimodel(small_ae, dx, counts, tx, ty, scfg,
                                traces[res.trace_index[b]])
        np.testing.assert_allclose(res.best_auroc[b], single.best_auroc,
                                   atol=1e-5)
        np.testing.assert_allclose(res.multi_auroc[b], single.multi_auroc,
                                   atol=1e-5)
        np.testing.assert_allclose(res.loss_curves[b], single.loss_curve,
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(res.assignments[b],
                                      single.assignments)


def test_multimodel_summary_and_select(small_ae, small_data):
    dx, counts, tx, ty = small_data
    cfg = MultiModelConfig(scheme="ifca", num_devices=10, num_models=2,
                           rounds=MM_ROUNDS, lr=1e-3)
    res = run_multimodel_campaign(small_ae, dx, counts, tx, ty, cfg,
                                  _mm_traces(10), seeds=MM_SEEDS)
    s = res.summary()
    assert s["num_scenarios"] == res.num_scenarios
    np.testing.assert_allclose(s["best_auroc_mean"],
                               res.best_auroc.mean(), rtol=1e-12)
    assert (s["multi_auroc_ci95_lo"] <= s["multi_auroc_mean"]
            <= s["multi_auroc_ci95_hi"])
    for i in range(4):
        assert len(res.select(i, "best")) == len(MM_SEEDS)
        assert len(res.select(i, "multi")) == len(MM_SEEDS)


def test_sweep_grid_dispatches_multimodel(small_ae, small_data):
    """sweep_grid cells for MULTI_SCHEMES run the multi-model engine
    with k interpreted as the model count M."""
    dx, counts, tx, ty = small_data
    base = SimConfig(num_devices=10, rounds=3, lr=1e-3, dropout=False)
    cells = sweep_grid(small_ae, dx, counts, tx, ty, base,
                       scheme_ks=[("tolfl", 5), ("ifca", 2)],
                       traces=[NO_FAILURE,
                               FailureSpec(epoch=1, kind="server")],
                       seeds=[0])
    assert cells[("ifca", 2)].cfg.num_models == 2
    assert cells[("ifca", 2)].num_scenarios == 2
    assert np.isfinite(cells[("ifca", 2)].best_auroc).all()
    assert np.isfinite(cells[("tolfl", 5)].auroc_used).all()


def test_sweep_grid_cells(small_ae, small_data):
    dx, counts, tx, ty = small_data
    base = SimConfig(num_devices=10, rounds=3, lr=1e-3, dropout=False)
    cells = sweep_grid(small_ae, dx, counts, tx, ty, base,
                       scheme_ks=[("tolfl", 5), ("tolfl", 2), ("sbt", 10)],
                       traces=[NO_FAILURE,
                               FailureSpec(epoch=1, kind="server")],
                       seeds=[0])
    assert set(cells) == {("tolfl", 5), ("tolfl", 2), ("sbt", 10)}
    for res in cells.values():
        assert res.num_scenarios == 2
        assert np.isfinite(res.auroc_used).all()
