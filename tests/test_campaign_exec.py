"""Execution-layer contracts of the campaign engine (ISSUE 3 + 4).

What is proven:

* **chunked == one-shot** — ``ExecPlan(chunk_size < B)`` with a
  non-divisible batch (padding required) returns the same per-scenario
  results as the unchunked call, and the whole chunked campaign still
  costs ONE compile (every chunk has the same padded shape).
* **padded-k sweep == per-cell sweep** — ``sweep_grid(fuse=False)``
  runs ALL single-model (scheme, k) cells through one compiled
  executable (TRACE_COUNT delta == 1) and matches the ``pad_k=False``
  per-cell build scenario-for-scenario.
* **fused sweep == per-cell sweep** — the default ``sweep_grid`` runs
  every cell of one iso-tracking kind through ONE dispatch over the
  flattened (cell x trace x seed) axis: exactly one trace per kind for
  the whole grid, results identical to both per-cell paths; multi-model
  cells of one scheme fuse the same way across DIFFERENT model counts
  (padded-M ``model_valid`` mask), matching ``fuse=False`` per cell.
  Ragged fused cells (per-cell trace lists of different lengths) match
  per-cell ``run_campaign``.
* **compile amortisation** — a repeated campaign with identical shapes
  hits the executable cache: 0 new traces (data arrays are arguments,
  not closures).
* **sharded == unsharded** — a 64-scenario campaign sharded over 8
  forced-host CPU devices (subprocess, like ``tests/test_distributed``)
  matches the one-shot path to <= 1e-5, including sharding + chunking
  combined, a non-divisible batch that needs device padding, and the
  fused single-/multi-model sweeps (padded-M cell included) whose
  flattened axis is what actually shards.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core import campaign
from repro.core.campaign import (ExecPlan, run_campaign,
                                 run_fused_campaigns, sweep_grid)
from repro.core.failure import sample_traces
from repro.core.simulate import SimConfig
from repro.data import commsml, federated

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# rounds is deliberately DISTINCT from every other campaign test in the
# suite: the executable cache is global, and the compile-count
# assertions below need cold cache entries
ROUNDS = 6


@pytest.fixture(scope="module")
def small_ae():
    return AutoencoderConfig(input_dim=commsml.N_FEATURES, hidden=(16,),
                             code_dim=4, dropout=0.2)


@pytest.fixture(scope="module")
def small_data():
    X, y = commsml.generate(seed=0, samples_per_class=60)
    split = federated.make_split(X, y, num_devices=10, num_clusters=5,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    return dx, counts, split.test_x, split.test_y


def _cfg():
    return SimConfig(scheme="tolfl", num_devices=10, num_clusters=5,
                     rounds=ROUNDS, lr=1e-3, dropout=False)


def _traces(cfg, n=4):
    return sample_traces(np.random.default_rng(3), cfg.topology(), 0.5,
                         max_events=8, rounds=ROUNDS, num_traces=n)


def test_chunked_equals_oneshot_with_padding(small_ae, small_data):
    """chunk_size=5 over B=12 -> chunks of 5/5/5 with 3 padded rows;
    results equal the one-shot batch and the padding is stripped."""
    dx, counts, tx, ty = small_data
    cfg = _cfg()
    traces = _traces(cfg)
    before = campaign.TRACE_COUNT
    one = run_campaign(small_ae, dx, counts, tx, ty, cfg, traces,
                       seeds=range(3), target_loss=2430.0)
    assert campaign.TRACE_COUNT - before == 1
    assert one.num_scenarios == 12
    before = campaign.TRACE_COUNT
    chunked = run_campaign(small_ae, dx, counts, tx, ty, cfg, traces,
                           seeds=range(3), target_loss=2430.0,
                           exec_plan=ExecPlan(chunk_size=5))
    # a new batch shape (5 vs 12) -> exactly one new compile, reused by
    # every chunk including the padded last one
    assert campaign.TRACE_COUNT - before == 1
    assert chunked.num_scenarios == 12
    np.testing.assert_allclose(one.auroc_used, chunked.auroc_used,
                               atol=1e-5)
    np.testing.assert_allclose(one.loss_curves, chunked.loss_curves,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(one.trace_index, chunked.trace_index)
    np.testing.assert_array_equal(
        np.isfinite(one.rounds_to_loss),
        np.isfinite(chunked.rounds_to_loss))


def test_repeated_campaign_reuses_executable(small_ae, small_data):
    """Data arrays are arguments of a cached jitted core: a second
    campaign on the same shapes costs ZERO new traces."""
    dx, counts, tx, ty = small_data
    cfg = _cfg()
    traces = _traces(cfg)
    first = run_campaign(small_ae, dx, counts, tx, ty, cfg, traces,
                         seeds=range(3))
    before = campaign.TRACE_COUNT
    again = run_campaign(small_ae, dx, counts, tx, ty, cfg, traces,
                         seeds=range(3))
    assert campaign.TRACE_COUNT - before == 0
    np.testing.assert_array_equal(first.auroc_used, again.auroc_used)


SWEEP_CELLS = [("tolfl", 5), ("tolfl", 2), ("sbt", 10)]


def _assert_cells_equal(padded, percell):
    for key in padded:
        np.testing.assert_allclose(padded[key].auroc_used,
                                   percell[key].auroc_used, atol=1e-5)
        np.testing.assert_allclose(padded[key].final_auroc,
                                   percell[key].final_auroc, atol=1e-5)
        np.testing.assert_allclose(padded[key].loss_curves,
                                   percell[key].loss_curves,
                                   rtol=1e-5, atol=1e-5)
        # iso outputs must match too: non-fl cells report zeros on BOTH
        # paths (the padded core must not silently enable iso tracking)
        np.testing.assert_allclose(padded[key].iso_loss_curves,
                                   percell[key].iso_loss_curves,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(padded[key].iso_active,
                                      percell[key].iso_active)


def test_padded_sweep_single_compile_and_parity(small_ae, small_data):
    """All (non-fl) single-model cells of a per-cell-dispatch sweep
    (``fuse=False``) share ONE compiled executable (padded cluster
    arrays as dynamic operands) and match the per-cell static build
    scenario-for-scenario."""
    dx, counts, tx, ty = small_data
    base = SimConfig(num_devices=10, rounds=ROUNDS, lr=1e-3,
                     dropout=False)
    cfg = _cfg()
    traces = _traces(cfg, n=3)
    before = campaign.TRACE_COUNT
    padded = sweep_grid(small_ae, dx, counts, tx, ty, base, SWEEP_CELLS,
                        traces, seeds=[0, 1], fuse=False)
    n_traces = campaign.TRACE_COUNT - before
    assert n_traces == 1, f"padded sweep traced {n_traces}x; expected 1"
    percell = sweep_grid(small_ae, dx, counts, tx, ty, base, SWEEP_CELLS,
                         traces, seeds=[0, 1], pad_k=False)
    _assert_cells_equal(padded, percell)


def test_padded_sweep_fl_cell_compiles_separately(small_ae, small_data):
    """An fl cell carries the isolated-fallback branch (extra compute);
    it must get its OWN padded executable — one more compile, never a
    silently iso-tracking executable for the non-fl cells."""
    dx, counts, tx, ty = small_data
    base = SimConfig(num_devices=10, rounds=ROUNDS, lr=1e-3,
                     dropout=False)
    cfg = _cfg()
    traces = _traces(cfg, n=3)
    cells = SWEEP_CELLS + [("fl", 1)]
    # warm the shared non-iso executable so the count below isolates
    # the fl cell's contribution (self-contained under -k selection)
    sweep_grid(small_ae, dx, counts, tx, ty, base, SWEEP_CELLS, traces,
               seeds=[0, 1], fuse=False)
    before = campaign.TRACE_COUNT
    padded = sweep_grid(small_ae, dx, counts, tx, ty, base, cells,
                        traces, seeds=[0, 1], fuse=False)
    n_traces = campaign.TRACE_COUNT - before
    assert n_traces == 1, \
        f"mixed sweep traced {n_traces}x; expected 1 (fl cell only)"
    percell = sweep_grid(small_ae, dx, counts, tx, ty, base, cells,
                         traces, seeds=[0, 1], pad_k=False)
    _assert_cells_equal(padded, percell)
    assert padded[("fl", 1)].cfg.scheme == "fl"


def test_fused_sweep_one_trace_per_kind_and_parity(small_ae, small_data):
    """ISSUE 4 tentpole contract: the default (fused) ``sweep_grid``
    dispatches ALL single-model cells of one iso-tracking kind as ONE
    stacked vmap over the flattened (cell x trace x seed) axis —
    exactly TWO traces for a mixed grid (non-fl + fl), with results
    identical to both per-cell paths."""
    dx, counts, tx, ty = small_data
    base = SimConfig(num_devices=10, rounds=ROUNDS, lr=1e-3,
                     dropout=False)
    cfg = _cfg()
    traces = _traces(cfg, n=3)
    cells = SWEEP_CELLS + [("fl", 1)]
    before = campaign.TRACE_COUNT
    fused = sweep_grid(small_ae, dx, counts, tx, ty, base, cells,
                       traces, seeds=[0, 1])
    n_traces = campaign.TRACE_COUNT - before
    assert n_traces == 2, \
        f"fused sweep traced {n_traces}x; expected 2 (one per kind)"
    # steady state: a repeat of the whole grid costs ZERO traces
    before = campaign.TRACE_COUNT
    again = sweep_grid(small_ae, dx, counts, tx, ty, base, cells,
                       traces, seeds=[0, 1])
    assert campaign.TRACE_COUNT - before == 0
    percell = sweep_grid(small_ae, dx, counts, tx, ty, base, cells,
                         traces, seeds=[0, 1], fuse=False)
    static = sweep_grid(small_ae, dx, counts, tx, ty, base, cells,
                        traces, seeds=[0, 1], pad_k=False)
    _assert_cells_equal(fused, percell)
    _assert_cells_equal(fused, static)
    _assert_cells_equal(fused, again)
    for key in cells:
        assert fused[key].cfg.scheme == key[0]


def test_fused_multimodel_padded_M_parity(small_ae, small_data):
    """Multi-model cells of one scheme with DIFFERENT model counts fuse
    into one dispatch via the padded-M ``model_valid`` mask: one trace
    per scheme for the whole grid, per-cell results identical to the
    unfused (unpadded) dispatch — including the padded-M (ifca, 2)
    cell."""
    dx, counts, tx, ty = small_data
    base = SimConfig(num_devices=10, rounds=3, lr=1e-3, dropout=False)
    cfg = _cfg()
    traces = _traces(cfg, n=2)
    cells = [("ifca", 2), ("ifca", 3), ("fesem", 2)]
    before = campaign.TRACE_COUNT
    fused = sweep_grid(small_ae, dx, counts, tx, ty, base, cells,
                       traces, seeds=[0])
    n_traces = campaign.TRACE_COUNT - before
    assert n_traces == 2, \
        f"fused multi sweep traced {n_traces}x; expected 2 (per scheme)"
    percell = sweep_grid(small_ae, dx, counts, tx, ty, base, cells,
                         traces, seeds=[0], fuse=False)
    for key in cells:
        f, p = fused[key], percell[key]
        np.testing.assert_allclose(f.best_auroc, p.best_auroc, atol=1e-5)
        np.testing.assert_allclose(f.multi_auroc, p.multi_auroc,
                                   atol=1e-5)
        np.testing.assert_allclose(f.loss_curves, p.loss_curves,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(f.assignments, p.assignments)
        assert f.cfg.num_models == key[1]


def test_fused_ragged_cells_match_run_campaign(small_ae, small_data):
    """``run_fused_campaigns`` accepts DIFFERENT trace lists per cell
    (the per-topology sampled grids of the failure_scenarios example):
    the flattened scenario axis is ragged across cells, and every cell
    still matches its own ``run_campaign``."""
    dx, counts, tx, ty = small_data
    cfg_a = _cfg()
    cfg_b = SimConfig(scheme="sbt", num_devices=10, num_clusters=10,
                      rounds=ROUNDS, lr=1e-3, dropout=False)
    tr_a = _traces(cfg_a, n=3)
    tr_b = sample_traces(np.random.default_rng(7), cfg_b.topology(), 0.5,
                         max_events=8, rounds=ROUNDS, num_traces=2)
    fused = run_fused_campaigns(small_ae, dx, counts, tx, ty,
                                [(cfg_a, tr_a), (cfg_b, tr_b)],
                                seeds=[0, 1], target_loss=2430.0)
    assert [r.num_scenarios for r in fused] == [6, 4]
    for cfg, traces, res in [(cfg_a, tr_a, fused[0]),
                             (cfg_b, tr_b, fused[1])]:
        solo = run_campaign(small_ae, dx, counts, tx, ty, cfg, traces,
                            seeds=[0, 1], target_loss=2430.0)
        np.testing.assert_allclose(res.auroc_used, solo.auroc_used,
                                   atol=1e-5)
        np.testing.assert_allclose(res.loss_curves, solo.loss_curves,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(res.trace_index, solo.trace_index)
        np.testing.assert_array_equal(
            np.isfinite(res.rounds_to_loss),
            np.isfinite(solo.rounds_to_loss))


# ---------------------------------------------------------------------------
# sharded execution (needs >1 device -> subprocess with a forced-host
# platform, exactly like tests/test_distributed.py)
# ---------------------------------------------------------------------------
SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax

    from repro.configs.autoencoder_paper import AutoencoderConfig
    from repro.core import campaign
    from repro.core.campaign import ExecPlan, run_campaign, sweep_grid
    from repro.core.failure import sample_traces
    from repro.core.simulate import SimConfig
    from repro.data import commsml, federated

    assert jax.device_count() == 8, jax.device_count()
    ae = AutoencoderConfig(input_dim=commsml.N_FEATURES, hidden=(16,),
                           code_dim=4, dropout=0.2)
    X, y = commsml.generate(seed=0, samples_per_class=60)
    split = federated.make_split(X, y, num_devices=10, num_clusters=5,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    cfg = SimConfig(scheme="tolfl", num_devices=10, num_clusters=5,
                    rounds=3, lr=1e-3, dropout=False)
    traces = sample_traces(np.random.default_rng(0), cfg.topology(), 0.5,
                           max_events=8, rounds=3, num_traces=16)
    seeds = range(4)                    # B = 64
    args = (ae, dx, counts, split.test_x, split.test_y, cfg)

    base = run_campaign(*args, traces, seeds)
    c0 = campaign.TRACE_COUNT
    sharded = run_campaign(*args, traces, seeds,
                           exec_plan=ExecPlan(shard=True))
    sharded_compiles = campaign.TRACE_COUNT - c0
    c0 = campaign.TRACE_COUNT
    both = run_campaign(*args, traces, seeds,
                        exec_plan=ExecPlan(shard=True, chunk_size=24))
    both_compiles = campaign.TRACE_COUNT - c0
    # non-divisible batch: 60 scenarios over 8 devices (pad to 64)
    base_nd = run_campaign(*args, traces[:15], seeds)
    shard_nd = run_campaign(*args, traces[:15], seeds,
                            exec_plan=ExecPlan(shard=True))

    def err(a, b):
        return float(np.max(np.abs(a - b)))

    # fused sweeps: the flattened (cell x trace x seed) axis is what
    # shards.  Single-model grid (incl. an fl cell) and a multi-model
    # grid with a padded-M cell, sharded vs unsharded.
    base_cfg = SimConfig(num_devices=10, rounds=3, lr=1e-3,
                         dropout=False)
    cells = [("tolfl", 5), ("tolfl", 2), ("sbt", 10), ("fl", 1)]
    grid_args = (ae, dx, counts, split.test_x, split.test_y, base_cfg)
    c0 = campaign.TRACE_COUNT
    fused = sweep_grid(*grid_args, cells, traces[:4], range(3))
    fused_compiles = campaign.TRACE_COUNT - c0
    c0 = campaign.TRACE_COUNT
    fused_sh = sweep_grid(*grid_args, cells, traces[:4], range(3),
                          exec_plan=ExecPlan(shard=True))
    fused_sh_compiles = campaign.TRACE_COUNT - c0
    d_fused = max(err(fused[c].auroc_used, fused_sh[c].auroc_used)
                  for c in cells)
    mcells = [("ifca", 2), ("ifca", 3)]
    multi = sweep_grid(*grid_args, mcells, traces[:4], range(2))
    multi_sh = sweep_grid(*grid_args, mcells, traces[:4], range(2),
                          exec_plan=ExecPlan(shard=True))
    d_multi = max(max(err(multi[c].best_auroc, multi_sh[c].best_auroc),
                      err(multi[c].multi_auroc, multi_sh[c].multi_auroc))
                  for c in mcells)

    print(json.dumps({
        "num_scenarios": int(base.num_scenarios),
        "sharded_compiles": sharded_compiles,
        "both_compiles": both_compiles,
        "d_auroc": err(base.auroc_used, sharded.auroc_used),
        "d_loss": err(base.loss_curves, sharded.loss_curves),
        "d_auroc_chunked": err(base.auroc_used, both.auroc_used),
        "d_auroc_nondiv": err(base_nd.auroc_used, shard_nd.auroc_used),
        "fused_compiles": fused_compiles,
        "fused_sharded_compiles": fused_sh_compiles,
        "d_auroc_fused_sharded": d_fused,
        "d_auroc_multi_sharded": d_multi,
    }))
""")


def test_sharded_campaign_matches_oneshot():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    stats = json.loads(out.stdout.strip().splitlines()[-1])
    assert stats["num_scenarios"] == 64
    # one (vmapped, shard_mapped) trace per new executable
    assert stats["sharded_compiles"] == 1, stats
    assert stats["both_compiles"] == 1, stats
    assert stats["d_auroc"] <= 1e-5, stats
    assert stats["d_loss"] <= 1e-4, stats
    assert stats["d_auroc_chunked"] <= 1e-5, stats
    assert stats["d_auroc_nondiv"] <= 1e-5, stats
    # fused sweeps shard their flattened (cell x trace x seed) axis:
    # still one trace per iso-tracking kind, results unchanged
    assert stats["fused_compiles"] == 2, stats
    assert stats["fused_sharded_compiles"] == 2, stats
    assert stats["d_auroc_fused_sharded"] <= 1e-5, stats
    assert stats["d_auroc_multi_sharded"] <= 1e-5, stats
