"""Execution-layer contracts of the campaign engine (ISSUE 3 tentpole).

What is proven:

* **chunked == one-shot** — ``ExecPlan(chunk_size < B)`` with a
  non-divisible batch (padding required) returns the same per-scenario
  results as the unchunked call, and the whole chunked campaign still
  costs ONE compile (every chunk has the same padded shape).
* **padded-k sweep == per-cell sweep** — ``sweep_grid`` with the
  default ``pad_k`` runs ALL single-model (scheme, k) cells through one
  compiled executable (TRACE_COUNT delta == 1) and matches the
  ``pad_k=False`` per-cell build scenario-for-scenario.
* **compile amortisation** — a repeated campaign with identical shapes
  hits the executable cache: 0 new traces (data arrays are arguments,
  not closures).
* **sharded == unsharded** — a 64-scenario campaign sharded over 8
  forced-host CPU devices (subprocess, like ``tests/test_distributed``)
  matches the one-shot path to <= 1e-5, including sharding + chunking
  combined and a non-divisible batch that needs device padding.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core import campaign
from repro.core.campaign import ExecPlan, run_campaign, sweep_grid
from repro.core.failure import sample_traces
from repro.core.simulate import SimConfig
from repro.data import commsml, federated

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# rounds is deliberately DISTINCT from every other campaign test in the
# suite: the executable cache is global, and the compile-count
# assertions below need cold cache entries
ROUNDS = 6


@pytest.fixture(scope="module")
def small_ae():
    return AutoencoderConfig(input_dim=commsml.N_FEATURES, hidden=(16,),
                             code_dim=4, dropout=0.2)


@pytest.fixture(scope="module")
def small_data():
    X, y = commsml.generate(seed=0, samples_per_class=60)
    split = federated.make_split(X, y, num_devices=10, num_clusters=5,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    return dx, counts, split.test_x, split.test_y


def _cfg():
    return SimConfig(scheme="tolfl", num_devices=10, num_clusters=5,
                     rounds=ROUNDS, lr=1e-3, dropout=False)


def _traces(cfg, n=4):
    return sample_traces(np.random.default_rng(3), cfg.topology(), 0.5,
                         max_events=8, rounds=ROUNDS, num_traces=n)


def test_chunked_equals_oneshot_with_padding(small_ae, small_data):
    """chunk_size=5 over B=12 -> chunks of 5/5/5 with 3 padded rows;
    results equal the one-shot batch and the padding is stripped."""
    dx, counts, tx, ty = small_data
    cfg = _cfg()
    traces = _traces(cfg)
    before = campaign.TRACE_COUNT
    one = run_campaign(small_ae, dx, counts, tx, ty, cfg, traces,
                       seeds=range(3), target_loss=2430.0)
    assert campaign.TRACE_COUNT - before == 1
    assert one.num_scenarios == 12
    before = campaign.TRACE_COUNT
    chunked = run_campaign(small_ae, dx, counts, tx, ty, cfg, traces,
                           seeds=range(3), target_loss=2430.0,
                           exec_plan=ExecPlan(chunk_size=5))
    # a new batch shape (5 vs 12) -> exactly one new compile, reused by
    # every chunk including the padded last one
    assert campaign.TRACE_COUNT - before == 1
    assert chunked.num_scenarios == 12
    np.testing.assert_allclose(one.auroc_used, chunked.auroc_used,
                               atol=1e-5)
    np.testing.assert_allclose(one.loss_curves, chunked.loss_curves,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(one.trace_index, chunked.trace_index)
    np.testing.assert_array_equal(
        np.isfinite(one.rounds_to_loss),
        np.isfinite(chunked.rounds_to_loss))


def test_repeated_campaign_reuses_executable(small_ae, small_data):
    """Data arrays are arguments of a cached jitted core: a second
    campaign on the same shapes costs ZERO new traces."""
    dx, counts, tx, ty = small_data
    cfg = _cfg()
    traces = _traces(cfg)
    first = run_campaign(small_ae, dx, counts, tx, ty, cfg, traces,
                         seeds=range(3))
    before = campaign.TRACE_COUNT
    again = run_campaign(small_ae, dx, counts, tx, ty, cfg, traces,
                         seeds=range(3))
    assert campaign.TRACE_COUNT - before == 0
    np.testing.assert_array_equal(first.auroc_used, again.auroc_used)


SWEEP_CELLS = [("tolfl", 5), ("tolfl", 2), ("sbt", 10)]


def _assert_cells_equal(padded, percell):
    for key in padded:
        np.testing.assert_allclose(padded[key].auroc_used,
                                   percell[key].auroc_used, atol=1e-5)
        np.testing.assert_allclose(padded[key].final_auroc,
                                   percell[key].final_auroc, atol=1e-5)
        np.testing.assert_allclose(padded[key].loss_curves,
                                   percell[key].loss_curves,
                                   rtol=1e-5, atol=1e-5)
        # iso outputs must match too: non-fl cells report zeros on BOTH
        # paths (the padded core must not silently enable iso tracking)
        np.testing.assert_allclose(padded[key].iso_loss_curves,
                                   percell[key].iso_loss_curves,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(padded[key].iso_active,
                                      percell[key].iso_active)


def test_padded_sweep_single_compile_and_parity(small_ae, small_data):
    """All (non-fl) single-model cells of a sweep share ONE compiled
    executable (padded cluster arrays as dynamic operands) and match
    the per-cell static build scenario-for-scenario."""
    dx, counts, tx, ty = small_data
    base = SimConfig(num_devices=10, rounds=ROUNDS, lr=1e-3,
                     dropout=False)
    cfg = _cfg()
    traces = _traces(cfg, n=3)
    before = campaign.TRACE_COUNT
    padded = sweep_grid(small_ae, dx, counts, tx, ty, base, SWEEP_CELLS,
                        traces, seeds=[0, 1])
    n_traces = campaign.TRACE_COUNT - before
    assert n_traces == 1, f"padded sweep traced {n_traces}x; expected 1"
    percell = sweep_grid(small_ae, dx, counts, tx, ty, base, SWEEP_CELLS,
                         traces, seeds=[0, 1], pad_k=False)
    _assert_cells_equal(padded, percell)


def test_padded_sweep_fl_cell_compiles_separately(small_ae, small_data):
    """An fl cell carries the isolated-fallback branch (extra compute);
    it must get its OWN padded executable — one more compile, never a
    silently iso-tracking executable for the non-fl cells."""
    dx, counts, tx, ty = small_data
    base = SimConfig(num_devices=10, rounds=ROUNDS, lr=1e-3,
                     dropout=False)
    cfg = _cfg()
    traces = _traces(cfg, n=3)
    cells = SWEEP_CELLS + [("fl", 1)]
    # warm the shared non-iso executable so the count below isolates
    # the fl cell's contribution (self-contained under -k selection)
    sweep_grid(small_ae, dx, counts, tx, ty, base, SWEEP_CELLS, traces,
               seeds=[0, 1])
    before = campaign.TRACE_COUNT
    padded = sweep_grid(small_ae, dx, counts, tx, ty, base, cells,
                        traces, seeds=[0, 1])
    n_traces = campaign.TRACE_COUNT - before
    assert n_traces == 1, \
        f"mixed sweep traced {n_traces}x; expected 1 (fl cell only)"
    percell = sweep_grid(small_ae, dx, counts, tx, ty, base, cells,
                         traces, seeds=[0, 1], pad_k=False)
    _assert_cells_equal(padded, percell)
    assert padded[("fl", 1)].cfg.scheme == "fl"


# ---------------------------------------------------------------------------
# sharded execution (needs >1 device -> subprocess with a forced-host
# platform, exactly like tests/test_distributed.py)
# ---------------------------------------------------------------------------
SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax

    from repro.configs.autoencoder_paper import AutoencoderConfig
    from repro.core import campaign
    from repro.core.campaign import ExecPlan, run_campaign
    from repro.core.failure import sample_traces
    from repro.core.simulate import SimConfig
    from repro.data import commsml, federated

    assert jax.device_count() == 8, jax.device_count()
    ae = AutoencoderConfig(input_dim=commsml.N_FEATURES, hidden=(16,),
                           code_dim=4, dropout=0.2)
    X, y = commsml.generate(seed=0, samples_per_class=60)
    split = federated.make_split(X, y, num_devices=10, num_clusters=5,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    cfg = SimConfig(scheme="tolfl", num_devices=10, num_clusters=5,
                    rounds=3, lr=1e-3, dropout=False)
    traces = sample_traces(np.random.default_rng(0), cfg.topology(), 0.5,
                           max_events=8, rounds=3, num_traces=16)
    seeds = range(4)                    # B = 64
    args = (ae, dx, counts, split.test_x, split.test_y, cfg)

    base = run_campaign(*args, traces, seeds)
    c0 = campaign.TRACE_COUNT
    sharded = run_campaign(*args, traces, seeds,
                           exec_plan=ExecPlan(shard=True))
    sharded_compiles = campaign.TRACE_COUNT - c0
    c0 = campaign.TRACE_COUNT
    both = run_campaign(*args, traces, seeds,
                        exec_plan=ExecPlan(shard=True, chunk_size=24))
    both_compiles = campaign.TRACE_COUNT - c0
    # non-divisible batch: 60 scenarios over 8 devices (pad to 64)
    base_nd = run_campaign(*args, traces[:15], seeds)
    shard_nd = run_campaign(*args, traces[:15], seeds,
                            exec_plan=ExecPlan(shard=True))

    def err(a, b):
        return float(np.max(np.abs(a - b)))

    print(json.dumps({
        "num_scenarios": int(base.num_scenarios),
        "sharded_compiles": sharded_compiles,
        "both_compiles": both_compiles,
        "d_auroc": err(base.auroc_used, sharded.auroc_used),
        "d_loss": err(base.loss_curves, sharded.loss_curves),
        "d_auroc_chunked": err(base.auroc_used, both.auroc_used),
        "d_auroc_nondiv": err(base_nd.auroc_used, shard_nd.auroc_used),
    }))
""")


def test_sharded_campaign_matches_oneshot():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    stats = json.loads(out.stdout.strip().splitlines()[-1])
    assert stats["num_scenarios"] == 64
    # one (vmapped, shard_mapped) trace per new executable
    assert stats["sharded_compiles"] == 1, stats
    assert stats["both_compiles"] == 1, stats
    assert stats["d_auroc"] <= 1e-5, stats
    assert stats["d_loss"] <= 1e-4, stats
    assert stats["d_auroc_chunked"] <= 1e-5, stats
    assert stats["d_auroc_nondiv"] <= 1e-5, stats
