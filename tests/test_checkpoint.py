"""Checkpoint save/restore roundtrip + manager policy tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.checkpoint import CheckpointManager, restore, save


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 4)),
                       "b": jnp.zeros(4, jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_roundtrip(tmp_path):
    tree = make_tree()
    p = save(str(tmp_path / "ckpt.msgpack"), tree, step=7)
    got, step = restore(p, jax.tree.map(jnp.zeros_like, tree))
    assert step == 7
    assert trees_equal(got, tree)
    # dtypes preserved (incl. bfloat16)
    assert got["params"]["b"].dtype == jnp.bfloat16


def test_restore_shape_mismatch_rejected(tmp_path):
    tree = make_tree()
    p = save(str(tmp_path / "c.msgpack"), tree)
    bad = {"params": {"w": jnp.zeros((2, 2)), "b": jnp.zeros(4)},
           "step": jnp.zeros((), jnp.int32)}
    with pytest.raises(AssertionError):
        restore(p, bad)


def test_no_tmp_left_behind(tmp_path):
    save(str(tmp_path / "c.msgpack"), make_tree())
    assert sorted(os.listdir(tmp_path)) == ["c.msgpack"]


def test_manager_keep_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = make_tree()
    for s in (1, 2, 3, 4):
        mgr.save(tree, s)
    files = sorted(os.listdir(tmp_path))
    assert files == ["ckpt_00000003.msgpack", "ckpt_00000004.msgpack"]
    assert mgr.latest_step() == 4


def test_manager_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    assert mgr.restore_latest(make_tree()) is None
    t1 = make_tree(1)
    t2 = make_tree(2)
    mgr.save(t1, 10)
    mgr.save(t2, 20)
    got, step = mgr.restore_latest(jax.tree.map(jnp.zeros_like, t2))
    assert step == 20
    assert trees_equal(got, t2)
