"""Assigned-architecture config validation (brief: exact specs).

Every config must carry the exact architecture parameters from the
assignment table and cite its source.
"""
import pytest

from repro.configs import ARCHS, ASSIGNED, INPUT_SHAPES
from repro.configs.registry import get_arch

# (layers, d_model, heads, kv_heads, d_ff, vocab) from the assignment
ASSIGNED_SPECS = {
    "recurrentgemma-9b":         (38, 4096, 16, 1, 12288, 256000),
    "rwkv6-7b":                  (32, 4096, None, None, 14336, 65536),
    "whisper-large-v3":          (32, 1280, 20, 20, 5120, 51866),
    "internlm2-1.8b":            (24, 2048, 16, 8, 8192, 92544),
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    "internvl2-26b":             (48, 6144, 48, 8, 16384, 92553),
    "llama4-scout-17b-a16e":     (48, 5120, 40, 8, 8192, 202048),
    "qwen3-8b":                  (36, 4096, 32, 8, 12288, 151936),
    "granite-3-2b":              (40, 2048, 32, 8, 8192, 49155),
    "qwen1.5-0.5b":              (24, 1024, 16, 16, 2816, 151936),
}


def test_all_assigned_present():
    assert set(ASSIGNED) == set(ASSIGNED_SPECS) == set(ARCHS)


@pytest.mark.parametrize("arch", sorted(ASSIGNED_SPECS))
def test_config_matches_assignment(arch):
    L, d, H, KVH, ff, V = ASSIGNED_SPECS[arch]
    cfg = ARCHS[arch]
    assert cfg.num_layers == L, "layers"
    assert cfg.d_model == d, "d_model"
    assert cfg.d_ff == ff, "d_ff"
    assert cfg.vocab_size == V, "vocab"
    if H is not None:          # rwkv6 is attention-free
        assert cfg.attention.num_heads == H, "heads"
        assert cfg.attention.num_kv_heads == KVH, "kv heads"
    assert cfg.citation, f"{arch} must cite its source"


def test_family_specific_markers():
    assert ARCHS["qwen3-8b"].attention.qk_norm               # qk_norm
    assert ARCHS["qwen1.5-0.5b"].attention.qkv_bias          # QKV bias
    assert ARCHS["recurrentgemma-9b"].recurrent.block_pattern  # hybrid
    assert ARCHS["rwkv6-7b"].family == "ssm"
    assert ARCHS["llama4-maverick-400b-a17b"].moe.num_experts == 128
    assert ARCHS["llama4-maverick-400b-a17b"].moe.num_experts_per_tok == 1
    assert ARCHS["llama4-scout-17b-a16e"].moe.num_experts == 16
    assert ARCHS["whisper-large-v3"].is_encdec
    assert ARCHS["whisper-large-v3"].encoder_seq == 1500
    assert ARCHS["internvl2-26b"].frontend.kind == "vision"


def test_hybrid_pattern_ratio():
    """RecurrentGemma: RG-LRU + local attn at 1:2 attn:recurrent."""
    pat = ARCHS["recurrentgemma-9b"].layer_pattern
    assert len(pat) == 38
    n_rec = sum(1 for p in pat if p == "rec")
    n_att = sum(1 for p in pat if p == "local")
    assert n_rec == 2 * n_att + (1 if len(pat) % 3 else 0) or n_rec >= 2 * n_att


def test_param_counts_near_nominal():
    """Analytic parameter counts land near the names' nominal sizes."""
    def bn(arch):
        return ARCHS[arch].param_count() / 1e9

    assert 7.0 < bn("qwen3-8b") < 10.0
    assert 0.4 < bn("qwen1.5-0.5b") < 0.8
    assert 1.5 < bn("internlm2-1.8b") < 2.4
    assert 2.0 < bn("granite-3-2b") < 3.5
    assert 6.5 < bn("rwkv6-7b") < 9.0
    assert 8.0 < bn("recurrentgemma-9b") < 11.0
    assert 250 < bn("llama4-maverick-400b-a17b") < 450
    # active params: maverick ~17B
    assert 10 < ARCHS["llama4-maverick-400b-a17b"].active_param_count() / 1e9 < 25


def test_input_shapes_table():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1
    assert INPUT_SHAPES["long_500k"].mode == "decode"


def test_get_arch_unknown():
    with pytest.raises(KeyError):
        get_arch("gpt-5")
