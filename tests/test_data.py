"""Data substrate tests: Comms-ML generator, reference sets, federated
splits, token pipeline."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.data import commsml, federated, reference
from repro.data.pipeline import TokenPipeline


# ---------------------------------------------------------------------------
# Comms-ML generator
# ---------------------------------------------------------------------------
def test_commsml_shapes():
    X, y = commsml.generate(seed=0, samples_per_class=50)
    assert X.shape == (4 * 50, commsml.N_FEATURES)
    assert X.dtype == np.float32
    assert set(y.tolist()) == {0, 1, 2, 3}


def test_commsml_deterministic():
    X1, _ = commsml.generate(seed=3, samples_per_class=20)
    X2, _ = commsml.generate(seed=3, samples_per_class=20)
    np.testing.assert_array_equal(X1, X2)
    X3, _ = commsml.generate(seed=4, samples_per_class=20)
    assert not np.array_equal(X1, X3)


def test_commsml_classes_separable():
    """Anomaly classes must be statistically distinct from class 0 —
    otherwise the whole experiment is vacuous."""
    X, y = commsml.generate(seed=0, samples_per_class=200)
    mu0 = X[y == 0].mean(0)
    for c in (2, 3):
        muc = X[y == c].mean(0)
        assert np.linalg.norm(muc - mu0) > 1.0


def test_commsml_standardised_on_class0():
    X, y = commsml.generate(seed=0, samples_per_class=300)
    np.testing.assert_allclose(X[y == 0].mean(0), 0.0, atol=0.05)
    np.testing.assert_allclose(X[y == 0].std(0), 1.0, atol=0.05)


# ---------------------------------------------------------------------------
# Reference datasets
# ---------------------------------------------------------------------------
def test_reference_shapes():
    for name, spec in reference.SPECS.items():
        X, y = reference.generate(name, seed=0, samples_per_class=5)
        dim = int(np.prod(spec.shape))
        assert X.shape == (5 * spec.n_classes, dim), name
        assert len(set(y.tolist())) == spec.n_classes


def test_reference_class_structure():
    """Same-class samples are closer than cross-class samples."""
    X, y = reference.generate("fmnist", seed=0, samples_per_class=20)
    a = X[y == 0]
    within = np.linalg.norm(a - a.mean(0), axis=1).mean()
    across = np.linalg.norm(X[y == 1] - a.mean(0), axis=1).mean()
    assert across > within


# ---------------------------------------------------------------------------
# Federated split
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    members=st.integers(1, 3),
    k=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
def test_split_partitions(members, k, seed):
    X, y = commsml.generate(seed=0, samples_per_class=60)
    n_dev = members * k
    split = federated.make_split(X, y, n_dev, k, anomaly_classes=[3],
                                 seed=seed)
    assert split.num_devices == n_dev
    assert len(split.clusters) == k
    # every device has data (3 normal classes over <=4 clusters)
    counts = split.sample_counts()
    assert counts.sum() > 0
    # test set contains all anomaly samples, labelled 1
    assert (split.test_y == 1).sum() == 60
    assert (split.test_y == 0).sum() > 0


def test_split_no_anomaly_in_train():
    """Training devices must never see anomalous data (one-class setup)."""
    X, y = commsml.generate(seed=0, samples_per_class=60)
    split = federated.make_split(X, y, 6, 3, anomaly_classes=[3], seed=0)
    anom = X[y == 3]
    anom_set = {a.tobytes() for a in anom}
    for d in split.device_data:
        for row in d:
            assert row.tobytes() not in anom_set


def test_pad_devices_roundtrip():
    X, y = commsml.generate(seed=0, samples_per_class=30)
    split = federated.make_split(X, y, 6, 3, anomaly_classes=[3], seed=0)
    padded, counts = federated.pad_devices(split)
    assert padded.shape[0] == 6
    np.testing.assert_array_equal(counts, split.sample_counts())
    for i, d in enumerate(split.device_data):
        np.testing.assert_array_equal(padded[i, :len(d)], d)
        assert np.all(padded[i, len(d):] == 0)


# ---------------------------------------------------------------------------
# Token pipeline
# ---------------------------------------------------------------------------
def test_token_pipeline_shapes():
    p = TokenPipeline(vocab_size=1000, seq_len=64, global_batch=8,
                      num_groups=4)
    batch = next(p.batches())
    assert batch["tokens"].shape == (8, 64)
    assert batch["labels"].shape == (8, 64)
    assert batch["tokens"].dtype == np.int32
    # next-token alignment
    assert batch["tokens"].max() < 1000


def test_token_pipeline_label_shift():
    p = TokenPipeline(vocab_size=500, seq_len=32, global_batch=4)
    b = next(p.batches())
    # tokens/labels come from one (seq_len+1) doc: labels are the shift
    # (verifiable because both are slices of the same underlying array)
    assert b["tokens"].shape == b["labels"].shape


def test_token_pipeline_groups_nontrivially_different():
    """Non-IID: group motif inventories differ."""
    p = TokenPipeline(vocab_size=1000, seq_len=128, global_batch=4,
                      num_groups=2, seed=0)
    assert not np.array_equal(p.motifs[0], p.motifs[1])


def test_token_pipeline_num_steps():
    p = TokenPipeline(vocab_size=100, seq_len=16, global_batch=2)
    batches = list(p.batches(num_steps=3))
    assert len(batches) == 3
