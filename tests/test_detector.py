"""Pluggable detector bodies: interface, cache-key identity, parity pins.

Three contracts guard the DetectorModel seam
(:mod:`repro.models.detector`):

* **Bit-identity for autoencoder specs** — threading the interface
  through simulate/baselines/campaign must not move a single bit for
  the paper autoencoder: the executable-cache key tuples, the
  persistent-cache fingerprints AND a whole campaign's result digest
  are pinned against values captured on the pre-refactor tree.
* **Deprecation alias** — ``DataSpec(ae_cfg=...)`` keeps working and
  emits exactly ONE ``DeprecationWarning`` per process.
* **Second body end-to-end** — a :class:`SeqDetector` (RG-LRU windowed
  sequence detector) campaign runs through ``plan(check=True)`` →
  ``execute`` with a clean static-analysis report, under its own named
  plancheck budgets.
"""
import dataclasses
import hashlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CellSpec, DataSpec, ExperimentSpec, SeedSpec,
                       SimConfig, TraceSpec, execute, plan)
from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core import campaign, compilecache
from repro.core import experiment as _x
from repro.core.failure import NO_FAILURE, FailureSpec
from repro.core.simulate import comm_mb_per_round
from repro.data import commsml
from repro.models import detector as D

# captured on the pre-refactor tree (see the module docstring): a whole
# tolfl/fl/ifca campaign's result arrays, hashed in execution order
PRE_REFACTOR_DIGEST = \
    "89d183d7bdcd80bc6a8703b4145d5735d26d03d8823120ff3b534598c8595261"
# sha256(repr(_exe_key(...))) of a representative fused single key
PRE_REFACTOR_FINGERPRINT = \
    "7c37de1094b392f2ceec5b6c253dd57e8e44e6100e5c081c63bd3bfe7fc87c13"


# ---------------------------------------------------------------------------
# interface + registry
# ---------------------------------------------------------------------------
def test_as_detector_normalises_and_rejects(tiny_ae_cfg):
    det = D.as_detector(tiny_ae_cfg)
    assert isinstance(det, D.AutoencoderDetector)
    assert det.cfg == tiny_ae_cfg
    assert D.as_detector(det) is det
    with pytest.raises(TypeError):
        D.as_detector("not a model")


def test_canonical_key_collapses_both_spellings(tiny_ae_cfg):
    det = D.AutoencoderDetector(tiny_ae_cfg)
    assert D.canonical_model_key(det) == tiny_ae_cfg
    assert D.canonical_model_key(tiny_ae_cfg) == tiny_ae_cfg
    seq = D.SeqDetector(input_dim=16, window=8, d_model=4)
    assert D.canonical_model_key(seq) == seq


def test_registry_roundtrip_and_replacement_guard():
    assert set(D.detector_names()) >= {"autoencoder", "seq-rglru"}
    det = D.make_detector("seq-rglru", input_dim=16, window=8, d_model=4)
    assert isinstance(det, D.SeqDetector)
    with pytest.raises(KeyError):
        D.make_detector("nope")
    # idempotent same-factory re-register; silent replacement forbidden
    D.register_detector("seq-rglru", D.SeqDetector)
    with pytest.raises(ValueError):
        D.register_detector("seq-rglru", D.AutoencoderDetector)
    assert D.SeqDetector in D.spec_classes()


def test_param_sizes_derive_from_real_init():
    from repro.models import params as P
    ae = D.AutoencoderDetector(
        AutoencoderConfig(input_dim=8, hidden=(4,), code_dim=2))
    params = ae.init_params(jax.random.PRNGKey(0))
    assert ae.param_count() == P.param_count(params)
    assert ae.param_bytes() == P.param_bytes(params)
    seq = D.SeqDetector(input_dim=16, window=8, d_model=4)
    assert seq.param_bytes() == P.param_bytes(
        seq.init_params(jax.random.PRNGKey(0)))


def test_comm_cost_accepts_detector_or_bytes():
    seq = D.SeqDetector(input_dim=16, window=8, d_model=4)
    via_det = comm_mb_per_round("tolfl", 10, 5, seq)
    via_int = comm_mb_per_round("tolfl", 10, 5, seq.param_bytes())
    assert via_det == via_int > 0


def test_seq_detector_loss_and_scores_shapes():
    seq = D.SeqDetector(input_dim=commsml.N_FEATURES, window=16,
                        d_model=8, dropout=0.2)
    params = seq.init_params(jax.random.PRNGKey(0))
    x = jnp.ones((5, commsml.N_FEATURES))
    valid = jnp.array([1.0, 1.0, 1.0, 0.0, 0.0])
    loss_eval = seq.loss(params, x, valid)
    loss_drop = seq.loss(params, x, valid, key=jax.random.PRNGKey(1))
    assert loss_eval.shape == () and np.isfinite(float(loss_eval))
    assert float(loss_drop) != float(loss_eval)   # dropout engaged
    scores = seq.anomaly_scores(params, x)
    assert scores.shape == (5,)
    grads = jax.grad(lambda p: seq.loss(p, x, valid))(params)
    total = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree.leaves(grads))
    assert total > 0.0                            # gradients flow


# ---------------------------------------------------------------------------
# bit-identity pins (autoencoder specs, vs the pre-refactor tree)
# ---------------------------------------------------------------------------
def test_exe_key_bit_identical_for_both_spellings():
    cfg = SimConfig(scheme="tolfl", num_devices=6, num_clusters=2,
                    rounds=2, dropout=False)
    ae = AutoencoderConfig(input_dim=8, hidden=(4,), code_dim=2)
    key_raw = campaign._exe_key("single", ae, cfg, 4, None, False, True)
    key_det = campaign._exe_key("single", D.AutoencoderDetector(ae),
                                cfg, 4, None, False, True)
    assert key_raw == key_det
    assert key_raw == ("single", ae, cfg, 4, None, False, True)
    assert (compilecache.exe_fingerprint(key_raw)
            == PRE_REFACTOR_FINGERPRINT)


def test_campaign_digest_bit_identical(tiny_ae_cfg, tiny_padded,
                                       tiny_split):
    """The pre-refactor pin: a 3-cell tolfl/fl/ifca campaign (2 traces x
    2 seeds) through plan -> execute hashes to the digest captured
    BEFORE the DetectorModel seam existed — the refactor moved zero
    bits.  Both DataSpec spellings produce the same digest."""
    dx, counts = tiny_padded

    def digest(model_kwargs):
        spec = ExperimentSpec(
            data=DataSpec(device_x=dx, device_counts=counts,
                          test_x=tiny_split.test_x,
                          test_y=tiny_split.test_y, **model_kwargs),
            base=SimConfig(num_devices=10, rounds=3, lr=1e-3,
                           dropout=True),
            cells=(CellSpec("tolfl", 5), CellSpec("fl", 1),
                   CellSpec("ifca", 2)),
            traces=TraceSpec(traces=(NO_FAILURE,
                                     FailureSpec(1, "server"))),
            seeds=SeedSpec((0, 1)))
        res = execute(plan(spec))
        h = hashlib.sha256()
        for r in res.results:
            for name in ("auroc_used", "final_auroc", "loss_curves",
                         "best_auroc", "multi_auroc"):
                arr = getattr(r, name, None)
                if arr is not None:
                    h.update(np.ascontiguousarray(
                        np.asarray(arr, np.float64)).tobytes())
        return h.hexdigest()

    assert digest({"model": tiny_ae_cfg}) == PRE_REFACTOR_DIGEST
    assert (digest({"model": D.AutoencoderDetector(tiny_ae_cfg)})
            == PRE_REFACTOR_DIGEST)


# ---------------------------------------------------------------------------
# deprecation alias
# ---------------------------------------------------------------------------
def test_ae_cfg_alias_warns_exactly_once_per_process(tiny_ae_cfg,
                                                     tiny_padded,
                                                     tiny_split):
    dx, counts = tiny_padded
    kw = dict(device_x=dx, device_counts=counts,
              test_x=tiny_split.test_x, test_y=tiny_split.test_y)
    _x._AE_CFG_WARNED = False
    try:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            d1 = DataSpec(ae_cfg=tiny_ae_cfg, **kw)
            d2 = DataSpec(ae_cfg=tiny_ae_cfg, **kw)
        dep = [w for w in caught
               if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1, [str(w.message) for w in dep]
    finally:
        _x._AE_CFG_WARNED = True
    # the alias normalises into model AND reads back as the raw config
    for d in (d1, d2):
        assert isinstance(d.model, D.AutoencoderDetector)
        assert d.ae_cfg == tiny_ae_cfg
    # the model= spelling never warns, and non-autoencoder bodies read
    # back ae_cfg=None
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        dm = DataSpec(model=tiny_ae_cfg, **kw)
        ds = DataSpec(model=D.SeqDetector(input_dim=commsml.N_FEATURES),
                      **kw)
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]
    assert dm.ae_cfg == tiny_ae_cfg and ds.ae_cfg is None
    with pytest.raises(TypeError):
        DataSpec(**kw)          # no model at all


# ---------------------------------------------------------------------------
# second body end-to-end
# ---------------------------------------------------------------------------
def test_seq_detector_campaign_end_to_end(tiny_padded, tiny_split):
    """A SeqDetector campaign through plan(check=True) -> execute: the
    static analyzer is CLEAN (the seq budget family covers its bigger
    cores), results are finite, and warm re-execution costs 0 traces."""
    dx, counts = tiny_padded
    seq = D.SeqDetector(input_dim=commsml.N_FEATURES, window=16,
                        d_model=8)
    spec = ExperimentSpec(
        data=DataSpec(model=seq, device_x=dx, device_counts=counts,
                      test_x=tiny_split.test_x,
                      test_y=tiny_split.test_y, name="seq-e2e"),
        base=SimConfig(num_devices=10, rounds=2, lr=1e-3,
                       dropout=False),
        cells=(CellSpec("tolfl", 2), CellSpec("fl", 1),
               CellSpec("ifca", 2)),
        traces=TraceSpec(traces=(NO_FAILURE, FailureSpec(1, "server"))),
        seeds=SeedSpec((0,)))
    p = plan(spec, check=True)
    assert p.static_report().clean, p.describe()
    res = execute(p)
    assert res.num_scenarios == 6
    for key, r in res.per_cell().items():
        auroc = (r.auroc_used if hasattr(r, "auroc_used")
                 else r.best_auroc)
        assert np.all(np.isfinite(auroc)), (key, auroc)
        assert np.all((auroc >= 0.0) & (auroc <= 1.0)), (key, auroc)
    # warm re-execution: the detector-keyed executables are cached
    t0 = campaign.TRACE_COUNT
    execute(plan(spec))
    assert campaign.TRACE_COUNT == t0


def test_seq_buckets_fall_under_named_seq_budgets(tiny_padded,
                                                  tiny_split):
    from repro.analysis.plancheck import budgets as pc_budgets
    dx, counts = tiny_padded
    seq = D.SeqDetector(input_dim=commsml.N_FEATURES, window=16,
                        d_model=8)
    data = DataSpec(model=seq, device_x=dx, device_counts=counts,
                    test_x=tiny_split.test_x, test_y=tiny_split.test_y)
    spec = ExperimentSpec(
        data=data,
        base=SimConfig(num_devices=10, rounds=2, lr=1e-3,
                       dropout=False),
        cells=(CellSpec("tolfl", 2), CellSpec("ifca", 2)),
        traces=TraceSpec(traces=(NO_FAILURE,)), seeds=SeedSpec((0,)))
    p = plan(spec)
    for b in p.buckets:
        cells = [p.cells[i] for i in b.cell_indices]
        avals = _x._bucket_avals(data, b, cells)
        jitted = campaign._executable(*_x._bucket_exe_args(data, b))
        n = pc_budgets.count_jaxpr(jax.make_jaxpr(jitted)(*avals))
        name = pc_budgets.bucket_budget_name(b.kind, b.fused,
                                             seq.budget_family)
        assert name.endswith(":seq"), name
        assert pc_budgets.check_budget(name, n) is None, (name, n)
    # an unknown family is a finding, not a KeyError
    f = pc_budgets.check_budget("campaign_core_single:unknown", 1, "x")
    assert f is not None and "no named budget" in f.message


def test_seq_and_ae_executables_never_alias(tiny_ae_cfg):
    cfg = SimConfig(scheme="tolfl", num_devices=6, num_clusters=2,
                    rounds=2, dropout=False)
    seq = D.SeqDetector(input_dim=commsml.N_FEATURES, window=16,
                        d_model=8)
    k_ae = campaign._exe_key("single", tiny_ae_cfg, cfg, 4, None,
                             False, True)
    k_seq = campaign._exe_key("single", seq, cfg, 4, None, False, True)
    assert k_ae != k_seq
    assert (compilecache.exe_fingerprint(k_ae)
            != compilecache.exe_fingerprint(k_seq))
    # replacing only a detector hyperparameter forks the key too
    k_seq2 = campaign._exe_key(
        "single", dataclasses.replace(seq, d_model=4), cfg, 4, None,
        False, True)
    assert k_seq2 != k_seq
