"""Mesh-engine equivalence tests (the production train step).

These need >1 device, so each test runs a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process keeps the single real CPU device, per the brief).

What is proven:
* the paper-faithful ring schedule (psum-in-cluster + ppermute SBT chain)
  and the beyond-paper weighted-psum schedule produce THE SAME updated
  parameters (the algebraic identity the optimisation relies on);
* both match the pure-simulator aggregation algebra on the same grads;
* in-graph failure masking (client and cluster-head) matches
  ``effective_weights`` semantics.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import OptimizerConfig, TolFLConfig
    from repro.configs.base import ModelConfig, AttentionConfig
    from repro.core import distributed as D
    from repro.core.failure import effective_weights
    from repro.core.topology import Topology
    from repro.sharding import logical as L

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = L.rules_for("replicated_data")

    cfg = ModelConfig(name="tiny", num_layers=2, d_model=64, d_ff=128,
                      vocab_size=256,
                      attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                                head_dim=16),
                      remat="none", dtype="float32")
    ocfg = OptimizerConfig(name="sgd", lr=0.1, schedule="constant",
                           warmup_steps=0, grad_clip=0.0)
    B, S = 8, 16

    key = jax.random.PRNGKey(0)
    with L.activate_mesh(mesh, rules):
        state = D.init_state(key, cfg, ocfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 256)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, 256)
    batch = {"tokens": tokens, "labels": labels}

    def run(schedule, alive_np):
        tolfl = TolFLConfig(num_clusters=2, schedule=schedule)
        alive = jnp.asarray(alive_np, jnp.float32)
        with L.activate_mesh(mesh, rules):
            step = D.make_train_step(cfg, tolfl, ocfg, mesh)
            new_state, metrics = jax.jit(step)(state, batch, alive)
        # flatten leaf-by-leaf on the HOST: eager jnp.concatenate over
        # differently-sharded leaves (replicated ring vs model-sharded
        # psum outputs) scrambles block order on this jax/CPU version
        flat = np.concatenate([
            np.asarray(jax.device_get(x)).astype(np.float32).ravel()
            for x in jax.tree.leaves(new_state["params"])])
        return flat, metrics

    results = {}
    for name, alive in [("none", np.ones(4)),
                        ("client", np.array([1., 0., 1., 1.])),
                        ("head", np.array([0., 1., 1., 1.]))]:
        ring, _ = run("tolfl_ring", alive)
        psum, _ = run("tolfl_psum", alive)
        err = float(np.max(np.abs(ring - psum)))
        scale = float(np.max(np.abs(ring)))
        results[name] = {"max_abs_err": err, "scale": scale}

    # reference-semantics check: dead head zeroes its whole cluster
    topo = Topology(4, 2)
    w = np.asarray(effective_weights(jnp.asarray([0., 1., 1., 1.]), topo))
    results["head_weights"] = w.tolist()

    # failure actually changes the update (the masked data matters)
    ring_all, _ = run("tolfl_ring", np.ones(4))
    ring_head, _ = run("tolfl_ring", np.array([0., 1., 1., 1.]))
    results["failure_changes_update"] = bool(
        np.max(np.abs(ring_all - ring_head)) > 1e-8)

    print("RESULT" + json.dumps(results))
""")


@pytest.fixture(scope="module")
def mesh_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_ring_equals_psum_no_failure(mesh_results):
    r = mesh_results["none"]
    assert r["max_abs_err"] < 1e-4 * max(r["scale"], 1.0), r


def test_ring_equals_psum_client_failure(mesh_results):
    r = mesh_results["client"]
    assert r["max_abs_err"] < 1e-4 * max(r["scale"], 1.0), r


def test_ring_equals_psum_head_failure(mesh_results):
    r = mesh_results["head"]
    assert r["max_abs_err"] < 1e-4 * max(r["scale"], 1.0), r


def test_head_failure_weights(mesh_results):
    assert mesh_results["head_weights"] == [0.0, 0.0, 1.0, 1.0]


def test_failure_changes_update(mesh_results):
    assert mesh_results["failure_changes_update"]
