"""Declarative experiment pipeline contracts (ISSUE 5).

What is proven:

* **ExecPlan validation** — ``chunk_size <= 0`` / ``devices <= 0``
  raise a clear ``ValueError`` at construction (they used to fail as
  shape errors deep inside ``_run_batched``), and ``shard=True`` on a
  single-device host warns and degrades to the unsharded path.
* **plan() is executable-free** — lowering a mixed
  (single + fl + batch + multi) spec builds the bucket structure,
  per-kind pad-k / padded-M choices, sampled per-topology trace grids
  and the shard/chunk geometry WITHOUT building or dispatching any
  compiled campaign core (``campaign.TRACE_COUNT`` never moves).
* **bucket grouping** — non-fl single cells share one fused bucket at
  the group max k, fl cells get their own iso bucket, batch cells are
  per-cell static, multi cells group per scheme with padded M;
  ``fuse=False`` / ``pad_k=False`` lower to the per-cell / static
  bucket modes.
* **shim parity** — ``sweep_grid`` / ``run_campaign`` /
  ``run_fused_campaigns`` are thin shims over spec -> plan -> execute:
  a hand-built spec reproduces them BIT-IDENTICALLY (same executables,
  same stacked operands), and a warm re-execute costs zero traces.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.api import (NO_FAILURE, AutoencoderConfig, CellSpec, DataSpec,
                       ExecPlan, ExperimentSpec, FailureSpec, SeedSpec,
                       SimConfig, TraceSpec, cell, execute, plan,
                       run_campaign, run_experiment, run_fused_campaigns,
                       sweep_grid)
from repro.core import campaign
from repro.core.failure import sample_traces
from repro.data import commsml, federated

# distinct from every other campaign test in the suite: the executable
# cache is global and the zero-compile assertions below need cold keys
ROUNDS = 7


@pytest.fixture(scope="module")
def small_ae():
    return AutoencoderConfig(input_dim=commsml.N_FEATURES, hidden=(16,),
                             code_dim=4, dropout=0.2)


@pytest.fixture(scope="module")
def small_data():
    X, y = commsml.generate(seed=0, samples_per_class=60)
    split = federated.make_split(X, y, num_devices=10, num_clusters=5,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    return dx, counts, split.test_x, split.test_y


def _data_spec(small_ae, small_data):
    dx, counts, tx, ty = small_data
    return DataSpec(model=small_ae, device_x=dx, device_counts=counts,
                    test_x=tx, test_y=ty, name="commsml")


def _base():
    return SimConfig(num_devices=10, rounds=ROUNDS, lr=1e-3,
                     dropout=False)


def _traces(n=3):
    cfg = dataclasses.replace(_base(), scheme="tolfl", num_clusters=5)
    return sample_traces(np.random.default_rng(3), cfg.topology(), 0.5,
                         max_events=8, rounds=ROUNDS, num_traces=n)


# ---------------------------------------------------------------------------
# ExecPlan validation (satellite: reject bad values up front)
# ---------------------------------------------------------------------------
def test_execplan_rejects_nonpositive_chunk_size():
    with pytest.raises(ValueError, match="chunk_size must be a positive"):
        ExecPlan(chunk_size=0)
    with pytest.raises(ValueError, match="chunk_size"):
        ExecPlan(chunk_size=-4)


def test_execplan_rejects_nonpositive_devices():
    with pytest.raises(ValueError, match="devices must be a positive"):
        ExecPlan(devices=0)
    with pytest.raises(ValueError, match="devices"):
        ExecPlan(shard=True, devices=-1)


def test_execplan_shard_degrades_on_single_device():
    if jax.local_device_count() > 1:
        pytest.skip("host has multiple devices")
    with pytest.warns(UserWarning, match="single local device"):
        assert ExecPlan(shard=True).resolved_devices() is None
    # the silent form (used internally after the entry point warned)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert ExecPlan(shard=True).resolved_devices(warn=False) is None
    assert ExecPlan(shard=False).resolved_devices() is None


def test_plan_rejects_empty_grids(small_ae, small_data):
    data = _data_spec(small_ae, small_data)
    with pytest.raises(ValueError, match="need >= 1 cell"):
        plan(ExperimentSpec(data=data, base=_base(), cells=()))
    spec = ExperimentSpec(data=data, base=_base(),
                          cells=(CellSpec("tolfl", 5),),
                          traces=TraceSpec.explicit(NO_FAILURE),
                          seeds=SeedSpec(()))
    with pytest.raises(ValueError, match=">=1 trace and >=1 seed"):
        plan(spec)
    spec = ExperimentSpec(data=data, base=_base(),
                          cells=(CellSpec("tolfl", 5),))
    with pytest.raises(ValueError, match=">=1 trace and >=1 seed"):
        plan(spec)   # no traces declared anywhere
    with pytest.raises(ValueError, match="unknown scheme"):
        plan(ExperimentSpec(data=data, base=_base(),
                            cells=(CellSpec("fedavg", 3),),
                            traces=TraceSpec.explicit(NO_FAILURE)))


# ---------------------------------------------------------------------------
# plan(): pure lowering, no executables
# ---------------------------------------------------------------------------
def test_plan_bucket_grouping_without_any_dispatch(small_ae, small_data):
    """The mixed grid lowers to: one fused non-fl single bucket at the
    group max k, one fl iso bucket, per-scheme multi buckets with the
    padded-M choice, one static batch bucket — and planning never
    touches the executable cache."""
    data = _data_spec(small_ae, small_data)
    spec = ExperimentSpec(
        data=data, base=_base(),
        cells=(CellSpec("tolfl", 5), CellSpec("tolfl", 2),
               CellSpec("sbt", 10), CellSpec("fl", 1),
               CellSpec("batch", 1), CellSpec("ifca", 2),
               CellSpec("ifca", 3), CellSpec("fesem", 2)),
        traces=TraceSpec(traces=tuple(_traces())),
        seeds=SeedSpec((0, 1)))
    before = campaign.TRACE_COUNT
    p = plan(spec)
    assert campaign.TRACE_COUNT == before, "plan() built an executable"

    by_cells = {tuple(b.cell_indices): b for b in p.buckets}
    nonfl = by_cells[(0, 1, 2)]
    assert (nonfl.kind, nonfl.fused, nonfl.track_iso) == \
        ("single", True, False)
    assert nonfl.k_pad == 10                 # group max k (sbt k=N=10)
    fl = by_cells[(3,)]
    assert (fl.kind, fl.fused, fl.track_iso, fl.k_pad) == \
        ("single", True, True, 1)
    ifca = by_cells[(5, 6)]
    assert (ifca.kind, ifca.fused, ifca.m_pad) == ("multi", True, 3)
    fesem = by_cells[(7,)]
    assert (fesem.kind, fesem.m_pad) == ("multi", 2)
    batch = by_cells[(4,)]
    assert (batch.kind, batch.fused, batch.k_pad) == \
        ("single", False, None)
    # 6 scenarios per cell (3 traces x 2 seeds), 8 cells
    assert p.num_scenarios == 48
    assert nonfl.num_scenarios == 18
    # printable without running anything
    desc = p.describe()
    assert "pad_k=10" in desc and "pad_m=3" in desc and "iso" in desc
    assert campaign.TRACE_COUNT == before


def test_plan_percell_and_static_modes(small_ae, small_data):
    """``fuse=False`` lowers singles to per-cell buckets padded to the
    PER-KIND max k; ``pad_k=False`` to static per-cell builds."""
    data = _data_spec(small_ae, small_data)
    kw = dict(data=data, base=_base(),
              cells=(CellSpec("tolfl", 2), CellSpec("sbt", 10),
                     CellSpec("fl", 1)),
              traces=TraceSpec(traces=tuple(_traces())),
              seeds=SeedSpec((0,)))
    before = campaign.TRACE_COUNT
    p = plan(ExperimentSpec(fuse=False, **kw))
    assert [b.cell_indices for b in p.buckets] == [[0], [1], [2]]
    assert [b.fused for b in p.buckets] == [False] * 3
    assert [b.k_pad for b in p.buckets] == [10, 10, 1]   # per kind
    p = plan(ExperimentSpec(fuse=False, pad_k=False, **kw))
    assert [b.k_pad for b in p.buckets] == [None] * 3
    # an explicit k_pad override applies to every bucket (the legacy
    # run_fused_campaigns semantics), fl's included
    p = plan(ExperimentSpec(k_pad=12, **kw))
    assert [b.k_pad for b in p.buckets] == [12, 12]
    # a batch cell always lowers to a static bucket, whatever the pad
    # knobs say: centralising the data changes the array shapes, so it
    # can never share the padded executable (pad_k=K used to crash deep
    # inside the vmapped core on this path)
    bkw = dict(kw, cells=(CellSpec("batch", 1),))
    assert plan(ExperimentSpec(k_pad=12, **bkw)).buckets[0].k_pad is None
    p = plan(ExperimentSpec(fuse=False, k_pad=12, **bkw))
    assert p.buckets[0].k_pad is None
    assert campaign.TRACE_COUNT == before


def test_plan_geometry_mirrors_run_batched(small_ae, small_data):
    """chunk_size=5 over B=12 -> 3 chunks of 5 with 3 padded rows,
    computed at plan time (and still zero executables)."""
    data = _data_spec(small_ae, small_data)
    spec = ExperimentSpec(
        data=data, base=_base(), cells=(CellSpec("tolfl", 5),),
        traces=TraceSpec(traces=tuple(_traces(4))),
        seeds=SeedSpec((0, 1, 2)), exec_plan=ExecPlan(chunk_size=5))
    before = campaign.TRACE_COUNT
    b = plan(spec).buckets[0]
    assert (b.num_scenarios, b.chunk, b.num_chunks,
            b.padded_scenarios) == (12, 5, 3, 15)
    assert b.devices is None
    assert campaign.TRACE_COUNT == before


def test_plan_sampled_traces_per_topology(small_ae, small_data):
    """A sampled TraceSpec resolves per cell: canonical conditions are
    normalised at the 2N slot budget and prepended (batch drops the
    client condition — no clients exist), each cell samples against its
    OWN topology, and the draw -> trace map covers every rate."""
    data = _data_spec(small_ae, small_data)
    canonical = (NO_FAILURE, FailureSpec(epoch=2, kind="client"),
                 FailureSpec(epoch=2, kind="server"))
    spec = ExperimentSpec(
        data=data, base=_base(),
        cells=(CellSpec("tolfl", 5), CellSpec("fl", 1),
               CellSpec("batch", 1), CellSpec("ifca", 3)),
        traces=TraceSpec(traces=canonical, p_grid=(0.3, 0.6),
                         traces_per_p=3, sample_seed=7),
        seeds=SeedSpec((0,)))
    before = campaign.TRACE_COUNT
    p = plan(spec)
    assert campaign.TRACE_COUNT == before
    tolfl, fl, batch, ifca = p.cells
    for c in (tolfl, fl, ifca):
        assert c.explicit_index == {0: 0, 1: 1, 2: 2}
        assert set(c.draws) == {0.3, 0.6}
        assert all(len(d) == 3 for d in c.draws.values())
        assert all(t.max_events == 20 for t in c.traces)  # 2N budget
    # batch has no clients: the client condition is dropped, later
    # canonicals shift down
    assert batch.explicit_index == {0: 0, 1: None, 2: 1}
    # all-none draws alias the canonical no-failure trace (dedup): every
    # draw index is in range and the trace lists stay deduplicated
    for c in p.cells:
        keys = set()
        for t in c.traces:
            keys.add(tuple(np.asarray(l).tobytes() for l in
                           (t.epochs, t.devices, t.alive_after, t.kinds)))
        assert len(keys) == len(c.traces)
        for idxs in c.draws.values():
            assert all(0 <= i < len(c.traces) for i in idxs)
    # different topologies -> different server/client attribution, so
    # the sampled grids genuinely differ between tolfl and fl
    assert len(tolfl.traces) != len(fl.traces) or any(
        not np.array_equal(np.asarray(a.devices), np.asarray(b.devices))
        for a, b in zip(tolfl.traces, fl.traces))


# ---------------------------------------------------------------------------
# spec -> plan -> execute == the legacy entry points, bit-identical
# ---------------------------------------------------------------------------
GRID = [("tolfl", 5), ("tolfl", 2), ("sbt", 10), ("fl", 1),
        ("batch", 1), ("ifca", 2), ("ifca", 3)]


def test_spec_execute_reproduces_sweep_grid(small_ae, small_data):
    """The acceptance contract: a hand-built ExperimentSpec reproduces
    ``sweep_grid(fuse=True)`` bit-identically — same executables, same
    stacked operands — and a warm re-execute costs ZERO new traces."""
    dx, counts, tx, ty = small_data
    base = _base()
    traces = _traces()
    grid = sweep_grid(small_ae, dx, counts, tx, ty, base, GRID, traces,
                      seeds=[0, 1], target_loss=2430.0)

    spec = ExperimentSpec(
        data=_data_spec(small_ae, small_data), base=base,
        cells=tuple(CellSpec(s, k) for s, k in GRID),
        traces=TraceSpec(traces=tuple(traces)),
        seeds=SeedSpec((0, 1)), target_loss=2430.0)
    before = campaign.TRACE_COUNT
    res = execute(plan(spec))
    assert campaign.TRACE_COUNT == before, \
        "spec pipeline missed the executable cache the shim warmed"
    assert res.num_scenarios == len(GRID) * 6
    for key, r in res.per_cell().items():
        g = grid[key]
        if hasattr(g, "auroc_used"):
            np.testing.assert_array_equal(g.auroc_used, r.auroc_used)
            np.testing.assert_array_equal(g.loss_curves, r.loss_curves)
            np.testing.assert_array_equal(g.iso_active, r.iso_active)
            np.testing.assert_array_equal(g.rounds_to_loss,
                                          r.rounds_to_loss)
        else:
            np.testing.assert_array_equal(g.best_auroc, r.best_auroc)
            np.testing.assert_array_equal(g.multi_auroc, r.multi_auroc)
            np.testing.assert_array_equal(g.assignments, r.assignments)
    # result-frame views agree with the per-cell arrays
    rows = res.to_rows()
    assert len(rows) == res.num_scenarios
    assert rows[0]["dataset"] == "commsml"
    summ = res.summary()
    for key in res.per_cell():
        assert summ[key]["num_scenarios"] == 6.0


def test_run_campaign_shim_parity(small_ae, small_data):
    """``run_campaign`` is a one-cell spec: same results, and the
    explicit-pad path keys the same shared executable."""
    dx, counts, tx, ty = small_data
    cfg = dataclasses.replace(_base(), scheme="tolfl", num_clusters=5)
    traces = _traces()
    solo = run_campaign(small_ae, dx, counts, tx, ty, cfg, traces,
                        seeds=range(2), pad_k=7)
    spec = ExperimentSpec(
        data=_data_spec(small_ae, small_data), base=cfg,
        cells=(CellSpec("tolfl", 5, traces=tuple(traces)),),
        seeds=SeedSpec((0, 1)), fuse=False, k_pad=7)
    before = campaign.TRACE_COUNT
    res = run_experiment(spec)
    assert campaign.TRACE_COUNT == before
    np.testing.assert_array_equal(solo.auroc_used,
                                  res.results[0].auroc_used)
    np.testing.assert_array_equal(solo.loss_curves,
                                  res.results[0].loss_curves)


def test_fused_shim_ragged_parity(small_ae, small_data):
    """``run_fused_campaigns`` with ragged per-cell trace lists == the
    same cells declared with per-cell ``CellSpec.traces``."""
    dx, counts, tx, ty = small_data
    base = _base()
    cfg_a = dataclasses.replace(base, scheme="tolfl", num_clusters=5)
    cfg_b = dataclasses.replace(base, scheme="sbt", num_clusters=10)
    tr_a, tr_b = _traces(3), _traces(2)
    fused = run_fused_campaigns(small_ae, dx, counts, tx, ty,
                                [(cfg_a, tr_a), (cfg_b, tr_b)],
                                seeds=[0])
    spec = ExperimentSpec(
        data=_data_spec(small_ae, small_data), base=base,
        cells=(CellSpec("tolfl", 5, traces=tuple(tr_a)),
               CellSpec("sbt", 10, traces=tuple(tr_b))),
        seeds=SeedSpec((0,)))
    res = run_experiment(spec)
    assert [r.num_scenarios for r in res.results] == [3, 2]
    for a, b in zip(fused, res.results):
        np.testing.assert_array_equal(a.auroc_used, b.auroc_used)
        np.testing.assert_array_equal(a.loss_curves, b.loss_curves)


def test_cell_sugar_and_overrides(small_ae, small_data):
    """``cell(...)`` kwargs become SimConfig overrides; labels key the
    result frame."""
    c = cell("tolfl", 5, label="wide", lr=5e-4)
    assert c.resolve(_base()).lr == 5e-4
    assert c.key() == "wide"
    spec = ExperimentSpec(
        data=_data_spec(small_ae, small_data), base=_base(),
        cells=(c,), traces=TraceSpec.explicit(*_traces(2)),
        seeds=SeedSpec((0,)))
    p = plan(spec)
    assert p.cells[0].cfg.lr == 5e-4
    assert p.cell("wide").num_scenarios == 2
