"""Failure-model semantics (paper Section II / IV-B).

* dead member  -> only its own weight zeroed; cluster continues.
* dead head    -> the whole cluster's weight zeroed (worst case).
* FL (k=1) head death == server death -> everyone zeroed.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.failure import (NO_FAILURE, FailureSpec, alive_mask,
                                effective_weights, surviving_fraction)
from repro.core.topology import Topology


def test_no_failure_all_alive():
    topo = Topology(8, 4)
    m = alive_mask(NO_FAILURE, topo, jnp.int32(100))
    np.testing.assert_array_equal(np.asarray(m), np.ones(8))
    w = effective_weights(m, topo)
    np.testing.assert_array_equal(np.asarray(w), np.ones(8))


def test_failure_fires_at_epoch():
    topo = Topology(8, 4)
    spec = FailureSpec(epoch=50, kind="client", device=3)
    before = np.asarray(alive_mask(spec, topo, jnp.int32(49)))
    after = np.asarray(alive_mask(spec, topo, jnp.int32(50)))
    np.testing.assert_array_equal(before, np.ones(8))
    assert after[3] == 0.0 and after.sum() == 7


def test_client_failure_removes_only_member():
    topo = Topology(8, 4)                  # clusters {0,1},{2,3},{4,5},{6,7}
    spec = FailureSpec(epoch=0, kind="client")   # defaults to dev 1 (member)
    alive = alive_mask(spec, topo, jnp.int32(10))
    w = np.asarray(effective_weights(alive, topo))
    assert w[1] == 0.0
    assert w.sum() == 7.0                  # everyone else keeps training


def test_server_failure_kills_whole_cluster():
    topo = Topology(8, 4)
    spec = FailureSpec(epoch=0, kind="server")   # defaults to head 0
    alive = alive_mask(spec, topo, jnp.int32(10))
    w = np.asarray(effective_weights(alive, topo))
    # head 0 dead => members {0,1} both gone; clusters 1..3 unaffected
    np.testing.assert_array_equal(w, [0, 0, 1, 1, 1, 1, 1, 1])


def test_fl_server_failure_kills_everyone():
    """FL = Tol-FL(k=1): the single head IS the server."""
    topo = Topology(8, 1)
    spec = FailureSpec(epoch=0, kind="server")
    alive = alive_mask(spec, topo, jnp.int32(10))
    w = np.asarray(effective_weights(alive, topo))
    np.testing.assert_array_equal(w, np.zeros(8))


def test_sbt_any_failure_loses_one():
    """SBT = Tol-FL(k=N): every device is its own head; any single death
    costs exactly one device (the paper's robustness argument)."""
    topo = Topology(8, 8)
    for dev in range(8):
        spec = FailureSpec(epoch=0, kind="server", device=dev)
        alive = alive_mask(spec, topo, jnp.int32(1))
        w = np.asarray(effective_weights(alive, topo))
        assert w.sum() == 7.0


@settings(max_examples=40, deadline=None)
@given(
    members=st.integers(1, 5),
    k=st.integers(1, 5),
    data=st.data(),
)
def test_worst_case_loss_bounded_by_cluster(members, k, data):
    """Paper IV-B: worst-case single failure loses at most one cluster."""
    topo = Topology(members * k, k)
    dev = data.draw(st.integers(0, topo.num_devices - 1))
    spec = FailureSpec(epoch=0, kind="server", device=dev)
    alive = alive_mask(spec, topo, jnp.int32(1))
    w = np.asarray(effective_weights(alive, topo))
    lost = topo.num_devices - w.sum()
    if topo.is_head(dev):
        assert lost == topo.members_per_cluster
    else:
        assert lost == 1


def test_surviving_fraction():
    topo = Topology(8, 4)
    alive = np.ones(8, np.float32)
    alive[0] = 0  # head of cluster 0
    assert surviving_fraction(alive, topo) == 0.75
