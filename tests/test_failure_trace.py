"""FailureTrace <-> FailureSpec equivalence and weight invariants.

The trace encoding must be a strict generalisation: a single-event
trace reproduces the legacy spec semantics BIT-IDENTICALLY (alive masks
and effective weights), and the derived per-device weights always
normalise to 1 over the surviving clusters or vanish entirely when
every head is dead.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.failure import (KIND_CODES, MAX_EVENTS, NO_FAILURE,
                                PAD_EPOCH, FailureEvent, FailureSpec,
                                FailureTrace, _trace_alive_mask_unrolled,
                                alive_mask, as_trace, effective_weights,
                                sample_rate_grid, sample_traces,
                                stack_traces, trace_alive_mask)
from repro.core.topology import Topology

TOPOLOGIES = [(8, 4), (8, 1), (8, 8), (6, 3), (10, 5), (1, 1)]


# ---------------------------------------------------------------------------
# single-event trace == legacy spec, bit-identically
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,k", TOPOLOGIES)
@pytest.mark.parametrize("kind", ["none", "client", "server"])
@pytest.mark.parametrize("fail_epoch", [0, 3])
def test_single_event_trace_matches_spec(n, k, kind, fail_epoch):
    topo = Topology(n, k)
    spec = (NO_FAILURE if kind == "none"
            else FailureSpec(epoch=fail_epoch, kind=kind))
    trace = as_trace(spec, topo)
    for epoch in [0, fail_epoch - 1, fail_epoch, fail_epoch + 1, 1000]:
        a_spec = np.asarray(alive_mask(spec, topo, jnp.int32(epoch)))
        a_tr = np.asarray(alive_mask(trace, topo, jnp.int32(epoch)))
        np.testing.assert_array_equal(a_tr, a_spec)
        w_spec = np.asarray(effective_weights(jnp.asarray(a_spec), topo))
        w_tr = np.asarray(effective_weights(jnp.asarray(a_tr), topo))
        np.testing.assert_array_equal(w_tr, w_spec)


@settings(max_examples=40, deadline=None)
@given(n_idx=st.integers(0, len(TOPOLOGIES) - 1),
       kind=st.sampled_from(["client", "server"]),
       fail_epoch=st.integers(0, 20),
       query=st.integers(0, 25),
       data=st.data())
def test_explicit_device_trace_matches_spec(n_idx, kind, fail_epoch,
                                            query, data):
    n, k = TOPOLOGIES[n_idx]
    topo = Topology(n, k)
    dev = data.draw(st.integers(0, n - 1))
    spec = FailureSpec(epoch=fail_epoch, kind=kind, device=dev)
    trace = as_trace(spec, topo)
    a_spec = np.asarray(alive_mask(spec, topo, jnp.int32(query)))
    a_tr = np.asarray(alive_mask(trace, topo, jnp.int32(query)))
    np.testing.assert_array_equal(a_tr, a_spec)


# ---------------------------------------------------------------------------
# multi-event semantics
# ---------------------------------------------------------------------------
def test_two_failures_compose():
    topo = Topology(8, 4)
    trace = FailureTrace.from_events(
        [FailureEvent(3, "client", device=1),
         FailureEvent(5, "server", device=2)], topo)
    np.testing.assert_array_equal(
        np.asarray(trace_alive_mask(trace, 8, jnp.int32(2))), np.ones(8))
    a4 = np.asarray(trace_alive_mask(trace, 8, jnp.int32(4)))
    np.testing.assert_array_equal(a4, [1, 0, 1, 1, 1, 1, 1, 1])
    a5 = np.asarray(trace_alive_mask(trace, 8, jnp.int32(5)))
    np.testing.assert_array_equal(a5, [1, 0, 0, 1, 1, 1, 1, 1])
    # head 2 dead kills cluster {2,3}; member 1 only kills itself
    w = np.asarray(effective_weights(jnp.asarray(a5), topo))
    np.testing.assert_array_equal(w, [1, 0, 0, 0, 1, 1, 1, 1])


def test_recovery_restores_device():
    topo = Topology(4, 2)
    trace = FailureTrace.from_events(
        [FailureEvent(2, "client", device=3),
         FailureEvent(6, "client", device=3, recover=True)], topo)
    assert float(trace_alive_mask(trace, 4, jnp.int32(1))[3]) == 1.0
    assert float(trace_alive_mask(trace, 4, jnp.int32(2))[3]) == 0.0
    assert float(trace_alive_mask(trace, 4, jnp.int32(5))[3]) == 0.0
    assert float(trace_alive_mask(trace, 4, jnp.int32(6))[3]) == 1.0
    assert float(trace_alive_mask(trace, 4, jnp.int32(99))[3]) == 1.0


def test_events_sorted_regardless_of_input_order():
    topo = Topology(4, 2)
    ev = [FailureEvent(6, "client", device=3, recover=True),
          FailureEvent(2, "client", device=3)]
    trace = FailureTrace.from_events(ev, topo)          # out of order
    assert float(trace_alive_mask(trace, 4, jnp.int32(7))[3]) == 1.0
    assert float(trace_alive_mask(trace, 4, jnp.int32(3))[3]) == 0.0


def test_same_epoch_ties_last_listed_wins():
    """Contract: same-device same-epoch events apply in list order."""
    topo = Topology(4, 2)
    fail = FailureEvent(5, "client", device=3)
    recover = FailureEvent(5, "client", device=3, recover=True)
    dead = FailureTrace.from_events([recover, fail], topo)
    alive = FailureTrace.from_events([fail, recover], topo)
    assert float(trace_alive_mask(dead, 4, jnp.int32(5))[3]) == 0.0
    assert float(trace_alive_mask(alive, 4, jnp.int32(5))[3]) == 1.0


def test_padding_slots_never_fire():
    topo = Topology(4, 2)
    trace = FailureTrace.none()
    assert trace.max_events == MAX_EVENTS
    assert int(np.asarray(trace.epochs)[0]) == PAD_EPOCH
    m = np.asarray(trace_alive_mask(trace, 4, jnp.int32(10 ** 9)))
    np.testing.assert_array_equal(m, np.ones(4))


def test_stack_traces_shapes():
    topo = Topology(8, 4)
    traces = [FailureTrace.none(),
              as_trace(FailureSpec(3, "server"), topo),
              as_trace(FailureSpec(5, "client"), topo)]
    stacked = stack_traces(traces)
    assert stacked.epochs.shape == (3, MAX_EVENTS)
    assert stacked.devices.shape == (3, MAX_EVENTS)


# ---------------------------------------------------------------------------
# vectorized alive mask == the unrolled per-slot fold (ISSUE 3 bugfix)
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(topo_idx=st.integers(0, len(TOPOLOGIES) - 1),
       rate_pct=st.integers(10, 100), max_events=st.integers(1, 24),
       query=st.integers(0, 20), seed=st.integers(0, 2 ** 31 - 1))
def test_trace_alive_mask_matches_unrolled(topo_idx, rate_pct, max_events,
                                           query, seed):
    """The argmax-based last-event-wins reduction equals the reference
    per-slot fold on sampled multi-event recovery traces."""
    n, k = TOPOLOGIES[topo_idx]
    topo = Topology(n, k)
    rng = np.random.default_rng(seed)
    for trace in sample_traces(rng, topo, rate_pct / 100.0,
                               max_events=max_events, rounds=15,
                               num_traces=2, recover_prob=0.7):
        got = np.asarray(trace_alive_mask(trace, n, jnp.int32(query)))
        want = np.asarray(_trace_alive_mask_unrolled(trace, n,
                                                     jnp.int32(query)))
        np.testing.assert_array_equal(got, want)


def test_trace_alive_mask_tie_break_matches_unrolled():
    """Same-epoch same-device slots: the LAST slot must win in both
    implementations (the from_events list-order contract)."""
    topo = Topology(4, 2)
    fail = FailureEvent(5, "client", device=3)
    recover = FailureEvent(5, "client", device=3, recover=True)
    for events in ([fail, recover], [recover, fail]):
        trace = FailureTrace.from_events(events, topo)
        got = np.asarray(trace_alive_mask(trace, 4, jnp.int32(5)))
        want = np.asarray(_trace_alive_mask_unrolled(trace, 4,
                                                     jnp.int32(5)))
        np.testing.assert_array_equal(got, want)


def test_trace_alive_mask_graph_size_constant_in_max_events():
    """Compile-size regression guard: the traced graph must be O(1) in
    max_events (the unrolled fold was O(M) `where`s, which blew up
    compile time at sample_rate_grid's default M = 2 * num_devices).
    The guard is the shared named budget in plancheck.budgets."""
    from repro.analysis.plancheck import budgets

    def n_eqns(fn, m):
        trace = FailureTrace.none(m)
        return budgets.eqn_count(lambda e: fn(trace, 16, e),
                                 jnp.int32(0))

    # the O(1)-in-knob property itself, across a max_events sweep
    assert budgets.constant_across(
        lambda m: n_eqns(trace_alive_mask, m), (4, 8, 64))
    # and the named ceiling: a breach is a PC-JAX-BUDGET finding
    count = n_eqns(trace_alive_mask, 64)
    assert budgets.check_budget("trace_alive_mask", count) is None, count
    assert n_eqns(_trace_alive_mask_unrolled, 64) > 3 * 64


# ---------------------------------------------------------------------------
# sampled trace grids (Section IV-B failure-rate sweeps)
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(topo_idx=st.integers(0, len(TOPOLOGIES) - 1),
       rate_pct=st.integers(0, 100), max_events=st.integers(1, 12),
       rounds=st.integers(1, 50), seed=st.integers(0, 2 ** 31 - 1))
def test_sampled_traces_well_formed(topo_idx, rate_pct, max_events,
                                    rounds, seed):
    """Sampled traces are epoch-sorted, in-range, respect max_events,
    and classify events by the topology's head set."""
    n, k = TOPOLOGIES[topo_idx]
    topo = Topology(n, k)
    heads = set(topo.heads)
    rng = np.random.default_rng(seed)
    traces = sample_traces(rng, topo, rate_pct / 100.0,
                           max_events=max_events, rounds=rounds,
                           num_traces=3)
    assert len(traces) == 3
    for trace in traces:
        ep = np.asarray(trace.epochs)
        dev = np.asarray(trace.devices)
        knd = np.asarray(trace.kinds)
        assert ep.shape == (max_events,)
        # epoch-sorted, padding (PAD_EPOCH) naturally sorts last
        assert (np.diff(ep) >= 0).all()
        real = ep < PAD_EPOCH
        assert real.sum() <= max_events
        assert (ep[real] >= 0).all() and (ep[real] < rounds).all()
        assert (dev[real] >= 0).all() and (dev[real] < n).all()
        for d, c in zip(dev[real], knd[real]):
            expect = "server" if int(d) in heads else "client"
            assert c == KIND_CODES[expect]
        # padding slots never fire
        assert (dev[~real] == -1).all()


def test_sampled_traces_rate_extremes():
    topo = Topology(6, 3)
    rng = np.random.default_rng(0)
    for trace in sample_traces(rng, topo, 0.0, rounds=10, num_traces=4):
        assert (np.asarray(trace.epochs) == PAD_EPOCH).all()
    # rate 1 with ample slots: every device fails exactly once (plus
    # possible recoveries)
    big = sample_traces(rng, topo, 1.0, max_events=12, rounds=10,
                        num_traces=4)
    for trace in big:
        dev = np.asarray(trace.devices)
        alv = np.asarray(trace.alive_after)
        failed = {int(d) for d, a in zip(dev, alv) if d >= 0 and a == 0}
        assert failed == set(range(6))


def test_sample_rate_grid_dedups_identical_draws():
    """Identical draws (all-none traces at p=0) collapse to ONE trained
    scenario while the per-p draw lists keep the Monte-Carlo weights."""
    topo = Topology(10, 5)
    traces, draws = sample_rate_grid(np.random.default_rng(0), topo,
                                     p_grid=(0.0, 0.5), rounds=20,
                                     traces_per_p=6)
    assert len(draws[0.0]) == len(draws[0.5]) == 6
    assert len(set(draws[0.0])) == 1           # 6 draws, 1 distinct trace
    assert (np.asarray(traces[draws[0.0][0]].epochs) == PAD_EPOCH).all()
    assert len(traces) == len(set(draws[0.0]) | set(draws[0.5]))
    assert len(traces) < 12                    # strictly fewer than draws
    # default slot budget fits every device failing AND recovering, so
    # high-p draws are never truncated
    assert all(t.max_events == 2 * topo.num_devices for t in traces)
    full = sample_traces(np.random.default_rng(1), topo, 1.0,
                         max_events=2 * topo.num_devices, rounds=20,
                         num_traces=3)
    for t in full:
        dead = {int(d) for d, a in zip(np.asarray(t.devices),
                                       np.asarray(t.alive_after))
                if d >= 0 and a == 0}
        assert dead == set(range(topo.num_devices))


def test_sample_rate_grid_base_traces_join_dedup():
    """All-none draws alias a caller-supplied no-failure base trace
    instead of retraining an identical scenario."""
    topo = Topology(10, 5)
    base = [FailureTrace.none(2 * topo.num_devices)]
    traces, draws = sample_rate_grid(np.random.default_rng(0), topo,
                                     p_grid=(0.0,), rounds=20,
                                     traces_per_p=4, base_traces=base)
    assert len(traces) == 1                    # nothing beyond the base
    assert traces[0] is base[0]
    assert draws[0.0] == [0, 0, 0, 0]


@settings(max_examples=30, deadline=None)
@given(topo_idx=st.integers(0, len(TOPOLOGIES) - 1),
       max_events=st.integers(1, 12), rounds=st.integers(2, 30),
       seed=st.integers(0, 2 ** 31 - 1))
def test_truncation_degrades_to_failure_not_skip(topo_idx, max_events,
                                                 rounds, seed):
    """Kept-event pin for the slot-budget fix: at failure_rate 1 every
    device fails, and a device may be dropped ONLY when the trace is
    already full — so either all devices appear as failure events or
    all max_events slots are used.  (The old code skipped a device
    whose 2-slot failure+recovery no longer fit even when 1 slot
    remained, violating both.)  With degradation each kept device costs
    at most 2 slots, so a full trace holds >= ceil(M / 2) failures."""
    n, k = TOPOLOGIES[topo_idx]
    topo = Topology(n, k)
    rng = np.random.default_rng(seed)
    for trace in sample_traces(rng, topo, 1.0, max_events=max_events,
                               rounds=rounds, num_traces=2,
                               recover_prob=1.0):
        ep = np.asarray(trace.epochs)
        dev = np.asarray(trace.devices)
        alv = np.asarray(trace.alive_after)
        real = ep < PAD_EPOCH
        fail_devs = {int(d) for d, a in zip(dev[real], alv[real])
                     if a == 0}
        used = int(real.sum())
        assert used == max_events or len(fail_devs) == n, \
            (used, max_events, fail_devs, n)
        assert len(fail_devs) >= min(n, (max_events + 1) // 2), \
            (fail_devs, max_events)


def test_sampled_traces_no_dangling_recovery():
    """A recovery is only ever emitted after its failure — never alone,
    and never at an earlier epoch."""
    topo = Topology(10, 5)
    rng = np.random.default_rng(7)
    for trace in sample_traces(rng, topo, 0.9, max_events=4, rounds=30,
                               num_traces=20):
        ep = np.asarray(trace.epochs)
        dev = np.asarray(trace.devices)
        alv = np.asarray(trace.alive_after)
        for j in np.flatnonzero((ep < PAD_EPOCH) & (alv == 1)):
            mates = (dev == dev[j]) & (alv == 0) & (ep < ep[j])
            assert mates.any(), (ep, dev, alv)


# ---------------------------------------------------------------------------
# weight normalisation invariants
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(members=st.integers(1, 4), k=st.integers(1, 5),
       seed=st.integers(0, 2 ** 31 - 1))
def test_weights_normalise_over_alive_clusters(members, k, seed):
    """Normalised sample weights sum to 1 over surviving clusters, and
    devices in a dead cluster carry exactly zero weight."""
    topo = Topology(members * k, k)
    n = topo.num_devices
    rng = np.random.default_rng(seed)
    alive = (rng.random(n) > 0.4).astype(np.float32)
    counts = rng.uniform(1.0, 50.0, n).astype(np.float32)
    w = np.asarray(effective_weights(jnp.asarray(alive), topo))
    ns = w * counts
    head_alive = alive[np.asarray(topo.heads)]
    cluster_ids = topo.device_cluster_array()
    # dead-head clusters contribute exactly nothing
    for i in range(n):
        if head_alive[cluster_ids[i]] == 0 or alive[i] == 0:
            assert ns[i] == 0.0
    tot = ns.sum()
    if tot > 0:
        np.testing.assert_allclose((ns / tot).sum(), 1.0, rtol=1e-6)
    else:
        np.testing.assert_array_equal(ns, np.zeros(n))


def test_all_heads_dead_zeroes_everything():
    topo = Topology(6, 3)
    alive = np.ones(6, np.float32)
    alive[np.asarray(topo.heads)] = 0.0
    w = np.asarray(effective_weights(jnp.asarray(alive), topo))
    np.testing.assert_array_equal(w, np.zeros(6))
