"""Regression: FL server-failure fallback == isolated local training.

Paper Fig 4 semantics: after the FL server dies no aggregation is
possible; every surviving device keeps training its own local model and
the reported metric is the MEAN of the independently trained devices.
We pin that by retraining each device by hand (plain per-device SGD
from the shared init) and demanding the simulator's reported fallback
metric match exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core.failure import (NO_FAILURE, FailureEvent, FailureSpec,
                                FailureTrace)
from repro.core.simulate import SimConfig, _device_grad_fn, run_simulation
from repro.data import commsml, federated
from repro.models import autoencoder as AE
from repro.training.metrics import auroc

N_DEV = 4
ROUNDS = 6
LR = 1e-3


@pytest.fixture(scope="module")
def setup():
    X, y = commsml.generate(seed=0, samples_per_class=50)
    split = federated.make_split(X, y, num_devices=N_DEV, num_clusters=2,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    ae = AutoencoderConfig(input_dim=commsml.N_FEATURES, hidden=(16,),
                           code_dim=4)
    return ae, dx, counts, split


def _train_isolated(ae_cfg, x, valid, rounds):
    """Plain SGD on one device's data from the shared seed-0 init —
    exactly what each surviving device does after the server dies at
    epoch 0 (dropout off => the per-round keys are inert)."""
    params, _ = AE.init_params(jax.random.PRNGKey(0), ae_cfg)
    grad_fn = _device_grad_fn(ae_cfg, dropout=False)
    key = jax.random.PRNGKey(0)
    for _ in range(rounds):
        g = grad_fn(params, x, valid, key)
        params = jax.tree.map(lambda p, g_: p - LR * g_, params, g)
    return params


def test_fallback_metric_is_mean_of_isolated_devices(setup):
    ae, dx, counts, split = setup
    cfg = SimConfig(scheme="fl", num_devices=N_DEV, num_clusters=1,
                    rounds=ROUNDS, lr=LR, dropout=False, seed=0)
    res = run_simulation(ae, dx, counts, split.test_x, split.test_y, cfg,
                         FailureSpec(epoch=0, kind="server"))
    assert res.iso_active
    assert res.auroc_used == res.iso_auroc

    valid = (np.arange(dx.shape[1])[None, :]
             < counts[:, None]).astype(np.float32)
    per_dev = []
    for i in range(N_DEV):
        if i == 0:
            continue                      # device 0 IS the dead server
        p = _train_isolated(ae, jnp.asarray(dx[i]),
                            jnp.asarray(valid[i]), ROUNDS)
        scores = np.asarray(AE.anomaly_scores(p, ae, split.test_x))
        per_dev.append(auroc(scores, split.test_y))
    np.testing.assert_allclose(res.iso_auroc, np.mean(per_dev), atol=1e-5)


def test_fallback_reported_curves_are_isolated(setup):
    """With the single head dead from round 0, no aggregation ever
    happens: the GLOBAL model is frozen and meaningless, so the REPORTED
    loss curve must be the isolated-mean curve for every round (Fig 4) —
    decreasing, not flat — and the reported AUROC curve must end on the
    isolated-mean AUROC the paper would report."""
    ae, dx, counts, split = setup
    cfg = SimConfig(scheme="fl", num_devices=N_DEV, num_clusters=1,
                    rounds=ROUNDS, lr=LR, dropout=False, seed=0)
    res = run_simulation(ae, dx, counts, split.test_x, split.test_y, cfg,
                         FailureSpec(epoch=0, kind="server"))
    np.testing.assert_allclose(res.loss_curve, res.iso_loss_curve,
                               rtol=1e-6)
    assert res.loss_curve[-1] < res.loss_curve[0]
    np.testing.assert_allclose(res.auroc_curve[-1], res.iso_auroc,
                               atol=1e-12)
    # the frozen global model is still measured by final_auroc, but it
    # is not what the curves report
    assert res.auroc_used == res.iso_auroc


def test_midtraining_failure_curves_switch_at_failure_round(setup):
    """Reported curves follow the global model until the server dies,
    then the isolated mean — and a recovered server switches back."""
    ae, dx, counts, split = setup
    cfg = SimConfig(scheme="fl", num_devices=N_DEV, num_clusters=1,
                    rounds=ROUNDS, lr=LR, dropout=False, seed=0)
    h = ROUNDS // 2
    nofail = run_simulation(ae, dx, counts, split.test_x, split.test_y,
                            cfg, NO_FAILURE)
    res = run_simulation(ae, dx, counts, split.test_x, split.test_y, cfg,
                         FailureSpec(epoch=h, kind="server"))
    # pre-failure: identical to the failure-free global curve
    np.testing.assert_allclose(res.loss_curve[:h], nofail.loss_curve[:h],
                               rtol=1e-6)
    np.testing.assert_allclose(res.auroc_curve[:h],
                               nofail.auroc_curve[:h], atol=1e-12)
    # post-failure: the isolated-mean curve, not the frozen global one
    np.testing.assert_allclose(res.loss_curve[h:], res.iso_loss_curve[h:],
                               rtol=1e-6)
    assert not np.allclose(res.loss_curve[h:], nofail.loss_curve[h:])

    topo = cfg.topology()
    churn = FailureTrace.from_events(
        [FailureEvent(1, "server"),
         FailureEvent(h, "server", recover=True)], topo)
    rec = run_simulation(ae, dx, counts, split.test_x, split.test_y, cfg,
                         churn)
    assert not rec.iso_active          # server alive at the end
    np.testing.assert_allclose(rec.loss_curve[1:h],
                               rec.iso_loss_curve[1:h], rtol=1e-6)


def test_midtraining_failure_reports_isolated_mean(setup):
    """Failure at the midpoint: fallback still reports the isolated
    mean, and the isolated branch tracked the global model up to the
    failure round (so it benefits from pre-failure collaboration)."""
    ae, dx, counts, split = setup
    cfg = SimConfig(scheme="fl", num_devices=N_DEV, num_clusters=1,
                    rounds=ROUNDS, lr=LR, dropout=False, seed=0)
    fail = FailureSpec(epoch=ROUNDS // 2, kind="server")
    res = run_simulation(ae, dx, counts, split.test_x, split.test_y, cfg,
                         fail)
    assert res.iso_active
    assert res.auroc_used == res.iso_auroc
    assert np.isfinite(res.iso_auroc)
    # pre-failure the isolated tracker mirrors the global model (it
    # snapshots params at the START of each round, so it lags the
    # post-update loss curve by exactly one round)
    h = ROUNDS // 2
    np.testing.assert_allclose(res.iso_loss_curve[1:h],
                               res.loss_curve[:h - 1], rtol=1e-5)
