"""Numerics tests for the beyond-paper perf levers (EXPERIMENTS.md §Perf):

* ``grad_sync_dtype=bfloat16`` — the synced update must stay within bf16
  rounding of the f32-synced update;
* ``microbatches=m`` — gradient accumulation must match the single-batch
  gradient exactly (same data, mean-of-means with equal shards);
* ``param_cast_dtype`` — loss computed off bf16-cast params stays close.

Run in a subprocess with 8 virtual devices (same pattern as
tests/test_distributed.py).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import OptimizerConfig, TolFLConfig
    from repro.configs.base import ModelConfig, AttentionConfig
    from repro.core import distributed as D
    from repro.sharding import logical as L

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = L.rules_for("replicated_data")
    cfg = ModelConfig(name="tiny", num_layers=2, d_model=64, d_ff=128,
                      vocab_size=256,
                      attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                                head_dim=16),
                      remat="none", dtype="float32")
    ocfg = OptimizerConfig(name="sgd", lr=0.1, schedule="constant",
                           warmup_steps=0, grad_clip=0.0)
    with L.activate_mesh(mesh, rules):
        state = D.init_state(jax.random.PRNGKey(0), cfg, ocfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                          0, 256),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16),
                                          0, 256)}
    alive = jnp.ones(4)

    def run(schedule, **kw):
        tolfl = TolFLConfig(num_clusters=2, schedule=schedule, **kw)
        with L.activate_mesh(mesh, rules):
            step = D.make_train_step(cfg, tolfl, ocfg, mesh)
            new_state, _ = jax.jit(step)(state, batch, alive)
        return np.concatenate([np.asarray(x, np.float32).ravel()
                               for x in jax.tree.leaves(
                                   new_state["params"])])

    base_ring = run("tolfl_ring")
    base_psum = run("tolfl_psum")
    out = {}

    def rel(a, b):
        return float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-12))

    out["bf16_sync_vs_f32"] = rel(
        run("tolfl_ring", grad_sync_dtype="bfloat16"), base_ring)
    # NB: ring microbatching splits the PER-SHARD batch (2 rows here), so
    # mb=2 is the max for this mesh; psum splits the global batch.
    out["ring_mb2_vs_mb1"] = rel(run("tolfl_ring", microbatches=2),
                                 base_ring)
    out["psum_mb2_vs_mb1"] = rel(run("tolfl_psum", microbatches=2),
                                 base_psum)
    out["psum_mb4_vs_mb1"] = rel(run("tolfl_psum", microbatches=4),
                                 base_psum)
    out["param_cast_vs_f32"] = rel(
        run("tolfl_psum", param_cast_dtype="bfloat16"), base_psum)
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_bf16_grad_sync_close(results):
    """bf16 has ~2^-8 relative precision; the synced update must stay
    within a few rounding steps of the f32 path."""
    assert results["bf16_sync_vs_f32"] < 0.05


def test_microbatch_accumulation_matches(results):
    # mean-of-means == global mean for equal splits; only reduction-order
    # float noise is allowed
    assert results["ring_mb2_vs_mb1"] < 1e-4
    assert results["psum_mb2_vs_mb1"] < 1e-4
    assert results["psum_mb4_vs_mb1"] < 1e-4


def test_param_cast_close(results):
    assert results["param_cast_vs_f32"] < 0.05
