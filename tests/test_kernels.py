"""Per-kernel Pallas allclose tests vs the pure-jnp oracles in ref.py.

Each kernel is swept over shapes and dtypes (brief deliverable c); the
kernels run in interpret mode on CPU (the TPU-target path is the same code
with interpret=False).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.tolfl_combine import tolfl_combine, tolfl_combine_tree


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_SHAPES = [
    # (B, Sq, H, KVH, D, causal, window)
    (1, 128, 4, 4, 32, True, None),          # MHA causal
    (2, 256, 4, 2, 32, True, None),          # GQA 2:1
    (1, 256, 8, 1, 16, True, None),          # MQA (recurrentgemma kv=1)
    (1, 128, 4, 4, 32, False, None),         # bidirectional (encoder)
    (2, 256, 4, 2, 32, True, 64),            # sliding window
    (1, 512, 2, 2, 64, True, 128),           # longer window
]


@pytest.mark.parametrize("B,S,H,KVH,D,causal,window", ATTN_SHAPES)
def test_flash_attention_matches_reference(B, S, H, KVH, D, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, S, H, D))
    k = rand(ks[1], (B, S, KVH, D))
    v = rand(ks[2], (B, S, KVH, D))
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=64, kv_block=64, interpret=True)
    want = ref.attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 128, 4, 2, ), jnp.float32)  # placeholder
    q = rand(ks[0], (1, 128, 4, 32), dtype)
    k = rand(ks[1], (1, 128, 2, 32), dtype)
    v = rand(ks[2], (1, 128, 2, 32), dtype)
    got = flash_attention(q, k, v, causal=True, q_block=64, kv_block=64,
                          interpret=True)
    want = ref.attention_reference(q, k, v, causal=True)
    assert got.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("q_block,kv_block", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shapes(q_block, kv_block):
    """Output must be block-shape independent."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (1, 256, 2, 32))
    k = rand(ks[1], (1, 256, 2, 32))
    v = rand(ks[2], (1, 256, 2, 32))
    got = flash_attention(q, k, v, causal=True, q_block=q_block,
                          kv_block=kv_block, interpret=True)
    want = ref.attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_fully_masked_rows_finite():
    """Window smaller than block: early rows see only themselves; no NaNs."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (1, 128, 2, 16))
    k = rand(ks[1], (1, 128, 2, 16))
    v = rand(ks[2], (1, 128, 2, 16))
    got = flash_attention(q, k, v, causal=True, window=1, q_block=64,
                          kv_block=64, interpret=True)
    assert np.all(np.isfinite(np.asarray(got, np.float32)))
    want = ref.attention_reference(q, k, v, causal=True, window=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------
LRU_SHAPES = [(1, 64, 8), (2, 128, 16), (1, 256, 64), (3, 128, 32)]


@pytest.mark.parametrize("B,S,W", LRU_SHAPES)
def test_rglru_scan_matches_reference(B, S, W):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    a = jax.nn.sigmoid(rand(ks[0], (B, S, W)))      # decay in (0,1)
    b = rand(ks[1], (B, S, W))
    got = rglru_scan(a, b, t_block=32, w_block=8, interpret=True)
    want = ref.rglru_reference(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rglru_scan_with_initial_state():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    B, S, W = 2, 64, 16
    a = jax.nn.sigmoid(rand(ks[0], (B, S, W)))
    b = rand(ks[1], (B, S, W))
    h0 = rand(ks[2], (B, W))
    got = rglru_scan(a, b, h0, t_block=16, w_block=16, interpret=True)
    want = ref.rglru_reference(a, b, h0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rglru_time_block_carry():
    """State must carry across time blocks: t_block << S."""
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    a = jax.nn.sigmoid(rand(ks[0], (1, 128, 8)))
    b = rand(ks[1], (1, 128, 8))
    small = rglru_scan(a, b, t_block=16, w_block=8, interpret=True)
    big = rglru_scan(a, b, t_block=128, w_block=8, interpret=True)
    np.testing.assert_allclose(np.asarray(small), np.asarray(big),
                               rtol=1e-6, atol=1e-6)


def test_rglru_matches_associative_scan_path():
    """Pallas kernel == the jnp associative_scan the model uses by default."""
    from repro.models.rglru import _lru_scan
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    a = jax.nn.sigmoid(rand(ks[0], (2, 64, 16)))
    b = rand(ks[1], (2, 64, 16))
    got = rglru_scan(a, b, interpret=True)
    want = _lru_scan(a, b, None, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# RWKV6 WKV scan
# ---------------------------------------------------------------------------
WKV_SHAPES = [(1, 32, 2, 8), (2, 64, 2, 16), (1, 128, 4, 32)]


@pytest.mark.parametrize("B,S,H,N", WKV_SHAPES)
def test_rwkv6_scan_matches_reference(B, S, H, N):
    ks = jax.random.split(jax.random.PRNGKey(8), 6)
    r = rand(ks[0], (B, S, H, N), scale=0.5)
    k = rand(ks[1], (B, S, H, N), scale=0.5)
    v = rand(ks[2], (B, S, H, N), scale=0.5)
    w = jax.nn.sigmoid(rand(ks[3], (B, S, H, N)) + 2.0)   # decay near 1
    u = rand(ks[4], (H, N), scale=0.3)
    s0 = rand(ks[5], (B, H, N, N), scale=0.1)
    y_got, s_got = rwkv6_scan(r, k, v, w, u, s0, t_block=16, interpret=True)
    y_want, s_want = ref.rwkv6_reference(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_time_block_carry():
    ks = jax.random.split(jax.random.PRNGKey(9), 6)
    B, S, H, N = 1, 64, 2, 8
    r, k, v = (rand(ks[i], (B, S, H, N), scale=0.5) for i in range(3))
    w = jax.nn.sigmoid(rand(ks[3], (B, S, H, N)) + 2.0)
    u = rand(ks[4], (H, N), scale=0.3)
    s0 = jnp.zeros((B, H, N, N))
    y1, st1 = rwkv6_scan(r, k, v, w, u, s0, t_block=8, interpret=True)
    y2, st2 = rwkv6_scan(r, k, v, w, u, s0, t_block=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=1e-5,
                               atol=1e-5)


def test_rwkv6_state_chaining():
    """Running two halves with carried state == one full pass (decode
    chunking correctness)."""
    ks = jax.random.split(jax.random.PRNGKey(10), 6)
    B, S, H, N = 1, 64, 2, 8
    r, k, v = (rand(ks[i], (B, S, H, N), scale=0.5) for i in range(3))
    w = jax.nn.sigmoid(rand(ks[3], (B, S, H, N)) + 2.0)
    u = rand(ks[4], (H, N), scale=0.3)
    s0 = rand(ks[5], (B, H, N, N), scale=0.1)
    y_full, s_full = ref.rwkv6_reference(r, k, v, w, u, s0)
    half = S // 2
    y1, s_mid = rwkv6_scan(*(t[:, :half] for t in (r, k, v, w)), u, s0,
                           t_block=16, interpret=True)
    y2, s_end = rwkv6_scan(*(t[:, half:] for t in (r, k, v, w)), u, s_mid,
                           t_block=16, interpret=True)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Tol-FL combine kernel
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 8),
    p=st.integers(1, 300),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_tolfl_combine_matches_reference(k, p, seed):
    rng = np.random.default_rng(seed)
    gs = jnp.asarray(rng.standard_normal((k, p)).astype(np.float32))
    ns = jnp.asarray(rng.uniform(0.1, 50.0, k).astype(np.float32))
    got = tolfl_combine(gs, ns, block=64, interpret=True)
    want = ref.tolfl_combine_reference(gs, ns)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_tolfl_combine_equals_direct_weighted_mean():
    """The kernel realises the k-invariant streaming mean."""
    rng = np.random.default_rng(0)
    gs = rng.standard_normal((5, 1000)).astype(np.float32)
    ns = rng.uniform(1, 10, 5).astype(np.float32)
    got = tolfl_combine(jnp.asarray(gs), jnp.asarray(ns), interpret=True)
    want = (ns / ns.sum()) @ gs
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_tolfl_combine_padding():
    """P not divisible by block: padding must not leak into the result."""
    rng = np.random.default_rng(1)
    gs = jnp.asarray(rng.standard_normal((3, 97)).astype(np.float32))
    ns = jnp.asarray([1.0, 2.0, 3.0])
    got = tolfl_combine(gs, ns, block=64, interpret=True)
    assert got.shape == (97,)
    want = ref.tolfl_combine_reference(gs, ns)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("k,p,block", [
    (1, 5, 4096),      # k == 1: the mean is the single gradient
    (1, 257, 64),      # k == 1 with padding (P % block != 0)
    (4, 130, 32),      # non-default block, P % block != 0
    (3, 64, 16),       # non-default block, exact multiple
    (8, 97, 128),      # block > P (clamped to P)
])
def test_tolfl_combine_edge_shapes(k, p, block):
    """P % block != 0 padding, k == 1, and non-default blocks all match
    the streaming reference exactly."""
    rng = np.random.default_rng(k * 1000 + p)
    gs = jnp.asarray(rng.standard_normal((k, p)).astype(np.float32))
    ns = jnp.asarray(rng.uniform(0.1, 50.0, k).astype(np.float32))
    got = tolfl_combine(gs, ns, block=block, interpret=True)
    assert got.shape == (p,)
    want = ref.tolfl_combine_reference(gs, ns)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    if k == 1:
        np.testing.assert_allclose(np.asarray(got), np.asarray(gs[0]),
                                   rtol=2e-5, atol=2e-5)


def test_tolfl_combine_all_zero_counts():
    """All clusters dead (every sample count zero): the combine must be
    an exact zero update, not NaN — the failure-masking path."""
    rng = np.random.default_rng(3)
    gs = jnp.asarray(rng.standard_normal((4, 50)).astype(np.float32))
    ns = jnp.zeros((4,), jnp.float32)
    got = np.asarray(tolfl_combine(gs, ns, block=16, interpret=True))
    assert np.all(np.isfinite(got))
    np.testing.assert_array_equal(got, np.zeros(50, np.float32))
    want = np.asarray(ref.tolfl_combine_reference(gs, ns))
    np.testing.assert_array_equal(got, want)


def test_tolfl_combine_partial_zero_counts():
    """Dead clusters are absorbed as no-ops; survivors renormalise."""
    rng = np.random.default_rng(4)
    gs = rng.standard_normal((5, 33)).astype(np.float32)
    ns = np.array([0.0, 2.0, 0.0, 3.0, 0.0], np.float32)
    got = tolfl_combine(jnp.asarray(gs), jnp.asarray(ns), block=8,
                        interpret=True)
    want = (ns[1] * gs[1] + ns[3] * gs[3]) / (ns[1] + ns[3])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5,
                               atol=2e-5)


def test_tolfl_combine_tree():
    rng = np.random.default_rng(2)
    tree = {"w": jnp.asarray(rng.standard_normal((4, 8, 8)).astype(np.float32)),
            "b": jnp.asarray(rng.standard_normal((4, 8)).astype(np.float32))}
    ns = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    got = tolfl_combine_tree(tree, ns, interpret=True)
    for key in ("w", "b"):
        flat = np.asarray(tree[key]).reshape(4, -1)
        want = (np.asarray(ns) / 10.0) @ flat
        np.testing.assert_allclose(np.asarray(got[key]).ravel(), want.ravel(),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ops.py dispatch layer
# ---------------------------------------------------------------------------
def test_ops_attention_backends_agree():
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = rand(ks[0], (1, 128, 4, 32))
    k = rand(ks[1], (1, 128, 2, 32))
    v = rand(ks[2], (1, 128, 2, 32))
    a = ops.attention(q, k, v, backend="pallas")
    b = ops.attention(q, k, v, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


def test_ops_rglru_backends_agree():
    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    a_t = jax.nn.sigmoid(rand(ks[0], (1, 64, 16)))
    b_t = rand(ks[1], (1, 64, 16))
    h0 = rand(ks[2], (1, 16))
    x = ops.rglru(a_t, b_t, h0, backend="pallas")
    y = ops.rglru(a_t, b_t, h0, backend="xla")
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5,
                               atol=1e-5)
