"""AUROC / ROC metric tests (exact rank-statistic vs brute force)."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.training.metrics import auroc, roc_curve


def brute_auroc(scores, labels):
    pos = scores[labels.astype(bool)]
    neg = scores[~labels.astype(bool)]
    wins = (pos[:, None] > neg[None, :]).sum()
    ties = (pos[:, None] == neg[None, :]).sum()
    return (wins + 0.5 * ties) / (len(pos) * len(neg))


def test_perfect_detector():
    scores = np.array([0.1, 0.2, 0.9, 0.8])
    labels = np.array([0, 0, 1, 1])
    assert auroc(scores, labels) == 1.0


def test_inverted_detector():
    scores = np.array([0.9, 0.8, 0.1, 0.2])
    labels = np.array([0, 0, 1, 1])
    assert auroc(scores, labels) == 0.0


def test_random_detector_half():
    scores = np.array([0.5, 0.5, 0.5, 0.5])
    labels = np.array([0, 0, 1, 1])
    assert auroc(scores, labels) == 0.5


def test_degenerate_labels_nan():
    assert np.isnan(auroc(np.array([1.0, 2.0]), np.array([1, 1])))
    assert np.isnan(auroc(np.array([1.0, 2.0]), np.array([0, 0])))


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(4, 60),
    n_pos=st.integers(1, 3),
    ties=st.booleans(),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_auroc_matches_brute_force(n, n_pos, ties, seed):
    rng = np.random.default_rng(seed)
    scores = rng.standard_normal(n)
    if ties:  # quantise to force ties
        scores = np.round(scores, 1)
    labels = np.zeros(n, np.int32)
    labels[rng.choice(n, min(n_pos, n - 1), replace=False)] = 1
    got = auroc(scores, labels)
    want = brute_auroc(scores, labels)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_roc_curve_endpoints():
    rng = np.random.default_rng(0)
    scores = rng.standard_normal(100)
    labels = (rng.random(100) < 0.3).astype(np.int32)
    fpr, tpr = roc_curve(scores, labels)
    assert fpr.min() >= 0 and fpr.max() <= 1
    assert tpr.min() >= 0 and tpr.max() <= 1
    # the lowest threshold admits everything
    assert fpr[0] == 1.0 and tpr[0] == 1.0
