"""AUROC / ROC metric tests (exact rank-statistic vs brute force)."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.training.metrics import auroc, auroc_batch, roc_curve


def brute_auroc(scores, labels):
    pos = scores[labels.astype(bool)]
    neg = scores[~labels.astype(bool)]
    wins = (pos[:, None] > neg[None, :]).sum()
    ties = (pos[:, None] == neg[None, :]).sum()
    return (wins + 0.5 * ties) / (len(pos) * len(neg))


def test_perfect_detector():
    scores = np.array([0.1, 0.2, 0.9, 0.8])
    labels = np.array([0, 0, 1, 1])
    assert auroc(scores, labels) == 1.0


def test_inverted_detector():
    scores = np.array([0.9, 0.8, 0.1, 0.2])
    labels = np.array([0, 0, 1, 1])
    assert auroc(scores, labels) == 0.0


def test_random_detector_half():
    scores = np.array([0.5, 0.5, 0.5, 0.5])
    labels = np.array([0, 0, 1, 1])
    assert auroc(scores, labels) == 0.5


def test_degenerate_labels_nan():
    assert np.isnan(auroc(np.array([1.0, 2.0]), np.array([1, 1])))
    assert np.isnan(auroc(np.array([1.0, 2.0]), np.array([0, 0])))


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(4, 60),
    n_pos=st.integers(1, 3),
    ties=st.booleans(),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_auroc_matches_brute_force(n, n_pos, ties, seed):
    rng = np.random.default_rng(seed)
    scores = rng.standard_normal(n)
    if ties:  # quantise to force ties
        scores = np.round(scores, 1)
    labels = np.zeros(n, np.int32)
    labels[rng.choice(n, min(n_pos, n - 1), replace=False)] = 1
    got = auroc(scores, labels)
    want = brute_auroc(scores, labels)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# batched AUROC (campaign post-processing hot path)
# ---------------------------------------------------------------------------
def test_auroc_batch_known_values():
    """Every row must equal the scalar rank statistic, including rows
    with tied scores (average ranks) and tied positive/negative pairs."""
    labels = np.array([0, 0, 1, 1])
    rows = np.array([
        [0.1, 0.2, 0.9, 0.8],     # perfect -> 1.0
        [0.9, 0.8, 0.1, 0.2],     # inverted -> 0.0
        [0.5, 0.5, 0.5, 0.5],     # all tied -> 0.5
        [0.3, 0.7, 0.7, 0.9],     # pos/neg tie -> one half-win
    ])
    got = auroc_batch(rows, labels)
    np.testing.assert_allclose(got, [1.0, 0.0, 0.5, 0.875],
                               rtol=0, atol=1e-12)
    for b in range(rows.shape[0]):
        np.testing.assert_allclose(got[b], auroc(rows[b], labels),
                                   rtol=1e-12, atol=1e-12)


def test_auroc_batch_degenerate_labels_nan():
    assert np.isnan(auroc_batch(np.ones((3, 4)), np.ones(4))).all()
    assert np.isnan(auroc_batch(np.ones((3, 4)), np.zeros(4))).all()


@settings(max_examples=40, deadline=None)
@given(n=st.integers(4, 60), b=st.integers(1, 6), ties=st.booleans(),
       seed=st.integers(0, 2 ** 31 - 1))
def test_auroc_batch_matches_scalar(n, b, ties, seed):
    rng = np.random.default_rng(seed)
    scores = rng.standard_normal((b, n))
    if ties:  # quantise to force ties within and across rows
        scores = np.round(scores, 1)
    labels = np.zeros(n, np.int32)
    labels[rng.choice(n, rng.integers(1, n), replace=False)] = 1
    if labels.sum() == n:
        labels[0] = 0
    got = auroc_batch(scores, labels)
    want = np.array([auroc(scores[i], labels) for i in range(b)])
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_roc_curve_endpoints():
    rng = np.random.default_rng(0)
    scores = rng.standard_normal(100)
    labels = (rng.random(100) < 0.3).astype(np.int32)
    fpr, tpr = roc_curve(scores, labels)
    assert fpr.min() >= 0 and fpr.max() <= 1
    assert tpr.min() >= 0 and tpr.max() <= 1
    # the lowest threshold admits everything
    assert fpr[0] == 1.0 and tpr[0] == 1.0
    # ... and the +inf sentinel admits nothing: the curve must span all
    # the way to (0, 0), else trapezoid areas under it are biased
    assert fpr[-1] == 0.0 and tpr[-1] == 0.0


def test_roc_curve_trapezoid_area_matches_auroc():
    """On tie-free data with a dense enough threshold grid the curve is
    the full staircase, so its trapezoid area IS the AUROC."""
    rng = np.random.default_rng(1)
    scores = rng.permutation(30).astype(np.float64)   # distinct scores
    labels = np.zeros(30, np.int32)
    labels[rng.choice(30, 9, replace=False)] = 1
    fpr, tpr = roc_curve(scores, labels, points=600)
    area = np.trapezoid(tpr[::-1], fpr[::-1])
    np.testing.assert_allclose(area, auroc(scores, labels), atol=1e-12)
