"""Model-component unit tests: blocked attention, RoPE, xent, MoE routing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import attention as A
from repro.models import params as P
from repro.models.moe import _capacity, _dispatch_mask, moe_apply, moe_init
from repro.models.transformer import (divisor_block, padded_vocab,
                                      sinusoidal_positions, xent_loss)


# ---------------------------------------------------------------------------
# blocked attention vs naive
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 16), (True, 100)])
def test_blocked_attention_matches_naive(causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 96, 4, 16))
    k = jax.random.normal(ks[1], (2, 96, 2, 16))
    v = jax.random.normal(ks[2], (2, 96, 2, 16))
    got = A.blocked_attention(q, k, v, causal=causal, window=window,
                              q_block=32, kv_block=32)
    want = A.naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_blocked_attention_non_divisible_seq():
    """divisor_block: odd seq lengths (whisper 1500) must still tile."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 60, 2, 8))
    k = jax.random.normal(ks[1], (1, 60, 2, 8))
    v = jax.random.normal(ks[2], (1, 60, 2, 8))
    got = A.blocked_attention(q, k, v, causal=False, q_block=512,
                              kv_block=512)
    want = A.naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_divisor_block():
    assert divisor_block(1500, 512) == 500
    assert divisor_block(4096, 512) == 512
    assert divisor_block(7, 512) == 7
    assert divisor_block(1, 4) == 1
    for S, t in [(1500, 512), (96, 32), (13, 8)]:
        b = divisor_block(S, t)
        assert S % b == 0 and b <= max(t, 1)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 2),
    s_mult=st.integers(1, 6),
    kvh=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    qb=st.sampled_from([16, 32, 512]),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_blocked_attention_property(b, s_mult, kvh, g, d, causal, qb, seed):
    """Random (shape x GQA x mask x block) sweep against the naive oracle."""
    S = 16 * s_mult
    H = kvh * g
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, S, H, d))
    k = jax.random.normal(ks[1], (b, S, kvh, d))
    v = jax.random.normal(ks[2], (b, S, kvh, d))
    got = A.blocked_attention(q, k, v, causal=causal, q_block=qb,
                              kv_block=qb)
    want = A.naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, 16))
    cos, sin = A.rope_freqs(16, 1e4, jnp.arange(8))
    y = A.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)


def test_rope_relative_position_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    D = 32
    q = jax.random.normal(jax.random.PRNGKey(3), (D,))
    k = jax.random.normal(jax.random.PRNGKey(4), (D,))

    def dot_at(m, n):
        cm, sm = A.rope_freqs(D, 1e4, jnp.asarray([m]))
        cn, sn = A.rope_freqs(D, 1e4, jnp.asarray([n]))
        qr = A.apply_rope(q[None, None, None, :], cm, sm)
        kr = A.apply_rope(k[None, None, None, :], cn, sn)
        return float(jnp.sum(qr * kr))

    np.testing.assert_allclose(dot_at(5, 3), dot_at(12, 10), rtol=1e-4)
    np.testing.assert_allclose(dot_at(7, 7), dot_at(0, 0), rtol=1e-4)


def test_rope_position_zero_identity():
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 1, 1, 16))
    cos, sin = A.rope_freqs(16, 1e4, jnp.zeros((1,)))
    y = A.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)


# ---------------------------------------------------------------------------
# decode attention vs full recompute
# ---------------------------------------------------------------------------
def test_decode_attention_matches_full():
    """One-token decode against a cache == last row of full attention."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    B, S, H, KVH, D = 1, 17, 4, 2, 16
    q_full = jax.random.normal(ks[0], (B, S, H, D))
    k_full = jax.random.normal(ks[1], (B, S, KVH, D))
    v_full = jax.random.normal(ks[2], (B, S, KVH, D))
    want = A.naive_attention(q_full, k_full, v_full, causal=True)[:, -1:]
    got = A.decode_attention(q_full[:, -1:], k_full[:, :-1], v_full[:, :-1],
                             k_full[:, -1:], v_full[:, -1:])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# xent loss
# ---------------------------------------------------------------------------
def naive_xent(params, cfg, h, labels):
    w = params["embed"]["table"].astype(jnp.float32)
    logits = h.astype(jnp.float32) @ w.T
    logits = logits.at[..., cfg.vocab_size:].set(-1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    return jnp.mean(lse - gold)


def test_xent_matches_naive():
    cfg = ModelConfig(vocab_size=300, d_model=32)
    Vp = padded_vocab(cfg)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    params = {"embed": {"table": jax.random.normal(ks[0], (Vp, 32)) * 0.1}}
    h = jax.random.normal(ks[1], (2, 24, 32))
    labels = jax.random.randint(ks[2], (2, 24), 0, 300)
    got = xent_loss(params, cfg, h, labels, chunk=8)
    want = naive_xent(params, cfg, h, labels)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_xent_mask_excludes_positions():
    cfg = ModelConfig(vocab_size=100, d_model=16)
    Vp = padded_vocab(cfg)
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    params = {"embed": {"table": jax.random.normal(ks[0], (Vp, 16)) * 0.1}}
    h = jax.random.normal(ks[1], (1, 16, 16))
    labels = jax.random.randint(ks[2], (1, 16), 0, 100)
    mask = jnp.zeros((1, 16)).at[:, :8].set(1.0)
    got = xent_loss(params, cfg, h, labels, mask=mask, chunk=4)
    want = naive_xent(params, cfg, h[:, :8], labels[:, :8])
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_xent_never_targets_padded_vocab():
    """Loss must be computed over the true vocab only: a model that puts
    all mass on padded ids must score badly, not well."""
    cfg = ModelConfig(vocab_size=100, d_model=16)
    Vp = padded_vocab(cfg)
    table = jnp.zeros((Vp, 16)).at[cfg.vocab_size:, :].set(10.0)
    params = {"embed": {"table": table}}
    h = jnp.ones((1, 4, 16))
    labels = jnp.zeros((1, 4), jnp.int32)
    lv = float(xent_loss(params, cfg, h, labels, chunk=4))
    assert lv > 1.0     # ~log(100): padded ids are masked out of the lse


# ---------------------------------------------------------------------------
# MoE routing
# ---------------------------------------------------------------------------
def test_dispatch_top1_selects_argmax():
    cfg = MoEConfig(num_experts=4, num_experts_per_tok=1,
                    capacity_factor=4.0)
    logits = jnp.asarray([[[0.1, 3.0, -1.0, 0.0],
                           [2.0, 0.0, 0.0, 0.0]]])     # (1,2,4)
    C = _capacity(2, cfg)
    dispatch, combine, probs = _dispatch_mask(logits, cfg, C)
    # token 0 -> expert 1, token 1 -> expert 0
    assert float(dispatch[0, 0, 1].sum()) == 1.0
    assert float(dispatch[0, 1, 0].sum()) == 1.0
    # combine weights = softmax gate of the chosen expert
    p = jax.nn.softmax(logits, -1)
    np.testing.assert_allclose(float(combine[0, 0, 1].sum()),
                               float(p[0, 0, 1]), rtol=1e-5)


def test_dispatch_capacity_drops_overflow():
    """All tokens want expert 0; only `capacity` survive."""
    cfg = MoEConfig(num_experts=2, num_experts_per_tok=1,
                    capacity_factor=0.5)
    T = 8
    logits = jnp.zeros((1, T, 2)).at[..., 0].set(5.0)
    C = _capacity(T, cfg)                         # = 2
    dispatch, _, _ = _dispatch_mask(logits, cfg, C)
    assert float(dispatch[..., 0, :].sum()) == C
    assert float(dispatch[..., 1, :].sum()) == 0.0


def test_dispatch_topk_distinct_experts():
    cfg = MoEConfig(num_experts=4, num_experts_per_tok=2,
                    capacity_factor=4.0)
    logits = jax.random.normal(jax.random.PRNGKey(9), (1, 6, 4))
    C = _capacity(6, cfg)
    dispatch, _, _ = _dispatch_mask(logits, cfg, C)
    per_token = np.asarray(dispatch.sum((-1, -2)))   # assignments per token
    assert np.all(per_token <= 2.0 + 1e-6)
    # each token's two routes hit different experts
    per_tok_exp = np.asarray(dispatch.sum(-1))       # (1, T, E)
    assert np.all(per_tok_exp <= 1.0 + 1e-6)


def test_moe_apply_shapes_and_aux():
    cfg = MoEConfig(num_experts=4, num_experts_per_tok=1,
                    capacity_factor=2.0)
    p, _ = moe_init(jax.random.PRNGKey(10), 16, 32, cfg, True, "float32")
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 8, 16))
    out, aux = moe_apply(p, x, cfg, "silu", True, chunk=8)
    assert out.shape == x.shape
    assert np.isfinite(float(aux["lb_loss"]))
    assert np.isfinite(float(aux["z_loss"]))
    assert float(aux["lb_loss"]) >= 1.0 - 1e-3   # Switch LB lower bound ~1


def test_moe_balanced_router_lb_near_one():
    """Uniform router => load-balance loss at its minimum (== 1)."""
    cfg = MoEConfig(num_experts=4, num_experts_per_tok=1,
                    capacity_factor=4.0)
    T = 64
    # logits that route tokens uniformly: one-hot rotating
    logits = jnp.eye(4)[jnp.arange(T) % 4][None] * 10.0
    C = _capacity(T, cfg)
    dispatch, combine, probs = _dispatch_mask(logits, cfg, C)
    frac_tokens = jnp.mean(jnp.sum(dispatch, -1), (0, 1))
    frac_probs = jnp.mean(probs, (0, 1))
    lb = 4 * float(jnp.sum(frac_tokens * frac_probs))
    np.testing.assert_allclose(lb, 1.0, rtol=0.05)


# ---------------------------------------------------------------------------
# misc param layers
# ---------------------------------------------------------------------------
def test_rmsnorm_unit_scale():
    p, _ = P.rmsnorm_init(8, "float32")
    x = jax.random.normal(jax.random.PRNGKey(12), (3, 8)) * 5
    y = P.rmsnorm_apply(p, x, 1e-6)
    rms = np.sqrt(np.mean(np.square(np.asarray(y)), -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_sinusoidal_positions_shapes():
    pe = sinusoidal_positions(16, 32)
    assert pe.shape == (16, 32)
    pe_off = sinusoidal_positions(8, 32, offset=8)
    np.testing.assert_allclose(np.asarray(pe[8:]), np.asarray(pe_off),
                               rtol=1e-5, atol=1e-6)
