"""Optimizer / schedule / clipping tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.optim.optimizers import (adam, apply_updates, clip_by_global_norm,
                                    make_optimizer, make_schedule, sgd)


def quad_grad(params):
    return jax.tree.map(lambda p: 2 * p, params)   # grad of ||p||^2


def test_sgd_descends_quadratic():
    cfg = OptimizerConfig(name="sgd", lr=0.1, schedule="constant",
                          warmup_steps=0, grad_clip=0.0)
    opt = sgd(cfg)
    params = {"w": jnp.asarray([1.0, -2.0])}
    state = opt.init(params)
    for _ in range(50):
        upd, state = opt.update(quad_grad(params), state, params)
        params = apply_updates(params, upd)
    assert float(jnp.linalg.norm(params["w"])) < 1e-3


def test_adam_descends_quadratic():
    cfg = OptimizerConfig(name="adam", lr=0.05, schedule="constant",
                          warmup_steps=0, grad_clip=0.0)
    opt = adam(cfg)
    params = {"w": jnp.asarray([3.0, -1.0])}
    state = opt.init(params)
    for _ in range(200):
        upd, state = opt.update(quad_grad(params), state, params)
        params = apply_updates(params, upd)
    assert float(jnp.linalg.norm(params["w"])) < 1e-2


def test_adam_first_step_is_lr_sized():
    """Bias correction: |first update| == lr regardless of grad scale."""
    cfg = OptimizerConfig(name="adam", lr=0.01, schedule="constant",
                          warmup_steps=0, grad_clip=0.0)
    opt = adam(cfg)
    params = {"w": jnp.asarray([1.0])}
    state = opt.init(params)
    upd, _ = opt.update({"w": jnp.asarray([1234.5])}, state, params)
    np.testing.assert_allclose(abs(float(upd["w"][0])), 0.01, rtol=1e-3)


def test_adamw_weight_decay():
    cfg = OptimizerConfig(name="adamw", lr=0.1, weight_decay=0.5,
                          schedule="constant", warmup_steps=0, grad_clip=0.0)
    opt = make_optimizer(cfg)
    params = {"w": jnp.asarray([10.0])}
    state = opt.init(params)
    upd_wd, _ = opt.update({"w": jnp.asarray([0.0])}, state, params)
    # zero grad, pure decay: update = -lr * wd * p = -0.5
    np.testing.assert_allclose(float(upd_wd["w"][0]), -0.5, rtol=1e-5)


def test_adam_state_dtype_override():
    cfg = OptimizerConfig(name="adam")
    opt = adam(cfg, state_dtype="bfloat16")
    state = opt.init({"w": jnp.zeros(4, jnp.float32)})
    assert state.mu["w"].dtype == jnp.bfloat16
    assert state.nu["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    total = np.sqrt(float(clipped["a"][0]) ** 2 + float(clipped["b"][0]) ** 2)
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)
    # under the limit: untouched
    small, _ = clip_by_global_norm(grads, 100.0)
    np.testing.assert_allclose(float(small["a"][0]), 3.0, rtol=1e-6)


def test_schedule_warmup_and_cosine():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          schedule="cosine")
    s = make_schedule(cfg)
    assert float(s(0)) < float(s(5)) < float(s(9))
    np.testing.assert_allclose(float(s(9)), 1.0, rtol=1e-5)
    # cosine end ~ 0
    assert float(s(109)) < 0.01
    # monotone decay after warmup
    assert float(s(20)) > float(s(60)) > float(s(100))


def test_schedule_linear_and_constant():
    lin = make_schedule(OptimizerConfig(lr=2.0, warmup_steps=0,
                                        total_steps=100, schedule="linear"))
    np.testing.assert_allclose(float(lin(50)), 1.0, rtol=0.05)
    const = make_schedule(OptimizerConfig(lr=2.0, warmup_steps=1,
                                          schedule="constant"))
    np.testing.assert_allclose(float(const(1000)), 2.0, rtol=1e-6)


def test_make_optimizer_rejects_unknown():
    with pytest.raises(ValueError):
        make_optimizer(OptimizerConfig(name="lion"))
