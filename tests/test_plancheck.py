"""plancheck: one planted violation per rule, plus repo-clean runs.

The battery seeds exactly the defect each rule exists to catch —
a weak-typed scalar leak, a dataset captured by value, a host callback
in a core, an oversized jaxpr, a knob missing from ``_exe_key``, a
reused PRNG key, a stray ``jax.jit`` — and asserts the analyzer flags
it with the RIGHT rule id (and nothing else).  The repo itself must
come out clean: the AST pass over ``src/repro``, the cache-key
contract, and the jaxpr pass over a real ``ExecutionPlan`` covering
every executable kind.
"""
import dataclasses
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import plancheck
from repro.analysis.plancheck import budgets as pc_budgets
from repro.analysis.plancheck import cachekey as pc_cachekey
from repro.analysis.plancheck.findings import (apply_inline, finding,
                                               inline_suppressions)
from repro.api import (CellSpec, DataSpec, ExperimentSpec, SeedSpec,
                       SimConfig, TraceSpec, plan)
from repro.core import campaign, compilecache
from repro.core.experiment import BucketPlan
from repro.core.failure import sample_traces
from repro.serving.anomaly.service import ServiceConfig

SRC_REPRO = os.path.join(os.path.dirname(__file__), os.pardir, "src",
                         "repro")


def _rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# findings / suppression layer
# ---------------------------------------------------------------------------
def test_finding_requires_registered_rule():
    with pytest.raises(AssertionError):
        finding("PC-NOPE", "x.py", 1, "bogus")


def test_inline_suppression_own_line_covers_next_line():
    src = textwrap.dedent("""\
        x = 1
        # plancheck: ignore[PC-AST-JIT]
        y = 2
        z = 3  # plancheck: ignore
    """)
    supp = inline_suppressions(src)
    assert supp[2] == {"PC-AST-JIT"} and supp[3] == {"PC-AST-JIT"}
    assert supp[4] is None                     # bare ignore = all rules
    fs = [finding("PC-AST-JIT", "m.py", 3, "a"),
          finding("PC-AST-NONDET", "m.py", 3, "b"),
          finding("PC-AST-KEYREUSE", "m.py", 4, "c")]
    kept, silenced = apply_inline(fs, src)
    assert _rules(kept) == ["PC-AST-NONDET"]
    assert len(silenced) == 2


def test_baseline_roundtrip_and_reason_required(tmp_path):
    fs = [finding("PC-AST-JIT", "src/x.py", 9, "stray jit",
                  tag="L9:jax.jit")]
    path = tmp_path / "baseline.toml"
    path.write_text(plancheck.format_baseline(fs, reason="legacy"))
    entries = plancheck.load_baseline(str(path))
    assert entries[0]["rule"] == "PC-AST-JIT"
    kept, silenced = plancheck.apply_baseline(fs, entries)
    assert not kept and len(silenced) == 1
    # a suppression without a reason is itself an error
    path.write_text('[[suppress]]\nrule = "PC-AST-JIT"\n')
    with pytest.raises(ValueError, match="reason"):
        plancheck.load_baseline(str(path))
    assert plancheck.load_baseline(str(tmp_path / "missing.toml")) == []


# ---------------------------------------------------------------------------
# pass 1: jaxpr analysis (one planted violation per rule)
# ---------------------------------------------------------------------------
def test_jaxpr_flags_retrace_hazard():
    # a Python float operand traces as a WEAK-typed aval: the jit cache
    # key forks per spelling of the same value
    closed = jax.make_jaxpr(lambda x: x * 2)(3.0)
    fs = plancheck.check_jaxpr(closed, "fixture")
    assert _rules(fs) == ["PC-JAX-RETRACE"]


def test_jaxpr_flags_captured_constant():
    data = jnp.arange(128.0)                   # dataset-sized capture
    closed = jax.make_jaxpr(
        lambda x: jnp.dot(data, x))(jnp.zeros((128,)))
    fs = plancheck.check_jaxpr(closed, "fixture")
    assert _rules(fs) == ["PC-JAX-CONST"]


def test_jaxpr_flags_host_sync():
    def core(x):
        jax.debug.print("x={x}", x=x)
        return x + 1
    closed = jax.make_jaxpr(core)(jnp.zeros((4,)))
    fs = plancheck.check_jaxpr(closed, "fixture")
    assert _rules(fs) == ["PC-JAX-SYNC"]


def test_jaxpr_flags_budget_breach():
    def bloated(x):                            # unrolled Python fold:
        for _ in range(40):                    # the exact regression
            x = jnp.where(x > 0, x * 2, x)     # class budgets exist for
        return x
    closed = jax.make_jaxpr(bloated)(jnp.zeros((4,)))
    fs = plancheck.check_jaxpr(closed, "fixture",
                               budget="trace_alive_mask")
    assert "PC-JAX-BUDGET" in _rules(fs)
    assert pc_budgets.check_budget("trace_alive_mask", 10) is None


def test_jaxpr_clean_function_flags_nothing():
    closed = jax.make_jaxpr(
        lambda x, y: jnp.tanh(x) @ y)(jnp.zeros((4, 4)),
                                      jnp.zeros((4, 4)))
    assert plancheck.check_jaxpr(closed, "fixture") == []


def test_jaxpr_counts_recurse_into_scan_bodies():
    def scanned(x):
        def body(c, _):
            return jnp.tanh(c) * 2 + 1, ()
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out
    top = jax.make_jaxpr(scanned)(jnp.zeros((4,)))
    assert pc_budgets.count_jaxpr(top) > len(top.jaxpr.eqns)


# ---------------------------------------------------------------------------
# PC-KEY: executable-cache key completeness
# ---------------------------------------------------------------------------
def test_cache_key_contract_holds_on_repo():
    assert plancheck.check_cache_keys() == []


def test_cache_key_flags_planted_missing_knob():
    fs = plancheck.check_cache_keys(
        extra_execplan_fields=["donate_args"])
    assert _rules(fs) == ["PC-KEY"]
    assert fs[0].tag == "ExecPlan.donate_args"
    fs = plancheck.check_cache_keys(
        extra_bucket_fields=["remat_policy"])
    assert _rules(fs) == ["PC-KEY"]
    assert fs[0].tag == "BucketPlan.remat_policy"


# generated test: one case per live dataclass field — adding a knob to
# any of these dataclasses without classifying it fails HERE with its
# name (ServiceConfig rides along: the serving buckets key on
# (serve_score, model) + avals, so its fields must stay shape-only)
@pytest.mark.parametrize(
    "cls_name,field_name",
    [("ExecPlan", f.name) for f in dataclasses.fields(campaign.ExecPlan)]
    + [("BucketPlan", f.name) for f in dataclasses.fields(BucketPlan)]
    + [("DataSpec", f.name) for f in dataclasses.fields(DataSpec)]
    + [("ServiceConfig", f.name)
       for f in dataclasses.fields(ServiceConfig)])
def test_every_exec_knob_is_keyed_or_allowlisted(cls_name, field_name):
    verdict = pc_cachekey.classify_field(cls_name, field_name)
    assert verdict in ("covered", "allowlisted"), (
        f"{cls_name}.{field_name} is neither mapped to an _exe_key "
        f"component (plancheck.cachekey.FIELD_COVERAGE) nor "
        f"allowlisted with a reason (plancheck.cachekey.ALLOWLIST)")


# ---------------------------------------------------------------------------
# pass 2: AST lint (one planted violation per rule)
# ---------------------------------------------------------------------------
def _lint(src, relpath="training/somefile.py"):
    fs, _ = plancheck.check_source(textwrap.dedent(src), relpath)
    return fs


def test_ast_flags_stray_jit():
    src = """\
        import jax
        fast = jax.jit(lambda x: x + 1)
    """
    fs = _lint(src)
    assert _rules(fs) == ["PC-AST-JIT"]
    # the same source in a blessed builder module is fine
    assert _lint(src, relpath="core/campaign.py") == []


def test_ast_flags_jit_via_from_import_and_alias():
    fs = _lint("""\
        from jax import jit
        import jax as j
        a = jit(lambda x: x)
        b = j.vmap(lambda x: x)
    """)
    assert _rules(fs) == ["PC-AST-JIT", "PC-AST-JIT"]


def test_ast_flags_loop_metric():
    fs = _lint("""\
        from repro.training.metrics import auroc
        def report(scores, ys):
            return [auroc(s, ys) for s in scores]
    """)
    assert _rules(fs) == ["PC-AST-LOOPMETRIC"]
    # one batched call is the fix -- and is clean
    assert _lint("""\
        from repro.training.metrics import auroc_batch
        def report(scores, ys):
            return auroc_batch(scores, ys)
    """) == []


def test_ast_flags_prng_key_reuse():
    fs = _lint("""\
        import jax
        def draws(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b
    """)
    assert _rules(fs) == ["PC-AST-KEYREUSE"]
    assert fs[0].line == 4


def test_ast_key_reuse_allows_split_and_reassignment():
    assert _lint("""\
        import jax
        def draws(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (3,))
            b = jax.random.uniform(k2, (3,))
            key = jax.random.fold_in(key, 7)
            c = jax.random.normal(key, (3,))
            return a + b + c
    """) == []


def test_ast_key_reuse_branches_do_not_cross_flag():
    # each branch consumes the key once: exclusive paths, no reuse
    assert _lint("""\
        import jax
        def draw(key, flag):
            if flag:
                return jax.random.normal(key, (3,))
            else:
                return jax.random.uniform(key, (3,))
    """) == []


def test_ast_flags_nondet_in_nested_function_only():
    src = """\
        import time
        def build_core(cfg):
            def core(x):
                return x * time.time()
            return core
    """
    fs = _lint(src)
    assert _rules(fs) == ["PC-AST-NONDET"]
    # module-level timers (compile stopwatches, CLI mains) are exempt
    assert _lint("""\
        import time
        def bench(fn):
            t0 = time.perf_counter()
            fn()
            return time.perf_counter() - t0
    """) == []


def test_ast_nondet_jax_random_is_not_stdlib_random():
    assert _lint("""\
        from jax import random
        def build(cfg):
            def core(key):
                return random.normal(key, (3,))
            return core
    """) == []


def test_ast_inline_ignore_silences_the_rule():
    fs, silenced = plancheck.check_source(textwrap.dedent("""\
        import jax
        # plancheck: ignore[PC-AST-JIT]
        fast = jax.jit(lambda x: x + 1)
    """), "training/somefile.py")
    assert fs == [] and _rules(silenced) == ["PC-AST-JIT"]


def test_repo_ast_pass_is_clean():
    fs, _ = plancheck.check_repo(SRC_REPRO, rel_prefix="src/repro/")
    assert fs == [], "\n".join(f.describe() for f in fs)


# ---------------------------------------------------------------------------
# pass 1 over a real ExecutionPlan (+ plan(check=True) wiring)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def plancheck_spec(tiny_ae_cfg, tiny_split, tiny_padded):
    dx, counts = tiny_padded
    base = SimConfig(num_devices=10, rounds=2, lr=1e-3, dropout=False)
    tcfg = dataclasses.replace(base, scheme="tolfl", num_clusters=5)
    traces = sample_traces(np.random.default_rng(1), tcfg.topology(),
                           0.5, max_events=6, rounds=2, num_traces=1)
    return ExperimentSpec(
        data=DataSpec(model=tiny_ae_cfg, device_x=dx,
                      device_counts=counts, test_x=tiny_split.test_x,
                      test_y=tiny_split.test_y, name="plancheck"),
        base=base,
        # all three executable kinds: fused single, fl/iso, multi
        cells=(CellSpec("tolfl", 2), CellSpec("fl", 1),
               CellSpec("ifca", 2)),
        traces=TraceSpec(traces=tuple(traces)), seeds=SeedSpec((0,)))


def test_plan_static_report_is_clean_on_real_buckets(plancheck_spec):
    p = plan(plancheck_spec, check=True)
    assert p.report is not None and p.report.clean, p.report.describe()
    assert "static analysis: clean" in p.describe()
    # default plan() stays analysis-free (and its describe unchanged)
    assert plan(plancheck_spec).report is None


def test_bucket_cores_fit_their_budgets(plancheck_spec):
    from repro.core import experiment as _x
    p = plan(plancheck_spec)
    assert {(b.kind, b.fused) for b in p.buckets} == {
        ("single", True), ("multi", True)}
    for b in p.buckets:
        cells = [p.cells[i] for i in b.cell_indices]
        avals = _x._bucket_avals(p.spec.data, b, cells)
        jitted = campaign._executable(
            *_x._bucket_exe_args(p.spec.data, b))
        n = pc_budgets.count_jaxpr(jax.make_jaxpr(jitted)(*avals))
        name = pc_budgets.bucket_budget_name(b.kind, b.fused)
        assert pc_budgets.check_budget(name, n) is None, (name, n)


def test_simulate_cached_core_fits_budget(tiny_ae_cfg, tiny_padded,
                                          tiny_split):
    from repro.core import simulate
    from repro.core.failure import FailureTrace
    device_x, device_counts = tiny_padded
    cfg = SimConfig(num_devices=10, rounds=2, lr=1e-3, dropout=False,
                    scheme="tolfl", num_clusters=5)
    core = simulate._jitted_core(tiny_ae_cfg, cfg, score_history=False)
    dx, counts, valid = simulate._prepare_arrays(cfg, device_x,
                                                 device_counts)
    n = pc_budgets.eqn_count(core, dx, counts, valid,
                             jnp.asarray(tiny_split.test_x),
                             FailureTrace.none(6), jnp.int32(0))
    assert pc_budgets.check_budget("campaign_core_single", n) is None, n


# ---------------------------------------------------------------------------
# zero-recompile windows without subprocesses
# ---------------------------------------------------------------------------
def test_reset_opens_zero_recompile_window(plancheck_spec):
    from repro.api import execute
    p = plan(plancheck_spec)
    execute(p)                                  # warm everything
    compilecache.reset_xla_compile_stats()
    assert compilecache.xla_compile_stats()["misses"] == 0
    before = campaign.TRACE_COUNT
    execute(plan(plancheck_spec))               # warm replay
    stats = compilecache.xla_compile_stats()
    assert stats["misses"] == 0 and stats["requests"] == stats["hits"]
    assert campaign.TRACE_COUNT == before
    assert compilecache.reset_stats is compilecache.reset_xla_compile_stats
