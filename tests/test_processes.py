"""Generative failure processes (``repro.core.processes``).

Covers the fault-injection layer end to end: per-family sampler
invariants (well-formedness, slot-budget degradation, straggler
pairing), deterministic seed derivation (equal specs -> bit-identical
trace grids), the faulty-update engine channel
(``trace_faulty_scale`` + ``FaultySimConfig``/
``FaultyMultiModelConfig``), plan()-level lowering (dedup,
``CellPlan.process_draws``, the per-process-family result axis), the
executable-cache-key class-identity contract, and the
``concat_traces``/zero-event/recovery-at-round-0 edge cases.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.autoencoder_paper import AutoencoderConfig
from repro.core import campaign
from repro.core.baselines import FaultyMultiModelConfig, MultiModelConfig
from repro.core.experiment import (CellSpec, DataSpec, ExperimentSpec,
                                   SeedSpec, TraceSpec, execute, plan)
from repro.core.failure import (KIND_CODES, PAD_EPOCH, FailureTrace,
                                NO_FAILURE, concat_traces,
                                effective_weights, stack_traces,
                                trace_alive_mask, trace_faulty_scale)
from repro.core.processes import (FAMILIES, ClusterCascadeProcess,
                                  FailureProcess, FaultyUpdateProcess,
                                  IidRateProcess, MarkovChurnProcess,
                                  ProcessGrid, StragglerProcess,
                                  _pack_groups, family_process,
                                  process_seed, trace_from_rows)
from repro.core.simulate import FaultySimConfig, SimConfig, run_simulation
from repro.core.topology import Topology
from repro.data import commsml, federated

TOPO = Topology(6, 2)
ROUNDS = 20

ALL_PROCESSES = (IidRateProcess(p=0.5),
                 MarkovChurnProcess(p_fail=0.15, p_recover=0.4),
                 ClusterCascadeProcess(p_head=0.7),
                 StragglerProcess(p=0.6, window=4),
                 FaultyUpdateProcess(p=0.5, scale=-1.0, window=5))


def sample(proc: FailureProcess, seed: int = 0, topo: Topology = TOPO,
           rounds: int = ROUNDS, max_events=None) -> FailureTrace:
    rng = np.random.default_rng(seed)
    return proc.sample(rng, topo, rounds, max_events=max_events)


def rows_of(t: FailureTrace):
    """Real (epoch, device, alive_after, kind) rows of a trace."""
    ep, dev = np.asarray(t.epochs), np.asarray(t.devices)
    alv, knd = np.asarray(t.alive_after), np.asarray(t.kinds)
    real = ep < PAD_EPOCH
    return list(zip(ep[real].tolist(), dev[real].tolist(),
                    alv[real].tolist(), knd[real].tolist()))


def assert_well_formed(t: FailureTrace, topo: Topology, rounds: int):
    """Shared sampler contract: sorted real rows, PAD tail, in-range
    epochs, device ids in [0, N) or the shadow range [N, 2N) for kind-3
    rows, never a recovery before its device's first failure."""
    ep = np.asarray(t.epochs)
    real = ep < PAD_EPOCH
    n_real = int(real.sum())
    assert real[:n_real].all() and not real[n_real:].any()  # PAD tail
    assert (np.diff(ep[:n_real]) >= 0).all()                # sorted
    n = topo.num_devices
    first_seen: dict = {}
    for e, d, a, k in rows_of(t):
        assert 0 <= e < rounds
        if k == KIND_CODES["faulty"]:
            assert n <= d < 2 * n
        else:
            assert 0 <= d < n
            assert k in (KIND_CODES["client"], KIND_CODES["server"])
        if d not in first_seen:
            # a device's FIRST event is never a recovery (dangling)
            assert a == 0.0 or k == KIND_CODES["faulty"], (d, a, k)
            first_seen[d] = a


# ---------------------------------------------------------------------------
# samplers: well-formedness, determinism, slot-budget degradation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("proc", ALL_PROCESSES,
                         ids=[p.family for p in ALL_PROCESSES])
def test_sampler_well_formed_and_deterministic(proc):
    for seed in range(8):
        t = sample(proc, seed)
        assert t.max_events == proc.default_max_events(TOPO)
        assert_well_formed(t, TOPO, ROUNDS)
    a, b = sample(proc, 3), sample(proc, 3)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_markov_tiny_budget_never_dangles_a_recovery():
    proc = MarkovChurnProcess(p_fail=0.5, p_recover=0.9)
    for seed in range(12):
        t = sample(proc, seed, max_events=3)
        assert_well_formed(t, TOPO, ROUNDS)
        # per device, events alternate fail/recover chronologically
        for d in range(TOPO.num_devices):
            states = [a for e, dd, a, k in rows_of(t) if dd == d]
            assert states == [i % 2.0 for i in range(len(states))]


def test_straggler_pairs_are_all_or_nothing():
    proc = StragglerProcess(p=1.0, window=3)
    # budget for 1.5 devices: packing must keep whole pairs only
    t = sample(proc, 0, max_events=3)
    rows = rows_of(t)
    assert len(rows) == 2                     # one whole pair, not 3 rows
    per_dev: dict = {}
    for e, d, a, k in rows:
        per_dev.setdefault(d, []).append((e, a))
    for d, evs in per_dev.items():
        assert len(evs) == 2
        (e0, a0), (e1, a1) = evs
        assert (a0, a1) == (0.0, 1.0) and e1 == e0 + 3


def test_straggler_single_round_is_a_noop():
    t = sample(StragglerProcess(p=1.0, window=5), 0, rounds=1)
    assert rows_of(t) == []


def test_cascade_takes_members_and_staggers_recovery():
    proc = ClusterCascadeProcess(p_head=1.0, q=1.0, recover_prob=1.0,
                                 recovery_lag=3, stagger=1)
    t = sample(proc, 1, rounds=100)
    rows = rows_of(t)
    heads = set(TOPO.heads)
    for c in range(TOPO.num_clusters):
        members = TOPO.clusters[c]
        head = members[0]
        he = [e for e, d, a, k in rows if d == head and a == 0.0]
        assert len(he) == 1 and head in heads
        e = he[0]
        for i, d in enumerate(members[1:]):
            assert (min(e + 1, 99), d, 0.0, KIND_CODES["client"]) in rows
            assert (e + 3 + (i + 1), d, 1.0, KIND_CODES["client"]) in rows
        assert (e + 3, head, 1.0, KIND_CODES["server"]) in rows


def test_iid_process_matches_sample_traces_bitwise():
    from repro.core.failure import sample_traces
    proc = IidRateProcess(p=0.4, recover_prob=0.5)
    t = sample(proc, 7)
    ref = sample_traces(np.random.default_rng(7), TOPO, 0.4,
                        max_events=2 * TOPO.num_devices, rounds=ROUNDS,
                        num_traces=1, recover_prob=0.5)[0]
    for la, lb in zip(jax.tree.leaves(t), jax.tree.leaves(ref)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_pack_groups_prefix_and_pairs_modes():
    g1 = [(0, 1, 0.0, 1), (5, 1, 1.0, 1)]
    g2 = [(2, 2, 0.0, 1), (6, 2, 1.0, 1)]
    assert _pack_groups([g1, g2], 3) == g1 + g2[:1]       # prefix cut
    assert _pack_groups([g1, g2], 3, pairs_only=True) == g1   # whole g2 drop
    assert _pack_groups([g1, g2], 4) == g1 + g2


def test_process_seed_is_stable_and_distinct():
    p = MarkovChurnProcess(p_fail=0.1, p_recover=0.2)
    s = process_seed(0, p, 0)
    assert s == process_seed(0, p, 0)          # sha256, not salted hash
    assert s != process_seed(0, p, 1)
    assert s != process_seed(1, p, 0)
    assert s != process_seed(0, dataclasses.replace(p, p_fail=0.2), 0)


def test_family_process_covers_every_family():
    for fam in FAMILIES:
        assert family_process(fam, 0.3).family == fam
    with pytest.raises(ValueError):
        family_process("nope", 0.3)


# ---------------------------------------------------------------------------
# the faulty-update channel
# ---------------------------------------------------------------------------
def test_trace_faulty_scale_windows_and_inert_alive_mask():
    n = 4
    rows = [(2, n + 1, -1.0, KIND_CODES["faulty"]),
            (5, n + 1, 1.0, KIND_CODES["faulty"]),
            (3, n + 3, 0.0, KIND_CODES["faulty"])]
    t = trace_from_rows(rows, 8)
    for epoch, want in [(0, [1, 1, 1, 1]), (2, [1, -1, 1, 1]),
                        (3, [1, -1, 1, 0]), (5, [1, 1, 1, 0])]:
        got = np.asarray(trace_faulty_scale(t, n, jnp.int32(epoch)))
        assert np.array_equal(got, np.asarray(want, np.float32)), epoch
        # shadow rows never touch the alive mask
        alive = np.asarray(trace_alive_mask(t, n, jnp.int32(epoch)))
        assert np.array_equal(alive, np.ones(n, np.float32))


def test_trace_faulty_scale_graph_size_constant_in_max_events():
    from repro.analysis.plancheck import budgets

    def n_eqns(m):
        trace = FailureTrace.none(m)
        return budgets.eqn_count(
            lambda e: trace_faulty_scale(trace, 16, e), jnp.int32(0))

    assert budgets.constant_across(n_eqns, (4, 8, 64))
    assert budgets.check_budget("trace_faulty_scale", n_eqns(64)) is None


@functools.lru_cache(maxsize=1)
def _tiny_data():
    X, y = commsml.generate(seed=0, samples_per_class=40)
    split = federated.make_split(X, y, num_devices=6, num_clusters=2,
                                 anomaly_classes=[3], seed=0)
    dx, counts = federated.pad_devices(split)
    return dx, counts, split


def test_faulty_config_is_a_noop_without_faulty_events():
    dx, counts, split = _tiny_data()
    base = dict(scheme="tolfl", num_devices=6, num_clusters=2, rounds=3,
                lr=1e-3, dropout=False)
    r_plain = run_simulation(AutoencoderConfig(), dx, counts,
                             split.test_x, split.test_y,
                             SimConfig(**base), NO_FAILURE)
    r_faulty = run_simulation(AutoencoderConfig(), dx, counts,
                              split.test_x, split.test_y,
                              FaultySimConfig(**base), NO_FAILURE)
    assert np.array_equal(r_plain.loss_curve, r_faulty.loss_curve)
    assert r_plain.final_auroc == r_faulty.final_auroc


def test_faulty_scale_zero_freezes_the_global_model():
    dx, counts, split = _tiny_data()
    n = 6
    rows = [(0, n + d, 0.0, KIND_CODES["faulty"]) for d in range(n)]
    trace = trace_from_rows(rows, 2 * n)
    cfg = FaultySimConfig(scheme="tolfl", num_devices=n, num_clusters=2,
                          rounds=4, lr=1e-3, dropout=False)
    r = run_simulation(AutoencoderConfig(), dx, counts, split.test_x,
                       split.test_y, cfg, trace)
    # every transmitted delta is zeroed -> params never move
    assert np.allclose(r.loss_curve, r.loss_curve[0])
    # the same trace under the PLAIN engine is completely inert
    r_plain = run_simulation(AutoencoderConfig(), dx, counts,
                             split.test_x, split.test_y,
                             dataclasses.replace(SimConfig(), **{
                                 f.name: getattr(cfg, f.name)
                                 for f in dataclasses.fields(SimConfig)}),
                             trace)
    r_none = run_simulation(AutoencoderConfig(), dx, counts,
                            split.test_x, split.test_y,
                            SimConfig(scheme="tolfl", num_devices=n,
                                      num_clusters=2, rounds=4, lr=1e-3,
                                      dropout=False), NO_FAILURE)
    assert np.array_equal(r_plain.loss_curve, r_none.loss_curve)
    assert not np.allclose(r_none.loss_curve, r_none.loss_curve[0])


def test_exe_key_distinguishes_faulty_configs_only_by_class():
    base = dict(scheme="tolfl", num_devices=6, num_clusters=2, rounds=3,
                lr=1e-3, dropout=False)
    plain, faulty = SimConfig(**base), FaultySimConfig(**base)
    model = AutoencoderConfig()
    k_plain = campaign._exe_key("single", model, plain, None, None,
                                False, False)
    k_faulty = campaign._exe_key("single", model, faulty, None, None,
                                 False, False)
    assert k_plain != k_faulty
    # process-free keys/fingerprints stay bit-identical: the plain
    # class never grew a field
    assert "faulty" not in repr(plain)
    assert {f.name for f in dataclasses.fields(SimConfig)} == {
        "scheme", "num_devices", "num_clusters", "rounds", "lr",
        "local_epochs", "combine", "dropout", "seed"}
    assert {f.name for f in dataclasses.fields(MultiModelConfig)} == {
        "scheme", "num_devices", "num_models", "rounds", "lr",
        "dropout", "seed"}
    # replace() keeps the subclass -> bucket grouping separates faulty
    assert isinstance(dataclasses.replace(faulty, seed=1),
                      FaultySimConfig)
    assert isinstance(
        dataclasses.replace(FaultyMultiModelConfig(), num_models=2),
        FaultyMultiModelConfig)


# ---------------------------------------------------------------------------
# straggler semantics (satellite: Fig 4 reporting must be unaffected)
# ---------------------------------------------------------------------------
def test_straggler_reenters_with_pre_failure_weight():
    topo = Topology(6, 2)
    d, w = 4, 3                               # a non-head member
    t = trace_from_rows([(2, d, 0.0, KIND_CODES["client"]),
                         (2 + w, d, 1.0, KIND_CODES["client"])], 12)
    before = effective_weights(
        trace_alive_mask(t, 6, jnp.int32(1)), topo)
    during = effective_weights(
        trace_alive_mask(t, 6, jnp.int32(3)), topo)
    after = effective_weights(
        trace_alive_mask(t, 6, jnp.int32(2 + w)), topo)
    assert np.array_equal(np.asarray(before), np.ones(6, np.float32))
    want_during = np.ones(6, np.float32)
    want_during[d] = 0.0
    assert np.array_equal(np.asarray(during), want_during)
    # re-entry: aggregation weight is bit-identical to pre-failure
    assert np.array_equal(np.asarray(after), np.asarray(before))


def test_straggling_client_never_trips_fl_iso_reporting():
    dx, counts, split = _tiny_data()
    cfg = SimConfig(scheme="fl", num_devices=6, num_clusters=1,
                    rounds=6, lr=1e-3, dropout=False)
    t = trace_from_rows([(1, 3, 0.0, KIND_CODES["client"]),
                         (4, 3, 1.0, KIND_CODES["client"])], 8)
    res = campaign.run_campaign(AutoencoderConfig(), dx, counts,
                                split.test_x, split.test_y, cfg,
                                [t, NO_FAILURE], seeds=[0])
    # the server (device 0) never died: no scenario engages the
    # isolated-mean fallback and the Fig 4 switch stays off
    assert not res.iso_active.any()
    assert np.isfinite(res.auroc_used).all()
    # the straggler scenario reports the GLOBAL model, same as none
    assert np.array_equal(res.auroc_used, res.final_auroc)


def test_straggler_process_runs_under_fl_without_iso():
    dx, counts, split = _tiny_data()
    spec = ExperimentSpec(
        data=DataSpec(model=AutoencoderConfig(), device_x=dx,
                      device_counts=counts, test_x=split.test_x,
                      test_y=split.test_y, name="straggler-fl"),
        base=SimConfig(num_devices=6, rounds=6, lr=1e-3, dropout=False),
        cells=(CellSpec("fl", 1),),
        traces=TraceSpec.generated(
            ProcessGrid(StragglerProcess(p=1.0, window=2), 3)),
        seeds=SeedSpec((0,)))
    res = execute(plan(spec))
    (r,) = res.results
    # a straggled fl SERVER recovers within the run: Fig 4 semantics
    # may engage the fallback mid-run but every AUROC stays finite and
    # the per-process axis reports all draws
    assert np.isfinite(r.auroc_used).all()
    assert len(res.per_process()[("fl", 1)][0]) == 3


# ---------------------------------------------------------------------------
# plan() lowering + the per-process result axis
# ---------------------------------------------------------------------------
def _process_spec(processes, cells=(("tolfl", 2), ("fl", 1)),
                  rounds=3, seeds=(0,), explicit=()):
    dx, counts, split = _tiny_data()
    return ExperimentSpec(
        data=DataSpec(model=AutoencoderConfig(), device_x=dx,
                      device_counts=counts, test_x=split.test_x,
                      test_y=split.test_y, name="proc-test"),
        base=SimConfig(num_devices=6, rounds=rounds, lr=1e-3,
                       dropout=False),
        cells=tuple(CellSpec(s, k) for s, k in cells),
        traces=TraceSpec(traces=tuple(explicit), processes=processes),
        seeds=SeedSpec(tuple(seeds)))


def test_plan_lowers_processes_deterministically():
    spec = _process_spec((ProcessGrid(MarkovChurnProcess(0.2, 0.5), 3),
                          ProcessGrid(StragglerProcess(0.8, 2), 3)))
    p1, p2 = plan(spec), plan(spec)
    for c1, c2 in zip(p1.cells, p2.cells):
        assert c1.process_draws == c2.process_draws
        assert len(c1.traces) == len(c2.traces)
        for t1, t2 in zip(c1.traces, c2.traces):
            for l1, l2 in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
                assert np.array_equal(np.asarray(l1), np.asarray(l2))
        for idxs in c1.process_draws.values():
            assert len(idxs) == 3


def test_plan_dedups_process_draws_against_base_traces():
    # p=0 draws only all-none traces: every draw must alias the single
    # no-failure base trace instead of multiplying scenarios
    spec = _process_spec((ProcessGrid(StragglerProcess(p=0.0), 4),),
                         cells=(("tolfl", 2),), explicit=(NO_FAILURE,))
    (cell,) = plan(spec).cells
    assert len(cell.traces) == 1
    assert cell.process_draws == {0: [0, 0, 0, 0]}
    assert cell.explicit_index == {0: 0}


def test_plan_swaps_faulty_engine_only_when_needed():
    plain = plan(_process_spec((ProcessGrid(MarkovChurnProcess(), 2),)))
    assert all(type(c.cfg) is SimConfig for c in plain.cells)
    faulty = plan(_process_spec(
        (ProcessGrid(MarkovChurnProcess(), 2),
         ProcessGrid(FaultyUpdateProcess(p=0.5), 2)),
        cells=(("tolfl", 2), ("ifca", 2))))
    assert type(faulty.cells[0].cfg) is FaultySimConfig
    assert type(faulty.cells[1].cfg) is FaultyMultiModelConfig


def test_process_free_specs_lower_exactly_as_before():
    spec = _process_spec((), explicit=(NO_FAILURE,))
    (c0, c1) = plan(spec).cells
    for c in (c0, c1):
        assert type(c.cfg) is SimConfig
        assert c.process_draws == {}
        assert list(c.traces) == [NO_FAILURE]   # verbatim pass-through


def test_end_to_end_families_execute_and_replay_without_retrace():
    spec = _process_spec(
        tuple(ProcessGrid(p, 2) for p in ALL_PROCESSES),
        cells=(("tolfl", 2), ("ifca", 2)))
    p = plan(spec, check=True)
    assert p.static_report().clean, p.describe()
    res = execute(p)
    per = res.per_process()
    for key in (("tolfl", 2), ("ifca", 2)):
        assert set(per[key]) == set(range(len(ALL_PROCESSES)))
        for gi, proc in enumerate(ALL_PROCESSES):
            assert per[key][gi].shape == (2,)
            assert np.isfinite(per[key][gi]).all()
        fam_keys = set(res.process_summary()[key])
        assert fam_keys == {f"{p_.family}[{i}]"
                            for i, p_ in enumerate(ALL_PROCESSES)}
    # summary() grows the per-family axis without losing the base keys
    s = res.summary()[("tolfl", 2)]
    assert "auroc_used_mean" in s and "E[auroc] iid[0]" in s
    # warm replay: identical process seeds -> identical traces ->
    # identical executables, zero retraces
    before = campaign.TRACE_COUNT
    execute(plan(spec))
    assert campaign.TRACE_COUNT == before


# ---------------------------------------------------------------------------
# edge-case hardening (satellite: concat/zero-event/recovery-at-0)
# ---------------------------------------------------------------------------
def test_concat_and_stack_reject_empty_lists():
    with pytest.raises(ValueError, match="empty"):
        concat_traces([])
    with pytest.raises(ValueError, match="empty"):
        stack_traces([])


def test_zero_event_trace_round_trips():
    t = FailureTrace.none(4)
    assert rows_of(t) == []
    alive = trace_alive_mask(t, 6, jnp.int32(0))
    assert np.array_equal(np.asarray(alive), np.ones(6, np.float32))
    batch = stack_traces([t, t])
    back = concat_traces([batch, batch])
    assert back.epochs.shape == (4, 4)
    scale = trace_faulty_scale(t, 6, jnp.int32(5))
    assert np.array_equal(np.asarray(scale), np.ones(6, np.float32))


def test_recovery_at_round_zero_round_trips():
    # a recovery firing at epoch 0 (no prior failure in the trace) must
    # win over the implicit alive default without shape surprises
    t = trace_from_rows([(0, 2, 1.0, KIND_CODES["client"])], 4)
    for epoch in (0, 1, 7):
        alive = np.asarray(trace_alive_mask(t, 6, jnp.int32(epoch)))
        assert alive.shape == (6,)
        assert np.array_equal(alive, np.ones(6, np.float32))
    # and composed with a same-device failure later, last event wins
    t2 = trace_from_rows([(0, 2, 1.0, KIND_CODES["client"]),
                          (3, 2, 0.0, KIND_CODES["client"])], 4)
    alive = np.asarray(trace_alive_mask(t2, 6, jnp.int32(3)))
    assert alive[2] == 0.0
