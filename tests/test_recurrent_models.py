"""Model-level tests for the recurrent families (RWKV6 / RG-LRU):
prefill-vs-decode state algebra, Pallas-vs-XLA parity at the block level,
and decay/stability properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import rglru as G
from repro.models import rwkv6 as R


@pytest.fixture(scope="module")
def rwkv_setup():
    cfg = ARCHS["rwkv6-7b"].reduced()
    p, _ = R.timemix_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model),
                          jnp.float32) * 0.5
    return cfg, p, x


@pytest.fixture(scope="module")
def rglru_setup():
    cfg = ARCHS["recurrentgemma-9b"].reduced()
    p, _ = G.rglru_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model),
                          jnp.float32) * 0.5
    return cfg, p, x


def test_rwkv_timemix_shapes_finite(rwkv_setup):
    cfg, p, x = rwkv_setup
    y, st = R.timemix_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    H, N = cfg.recurrent.num_heads, cfg.recurrent.head_size
    assert st["wkv"].shape == (2, H, N, N)
    assert st["shift"].shape == (2, cfg.d_model)


def test_rwkv_sequential_state_chaining(rwkv_setup):
    """Processing [:6] then [6:] with carried state == one-shot [0:12]."""
    cfg, p, x = rwkv_setup
    y_full, st_full = R.timemix_apply(p, x, cfg)
    y1, st1 = R.timemix_apply(p, x[:, :6], cfg)
    y2, st2 = R.timemix_apply(p, x[:, 6:], cfg, state=st1)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st2["wkv"]),
                               np.asarray(st_full["wkv"]),
                               rtol=2e-4, atol=2e-4)


def test_rwkv_single_token_decode_matches(rwkv_setup):
    cfg, p, x = rwkv_setup
    y_full, _ = R.timemix_apply(p, x, cfg)
    _, st = R.timemix_apply(p, x[:, :-1], cfg)
    y_last, _ = R.timemix_apply(p, x[:, -1:], cfg, state=st)
    np.testing.assert_allclose(np.asarray(y_last),
                               np.asarray(y_full[:, -1:]),
                               rtol=2e-4, atol=2e-4)


def test_rwkv_pallas_model_path(rwkv_setup):
    cfg, p, x = rwkv_setup
    y_xla, _ = R.timemix_apply(p, x, cfg, use_pallas=False)
    y_pl, _ = R.timemix_apply(p, x, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_xla),
                               rtol=2e-4, atol=2e-4)


def test_rwkv_channelmix_state(rwkv_setup):
    cfg, p_tm, x = rwkv_setup
    p, _ = R.channelmix_init(jax.random.PRNGKey(3), cfg)
    y_full, sh_full = R.channelmix_apply(p, x)
    y1, sh1 = R.channelmix_apply(p, x[:, :6])
    y2, sh2 = R.channelmix_apply(p, x[:, 6:], state=sh1)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sh2), np.asarray(sh_full),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------
def test_rglru_shapes_finite(rglru_setup):
    cfg, p, x = rglru_setup
    y, st = G.rglru_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    W = cfg.recurrent.lru_width or cfg.d_model
    assert st["h"].shape == (2, W)
    assert st["conv"].shape == (2, cfg.recurrent.conv1d_width - 1, W)


def test_rglru_state_chaining(rglru_setup):
    cfg, p, x = rglru_setup
    y_full, st_full = G.rglru_apply(p, x, cfg)
    y1, st1 = G.rglru_apply(p, x[:, :6], cfg)
    y2, st2 = G.rglru_apply(p, x[:, 6:], cfg, state=st1)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st2["h"]),
                               np.asarray(st_full["h"]), rtol=2e-4,
                               atol=2e-4)


def test_rglru_decode_step(rglru_setup):
    cfg, p, x = rglru_setup
    y_full, _ = G.rglru_apply(p, x, cfg)
    _, st = G.rglru_apply(p, x[:, :-1], cfg)
    y_last, _ = G.rglru_decode(p, x[:, -1:], cfg, st)
    np.testing.assert_allclose(np.asarray(y_last),
                               np.asarray(y_full[:, -1:]),
                               rtol=2e-4, atol=2e-4)


def test_rglru_decay_in_unit_interval(rglru_setup):
    """a_t = a^(c·r_t) with a = sigmoid(lam) must stay in (0, 1) — the
    recurrence is contractive (no state blow-up at 500k contexts)."""
    cfg, p, x = rglru_setup
    import jax.nn as nn
    xw = jnp.ones((1, 4, (cfg.recurrent.lru_width or cfg.d_model)))
    r = nn.sigmoid(xw)  # worst-case gate
    log_a = G.C_EXP * r * nn.log_sigmoid(p["lam"].astype(jnp.float32))
    a_t = np.asarray(jnp.exp(log_a))
    assert np.all(a_t > 0) and np.all(a_t < 1)


def test_rglru_long_sequence_stable(rglru_setup):
    """1k-step recurrence stays bounded (contractivity in practice)."""
    cfg, p, _ = rglru_setup
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 1024, cfg.d_model)) * 0.5
    y, st = G.rglru_apply(p, x, cfg)
    assert np.all(np.isfinite(np.asarray(y)))
    assert float(jnp.max(jnp.abs(st["h"]))) < 1e3
