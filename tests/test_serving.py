"""Serving correctness: prefill-vs-decode logits parity per family.

The strongest end-to-end check we can run on CPU: for each architecture
family, the logits for token t computed by (a) prefilling t_0..t_t in one
shot and (b) prefilling t_0..t_{t-1} then running one decode_step must
agree — KV caches, recurrent states, ring buffers, positions and RoPE all
have to line up exactly for this to hold.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import transformer as T
from repro.serving.decode import decode_step, pad_cache, prefill

B, S = 1, 16

# one representative per family (full 10-arch structural coverage lives in
# test_arch_smoke.py; parity is about the cache algebra per family)
FAMILY_REPS = [
    "qwen3-8b",            # dense + qk_norm
    "qwen1.5-0.5b",        # dense + qkv bias
    "llama4-scout-17b-a16e",  # moe
    "rwkv6-7b",            # ssm
    "recurrentgemma-9b",   # hybrid (rec + local attn)
    "whisper-large-v3",    # enc-dec audio
    "internvl2-26b",       # vlm prefix
]


def build(arch):
    cfg = ARCHS[arch].reduced()
    if cfg.moe.num_experts:
        # capacity >= chunk so no token is dropped: prefill (chunked) and
        # decode (token-at-a-time) then route identically — parity is exact.
        # (with real capacity factors GShard drop semantics legitimately
        # differ between the two, which is documented behaviour.)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        F = cfg.encoder_seq or 16
        batch["frames"] = jax.random.normal(ks[1], (B, F, cfg.d_model))
    if cfg.frontend.kind == "vision":
        Pfx = cfg.frontend.frontend_seq or 16
        batch["prefix"] = jax.random.normal(ks[1], (B, Pfx, cfg.d_model))
    return cfg, params, batch


@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_prefill_decode_parity(arch):
    cfg, params, batch = build(arch)
    # (a) one-shot prefill over all S tokens
    logits_full, _ = prefill(params, cfg, batch)

    # (b) prefill S-1 tokens, then decode token S-1
    batch_m1 = dict(batch, tokens=batch["tokens"][:, :-1])
    _, cache = prefill(params, cfg, batch_m1)
    pos = S - 1
    if cfg.frontend.kind == "vision":
        pos = batch["prefix"].shape[1] + S - 1
    logits_step, _ = decode_step(params, cfg, batch["tokens"][:, -1:],
                                 cache, jnp.int32(pos))
    np.testing.assert_allclose(
        np.asarray(logits_step, np.float32),
        np.asarray(logits_full, np.float32), rtol=2e-3, atol=2e-3,
        err_msg=f"prefill/decode divergence for {arch}")


@pytest.mark.parametrize("arch", ["qwen3-8b", "rwkv6-7b",
                                  "recurrentgemma-9b"])
def test_multi_step_decode_parity(arch):
    """Three consecutive decode steps == one-shot prefill of S+3."""
    cfg = ARCHS[arch].reduced()
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg)
    total = S + 3
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, total), 0,
                                cfg.vocab_size)
    want, _ = prefill(params, cfg, {"tokens": tokens})

    _, cache = prefill(params, cfg, {"tokens": tokens[:, :S]})
    cache = pad_cache(cache, cfg, prompt_len=S, target_len=total)
    logits = None
    for t in range(S, total):
        logits, cache = decode_step(params, cfg, tokens[:, t:t + 1], cache,
                                    jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_buffer():
    """Decode past the window: the circular cache must evict the oldest
    position and match a fresh windowed prefill."""
    cfg = ARCHS["recurrentgemma-9b"].reduced()   # local attn window=64
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, sliding_window=8))
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg)
    total = 24                                   # 3x the window
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, total), 0,
                                cfg.vocab_size)
    want, _ = prefill(params, cfg, {"tokens": tokens})

    Sp = 8
    _, cache = prefill(params, cfg, {"tokens": tokens[:, :Sp]})
    logits = None
    for t in range(Sp, total):
        logits, cache = decode_step(params, cfg, tokens[:, t:t + 1], cache,
                                    jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-3, atol=5e-3)
