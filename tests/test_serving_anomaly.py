"""Anomaly scoring service: failover parity, coalescing, warm buckets.

The three service contracts the issue pins:

* **failover parity** — a window served through the failover path is
  BIT-IDENTICAL to scoring the client's isolated model directly (and a
  head-served window to the global model): row selection is a gather,
  never an arithmetic change;
* **order** — the coalescing queue never reorders a client's windows,
  whatever mix of bucket sizes the drain picks;
* **warm buckets** — once the bucket set is compiled, serving performs
  ZERO retraces and ZERO XLA compiles (`compilecache` stats window),
  and a second service over the same bank resolves every bucket from
  memory.

Plus the bank/export invariants (row 0 is the global model, isolated
rows genuinely differ) and the named eqn budget of the score core.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.plancheck import budgets as pc_budgets
from repro.core import compilecache
from repro.core.failure import FailureSpec, FailureTrace
from repro.core.processes import ClusterCascadeProcess
from repro.core.simulate import SimConfig, trained_params
from repro.models.detector import SeqDetector, as_detector
from repro.serving.anomaly import (AnomalyService, ServiceConfig,
                                   train_model_bank)
from repro.serving.anomaly.engine import (_build_score_core,
                                          score_budget_name)

N, K = 10, 5
WINDOW = 4


@pytest.fixture(scope="module")
def tiny_cfg(tiny_ae_cfg):
    return SimConfig(scheme="tolfl", num_devices=N, num_clusters=K,
                     rounds=2, lr=1e-3, dropout=False)


@pytest.fixture(scope="module")
def bank(tiny_ae_cfg, tiny_padded, tiny_cfg):
    dx, counts = tiny_padded
    return train_model_bank(tiny_ae_cfg, dx, counts, tiny_cfg)


@pytest.fixture(scope="module")
def windows(tiny_split):
    """A pool of (WINDOW, D) float32 traffic windows from the test set."""
    tx = np.asarray(tiny_split.test_x, np.float32)
    n = tx.shape[0] // WINDOW
    return tx[:n * WINDOW].reshape(n, WINDOW, tx.shape[-1])


def head_dead_trace(cluster: int, epoch: int = 0,
                    recover: int = None) -> FailureTrace:
    """Kill the head of ``cluster`` at ``epoch`` (optional recovery)."""
    head = cluster * (N // K)
    events = [(epoch, head, 0.0, 2)]
    if recover is not None:
        events.append((recover, head, 1.0, 2))
    from repro.core.processes import trace_from_rows
    return trace_from_rows(events, max_events=4)


# ---------------------------------------------------------------------------
# bank / params export
# ---------------------------------------------------------------------------
def test_bank_rows_stack_global_then_isolated(bank):
    for leaf_r, leaf_g, leaf_i in zip(jax.tree.leaves(bank.row_params),
                                      jax.tree.leaves(bank.global_params),
                                      jax.tree.leaves(bank.iso_params)):
        assert leaf_r.shape[0] == N + 1
        assert leaf_i.shape[0] == N
        np.testing.assert_array_equal(leaf_r[0], leaf_g)
        np.testing.assert_array_equal(leaf_r[1:], leaf_i)


def test_isolated_models_differ_from_global_and_each_other(bank):
    # genuinely-isolated training on different shards must diverge
    l_g = jax.tree.leaves(bank.global_params)[0]
    l_i = jax.tree.leaves(bank.iso_params)[0]
    assert not np.array_equal(np.asarray(l_i[0]), np.asarray(l_g))
    assert not np.array_equal(np.asarray(l_i[0]), np.asarray(l_i[1]))


def test_trained_params_match_training_engine(tiny_ae_cfg, tiny_padded,
                                              tiny_cfg):
    """The params export rides the SAME round loop as the campaign
    cores: scoring the exported global model reproduces the simulator's
    final scores bit-for-bit."""
    from repro.core.simulate import run_simulation
    dx, counts = tiny_padded
    tx = np.zeros((3, dx.shape[-1]), np.float32)
    params, _, _ = trained_params(tiny_ae_cfg, dx, counts, tiny_cfg)
    det = as_detector(tiny_ae_cfg)
    got = np.asarray(det.anomaly_scores(params, jnp.asarray(tx)))
    res = run_simulation(tiny_ae_cfg, dx, counts, tx,
                         np.zeros((3,)), tiny_cfg)
    # run_simulation reports scores over ITS test set; rebuild directly:
    from repro.core import simulate
    core = simulate._jitted_core(tiny_ae_cfg, tiny_cfg, True)
    dxj, cj, vj = simulate._prepare_arrays(tiny_cfg, dx, counts)
    out = core(dxj, cj, vj, jnp.asarray(tx),
               FailureTrace.none(6), jnp.int32(tiny_cfg.seed))
    np.testing.assert_array_equal(got, np.asarray(out.final_scores))
    assert res is not None


# ---------------------------------------------------------------------------
# failover parity (bit-identical routing)
# ---------------------------------------------------------------------------
def test_failover_scores_bit_identical_to_isolated_model(bank, windows):
    svc = AnomalyService(bank, ServiceConfig(bucket_sizes=(1, 8),
                                             window=WINDOW),
                         failure=head_dead_trace(cluster=0))
    client = 1                       # member of cluster 0, head dead
    svc.submit(client, windows[0])
    (res,) = svc.tick()
    assert res.served_by == "isolated"
    det = bank.detector
    direct = np.asarray(det.anomaly_scores(
        bank.client_iso_params(client), jnp.asarray(windows[0])))
    np.testing.assert_array_equal(res.scores, direct)


def test_head_scores_bit_identical_to_global_model(bank, windows):
    svc = AnomalyService(bank, ServiceConfig(bucket_sizes=(1, 8),
                                             window=WINDOW),
                         failure=head_dead_trace(cluster=0))
    client = 7                       # cluster 3, head alive
    svc.submit(client, windows[1])
    (res,) = svc.tick()
    assert res.served_by == "head"
    direct = np.asarray(bank.detector.anomaly_scores(
        bank.global_params, jnp.asarray(windows[1])))
    np.testing.assert_array_equal(res.scores, direct)


def test_failover_then_failback_on_recovery(bank, windows):
    svc = AnomalyService(bank, ServiceConfig(bucket_sizes=(1,),
                                             window=WINDOW),
                         failure=head_dead_trace(cluster=0, epoch=1,
                                                 recover=3))
    client, modes = 0, []
    for _ in range(5):
        svc.submit(client, windows[2])
        (res,) = svc.tick()
        modes.append(res.served_by)
    assert modes == ["head", "isolated", "isolated", "head", "head"]
    rep = svc.report()
    assert (rep.failovers, rep.failbacks) == (1, 1)
    assert svc.timeline == [(1, client, "failover"),
                            (3, client, "failback")]
    assert rep.dropped == 0 and rep.windows == 5


def test_process_driven_service_samples_deterministically(bank, windows):
    proc = ClusterCascadeProcess(p_head=1.0)
    a = AnomalyService(bank, ServiceConfig(bucket_sizes=(8,),
                                           window=WINDOW),
                       failure=proc, sample_seed=7)
    b = AnomalyService(bank, ServiceConfig(bucket_sizes=(8,),
                                           window=WINDOW),
                       failure=proc, sample_seed=7)
    np.testing.assert_array_equal(np.asarray(a._trace.epochs),
                                  np.asarray(b._trace.epochs))


# ---------------------------------------------------------------------------
# queue coalescing
# ---------------------------------------------------------------------------
def test_queue_never_reorders_a_clients_windows(bank, windows):
    svc = AnomalyService(bank, ServiceConfig(bucket_sizes=(1, 8),
                                             window=WINDOW))
    # 21 windows across 3 clients, interleaved: drains as 8+8+8(pad)
    order = [(c, i) for i in range(7) for c in (2, 5, 9)]
    for c, i in order:
        svc.submit(c, windows[i % len(windows)])
    res = svc.tick()
    assert len(res) == 21 and svc.report().dropped == 0
    for c in (2, 5, 9):
        seqs = [r.seq for r in res if r.client == c]
        assert seqs == sorted(seqs) == list(range(7))
    # FIFO across the whole stream, not just per client
    assert [(r.client, r.seq) for r in res] == order


def test_padded_batches_score_identically_to_exact_ones(bank, windows):
    """A window scored in a padded remainder batch equals the same
    window scored alone — padding rows are inert."""
    svc = AnomalyService(bank, ServiceConfig(bucket_sizes=(1, 8),
                                             window=WINDOW))
    svc.submit(3, windows[0])
    svc.submit(4, windows[1])        # n=2 -> bucket 8, 6 padded rows
    padded = {r.client: r.scores for r in svc.tick()}
    alone = AnomalyService(bank, ServiceConfig(bucket_sizes=(1,),
                                               window=WINDOW))
    alone.submit(3, windows[0])
    (solo,) = alone.tick()
    np.testing.assert_array_equal(padded[3], solo.scores)


def test_oversized_load_splits_into_max_buckets(bank, windows):
    svc = AnomalyService(bank, ServiceConfig(bucket_sizes=(1, 8),
                                             window=WINDOW))
    for i in range(19):
        svc.submit(i % N, windows[i % len(windows)])
    res = svc.tick()
    assert len(res) == 19
    rep = svc.report()
    assert rep.bucket_batches == {1: 0, 8: 3}   # 8 + 8 + 3(padded)


# ---------------------------------------------------------------------------
# warm service: zero retraces / zero XLA after the bucket set compiles
# ---------------------------------------------------------------------------
def test_warm_service_opens_zero_recompile_window(bank, windows):
    cfg = ServiceConfig(bucket_sizes=(1, 8), window=WINDOW)
    svc = AnomalyService(bank, cfg,
                         failure=head_dead_trace(cluster=0, epoch=2))
    svc.submit(0, windows[0])
    svc.tick()                       # warm the eager liveness-mask ops
    compilecache.reset_xla_compile_stats()
    for t in range(4):               # exercise every bucket + failover
        for c in range(1 + (t % 8)):
            svc.submit(c, windows[c % len(windows)])
        svc.tick()
    stats = compilecache.xla_compile_stats()
    assert stats["misses"] == 0, stats
    # a second service over the same bank resolves purely from memory
    again = AnomalyService(bank, cfg)
    assert set(again.compile_sources.values()) == {"memory"}
    stats = compilecache.xla_compile_stats()
    assert stats["misses"] == 0, stats


# ---------------------------------------------------------------------------
# budgets: the score core is O(1) in every shape knob
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("det,family", [
    (None, "ae"),
    (SeqDetector(input_dim=112, window=16, d_model=8), "seq"),
])
def test_score_core_fits_named_budget(tiny_ae_cfg, det, family):
    det = as_detector(tiny_ae_cfg if det is None else det)
    params = det.init_params(jax.random.PRNGKey(0))
    core = _build_score_core(det)

    def count(bs):
        rows = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (N + 1,) + p.shape), params)
        return pc_budgets.eqn_count(
            core, rows, jnp.int32(0),
            jnp.zeros((bs, 16, 112), jnp.float32))

    name = score_budget_name(family)
    assert pc_budgets.check_budget(name, count(8)) is None
    assert pc_budgets.constant_across(count, (1, 8, 64))


def test_service_config_validates():
    with pytest.raises(AssertionError):
        ServiceConfig(bucket_sizes=())
    with pytest.raises(AssertionError):
        ServiceConfig(window=0)
    # frozen: fields cannot drift silently out of the classified set
    with pytest.raises(dataclasses.FrozenInstanceError):
        ServiceConfig().window = 3
