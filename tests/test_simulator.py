"""Paper-scale simulator integration tests (Tables III-V orderings).

Quantities are scaled down (10 devices, few rounds, small AE) but the
paper's qualitative claims are asserted:

* failure-free: all schemes learn (AUROC well above chance);
* client failure: training continues, performance close to failure-free;
* server failure: Tol-FL degrades gracefully (loses one cluster) while
  FL collapses to isolated training — Tol-FL > FL (Table V ordering).
"""
import pytest

from repro.core.failure import NO_FAILURE, FailureSpec
from repro.core.simulate import (SimConfig, comm_transfers_per_round,
                                 run_simulation, round_time_model)

ROUNDS = 40
LR = 1e-3
SERVER_FAIL_EPOCH = 5   # early failure => many post-failure rounds (the
                        # regime where Table V's ~8% gap appears)


def run(ae_cfg, padded, split, scheme, k, failure=NO_FAILURE, seed=0):
    dx, counts = padded
    cfg = SimConfig(scheme=scheme, num_devices=10, num_clusters=k,
                    rounds=ROUNDS, lr=LR, dropout=True, seed=seed)
    return run_simulation(ae_cfg, dx, counts, split.test_x, split.test_y,
                          cfg, failure)


@pytest.fixture(scope="module")
def failure_free(tiny_ae_cfg, tiny_padded, tiny_split):
    return {
        "batch": run(tiny_ae_cfg, tiny_padded, tiny_split, "batch", 1),
        "fl": run(tiny_ae_cfg, tiny_padded, tiny_split, "fl", 1),
        "tolfl": run(tiny_ae_cfg, tiny_padded, tiny_split, "tolfl", 5),
        "sbt": run(tiny_ae_cfg, tiny_padded, tiny_split, "sbt", 10),
    }


def test_all_schemes_learn(failure_free):
    """Table III: every scheme reaches strong AUROC without failures."""
    for name, res in failure_free.items():
        assert res.final_auroc > 0.7, (name, res.final_auroc)
        assert res.loss_curve[-1] < res.loss_curve[0], name


def test_client_failure_training_continues(tiny_ae_cfg, tiny_padded,
                                           tiny_split, failure_free):
    """Table IV: client failure costs little — training continues."""
    fail = FailureSpec(epoch=ROUNDS // 2, kind="client")
    for scheme, k in (("fl", 1), ("tolfl", 5)):
        res = run(tiny_ae_cfg, tiny_padded, tiny_split, scheme, k, fail)
        base = failure_free[scheme].final_auroc
        assert res.final_auroc > base - 0.10, (scheme, res.final_auroc, base)


def test_server_failure_tolfl_beats_fl(tiny_ae_cfg, tiny_padded, tiny_split):
    """Table V / Fig 4: under server failure Tol-FL keeps collaborative
    training (loses one cluster); FL falls back to isolated devices."""
    fail = FailureSpec(epoch=SERVER_FAIL_EPOCH, kind="server")
    tolfl = run(tiny_ae_cfg, tiny_padded, tiny_split, "tolfl", 5, fail)
    fl = run(tiny_ae_cfg, tiny_padded, tiny_split, "fl", 1, fail)
    assert fl.iso_active                       # FL used the fallback path
    assert not tolfl.iso_active
    assert tolfl.auroc_used > fl.auroc_used, (
        tolfl.auroc_used, fl.auroc_used)


def test_server_failure_tolfl_still_learns(tiny_ae_cfg, tiny_padded,
                                           tiny_split):
    fail = FailureSpec(epoch=SERVER_FAIL_EPOCH, kind="server")
    res = run(tiny_ae_cfg, tiny_padded, tiny_split, "tolfl", 5, fail)
    # 4 of 5 clusters keep training collaboratively => detection stays strong
    assert res.final_auroc > 0.8


def test_batch_server_failure_freezes():
    """Centralised batch: server failure => the model stops improving.
    (Behavioural contract; cheap standalone check.)"""
    # batch has 1 device which IS the server; alive mask zeroes the update
    from repro.core.topology import Topology
    from repro.core.failure import alive_mask, effective_weights
    import jax.numpy as jnp
    topo = Topology(1, 1)
    spec = FailureSpec(epoch=5, kind="server")
    w = effective_weights(alive_mask(spec, topo, jnp.int32(6)), topo)
    assert float(w.sum()) == 0.0


# ---------------------------------------------------------------------------
# Resource-usage models (Table II / VI)
# ---------------------------------------------------------------------------
def test_comm_cost_ordering():
    """Table VI: SBT O(N) < Tol-FL O(N+k) < FL O(2N)."""
    n, k = 10, 4
    sbt = comm_transfers_per_round("sbt", n, k)
    tolfl = comm_transfers_per_round("tolfl", n, k)
    fl = comm_transfers_per_round("fl", n, k)
    assert sbt < tolfl < fl
    assert fl == 2 * n
    assert sbt == n - 1
    assert tolfl == n + k - 1


def test_tolfl_comm_interpolates():
    """k=1 -> FL-like; k=N -> SBT-like (+broadcast bookkeeping)."""
    n = 12
    costs = [comm_transfers_per_round("tolfl", n, k) for k in (1, 3, 6, 12)]
    assert costs == sorted(costs)          # monotone in k


def test_round_time_parallel_beats_sequential():
    """Fig 5 ordering: FL (parallel) < Tol-FL < SBT (sequential) in time,
    all < centralised batch for large sample counts."""
    args = dict(n=10, k=4, samples=50000, model_bytes=400_000,
                flops_per_sample=1e6)
    t_batch = round_time_model("batch", **args)
    t_fl = round_time_model("fl", **args)
    t_tolfl = round_time_model("tolfl", **args)
    t_sbt = round_time_model("sbt", **args)
    assert t_fl < t_tolfl < t_sbt
    assert t_fl < t_batch
